#!/usr/bin/env python3
"""Validate a BENCH_hotpath snapshot (schema ``pk-hotpath-v4``).

CI runs the hotpath bench in ``--smoke`` mode and used to just ``cat`` the
resulting ``BENCH_hotpath.smoke.json`` — which proved the file existed,
not that the emitter still wrote anything meaningful. This gate parses the
snapshot and fails on schema drift or degenerate values:

* wrong/missing ``schema`` tag, or a missing ``sections`` object;
* any required section absent (e.g. the solver memo-hit rate, the
  event-throughput metric, the v2 serving-engine section, the v3
  scan-vs-heap and serial-vs-partitioned head-to-head sections, or the
  v4 fault-injection/degraded-rail section);
* non-numeric / non-finite / negative section values;
* degenerate rates (``event_throughput_per_s == 0`` would mean the DES
  ran no events — a broken bench, not a slow one);
* a memo hit rate outside ``[0, 1]``.

Usage: ``python3 tools/check_bench.py BENCH_hotpath.smoke.json``

Exit status 0 when clean; 1 with one line per problem otherwise. The
checked-in ``BENCH_hotpath.json`` trajectory baseline is allowed to be
schema-only (all-null values, written before the first toolchain-equipped
run); pass ``--allow-null`` to validate just its shape.

No third-party imports: runs on any Python 3. Covered by
``python/tests/test_bench_gate.py`` (including injected schema breaks).
"""

import json
import math
import sys

SCHEMA = "pk-hotpath-v4"

# Section keys the emitter must always write (bench names and derived
# metrics). Keep in sync with rust/benches/hotpath.rs; the bench-gate
# pytest pins a synthetic snapshot against this list.
REQUIRED_SECTIONS = [
    "timed_exec: GEMM+RS @ N=32768 (full sim)",
    "event_throughput_per_s",
    "solver_memo_hit_rate",
    "plan build: GEMM+RS @ N=32768",
    "timed_exec: hier AR @ 4 nodes x 8 GPUs",
    "compute_rates (naive): 2048 flows / 16 ports",
    "flownet churn (incremental): 2048 flows",
    "functional exec: 64x 256x256 tile copies",
    "copy_throughput_gb_s",
    "linalg: 128^3 matmul_accum",
    "tile_math_gflop_s",
    # v2: the trace-driven serving engine (sim::serve) must be benched
    "serve: colocated chat trace @ 0.8x capacity",
    "serve_tokens_per_s",
    # v3: event-engine head-to-head (scan vs epoch-keyed heap) and
    # serial-vs-partitioned cluster DES must both be benched
    "flownet steady drain (scan): staggered flows",
    "flownet steady drain (heap): staggered flows",
    "engine_events_per_s_scan",
    "engine_events_per_s_heap",
    "engine_heap_speedup",
    "timed_exec: hier AR @ 4 nodes (serial net)",
    "timed_exec: hier AR @ 4 nodes (partitioned net)",
    "cluster_events_per_s_serial",
    "cluster_events_per_s_partitioned",
    "partitioned_net_speedup",
    # v4: the fault-injection path (health-masked rail reroute under a
    # hard NIC failure) must be benched, and its simulated slowdown vs
    # the healthy rail plan recorded
    "timed_exec: GEMM+RS rail reroute @ 1 failed NIC",
    "fault_slowdown",
]

# sections that must be strictly positive when present with a value
POSITIVE_SECTIONS = {
    "event_throughput_per_s",
    "copy_throughput_gb_s",
    "tile_math_gflop_s",
    "serve_tokens_per_s",
    "engine_events_per_s_scan",
    "engine_events_per_s_heap",
    "engine_heap_speedup",
    "cluster_events_per_s_serial",
    "cluster_events_per_s_partitioned",
    "partitioned_net_speedup",
    "fault_slowdown",
}


def check_snapshot(doc, allow_null=False):
    """Return a list of problem strings (empty = snapshot is healthy)."""
    problems = []
    if not isinstance(doc, dict):
        return ["snapshot root is not an object"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema drift: expected {SCHEMA!r}, got {doc.get('schema')!r}")
    sections = doc.get("sections")
    if not isinstance(sections, dict):
        problems.append("missing 'sections' object")
        return problems
    for key in REQUIRED_SECTIONS:
        if key not in sections:
            problems.append(f"missing section {key!r}")
    for key, value in sections.items():
        if value is None:
            if not allow_null:
                problems.append(f"section {key!r} is null (schema-only snapshot?)")
            continue
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            problems.append(f"section {key!r} is not a number: {value!r}")
            continue
        if not math.isfinite(value):
            problems.append(f"section {key!r} is not finite: {value!r}")
            continue
        if value < 0:
            problems.append(f"section {key!r} is negative: {value!r}")
        if key in POSITIVE_SECTIONS and value == 0:
            problems.append(f"section {key!r} is degenerate (zero rate)")
    rate = sections.get("solver_memo_hit_rate")
    if isinstance(rate, (int, float)) and not isinstance(rate, bool):
        if not 0.0 <= rate <= 1.0:
            problems.append(f"solver_memo_hit_rate out of [0, 1]: {rate!r}")
    if not allow_null:
        events = doc.get("events")
        if (
            isinstance(events, bool)
            or not isinstance(events, (int, float))
            or events <= 0
        ):
            problems.append(f"'events' is missing or degenerate: {events!r}")
    return problems


def main(argv):
    allow_null = "--allow-null" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 1:
        print("usage: check_bench.py [--allow-null] <BENCH_hotpath[.smoke].json>")
        return 2
    try:
        with open(paths[0]) as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as exc:
        print(f"check_bench: cannot read {paths[0]}: {exc}")
        return 1
    problems = check_snapshot(doc, allow_null=allow_null)
    for p in problems:
        print(f"check_bench: {p}")
    if problems:
        return 1
    sections = doc["sections"]
    rate = sections.get("event_throughput_per_s")
    print(
        f"check_bench: {paths[0]} ok "
        f"({len(sections)} sections, schema {SCHEMA}"
        + (f", {rate:.0f} events/s)" if isinstance(rate, (int, float)) else ")")
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
