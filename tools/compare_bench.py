#!/usr/bin/env python3
"""Diff two BENCH_hotpath snapshots and fail on throughput regressions.

``check_bench.py`` validates one snapshot's *shape*; this tool compares
two snapshots' *values*: every throughput section (key containing
``_per_s`` — DES events/s, engine head-to-head events/s, serve tokens/s)
present in both files is diffed, and a drop of more than the threshold
(default 15%) fails the run.

A comparison only happens when **both** sides carry a measured number.
The committed ``BENCH_hotpath.json`` baseline is schema-only (all-null)
until the first toolchain-equipped full run lands real values, so this
gate is a deliberate no-op today — but it is wired into CI now, so the
moment measured numbers are committed, events/s is tracked
release-to-release with zero further plumbing.

Usage::

    python3 tools/compare_bench.py BASELINE.json CURRENT.json [--threshold 0.15]

Exit status: 0 when no comparable section regressed (including the
all-null no-op), 1 on a regression, 2 on malformed input (unreadable
file, invalid JSON, missing ``sections`` object, bad threshold).

No third-party imports: runs on any Python 3. Covered by
``python/tests/test_compare_bench.py``.
"""

import json
import math
import sys

# Substring selecting the throughput sections to compare. Time-valued
# sections (bench seconds) are skipped: smoke runs are 1-iteration noise
# and times also legitimately grow when a bench's workload is extended,
# while the *_per_s metrics are normalized per event/token.
RATE_KEY = "_per_s"

DEFAULT_THRESHOLD = 0.15


def load_sections(path):
    """Return the snapshot's sections dict, or raise ValueError."""
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as exc:
        raise ValueError(f"cannot read {path}: {exc}") from exc
    if not isinstance(doc, dict) or not isinstance(doc.get("sections"), dict):
        raise ValueError(f"{path}: snapshot has no 'sections' object")
    return doc["sections"]


def numeric(value):
    return (
        not isinstance(value, bool)
        and isinstance(value, (int, float))
        and math.isfinite(value)
    )


def compare(base_sections, cur_sections, threshold=DEFAULT_THRESHOLD):
    """Return (regressions, compared, skipped) for the rate sections.

    ``regressions`` is a list of problem strings; ``compared`` counts the
    sections with measured values on both sides; ``skipped`` counts rate
    sections present in both but not comparable (null/non-numeric on
    either side — e.g. the schema-only baseline).
    """
    regressions = []
    compared = 0
    skipped = 0
    for key in sorted(set(base_sections) & set(cur_sections)):
        if RATE_KEY not in key:
            continue
        base, cur = base_sections[key], cur_sections[key]
        if not numeric(base) or not numeric(cur) or base <= 0:
            skipped += 1
            continue
        compared += 1
        drop = (base - cur) / base
        if drop > threshold:
            regressions.append(
                f"{key}: {cur:.4g} is {drop * 100.0:.1f}% below baseline "
                f"{base:.4g} (threshold {threshold * 100.0:.0f}%)"
            )
    return regressions, compared, skipped


def main(argv):
    threshold = DEFAULT_THRESHOLD
    paths = []
    it = iter(argv)
    for a in it:
        if a == "--threshold":
            try:
                threshold = float(next(it, "nan"))
            except ValueError:
                threshold = float("nan")
            if not math.isfinite(threshold) or threshold <= 0:
                print("compare_bench: --threshold needs a positive number")
                return 2
        elif a.startswith("--"):
            print(f"compare_bench: unknown flag {a!r}")
            return 2
        else:
            paths.append(a)
    if len(paths) != 2:
        print("usage: compare_bench.py [--threshold 0.15] <baseline.json> <current.json>")
        return 2
    try:
        base = load_sections(paths[0])
        cur = load_sections(paths[1])
    except ValueError as exc:
        print(f"compare_bench: {exc}")
        return 2
    regressions, compared, skipped = compare(base, cur, threshold)
    for r in regressions:
        print(f"compare_bench: REGRESSION {r}")
    if regressions:
        return 1
    if compared == 0:
        print(
            f"compare_bench: no comparable rate sections "
            f"({skipped} skipped — schema-only baseline?); nothing to gate"
        )
    else:
        print(
            f"compare_bench: ok — {compared} rate section(s) within "
            f"{threshold * 100.0:.0f}% of baseline ({skipped} skipped)"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
