#!/usr/bin/env python3
"""Validate a ``pk lint --json`` sweep document (schema ``pk-lint-v1``).

CI runs ``pk lint --json LINT_zoo.json`` — the static plan verifier over
every kernel in the zoo — and the CLI already exits non-zero on any
error-severity finding. This gate re-checks the *document*, so a CLI
regression that stops sweeping (or sweeps nothing) cannot pass silently:

* wrong/missing ``schema`` tag, or a missing/empty ``kernels`` array;
* any kernel entry with ``errors > 0`` (each finding line is echoed);
* degenerate entries: a plan with zero ops, zero workers, or negative
  counters means the builder under that name produced nothing;
* a sweep that shrank below the expected minimum number of zoo entries
  (``--min-kernels``, default 33 — keep in sync with the registry test
  in ``rust/src/report/lint.rs``).

Usage: ``python3 tools/check_lint.py [--min-kernels N] LINT_zoo.json``

Exit status 0 when clean; 1 with one line per problem otherwise; 2 on
usage errors. No third-party imports: runs on any Python 3. Covered by
``python/tests/test_lint_gate.py`` (including injected breaks).
"""

import json
import sys

SCHEMA = "pk-lint-v1"
DEFAULT_MIN_KERNELS = 33

COUNTER_KEYS = ["workers", "ops", "sems", "sync_edges", "accesses", "pairs_checked"]


def check_sweep(doc, min_kernels=DEFAULT_MIN_KERNELS):
    """Return a list of problem strings (empty = sweep is healthy)."""
    problems = []
    if not isinstance(doc, dict):
        return ["sweep root is not an object"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema drift: expected {SCHEMA!r}, got {doc.get('schema')!r}")
    kernels = doc.get("kernels")
    if not isinstance(kernels, list) or not kernels:
        problems.append("missing or empty 'kernels' array")
        return problems
    if len(kernels) < min_kernels:
        problems.append(
            f"sweep shrank: {len(kernels)} kernel(s), expected >= {min_kernels}"
        )
    for entry in kernels:
        if not isinstance(entry, dict) or not isinstance(entry.get("name"), str):
            problems.append(f"malformed kernel entry: {entry!r}")
            continue
        name = entry["name"]
        for key in COUNTER_KEYS + ["errors", "warnings"]:
            value = entry.get(key)
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                problems.append(f"{name}: counter {key!r} is not a number: {value!r}")
            elif value < 0:
                problems.append(f"{name}: counter {key!r} is negative: {value!r}")
        ops = entry.get("ops")
        if isinstance(ops, (int, float)) and ops == 0:
            problems.append(f"{name}: plan has zero ops (builder produced nothing)")
        workers = entry.get("workers")
        if isinstance(workers, (int, float)) and workers == 0:
            problems.append(f"{name}: plan has zero workers")
        errors = entry.get("errors")
        if isinstance(errors, (int, float)) and errors > 0:
            problems.append(f"{name}: {int(errors)} error-severity finding(s)")
            for finding in entry.get("findings", []):
                problems.append(f"{name}:   {finding}")
    return problems


def main(argv):
    min_kernels = DEFAULT_MIN_KERNELS
    paths = []
    it = iter(argv)
    for arg in it:
        if arg == "--min-kernels":
            try:
                min_kernels = int(next(it, ""))
            except ValueError:
                print("check_lint: bad --min-kernels value")
                return 2
        elif arg.startswith("--"):
            print(f"check_lint: unknown flag {arg!r}")
            return 2
        else:
            paths.append(arg)
    if len(paths) != 1:
        print("usage: check_lint.py [--min-kernels N] <LINT_zoo.json>")
        return 2
    try:
        with open(paths[0]) as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as exc:
        print(f"check_lint: cannot read {paths[0]}: {exc}")
        return 1
    problems = check_sweep(doc, min_kernels=min_kernels)
    for p in problems:
        print(f"check_lint: {p}")
    if problems:
        return 1
    kernels = doc["kernels"]
    edges = sum(k.get("sync_edges", 0) for k in kernels)
    pairs = sum(k.get("pairs_checked", 0) for k in kernels)
    warnings = sum(k.get("warnings", 0) for k in kernels)
    print(
        f"check_lint: {paths[0]} ok ({len(kernels)} kernel plans, "
        f"{int(edges)} sync edges, {int(pairs)} access pairs, 0 errors, "
        f"{int(warnings)} warnings)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
