//! Quickstart: the PK primitives on a simulated 8×H100 node.
//!
//! Allocates a PGL, broadcasts a tile with `multicast_store`, all-reduces
//! with the in-network primitive, and times a fused GEMM+RS kernel at
//! paper scale — the 60-second tour of the API.
//!
//! Run: `cargo run --release --example quickstart`

use pk::exec::{FunctionalExec, TimedExec};
use pk::hw::spec::NodeSpec;
use pk::hw::DeviceId;
use pk::kernels::gemm_rs::{self, Schedule};
use pk::kernels::GemmKernelCfg;
use pk::mem::pgl::{Pgl, PglId, ReduceOp};
use pk::mem::tile::{Shape4, TileCoord, TileShape};
use pk::mem::MemPool;
use pk::pk::primitives::{all_reduce, multicast_store_async, TileRef};
use pk::plan::{MatView, Op, Plan, Role};

fn main() {
    let node = NodeSpec::hgx_h100();
    println!("node: 8x{} / NVLink {:.0} GB/s / multimem={}", node.gpu.arch, node.gpu.nvlink_bw / 1e9, node.multimem);

    // ---- 1. PGL: one tensor, replicated across all 8 devices -----------
    let mut pool = MemPool::new();
    let pgl = Pgl::alloc(&mut pool, PglId(0), Shape4::mat(32, 32), node.num_devices);
    let ts = TileShape::new(16, 16);
    // direct functional use of the PGL: in-fabric broadcast of a tile
    pgl.multicast_store(&mut pool, TileCoord::rc(0, 0), ts, &vec![1.5; 256], None);
    let back = pgl.ld_reduce(&pool, TileCoord::rc(0, 0), ts, ReduceOp::Add);
    println!("pgl broadcast + ld_reduce over 8 devices: 1.5 * 8 = {}", back[0]);

    // ---- 2. the primitives inside a kernel plan ------------------------
    let mut plan = Plan::new();
    let src = pool.alloc_init(DeviceId(0), Shape4::mat(16, 16), vec![2.0; 256]);
    let w = plan.add_worker(DeviceId(0), Role::CommSm, "demo");
    let done = plan.add_sem(0);
    // async in-fabric broadcast into every PGL replica (single TMA message)
    multicast_store_async(
        &mut plan,
        &node.gpu,
        w,
        TileRef::new(MatView::full2d(src, 16, 16), DeviceId(0)),
        pgl.bufs.iter().map(|&b| MatView::full2d(b, 32, 32).sub(16, 16, 16, 16)).collect(),
        None,
        Some(done),
    );
    plan.push(w, Op::Wait { sem: done, value: 1 });
    // in-network all-reduce of the tile we just planted
    all_reduce(
        &mut plan,
        &node.gpu,
        w,
        pgl.bufs.iter().map(|&b| MatView::full2d(b, 32, 32).sub(16, 16, 16, 16)).collect(),
        DeviceId(0),
        ReduceOp::Add,
        4.0,
    );
    FunctionalExec::new(&mut pool).run(&plan).expect("plan runs");
    let v = pool.get(pgl.on(DeviceId(5))).read_tile(TileCoord::rc(1, 1), ts)[0];
    println!("multicast_store_async + all_reduce: 2.0 * 8 = {v}");

    // the same plan, timed on the simulated hardware:
    let timed = TimedExec::new(node.clone()).run(&plan);
    println!("timed: {} ({} events)", pk::util::fmt_time(timed.total_time), timed.events);

    // ---- 3. a real kernel at paper scale --------------------------------
    let n = 32768;
    let cfg = GemmKernelCfg::new(node.clone(), n, n, n / 8);
    let t = TimedExec::new(node.clone()).run(&gemm_rs::build(&cfg, Schedule::IntraSm, None)).total_time;
    let t_gemm = TimedExec::new(node).run(&pk::kernels::gemm::build(&cfg, None)).total_time;
    println!(
        "fused GEMM+RS, local {n}x{n}x{}: {} ({:.1} TFLOP/s, non-overlapped comm {:.1}%)",
        n / 8,
        pk::util::fmt_time(t),
        cfg.local_flops() / t / 1e12,
        (t - t_gemm) / t * 100.0
    );
}
