//! Expert-parallel MoE dispatch + grouped GEMM (Figure 12), with the
//! expert MLP optionally executed through the AOT Pallas artifact.
//!
//! Run after `make artifacts`: `cargo run --release --example moe_dispatch`

use pk::baselines::comet;
use pk::exec::{FunctionalExec, TimedExec};
use pk::hw::spec::NodeSpec;
use pk::kernels::moe::{build, MoeBufs, MoeCfg, MoeSchedule, Routing};
use pk::mem::MemPool;
use pk::runtime::Runtime;
use pk::util::{assert_allclose, linalg, seeded_vec};

fn main() {
    functional_check();
    pjrt_expert_mlp();
    paper_scale();
}

fn functional_check() {
    let n_dev = 4;
    let cfg = MoeCfg {
        node: NodeSpec::test_node(n_dev),
        tokens: n_dev * 8,
        hidden: 16,
        h_expert: 8,
        n_experts: n_dev * 2,
        top_k: 2,
        comm_sms: 8,
    };
    let routing = Routing::uniform(&cfg, 42);
    let mut pool = MemPool::new();
    let bufs = MoeBufs::alloc(&mut pool, &cfg, &routing);
    let tl = cfg.tokens_local();
    for d in 0..n_dev {
        pool.get_mut(bufs.tokens[d]).data = seeded_vec(d as u64 + 1, tl * cfg.hidden);
        pool.get_mut(bufs.w1[d]).data =
            seeded_vec(d as u64 + 77, cfg.experts_local() * cfg.hidden * cfg.h_expert);
    }
    FunctionalExec::new(&mut pool)
        .run(&build(&cfg, &routing, MoeSchedule::Overlapped, Some(&bufs)))
        .expect("moe plan");
    // verify one expert end-to-end
    let e = 3;
    let toks = routing.tokens_for(e);
    let dev = cfg.expert_device(e);
    let le = e % cfg.experts_local();
    let mut x = vec![0.0f32; toks.len() * cfg.hidden];
    for (i, &t) in toks.iter().enumerate() {
        let row = &pool.get(bufs.tokens[t / tl]).data[(t % tl) * cfg.hidden..(t % tl + 1) * cfg.hidden];
        x[i * cfg.hidden..(i + 1) * cfg.hidden].copy_from_slice(row);
    }
    let wb = pool.get(bufs.w1[dev]);
    let woff = wb.shape.offset(le, 0, 0, 0);
    let want = linalg::matmul(&x, &wb.data[woff..woff + cfg.hidden * cfg.h_expert], toks.len(), cfg.h_expert, cfg.hidden);
    let ob = pool.get(bufs.expert_out[dev]);
    let ooff = ob.shape.offset(le, 0, 0, 0);
    assert_allclose(&ob.data[ooff..ooff + toks.len() * cfg.h_expert], &want, 1e-4, 1e-5);
    println!("functional MoE dispatch + expert GEMM matches the gather reference (expert {e}: {} tokens)", toks.len());
}

/// The expert MLP through the AOT Pallas grouped-GEMM artifact.
fn pjrt_expert_mlp() {
    let mut rt = match Runtime::open(Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("pjrt expert MLP skipped (run `make artifacts`): {e}");
            return;
        }
    };
    let (e, cap, h, he) = (4, 32, 64, 32);
    let x = seeded_vec(21, e * cap * h);
    let w = seeded_vec(22, e * h * he);
    let out = rt
        .execute("expert_mlp_e4_cap32_h64_he32", &[(x.clone(), vec![e, cap, h]), (w.clone(), vec![e, h, he])])
        .expect("expert artifact");
    // reference: per-expert matmul + gelu
    for ei in 0..e {
        let xe = &x[ei * cap * h..(ei + 1) * cap * h];
        let we = &w[ei * h * he..(ei + 1) * h * he];
        let mut want = linalg::matmul(xe, we, cap, he, h);
        linalg::gelu_inplace(&mut want);
        assert_allclose(&out[0][ei * cap * he..(ei + 1) * cap * he], &want, 1e-3, 1e-4);
    }
    println!("PJRT-executed Pallas grouped-GEMM expert MLP matches the Rust reference");
}

fn paper_scale() {
    let node = NodeSpec::hgx_h100();
    println!("MoE dispatch + first expert GEMM (TopK=8, E=256, H=7168, He=2048):");
    for tokens in [4096usize, 16384, 65536] {
        let cfg = MoeCfg::paper(node.clone(), tokens);
        let routing = Routing::uniform(&cfg, 5);
        let t_pk = TimedExec::new(node.clone()).run(&build(&cfg, &routing, MoeSchedule::Overlapped, None)).total_time;
        let t_seq = TimedExec::new(node.clone()).run(&build(&cfg, &routing, MoeSchedule::Sequential, None)).total_time;
        let t_comet = comet::moe(&cfg, &routing);
        println!(
            "  tokens={tokens:>6}: PK {} | Comet {} ({:.2}x) | non-overlapped {} ({:.2}x)",
            pk::util::fmt_time(t_pk),
            pk::util::fmt_time(t_comet),
            t_comet / t_pk,
            pk::util::fmt_time(t_seq),
            t_seq / t_pk,
        );
    }
}
