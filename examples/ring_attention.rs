//! Ring Attention (Figure 10): fused blockwise attention + KV ring on the
//! simulated node, with a PJRT-executed Pallas attention block proving the
//! three-layer composition.
//!
//! Run after `make artifacts`: `cargo run --release --example ring_attention`

use pk::baselines::xdit;
use pk::exec::{FunctionalExec, TimedExec};
use pk::hw::spec::NodeSpec;
use pk::kernels::ring_attention::{build, RingAttnBufs, RingAttnCfg};
use pk::mem::MemPool;
use pk::pk::template::LcscOpts;
use pk::runtime::Runtime;
use pk::util::{assert_allclose, linalg, seeded_vec};

fn main() {
    functional_check();
    pjrt_attention_block();
    paper_scale();
}

/// Small functional ring: output must equal full attention over the whole
/// (gathered) sequence.
fn functional_check() {
    let n = 4;
    let node = NodeSpec::test_node(n);
    let cfg = RingAttnCfg {
        node,
        b: 1,
        h: 2,
        s: 64,
        d: 16,
        opts: LcscOpts { num_comm_sms: 4, workers_per_device: 2, comm_workers_per_device: 1, pipeline_stages: 2 },
        flash_util: 0.75,
    };
    let sl = cfg.s_local();
    let mut pool = MemPool::new();
    let bufs = RingAttnBufs::alloc(&mut pool, &cfg);
    // K/V global per (b, h); shards planted on home devices
    let kg = seeded_vec(1, cfg.s * cfg.d);
    let vg = seeded_vec(2, cfg.s * cfg.d);
    for dev in 0..n {
        for bi in 0..cfg.b {
            for hi in 0..cfg.h {
                let q = seeded_vec((dev * 7 + hi) as u64 + 100, sl * cfg.d);
                let qb = pool.get_mut(bufs.q[dev]);
                let off = qb.shape.offset(bi, hi, 0, 0);
                qb.data[off..off + sl * cfg.d].copy_from_slice(&q);
                let kb = pool.get_mut(bufs.k[dev]);
                let koff = kb.shape.offset(bi, hi, dev * sl, 0);
                kb.data[koff..koff + sl * cfg.d].copy_from_slice(&kg[dev * sl * cfg.d..(dev + 1) * sl * cfg.d]);
                let vb = pool.get_mut(bufs.v[dev]);
                let voff = vb.shape.offset(bi, hi, dev * sl, 0);
                vb.data[voff..voff + sl * cfg.d].copy_from_slice(&vg[dev * sl * cfg.d..(dev + 1) * sl * cfg.d]);
            }
        }
    }
    FunctionalExec::new(&mut pool).run(&build(&cfg, Some(&bufs))).expect("ring attention");
    // spot-check one (dev, b, h)
    let dev = 2;
    let qb = pool.get(bufs.q[dev]);
    let off = qb.shape.offset(0, 1, 0, 0);
    let q = &qb.data[off..off + sl * cfg.d];
    let want = linalg::attention_ref(q, &kg, &vg, sl, cfg.s, cfg.d);
    let ob = pool.get(bufs.o[dev]);
    let ooff = ob.shape.offset(0, 1, 0, 0);
    assert_allclose(&ob.data[ooff..ooff + sl * cfg.d], &want, 1e-4, 1e-5);
    println!("functional ring attention matches full attention over the gathered sequence");
}

/// Execute the AOT-compiled Pallas attention block from Rust (L1→L2→L3).
fn pjrt_attention_block() {
    let mut rt = match Runtime::open(Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("pjrt attention block skipped (run `make artifacts`): {e}");
            return;
        }
    };
    let (s, d) = (64, 32);
    let q = seeded_vec(11, s * d);
    let k = seeded_vec(12, s * d);
    let v = seeded_vec(13, s * d);
    let out = rt
        .execute(
            "attn_block_s64_kv64_d32",
            &[(q.clone(), vec![s, d]), (k.clone(), vec![s, d]), (v.clone(), vec![s, d])],
        )
        .expect("attention artifact");
    let want = linalg::attention_ref(&q, &k, &v, s, s, d);
    assert_allclose(&out[0], &want, 1e-3, 1e-4);
    println!("PJRT-executed Pallas attention block matches the Rust reference");
}

/// Paper-scale sweep vs the xDiT baseline.
fn paper_scale() {
    let node = NodeSpec::hgx_h100();
    println!("ring attention, B=16 H=16 D=128, 8xH100:");
    for s in [6144usize, 24576, 98304] {
        let cfg = RingAttnCfg::paper(node.clone(), s);
        let t_pk = TimedExec::new(node.clone()).run(&build(&cfg, None)).total_time;
        let t_xdit = xdit::ring_attention(&cfg);
        println!(
            "  S={s:>6}: PK {} vs xDiT {}  ({:.2}x, {:.1} TFLOP/s)",
            pk::util::fmt_time(t_pk),
            pk::util::fmt_time(t_xdit),
            t_xdit / t_pk,
            cfg.total_flops() / t_pk / 1e12
        );
    }
}
