//! Tensor-parallel MLP layer (the §4.1 workload): AG + GEMM, GeLU, then
//! GEMM + RS, end to end across 8 simulated devices.
//!
//! Functional at a small shape (verified against a dense reference), then
//! timed at paper scale for each fused kernel, reporting the speedup over
//! the non-overlapped cuBLAS+NCCL composition.
//!
//! Run: `cargo run --release --example tp_mlp`

use pk::baselines;
use pk::exec::{FunctionalExec, TimedExec};
use pk::hw::spec::NodeSpec;
use pk::kernels::ag_gemm::{self, AgGemmBufs};
use pk::kernels::gemm_rs::{self, GemmRsBufs, Schedule};
use pk::kernels::GemmKernelCfg;
use pk::mem::MemPool;
use pk::util::{assert_allclose, linalg, seeded_vec};

fn main() {
    functional_check();
    paper_scale();
}

/// Small-shape functional run: AG+GEMM output feeds a GeLU and the second
/// GEMM+RS; the result must match the dense (single-device) computation.
fn functional_check() {
    let n_dev = 4;
    let node = NodeSpec::test_node(n_dev);
    let (t, d, f) = (64, 32, 32); // tokens, model dim, ffn dim (per shard!)
    // --- stage 1: AG + GEMM (x row-sharded, w1 column-sharded)
    let mut pool = MemPool::new();
    let cfg1 = GemmKernelCfg::functional(node.clone(), t, f, d);
    let mut c1 = cfg1.clone();
    c1.opts.num_comm_sms = 4;
    let bufs1 = AgGemmBufs::alloc(&mut pool, &c1);
    let x_global = seeded_vec(1, t * d);
    let shard_rows = t / n_dev;
    for dev in 0..n_dev {
        let start = dev * shard_rows * d;
        let end = (dev + 1) * shard_rows * d;
        pool.get_mut(bufs1.a[dev]).data[start..end].copy_from_slice(&x_global[start..end]);
        pool.get_mut(bufs1.b[dev]).data = seeded_vec(dev as u64 + 10, d * f);
    }
    let w1_shards: Vec<Vec<f32>> = (0..n_dev).map(|dev| pool.get(bufs1.b[dev]).data.clone()).collect();
    FunctionalExec::new(&mut pool).run(&ag_gemm::build(&c1, Some(&bufs1))).expect("ag+gemm");

    // --- GeLU on each shard's activation, then stage 2: GEMM + RS
    let cfg2 = GemmKernelCfg::functional(node.clone(), t, d, f);
    let bufs2 = GemmRsBufs::alloc(&mut pool, &cfg2);
    let mut w2_shards = vec![];
    for dev in 0..n_dev {
        let mut h = pool.get(bufs1.c[dev]).data.clone();
        linalg::gelu_inplace(&mut h);
        pool.get_mut(bufs2.gemm.a[dev]).data = h;
        let w2 = seeded_vec(dev as u64 + 50, f * d);
        w2_shards.push(w2.clone());
        pool.get_mut(bufs2.gemm.b[dev]).data = w2;
    }
    FunctionalExec::new(&mut pool).run(&gemm_rs::build(&cfg2, Schedule::IntraSm, Some(&bufs2))).expect("gemm+rs");

    // --- dense reference: y = gelu(x @ W1) @ W2 summed over shards
    let mut y_ref = vec![0.0f32; t * d];
    for dev in 0..n_dev {
        let mut h = linalg::matmul(&x_global, &w1_shards[dev], t, f, d);
        linalg::gelu_inplace(&mut h);
        let y = linalg::matmul(&h, &w2_shards[dev], t, d, f);
        for (acc, v) in y_ref.iter_mut().zip(y) {
            *acc += v;
        }
    }
    let chunk = t / n_dev * d;
    for dev in 0..n_dev {
        assert_allclose(&pool.get(bufs2.out[dev]).data, &y_ref[dev * chunk..(dev + 1) * chunk], 1e-3, 1e-4);
    }
    println!("functional TP MLP (AG+GEMM -> GeLU -> GEMM+RS) matches dense reference");
}

/// Paper-scale timing: both fused kernels vs the non-overlapped baseline.
fn paper_scale() {
    let node = NodeSpec::hgx_h100();
    let n = 32768;
    let cfg_ag = GemmKernelCfg::new(node.clone(), n, n / 8, n);
    let cfg_rs = GemmKernelCfg::new(node.clone(), n, n, n / 8);
    let t_ag = TimedExec::new(node.clone()).run(&ag_gemm::build(&cfg_ag, None)).total_time;
    let t_rs = TimedExec::new(node.clone()).run(&gemm_rs::build(&cfg_rs, Schedule::IntraSm, None)).total_time;
    let base_ag = baselines::nonoverlap::ag_gemm(&cfg_ag);
    let base_rs = baselines::nonoverlap::gemm_rs(&cfg_rs);
    println!("paper scale (N={n}, 8xH100):");
    println!(
        "  AG+GEMM : PK {} vs non-overlapped {}  ({:.2}x)",
        pk::util::fmt_time(t_ag),
        pk::util::fmt_time(base_ag),
        base_ag / t_ag
    );
    println!(
        "  GEMM+RS : PK {} vs non-overlapped {}  ({:.2}x)",
        pk::util::fmt_time(t_rs),
        pk::util::fmt_time(base_rs),
        base_rs / t_rs
    );
    println!(
        "  layer   : PK {} vs non-overlapped {}  ({:.2}x)",
        pk::util::fmt_time(t_ag + t_rs),
        pk::util::fmt_time(base_ag + base_rs),
        (base_ag + base_rs) / (t_ag + t_rs)
    );
}
