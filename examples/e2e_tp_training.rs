//! End-to-end driver: train a tensor-parallel MLP across 8 simulated
//! devices for several hundred steps, with **all three layers composed**:
//!
//! * L1 — the Pallas GEMM kernel (inside the AOT artifacts),
//! * L2 — the JAX per-shard forward / backward+SGD stages
//!   (`tp_mlp_fwd` / `tp_mlp_bwd`, lowered once by `make artifacts`),
//! * L3 — this Rust coordinator: the threaded Node runs one worker per
//!   device; the all-reduce between forward and backward goes through the
//!   PK in-network primitives over the simulated fabric.
//!
//! Also times one step on the simulated H100 node (overlap accounting) —
//! the numbers recorded in EXPERIMENTS.md §E2E.
//!
//! Substitution note (DESIGN.md): the model is ~1.4 M params
//! (T=128, D=256, F=1024) rather than the 100 M the prompt suggests —
//! hundreds of steps × 8 simulated devices must run on one CPU core.
//!
//! Run: `make artifacts && cargo run --release --example e2e_tp_training`

use pk::coordinator::Node;
use pk::hw::spec::NodeSpec;
use pk::hw::DeviceId;
use pk::mem::pgl::ReduceOp;
use pk::mem::tile::Shape4;
use pk::mem::{BufId, MemPool};
use pk::pk::primitives::all_reduce;
use pk::plan::{Effect, MatView, Op, Plan, Role, SyncScope};
use pk::runtime::Runtime;
use pk::util::seeded_vec;

// must match python/compile/aot.py E2E_* constants
const N_DEV: usize = 8;
const T: usize = 128;
const D: usize = 256;
const F: usize = 1024;
const F_SHARD: usize = F / N_DEV;
const STEPS: usize = 300;

struct Bufs {
    x: Vec<BufId>,
    w1: Vec<BufId>,
    w2: Vec<BufId>,
    y: Vec<BufId>, // partial outputs; post-AR they hold the summed Y
    target: Vec<BufId>,
    loss: Vec<BufId>,
}

fn alloc(pool: &mut MemPool) -> Bufs {
    let mk = |pool: &mut MemPool, shape| (0..N_DEV).map(|d| pool.alloc(DeviceId(d), shape)).collect::<Vec<_>>();
    Bufs {
        x: mk(pool, Shape4::mat(T, D)),
        w1: mk(pool, Shape4::mat(D, F_SHARD)),
        w2: mk(pool, Shape4::mat(F_SHARD, D)),
        y: mk(pool, Shape4::mat(T, D)),
        target: mk(pool, Shape4::mat(T, D)),
        loss: mk(pool, Shape4::mat(1, 1)),
    }
}

/// One training step: fwd (PJRT) → PK in-network all-reduce → bwd+SGD (PJRT).
fn step_plan(node: &NodeSpec, b: &Bufs) -> Plan {
    let mut plan = Plan::new();
    let fwd_done: Vec<_> = (0..N_DEV).map(|_| plan.add_sem(0)).collect();
    let ar_done: Vec<_> = (0..N_DEV).map(|_| plan.add_sem(0)).collect();
    for dev in 0..N_DEV {
        let w = plan.add_worker(DeviceId(dev), Role::ComputeSm, format!("train/d{dev}"));
        // ---- forward shard (L2 artifact calling the L1 Pallas GEMM)
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "tp_mlp_fwd",
                effect: Some(Effect::RunArtifact {
                    name: "tp_mlp_fwd".into(),
                    inputs: vec![
                        MatView::full2d(b.x[dev], T, D),
                        MatView::full2d(b.w1[dev], D, F_SHARD),
                        MatView::full2d(b.w2[dev], F_SHARD, D),
                    ],
                    outputs: vec![MatView::full2d(b.y[dev], T, D)],
                }),
            },
        );
        // ---- barrier: everyone's partial is in HBM
        for s in &fwd_done {
            plan.push(w, Op::Signal { sem: *s, value: 1, scope: SyncScope::InterDevice });
        }
        plan.push(w, Op::Wait { sem: fwd_done[dev], value: N_DEV as u64 });
        // ---- PK in-network all-reduce: device d reduces row-shard d of Y
        // and multicasts it back (the GEMM+AR pattern of Appendix D).
        let rows = T / N_DEV;
        let shard_views: Vec<MatView> = (0..N_DEV)
            .map(|o| MatView::full2d(b.y[o], T, D).sub(dev * rows, 0, rows, D))
            .collect();
        all_reduce(&mut plan, &node.gpu, w, shard_views, DeviceId(dev), ReduceOp::Add, 8.0);
        for s in &ar_done {
            plan.push(w, Op::Signal { sem: *s, value: 1, scope: SyncScope::InterDevice });
        }
        plan.push(w, Op::Wait { sem: ar_done[dev], value: N_DEV as u64 });
        // ---- backward + SGD shard (recomputes activations; L2 artifact)
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "tp_mlp_bwd",
                effect: Some(Effect::RunArtifact {
                    name: "tp_mlp_bwd".into(),
                    inputs: vec![
                        MatView::full2d(b.x[dev], T, D),
                        MatView::full2d(b.w1[dev], D, F_SHARD),
                        MatView::full2d(b.w2[dev], F_SHARD, D),
                        MatView::full2d(b.y[dev], T, D),
                        MatView::full2d(b.target[dev], T, D),
                    ],
                    outputs: vec![
                        MatView::full2d(b.w1[dev], D, F_SHARD),
                        MatView::full2d(b.w2[dev], F_SHARD, D),
                        MatView::full2d(b.loss[dev], 1, 1),
                    ],
                }),
            },
        );
    }
    plan
}

fn main() -> anyhow::Result<()> {
    let node = NodeSpec::test_node(N_DEV);
    let runtime = Runtime::open(Runtime::default_dir())?;
    let mut pool = MemPool::new();
    let b = alloc(&mut pool);
    // synthetic regression task: target = teacher MLP of x + noise
    let x = seeded_vec(1, T * D);
    let teacher = {
        let w = seeded_vec(2, D * D);
        let mut y = pk::util::linalg::matmul(&x, &w, T, D, D);
        for v in y.iter_mut() {
            *v = (*v * 0.1).tanh();
        }
        y
    };
    for dev in 0..N_DEV {
        pool.get_mut(b.x[dev]).data = x.clone();
        pool.get_mut(b.target[dev]).data = teacher.clone();
        // small random init, identical layout to the python shard layout
        pool.get_mut(b.w1[dev]).data =
            seeded_vec(100 + dev as u64, D * F_SHARD).iter().map(|v| v * 0.05).collect();
        pool.get_mut(b.w2[dev]).data =
            seeded_vec(200 + dev as u64, F_SHARD * D).iter().map(|v| v * 0.05).collect();
    }
    let mut node_exec = Node::with_runtime(node.clone(), pool, runtime);
    let plan = step_plan(&node, &b);
    println!(
        "training TP MLP: {} params across {N_DEV} devices, {STEPS} steps, plan = {} ops / {} workers",
        D * F * 2,
        plan.total_ops(),
        plan.workers.len()
    );
    let start = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..STEPS {
        node_exec.run_plan(&plan)?;
        let loss = node_exec.pool().get(b.loss[0]).data[0];
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
        if step % 25 == 0 || step == STEPS - 1 {
            println!("  step {step:>4}: loss = {loss:.6}");
        }
    }
    let wall = start.elapsed();
    println!(
        "done in {:.1}s ({:.1} ms/step); loss {:.6} -> {:.6}",
        wall.as_secs_f64(),
        wall.as_secs_f64() * 1e3 / STEPS as f64,
        first_loss.unwrap(),
        last_loss
    );
    assert!(last_loss < first_loss.unwrap() * 0.5, "training must reduce the loss");

    // ---- simulated-hardware timing of one step's communication pattern
    let timed = pk::exec::TimedExec::new(NodeSpec::hgx_h100()).run(&plan);
    println!(
        "simulated H100 step comm pattern: {} ({} events)",
        pk::util::fmt_time(timed.total_time),
        timed.events
    );
    Ok(())
}
