//! `cargo bench --bench hotpath` — microbenchmarks of the library's own
//! hot paths (the §Perf instrumentation): DES event throughput, the
//! max-min fair solver, functional tile movement, and plan construction.
//!
//! Hand-rolled harness (measure-N-iterations, report best-of-K) — the
//! vendored environment has no criterion; methodology matches its
//! flat-sampling mode.

use pk::exec::TimedExec;
use pk::hw::spec::NodeSpec;
use pk::hw::DeviceId;
use pk::kernels::gemm_rs::{self, Schedule};
use pk::kernels::GemmKernelCfg;
use pk::mem::tile::Shape4;
use pk::mem::MemPool;
use std::time::Instant;

/// Run `f` for `iters` iterations, `k` times; return the best per-iter
/// seconds (criterion-style minimum to suppress scheduler noise).
fn bench<F: FnMut()>(name: &str, iters: usize, k: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..k {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() / iters as f64);
    }
    println!("{name:<44} {:>12}", pk::util::fmt_time(best));
    best
}

fn main() {
    println!("{:-^60}", " hotpath microbenchmarks ");

    // ---- DES end-to-end: paper-scale GEMM+RS simulation
    let node = NodeSpec::hgx_h100();
    let cfg = GemmKernelCfg::new(node.clone(), 32768, 32768, 4096);
    let plan = gemm_rs::build(&cfg, Schedule::IntraSm, None);
    let exec = TimedExec::new(node.clone());
    let mut events = 0u64;
    let t = bench("timed_exec: GEMM+RS @ N=32768 (full sim)", 3, 3, || {
        events = exec.run(&plan).events;
    });
    println!("{:<44} {:>12.0} events/s", "  -> event throughput", events as f64 / t);

    // ---- plan construction
    bench("plan build: GEMM+RS @ N=32768", 5, 3, || {
        let _ = gemm_rs::build(&cfg, Schedule::IntraSm, None);
    });

    // ---- cluster DES: 4-node hierarchical all-reduce over NIC ports
    {
        use pk::hw::ClusterSpec;
        use pk::kernels::collectives::{hier_all_reduce, ClusterCollCtx};
        use pk::plan::Plan;
        let cluster = ClusterSpec::hgx_h100_pod(4);
        let views = pk::baselines::phantom_replicas(cluster.total_devices(), 4096, 8192);
        let mut plan = Plan::new();
        hier_all_reduce(&mut plan, &ClusterCollCtx::new(&cluster, views));
        let exec = TimedExec::on_cluster(cluster);
        bench("timed_exec: hier AR @ 4 nodes x 8 GPUs", 5, 3, || {
            let _ = exec.run(&plan);
        });
    }

    // ---- max-min fair solver at high flow counts
    {
        use pk::hw::topology::Port;
        use pk::sim::flownet::{compute_rates, FlowSpec};
        use std::collections::HashMap;
        let mut caps = HashMap::new();
        for d in 0..8 {
            caps.insert(Port::Egress(DeviceId(d)), 450e9);
            caps.insert(Port::Ingress(DeviceId(d)), 450e9);
        }
        let flows: Vec<FlowSpec> = (0..2048)
            .map(|i| FlowSpec {
                active: true,
                ports: vec![Port::Egress(DeviceId(i % 8)), Port::Ingress(DeviceId((i + 1) % 8))],
                cap: 23e9,
            })
            .collect();
        bench("compute_rates: 2048 flows / 16 ports", 20, 3, || {
            let r = compute_rates(&flows, &caps);
            assert!(r[0] > 0.0);
        });
    }

    // ---- functional executor: tile movement throughput
    {
        use pk::util::prop::run_functional;
        use pk::plan::{Effect, MatView, Op, Plan, Role};
        let mut pool = MemPool::new();
        let a = pool.alloc(DeviceId(0), Shape4::mat(256, 256));
        let b = pool.alloc(DeviceId(1), Shape4::mat(256, 256));
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "w");
        for _ in 0..64 {
            plan.push(
                w,
                Op::Compute {
                    dur: 0.0,
                    label: "copy",
                    effect: Some(Effect::CopyMat {
                        src: MatView::full2d(a, 256, 256),
                        dst: MatView::full2d(b, 256, 256),
                        reduce: None,
                    }),
                },
            );
        }
        let bytes_per_run = 64.0 * 256.0 * 256.0 * 4.0;
        let t = bench("functional exec: 64x 256x256 tile copies", 20, 3, || {
            run_functional(&mut pool, &plan);
        });
        println!("{:<44} {:>9.2} GB/s", "  -> copy throughput", bytes_per_run / t / 1e9);
    }

    // ---- native GEMM tile math (functional compute reference)
    {
        use pk::util::linalg::matmul_accum;
        let a = pk::util::seeded_vec(1, 128 * 128);
        let b = pk::util::seeded_vec(2, 128 * 128);
        let mut c = vec![0.0f32; 128 * 128];
        let flops = 2.0 * 128f64.powi(3);
        let t = bench("linalg: 128^3 matmul_accum", 20, 3, || {
            matmul_accum(&mut c, &a, &b, 128, 128, 128);
        });
        println!("{:<44} {:>9.2} GFLOP/s", "  -> tile math", flops / t / 1e9);
    }

    println!("{:-^60}", "");
}
