//! `cargo bench --bench hotpath` — microbenchmarks of the library's own
//! hot paths (the §Perf instrumentation): DES event throughput, the
//! max-min fair solver (naive reference vs the engine's incremental
//! path), functional tile movement, plan construction, the parallel
//! sweep driver, and the trace-driven serving engine.
//!
//! Hand-rolled harness (measure-N-iterations, report best-of-K) — the
//! vendored environment has no criterion; methodology matches its
//! flat-sampling mode.
//!
//! Every run rewrites `BENCH_hotpath.json` at the repo root with the
//! per-section best times plus derived rates (events/s, solver memo hit
//! rate, parallel sweep speedup), so the perf trajectory is machine
//! readable. CI runs `-- --smoke` (one tiny iteration per section) so
//! the bench itself can never rot.

use pk::exec::TimedExec;
use pk::hw::spec::NodeSpec;
use pk::hw::DeviceId;
use pk::kernels::gemm_rs::{self, Schedule};
use pk::kernels::GemmKernelCfg;
use pk::mem::tile::Shape4;
use pk::mem::MemPool;
use pk::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

struct Harness {
    smoke: bool,
    sections: BTreeMap<String, Json>,
}

impl Harness {
    /// Run `f` for `iters` iterations, `k` times; record + return the best
    /// per-iter seconds (criterion-style minimum to suppress scheduler
    /// noise). Smoke mode collapses to a single iteration — correctness
    /// coverage only.
    fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, k: usize, mut f: F) -> f64 {
        let (iters, k) = if self.smoke { (1, 1) } else { (iters, k) };
        let mut best = f64::INFINITY;
        for _ in 0..k {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            best = best.min(t0.elapsed().as_secs_f64() / iters as f64);
        }
        println!("{name:<44} {:>12}", pk::util::fmt_time(best));
        self.sections.insert(name.to_string(), Json::Num(best));
        best
    }

    fn metric(&mut self, name: &str, value: f64, display: &str) {
        println!("{:<44} {display}", format!("  -> {name}"));
        self.sections.insert(name.to_string(), Json::Num(value));
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut h = Harness { smoke, sections: BTreeMap::new() };
    let title =
        if smoke { " hotpath microbenchmarks (smoke) " } else { " hotpath microbenchmarks " };
    println!("{title:-^60}");

    // ---- DES end-to-end: paper-scale GEMM+RS simulation
    let node = NodeSpec::hgx_h100();
    let cfg = GemmKernelCfg::new(node.clone(), 32768, 32768, 4096);
    let plan = gemm_rs::build(&cfg, Schedule::IntraSm, None);
    let exec = TimedExec::new(node.clone());
    let mut events = 0u64;
    let mut solver = pk::sim::flownet::SolverStats::default();
    let t = h.bench("timed_exec: GEMM+RS @ N=32768 (full sim)", 3, 3, || {
        let r = exec.run(&plan);
        events = r.events;
        solver = r.solver;
    });
    let ev_rate = events as f64 / t;
    h.metric("event_throughput_per_s", ev_rate, &format!("{ev_rate:>12.0} events/s"));
    let hit_rate =
        if solver.solves > 0 { solver.memo_hits as f64 / solver.solves as f64 } else { 0.0 };
    h.metric(
        "solver_memo_hit_rate",
        hit_rate,
        &format!(
            "{:>11.1}% ({} solves, {} classes)",
            hit_rate * 100.0,
            solver.solves,
            solver.classes
        ),
    );

    // ---- plan construction
    h.bench("plan build: GEMM+RS @ N=32768", 5, 3, || {
        let _ = gemm_rs::build(&cfg, Schedule::IntraSm, None);
    });

    // ---- cluster DES: 4-node hierarchical all-reduce over NIC ports
    {
        use pk::hw::ClusterSpec;
        use pk::kernels::collectives::{hier_all_reduce, ClusterCollCtx};
        use pk::plan::Plan;
        let cluster = ClusterSpec::hgx_h100_pod(4);
        let views = pk::baselines::phantom_replicas(cluster.total_devices(), 4096, 8192);
        let mut plan = Plan::new();
        hier_all_reduce(&mut plan, &ClusterCollCtx::new(&cluster, views));
        let exec = TimedExec::on_cluster(cluster);
        h.bench("timed_exec: hier AR @ 4 nodes x 8 GPUs", 5, 3, || {
            let _ = exec.run(&plan);
        });
    }

    // ---- max-min fair solver: naive reference at high flow counts
    {
        use pk::hw::topology::Port;
        use pk::sim::flownet::{compute_rates, FlowSpec};
        use std::collections::HashMap;
        let mut caps = HashMap::new();
        for d in 0..8 {
            caps.insert(Port::Egress(DeviceId(d)), 450e9);
            caps.insert(Port::Ingress(DeviceId(d)), 450e9);
        }
        let flows: Vec<FlowSpec> = (0..2048)
            .map(|i| FlowSpec {
                active: true,
                ports: vec![Port::Egress(DeviceId(i % 8)), Port::Ingress(DeviceId((i + 1) % 8))],
                cap: 23e9,
            })
            .collect();
        h.bench("compute_rates (naive): 2048 flows / 16 ports", 20, 3, || {
            let r = compute_rates(&flows, &caps);
            assert!(r[0] > 0.0);
        });
    }

    // ---- incremental solver: the same flow population through FlowNet
    // churn (start a generation, drain it, repeat — what the engine does)
    {
        use pk::hw::topology::Port;
        use pk::sim::flownet::FlowNet;
        h.bench("flownet churn (incremental): 2048 flows", 20, 3, || {
            let mut net = FlowNet::new();
            for d in 0..8 {
                net.set_capacity(Port::Egress(DeviceId(d)), 450e9);
                net.set_capacity(Port::Ingress(DeviceId(d)), 450e9);
            }
            for i in 0..2048usize {
                net.start(
                    1e6,
                    vec![Port::Egress(DeviceId(i % 8)), Port::Ingress(DeviceId((i + 1) % 8))],
                    23e9,
                );
            }
            while let Some(dt) = net.next_completion() {
                net.advance(dt);
            }
            assert_eq!(net.n_active(), 0);
        });
    }

    // ---- event engine head-to-head: scan vs epoch-keyed heap on a
    // timer-dominated steady phase (staggered cap-bound flows, several
    // partial advances per completion window — the profile deep pipelined
    // sims produce). Cap-bound rates never change bits across
    // completions, so the heap path pays O(log A) per partial step where
    // the scan pays O(A).
    {
        use pk::hw::topology::Port;
        use pk::sim::flownet::{Engine, FlowNet};
        let n_flows = if smoke { 256 } else { 4096 };
        let churn = |engine: Engine| -> u64 {
            let mut net = FlowNet::with_engine(engine);
            for d in 0..8 {
                net.set_capacity(Port::Egress(DeviceId(d)), 450e9);
                net.set_capacity(Port::Ingress(DeviceId(d)), 450e9);
            }
            for i in 0..n_flows {
                // staggered sizes -> staggered completions (no tie storms)
                net.start(
                    1e6 * (1.0 + i as f64 / n_flows as f64),
                    vec![Port::Egress(DeviceId(i % 8)), Port::Ingress(DeviceId((i + 1) % 8))],
                    0.5e9,
                );
            }
            let mut events = 0u64;
            while let Some(dt) = net.next_completion() {
                events += 1;
                // timer-style partial steps inside the completion window…
                for _ in 0..3 {
                    net.advance(dt * 0.25);
                    events += 1;
                }
                // …then cross it
                let rem = net.next_completion().unwrap_or(0.0);
                net.advance(rem);
                events += 1;
            }
            assert_eq!(net.n_active(), 0);
            events
        };
        let mut ev = 0u64;
        let ts = h.bench("flownet steady drain (scan): staggered flows", 2, 3, || {
            ev = churn(Engine::Scan);
        });
        h.metric(
            "engine_events_per_s_scan",
            ev as f64 / ts,
            &format!("{:>12.0} events/s", ev as f64 / ts),
        );
        let th = h.bench("flownet steady drain (heap): staggered flows", 2, 3, || {
            ev = churn(Engine::Heap);
        });
        h.metric(
            "engine_events_per_s_heap",
            ev as f64 / th,
            &format!("{:>12.0} events/s", ev as f64 / th),
        );
        h.metric("engine_heap_speedup", ts / th, &format!("{:>11.2}x", ts / th));
    }

    // ---- serial vs partitioned cluster DES: the same hier-AR plan on
    // the monolithic net and on the per-node-partitioned net (NIC
    // boundary partition; outputs are bit-identical — asserted here, so
    // every CI smoke run re-checks the equivalence on a real kernel)
    {
        use pk::hw::ClusterSpec;
        use pk::kernels::collectives::{hier_all_reduce, ClusterCollCtx};
        use pk::plan::Plan;
        let cluster = ClusterSpec::hgx_h100_pod(4);
        let views = pk::baselines::phantom_replicas(cluster.total_devices(), 4096, 8192);
        let mut plan = Plan::new();
        hier_all_reduce(&mut plan, &ClusterCollCtx::new(&cluster, views));
        let serial_exec = TimedExec::on_cluster(cluster.clone());
        let part_exec = TimedExec::on_cluster(cluster).with_partitioned_net();
        let rs = serial_exec.run(&plan);
        let rp = part_exec.run(&plan);
        assert_eq!(
            rs.total_time.to_bits(),
            rp.total_time.to_bits(),
            "partitioned net must be bit-identical to serial"
        );
        assert_eq!(rs.events, rp.events);
        let tser = h.bench("timed_exec: hier AR @ 4 nodes (serial net)", 5, 3, || {
            let _ = serial_exec.run(&plan);
        });
        h.metric(
            "cluster_events_per_s_serial",
            rs.events as f64 / tser,
            &format!("{:>12.0} events/s", rs.events as f64 / tser),
        );
        let tpar = h.bench("timed_exec: hier AR @ 4 nodes (partitioned net)", 5, 3, || {
            let _ = part_exec.run(&plan);
        });
        h.metric(
            "cluster_events_per_s_partitioned",
            rp.events as f64 / tpar,
            &format!("{:>12.0} events/s", rp.events as f64 / tpar),
        );
        h.metric("partitioned_net_speedup", tser / tpar, &format!("{:>11.2}x", tser / tpar));
    }

    // ---- fault-injection path (v4): health-masked rail reroute under a
    // permanent hard NIC failure. Benches the fault hook + capacity-churn
    // overhead in TimedExec and records the *simulated* slowdown of the
    // rerouted plan vs the healthy rail plan — the number fx1 bounds at
    // P/(P-1) + tolerance. A degraded plan that still touched the dead
    // NIC would deadlock here, so the smoke run also re-proves avoidance.
    {
        use pk::hw::ClusterSpec;
        use pk::kernels::gemm_rs::ClusterPath;
        use pk::pk::rail::RailHealth;
        use pk::sim::fault::{FaultSpec, LinkFault};
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let fcfg = GemmKernelCfg::new(cluster.node.clone(), 8192, 4096, 1024);
        let healthy_plan = gemm_rs::build_cluster(&fcfg, &cluster, Schedule::IntraSm, None);
        let health = RailHealth::all_healthy(&cluster).fail_nic(1);
        let degraded_plan = gemm_rs::build_cluster_health(
            &fcfg,
            &cluster,
            Schedule::IntraSm,
            ClusterPath::RailReduce,
            &health,
            None,
        );
        let spec = FaultSpec::seeded(7)
            .with_nic_fault(LinkFault { device: 1, at: 0.0, frac: 0.0, restore_at: None });
        let healthy_exec = TimedExec::on_cluster(cluster.clone());
        let faulted_exec = TimedExec::on_cluster(cluster).with_faults(spec);
        let t_healthy = healthy_exec.run(&healthy_plan).total_time;
        let mut t_degraded = 0.0;
        h.bench("timed_exec: GEMM+RS rail reroute @ 1 failed NIC", 5, 3, || {
            t_degraded = faulted_exec.run(&degraded_plan).total_time;
        });
        let slow = t_degraded / t_healthy;
        h.metric("fault_slowdown", slow, &format!("{slow:>11.2}x vs healthy rail"));
    }

    // ---- parallel sweep driver: the fig5-style partition grid, serial
    // vs the scoped-thread pool (deterministic output either way)
    if !smoke {
        use pk::util::par::par_map_with;
        let node = NodeSpec::hgx_h100();
        let cands = [4u32, 8, 12, 16, 24, 32, 48, 64];
        let plans: Vec<_> = cands
            .iter()
            .map(|&c| {
                let mut cfg = GemmKernelCfg::new(node.clone(), 16384, 2048, 16384);
                cfg.opts.num_comm_sms = c;
                pk::kernels::ag_gemm::build(&cfg, None)
            })
            .collect();
        let sweep_exec = TimedExec::new(node.clone());
        let ts = h.bench("tuner sweep: 8-pt AG+GEMM grid (serial)", 1, 3, || {
            let _ = par_map_with(1, &plans, |_, p| sweep_exec.run(p).total_time);
        });
        let threads = pk::util::par::default_threads();
        let tp = h.bench("tuner sweep: 8-pt AG+GEMM grid (parallel)", 1, 3, || {
            let _ = par_map_with(threads, &plans, |_, p| sweep_exec.run(p).total_time);
        });
        h.metric(
            "parallel_sweep_speedup",
            ts / tp,
            &format!("{:>11.2}x on {threads} thread(s)", ts / tp),
        );
    }

    // ---- serving engine: trace-driven continuous batching, end-to-end
    // (calibration + capacity probe happen once outside the timed loop)
    {
        use pk::hw::ClusterSpec;
        use pk::sim::serve::{self, KernelMode, ServeCfg, StepCostModel};
        use pk::sim::workload::{self, ArrivalProcess, TraceCfg};
        let n_req = if smoke { 48 } else { 512 };
        let cfg = ServeCfg::reference(ClusterSpec::hgx_h100_pod(1), KernelMode::PkOverlap);
        let cost = StepCostModel::calibrate(&cfg.cluster.node, cfg.mode, &cfg.model);
        let cap = serve::capacity_probe(&cfg, &cost, 48, 1234);
        let trace =
            workload::generate(&TraceCfg::chat(ArrivalProcess::Poisson, 0.8 * cap, n_req, 7));
        let mut tok_s = 0.0;
        h.bench("serve: colocated chat trace @ 0.8x capacity", 2, 3, || {
            let rep = serve::run_with_cost(&cfg, &cost, &trace);
            tok_s = rep.tokens_per_s;
        });
        h.metric("serve_tokens_per_s", tok_s, &format!("{tok_s:>12.0} tok/s"));
    }

    // ---- functional executor: tile movement throughput
    {
        use pk::plan::{Effect, MatView, Op, Plan, Role};
        use pk::util::prop::run_functional;
        let mut pool = MemPool::new();
        let a = pool.alloc(DeviceId(0), Shape4::mat(256, 256));
        let b = pool.alloc(DeviceId(1), Shape4::mat(256, 256));
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "w");
        for _ in 0..64 {
            plan.push(
                w,
                Op::Compute {
                    dur: 0.0,
                    label: "copy",
                    effect: Some(Effect::CopyMat {
                        src: MatView::full2d(a, 256, 256),
                        dst: MatView::full2d(b, 256, 256),
                        reduce: None,
                    }),
                },
            );
        }
        let bytes_per_run = 64.0 * 256.0 * 256.0 * 4.0;
        let t = h.bench("functional exec: 64x 256x256 tile copies", 20, 3, || {
            run_functional(&mut pool, &plan);
        });
        let gbs = bytes_per_run / t / 1e9;
        h.metric("copy_throughput_gb_s", gbs, &format!("{gbs:>9.2} GB/s"));
    }

    // ---- native GEMM tile math (functional compute reference)
    {
        use pk::util::linalg::matmul_accum;
        let a = pk::util::seeded_vec(1, 128 * 128);
        let b = pk::util::seeded_vec(2, 128 * 128);
        let mut c = vec![0.0f32; 128 * 128];
        let flops = 2.0 * 128f64.powi(3);
        let t = h.bench("linalg: 128^3 matmul_accum", 20, 3, || {
            matmul_accum(&mut c, &a, &b, 128, 128, 128);
        });
        let gf = flops / t / 1e9;
        h.metric("tile_math_gflop_s", gf, &format!("{gf:>9.2} GFLOP/s"));
    }

    println!("{:-^60}", "");

    // ---- machine-readable snapshot at the repo root. Full runs rewrite
    // the checked-in trajectory baseline; --smoke runs (CI, sanity
    // checks) write next to it so 1-iteration noise never clobbers the
    // committed numbers.
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str("pk-hotpath-v4".to_string()));
    top.insert(
        "note".to_string(),
        Json::Str(
            "perf trajectory snapshot; regenerate with `cargo bench --bench hotpath` \
             (smoke runs write BENCH_hotpath.smoke.json instead)"
                .to_string(),
        ),
    );
    top.insert("smoke".to_string(), Json::Bool(smoke));
    top.insert("events".to_string(), Json::Num(events as f64));
    top.insert("sections".to_string(), Json::Obj(h.sections.clone()));
    let path = if smoke {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.smoke.json")
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json")
    };
    std::fs::write(path, Json::Obj(top).to_string() + "\n").expect("write hotpath snapshot");
    println!("snapshot -> {path}");
}
