//! `cargo bench --bench figures` — regenerates every table and figure of
//! the paper (the full experiment harness; DESIGN.md §4 maps exhibits to
//! modules). Prints each exhibit as markdown with its generation time and
//! writes CSVs to `bench_results/`.
//!
//! Pass `--fast` (after `--`) to trim the sweeps.

use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let out_dir = "bench_results";
    std::fs::create_dir_all(out_dir).ok();
    let mut total = 0.0;
    println!("# ParallelKittens — paper exhibit reproduction\n");
    for e in pk::report::all_exhibits() {
        let t0 = Instant::now();
        let table = (e.run)(fast);
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!("{}", table.to_markdown());
        println!("_generated in {dt:.2}s_\n");
        std::fs::write(format!("{out_dir}/{}.csv", e.id), table.to_csv()).expect("write csv");
    }
    println!("## Design-choice ablations (DESIGN.md calls these out)\n");
    for (id, table) in pk::report::ablations::all_ablations() {
        println!("{}", table.to_markdown());
        std::fs::write(format!("{out_dir}/{id}.csv"), table.to_csv()).expect("write csv");
    }
    println!("---\nall exhibits + ablations regenerated in {total:.1}s (CSVs in {out_dir}/)");
}
