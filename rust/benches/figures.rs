//! `cargo bench --bench figures` — regenerates every table and figure of
//! the paper (the full experiment harness; DESIGN.md §4 maps exhibits to
//! modules). Prints each exhibit as markdown with its generation time and
//! writes CSVs to `bench_results/`. Exhibits regenerate in parallel
//! (`--serial` or `PK_THREADS=1` to disable); output order and bytes are
//! identical either way.
//!
//! Pass `--fast` (after `--`) to trim the sweeps.

use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let threads =
        if args.iter().any(|a| a == "--serial") { 1 } else { pk::util::par::default_threads() };
    let out_dir = "bench_results";
    std::fs::create_dir_all(out_dir).ok();
    let wall0 = Instant::now();
    println!("# ParallelKittens — paper exhibit reproduction\n");
    let results = pk::report::run_exhibits(fast, None, threads);
    let mut total = 0.0;
    for r in &results {
        total += r.wall;
        println!("{}", r.table.to_markdown());
        println!("_generated in {:.2}s_\n", r.wall);
        std::fs::write(format!("{out_dir}/{}.csv", r.id), r.table.to_csv()).expect("write csv");
    }
    println!(
        "_all exhibits in {:.1}s wall on {threads} thread(s) (Σ per-exhibit {total:.1}s)_\n",
        wall0.elapsed().as_secs_f64()
    );
    println!("## Design-choice ablations (DESIGN.md calls these out)\n");
    for (id, table) in pk::report::ablations::all_ablations() {
        println!("{}", table.to_markdown());
        std::fs::write(format!("{out_dir}/{id}.csv"), table.to_csv()).expect("write csv");
    }
    println!("---\nall exhibits + ablations regenerated in {total:.1}s (CSVs in {out_dir}/)");
}
