//! Integration over the PJRT runtime: the three-layer composition
//! (Pallas kernel → JAX stage → AOT HLO text → Rust load/compile/execute)
//! with numerics checked against the native references.
//!
//! Requires `make artifacts` (skips gracefully otherwise).

use pk::coordinator::Node;
use pk::exec::FunctionalExec;
use pk::hw::spec::NodeSpec;
use pk::hw::DeviceId;
use pk::mem::tile::Shape4;
use pk::mem::MemPool;
use pk::plan::{Effect, MatView, Op, Plan, Role};
use pk::runtime::Runtime;
use pk::util::{assert_allclose, linalg, seeded_vec};

fn runtime() -> Option<Runtime> {
    Runtime::open(Runtime::default_dir()).ok()
}

#[test]
fn gemm_artifacts_match_native_matmul() {
    let Some(mut rt) = runtime() else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    for (name, n) in [("gemm_64x64x64", 64usize), ("gemm_128x128x128", 128)] {
        let x = seeded_vec(1, n * n);
        let y = seeded_vec(2, n * n);
        let out = rt.execute(name, &[(x.clone(), vec![n, n]), (y.clone(), vec![n, n])]).unwrap();
        let want = linalg::matmul(&x, &y, n, n, n);
        assert_allclose(&out[0], &want, 1e-3, 1e-3);
    }
}

#[test]
fn attention_artifact_matches_reference() {
    let Some(mut rt) = runtime() else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let (s, d) = (64, 32);
    let q = seeded_vec(3, s * d);
    let k = seeded_vec(4, s * d);
    let v = seeded_vec(5, s * d);
    let out = rt
        .execute("attn_block_s64_kv64_d32", &[(q.clone(), vec![s, d]), (k.clone(), vec![s, d]), (v.clone(), vec![s, d])])
        .unwrap();
    let want = linalg::attention_ref(&q, &k, &v, s, s, d);
    assert_allclose(&out[0], &want, 1e-3, 1e-4);
}

#[test]
fn tp_mlp_fwd_artifact_matches_native_composition() {
    let Some(mut rt) = runtime() else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let (t, d, f) = (128, 256, 128);
    let x = seeded_vec(6, t * d);
    let w1 = seeded_vec(7, d * f);
    let w2 = seeded_vec(8, f * d);
    let out = rt
        .execute("tp_mlp_fwd", &[(x.clone(), vec![t, d]), (w1.clone(), vec![d, f]), (w2.clone(), vec![f, d])])
        .unwrap();
    let mut h = linalg::matmul(&x, &w1, t, f, d);
    linalg::gelu_inplace(&mut h);
    let want = linalg::matmul(&h, &w2, t, d, f);
    assert_allclose(&out[0], &want, 1e-2, 1e-3);
}

#[test]
fn run_artifact_effect_through_functional_exec() {
    let Some(mut rt) = runtime() else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let mut pool = MemPool::new();
    let n = 64;
    let a = pool.alloc_init(DeviceId(0), Shape4::mat(n, n), seeded_vec(10, n * n));
    let b = pool.alloc_init(DeviceId(0), Shape4::mat(n, n), seeded_vec(11, n * n));
    let c = pool.alloc(DeviceId(0), Shape4::mat(n, n));
    let mut plan = Plan::new();
    let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "pjrt");
    plan.push(
        w,
        Op::Compute {
            dur: 0.0,
            label: "artifact_gemm",
            effect: Some(Effect::RunArtifact {
                name: "gemm_64x64x64".into(),
                inputs: vec![MatView::full2d(a, n, n), MatView::full2d(b, n, n)],
                outputs: vec![MatView::full2d(c, n, n)],
            }),
        },
    );
    FunctionalExec::with_runtime(&mut pool, &mut rt).run(&plan).unwrap();
    let want = linalg::matmul(&pool.get(a).data, &pool.get(b).data, n, n, n);
    assert_allclose(&pool.get(c).data, &want, 1e-3, 1e-3);
}

#[test]
fn threaded_node_runs_artifacts_from_multiple_workers() {
    let Some(rt) = runtime() else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let n_dev = 4;
    let n = 64;
    let mut pool = MemPool::new();
    let mut abufs = vec![];
    let mut cbufs = vec![];
    for d in 0..n_dev {
        abufs.push(pool.alloc_init(DeviceId(d), Shape4::mat(n, n), seeded_vec(20 + d as u64, n * n)));
        cbufs.push(pool.alloc(DeviceId(d), Shape4::mat(n, n)));
    }
    let eye = {
        let mut e = vec![0.0f32; n * n];
        for i in 0..n {
            e[i * n + i] = 1.0;
        }
        e
    };
    let id_buf = pool.alloc_init(DeviceId(0), Shape4::mat(n, n), eye);
    let mut plan = Plan::new();
    for d in 0..n_dev {
        let w = plan.add_worker(DeviceId(d), Role::ComputeSm, format!("d{d}"));
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "artifact_gemm",
                effect: Some(Effect::RunArtifact {
                    name: "gemm_64x64x64".into(),
                    inputs: vec![MatView::full2d(abufs[d], n, n), MatView::full2d(id_buf, n, n)],
                    outputs: vec![MatView::full2d(cbufs[d], n, n)],
                }),
            },
        );
    }
    let mut node = Node::with_runtime(NodeSpec::test_node(n_dev), pool, rt);
    let metrics = node.run_plan(&plan).unwrap();
    assert_eq!(metrics.artifact_calls["gemm_64x64x64"], n_dev as u64);
    let pool = node.pool();
    for d in 0..n_dev {
        // X @ I == X
        assert_allclose(&pool.get(cbufs[d]).data, &pool.get(abufs[d]).data, 1e-4, 1e-4);
    }
}

#[test]
fn manifest_covers_required_artifacts() {
    let Some(rt) = runtime() else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    for name in [
        "gemm_64x64x64",
        "gemm_128x128x128",
        "attn_block_s64_kv64_d32",
        "expert_mlp_e4_cap32_h64_he32",
        "tp_mlp_fwd",
        "tp_mlp_bwd",
    ] {
        assert!(rt.has(name), "missing artifact {name}");
    }
}
