//! Integration tests asserting the paper's headline *claims* hold in the
//! reproduction — the qualitative shape of every major result (DESIGN.md
//! §4's "expected shape" column). These run the same harness as
//! `cargo bench --bench figures`, in fast mode.

use pk::report::run_exhibit;

fn col(t: &pk::report::Table, name: &str) -> Vec<f64> {
    t.col_f64(name)
}

#[test]
fn claim_table1_ordering_ce_tma_reg() {
    let t = run_exhibit("tab1", true).unwrap();
    let h100: Vec<f64> = col(&t, "H100 GB/s");
    assert!(h100[0] > h100[1] && h100[1] > h100[2], "CE > TMA > Reg: {h100:?}");
    // Table 1 values within 2%
    for (got, want) in h100.iter().zip([368.82, 350.01, 342.68]) {
        assert!((got - want).abs() / want < 0.02, "{got} vs {want}");
    }
}

#[test]
fn claim_fig2_ce_needs_large_messages() {
    let t = run_exhibit("fig2", true).unwrap();
    let msgs = col(&t, "msg_bytes");
    let ce = col(&t, "copy_engine");
    let tma = col(&t, "tma");
    for i in 0..msgs.len() {
        if msgs[i] <= 65536.0 {
            assert!(tma[i] > ce[i] * 2.0, "device-initiated wins small messages");
        }
        if msgs[i] >= 256e6 {
            assert!(ce[i] >= 0.80, "CE >= 80% at >= 256MB");
        }
    }
}

#[test]
fn claim_fig3_saturation_points() {
    let t = run_exhibit("fig3", true).unwrap();
    let sms = col(&t, "sms");
    let tma = col(&t, "tma");
    let reg = col(&t, "reg");
    for i in 0..sms.len() {
        if sms[i] as u32 == 15 {
            assert!(tma[i] >= 0.77, "TMA saturated by 15 SMs: {}", tma[i]);
        }
        if sms[i] as u32 == 76 {
            assert!(reg[i] >= 0.75, "reg saturated by 76 SMs: {}", reg[i]);
        }
        if sms[i] as u32 == 15 {
            assert!(reg[i] < 0.2, "reg far from saturation at 15 SMs");
        }
    }
}

#[test]
fn claim_fig4_schedule_tradeoffs() {
    let t = run_exhibit("fig4", true).unwrap();
    // rows: RS-intra, RS-inter, AR-intra, AR-inter
    let tf = col(&t, "tflops");
    let rs_ratio = tf[0] / tf[1];
    assert!(rs_ratio > 1.05 && rs_ratio < 1.5, "RS: intra ~1.2x inter, got {rs_ratio}");
    let ar_ratio = tf[3] / tf[2];
    assert!(ar_ratio > 2.5 && ar_ratio < 5.0, "AR: inter ~3.62x intra, got {ar_ratio}");
}

#[test]
fn claim_tab3_comm_hidden_past_k_threshold() {
    let t = run_exhibit("tab3", true).unwrap();
    let ks = col(&t, "K");
    let ratios: Vec<f64> = t
        .rows
        .iter()
        .map(|r| r[3].trim_end_matches('%').parse::<f64>().unwrap())
        .collect();
    for (k, ratio) in ks.iter().zip(&ratios) {
        if *k <= 1024.0 {
            assert!(*ratio > 40.0, "K={k}: comm dominates, got {ratio}%");
        }
        if *k >= 4096.0 {
            assert!(*ratio < 10.0, "K={k}: comm hidden past sR/2B ~ 2197, got {ratio}%");
        }
    }
}

#[test]
fn claim_fig6_pk_ar_up_to_1_79x_nccl() {
    let t = run_exhibit("fig6", true).unwrap();
    let sp = col(&t, "speedup");
    assert!(sp.iter().all(|s| *s > 1.0), "PK always wins: {sp:?}");
    assert!(sp.iter().any(|s| *s > 1.2), "meaningful gap somewhere: {sp:?}");
    assert!(sp.iter().all(|s| *s < 2.2), "bounded (paper: up to 1.79x): {sp:?}");
}

#[test]
fn claim_fig8_pk_geq_flux_and_nonoverlap() {
    let t = run_exhibit("fig8", true).unwrap();
    let pk = col(&t, "pk");
    let nonov = col(&t, "nonoverlap");
    let flux = col(&t, "flux");
    for i in 0..pk.len() {
        assert!(pk[i] > nonov[i], "PK beats non-overlap");
        assert!(pk[i] >= flux[i] * 0.95, "PK >= ~Flux (0.97-2.33x band)");
    }
}

#[test]
fn claim_fig9_pk_dominates_gemm_ar() {
    let t = run_exhibit("fig9", true).unwrap();
    let pk = col(&t, "pk");
    let nonov = col(&t, "nonoverlap");
    let td = col(&t, "triton_dist");
    for i in 0..pk.len() {
        assert!(pk[i] > nonov[i] && pk[i] > td[i], "PK wins GEMM+AR everywhere");
    }
}

#[test]
fn claim_fig11_modest_ulysses_gap() {
    let t = run_exhibit("fig11", true).unwrap();
    let sp = col(&t, "speedup");
    for s in &sp {
        assert!(*s >= 1.0 && *s <= 1.8, "PK 1.01-1.39x band-ish: {sp:?}");
    }
}

#[test]
fn claim_fig12_pk_comet_parity() {
    let t = run_exhibit("fig12", true).unwrap();
    let r = col(&t, "pk_vs_comet");
    for v in &r {
        assert!(*v > 0.8 && *v < 1.45, "PK 0.92-1.22x of Comet band-ish: {r:?}");
    }
}

#[test]
fn claim_fig13_b200_same_ordering() {
    let t = run_exhibit("fig13", true).unwrap();
    let pk = col(&t, "pk");
    let nonov = col(&t, "nonoverlap");
    for i in 0..pk.len() {
        assert!(pk[i] > nonov[i], "B200 preserves the ordering");
    }
    // B200 absolute throughput exceeds H100's fig8 at the same N
    let h = run_exhibit("fig8", true).unwrap();
    assert!(pk[pk.len() - 1] > col(&h, "pk")[h.rows.len() - 1]);
}

#[test]
fn claim_fig15_16_17_tensor_dim_wins() {
    for id in ["fig15", "fig16", "fig17"] {
        let t = run_exhibit(id, true).unwrap();
        let sp = col(&t, "speedup");
        for s in &sp {
            assert!(*s > 1.0, "{id}: PK beats NCCL+reshape: {sp:?}");
        }
    }
}

#[test]
fn claim_mu1_sync_costs() {
    let t = run_exhibit("mu1", true).unwrap();
    let lat = col(&t, "latency_ns");
    assert_eq!(lat[0], 64.0, "mbarrier 64 ns");
    assert_eq!(lat[1], 832.0, "HBM sync 832 ns");
}

#[test]
fn claim_mu2_nvshmem_tax() {
    let t = run_exhibit("mu2", true).unwrap();
    let lat = col(&t, "elementwise_latency_us");
    assert!((lat[0] / lat[1] - 4.5).abs() < 1e-6, "4.5x latency tax");
    let bw = col(&t, "bandwidth_GBps");
    assert!((bw[1] - bw[0] - 20.0).abs() < 0.5, "~20 GB/s bandwidth tax");
}

#[test]
fn claim_scaleout_sweep_monotone_and_runs_1_to_4_nodes() {
    // The cluster-layer exhibit: per collective, the 1-node (NVLink-only)
    // row is the per-device-byte optimum — crossing the first NIC is a
    // cliff — while aggregate algorithm bandwidth is monotone
    // non-decreasing in node count across the scale-out regime (the rail
    // ring bounds per-NIC traffic by 2·S/P regardless of K).
    // fast mode sweeps one NIC level (50 GB/s), so all multi-node rows are
    // at the same NIC bandwidth and monotonicity is well-posed.
    let t = run_exhibit("sx1", true).unwrap();
    assert_eq!(t.columns, vec!["collective", "nodes", "nic_GBps", "time_ms", "agg_GBps", "per_dev_GBps"]);
    for name in ["all_reduce", "all_gather", "reduce_scatter"] {
        let mut series: Vec<(f64, f64, f64)> = vec![]; // (nodes, agg, per_dev)
        for r in &t.rows {
            if r[0] == name {
                series.push((r[1].parse().unwrap(), r[4].parse().unwrap(), r[5].parse().unwrap()));
            }
        }
        let max_nodes = series.iter().map(|(n, _, _)| *n).fold(0.0f64, f64::max);
        assert!(series.iter().any(|(n, _, _)| *n == 1.0) && max_nodes == 4.0, "{name}: sweeps 1 -> 4 nodes");
        let one = series.iter().find(|(n, _, _)| *n == 1.0).unwrap().2;
        let two = series.iter().find(|(n, _, _)| *n == 2.0).unwrap().2;
        assert!(one > two, "{name}: the per-device NIC cliff exists ({one} vs {two} GB/s)");
        let multi: Vec<f64> = series.iter().filter(|(n, _, _)| *n >= 2.0).map(|(_, a, _)| *a).collect();
        for w in multi.windows(2) {
            assert!(w[1] >= w[0] * 0.999, "{name}: more nodes => >= aggregate throughput: {multi:?}");
        }
    }
}

#[test]
fn claim_one_node_cluster_bit_identical_to_nodespec_path() {
    // Regression guard for the cluster refactor. The single-node builders
    // now *delegate* to the cluster code path, so (a) and (b) pin the
    // constructor equivalence — they fail if TimedExec::on_cluster or a
    // 1-node ClusterSpec ever diverges from TimedExec::new (e.g. someone
    // declares NIC capacities unconditionally or changes
    // ClusterSpec::single's defaults). (c) pins the K=1 delegation of the
    // hierarchical collectives onto the PK builders — the part that could
    // genuinely drift. Drift vs the *seed's* absolute numbers is pinned
    // separately by the pre-existing figure/claim tests in this file.
    use pk::exec::TimedExec;
    use pk::hw::spec::NodeSpec;
    use pk::hw::ClusterSpec;
    use pk::kernels::collectives::{hier_all_reduce, pk_all_reduce, ClusterCollCtx, PkCollCtx};
    use pk::kernels::gemm_rs::{self, Schedule};
    use pk::kernels::GemmKernelCfg;
    use pk::plan::Plan;

    let node = NodeSpec::hgx_h100();
    let phantom = pk::baselines::phantom_replicas;

    // (a) a collective plan through both executor constructions
    let mut coll_plan = Plan::new();
    pk_all_reduce(&mut coll_plan, &PkCollCtx::new(&node, phantom(8, 1024, 4096)));
    let a = TimedExec::new(node.clone()).run(&coll_plan);
    let b = TimedExec::on_cluster(ClusterSpec::single(node.clone())).run(&coll_plan);
    assert_eq!(a.total_time.to_bits(), b.total_time.to_bits(), "collective time identical");
    assert_eq!(a.events, b.events);
    for (port, bytes) in &a.port_bytes {
        assert_eq!(bytes.to_bits(), b.port_bytes[port].to_bits(), "{port:?} bytes identical");
    }

    // (b) a fused kernel built per-node vs on the 1-node cluster
    let cfg = GemmKernelCfg::new(node.clone(), 8192, 8192, 1024);
    let p_node = gemm_rs::build(&cfg, Schedule::IntraSm, None);
    let p_cluster = gemm_rs::build_cluster(&cfg, &ClusterSpec::single(node.clone()), Schedule::IntraSm, None);
    assert_eq!(p_node.total_ops(), p_cluster.total_ops());
    let t_node = TimedExec::new(node.clone()).run(&p_node).total_time;
    let t_cluster = TimedExec::on_cluster(ClusterSpec::single(node.clone())).run(&p_cluster).total_time;
    assert_eq!(t_node.to_bits(), t_cluster.to_bits(), "gemm_rs time identical");

    // (c) hierarchical collectives with K=1 delegate to the PK builders
    let cluster = ClusterSpec::single(node.clone());
    let mut h = Plan::new();
    hier_all_reduce(&mut h, &ClusterCollCtx::new(&cluster, phantom(8, 1024, 4096)));
    let th = TimedExec::on_cluster(cluster).run(&h).total_time;
    assert_eq!(th.to_bits(), a.total_time.to_bits(), "K=1 hier AR == pk AR");
}

#[test]
fn claim_scaleout_runs_both_executors_end_to_end() {
    // The acceptance bar: a hierarchical collective runs 1 -> 4 nodes
    // through the functional executor (numerics) and the timed executor
    // (NIC accounting) end-to-end.
    use pk::exec::TimedExec;
    use pk::util::prop::run_functional;
    use pk::hw::topology::Port;
    use pk::hw::{ClusterSpec, DeviceId};
    use pk::kernels::collectives::{hier_all_reduce, ClusterCollCtx};
    use pk::mem::tile::Shape4;
    use pk::mem::MemPool;
    use pk::plan::{MatView, Op, Plan};

    for k in [1usize, 2, 4] {
        let p = 2;
        let cluster = ClusterSpec::test_cluster(k, p);
        let n = cluster.total_devices();
        let (rows, cols) = (n * 2, 4);
        let mut pool = MemPool::new();
        let bufs: Vec<_> = (0..n)
            .map(|d| pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), vec![(d + 1) as f32; rows * cols]))
            .collect();
        let ctx = ClusterCollCtx::new(&cluster, bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect());
        let mut plan = Plan::new();
        hier_all_reduce(&mut plan, &ctx);
        run_functional(&mut pool, &plan);
        let want = (n * (n + 1) / 2) as f32;
        for &b in &bufs {
            assert!(pool.get(b).data.iter().all(|v| *v == want), "{k} nodes: sum everywhere");
        }
        // timed: strip effects, run, sanity-check the NIC accounting
        for w in &mut plan.workers {
            for op in &mut w.ops {
                if let Op::Transfer { effect, .. } = op {
                    *effect = None;
                }
                if let Op::Compute { effect, .. } = op {
                    *effect = None;
                }
            }
        }
        let r = TimedExec::on_cluster(cluster).run(&plan);
        assert!(r.total_time.is_finite() && r.total_time > 0.0);
        let any_nic = r.port_bytes.keys().any(|p| matches!(p, Port::NicEgress(_)));
        assert_eq!(any_nic, k > 1, "{k} nodes: NICs charged iff multi-node");
    }
}

#[test]
fn claim_moe_per_rail_aggregation_cuts_nic_traffic_by_p() {
    // The canonical worst-case routing: every token picks P experts, one
    // on each device of a single remote node. Naive per-device RDMA sends
    // cross the source NIC P times per token; the per-rail aggregated
    // dispatch exactly once — the ×P NIC-traffic reduction, pinned both
    // analytically and against the timed executor's port accounting.
    use pk::exec::TimedExec;
    use pk::hw::spec::NodeSpec;
    use pk::hw::topology::Port;
    use pk::hw::{ClusterSpec, DeviceId};
    use pk::kernels::moe::{self, MoeCfg, MoeSchedule, Routing, DEFAULT_RDMA_CHUNK};

    let (k, p) = (2usize, 4usize);
    let n = k * p;
    let cluster = ClusterSpec::test_cluster(k, p);
    let cfg = MoeCfg {
        node: NodeSpec::test_node(p),
        tokens: n * 8,
        hidden: 32,
        h_expert: 16,
        n_experts: n * 2,
        top_k: p,
        comm_sms: 8,
        rdma_chunk: DEFAULT_RDMA_CHUNK,
    };
    let tl = cfg.tokens_local_of(n);
    let el = cfg.experts_local_of(n);
    // token t on node kn -> one expert on each device of node (kn+1) % k
    let experts: Vec<Vec<usize>> = (0..cfg.tokens)
        .map(|t| {
            let src_node = t / tl / p;
            let dst_node = (src_node + 1) % k;
            (0..p).map(|q| (dst_node * p + q) * el + t % el).collect()
        })
        .collect();
    let routing = Routing { experts };
    let agg: f64 = moe::nic_dispatch_bytes(&cfg, &cluster, &routing, true).iter().sum();
    let naive: f64 = moe::nic_dispatch_bytes(&cfg, &cluster, &routing, false).iter().sum();
    assert!(agg > 0.0);
    assert!(
        (naive / agg - p as f64).abs() < 1e-9,
        "per-rail aggregation must cut NIC traffic exactly xP: {}",
        naive / agg
    );
    // the built plan's NIC accounting matches the aggregated figure
    let plan = moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, None);
    let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
    let nic_total: f64 = (0..n)
        .map(|g| r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0))
        .sum();
    assert!((nic_total - agg).abs() < 1.0, "timed NIC bytes {nic_total} vs aggregated {agg}");
    assert!((nic_total * p as f64 - naive).abs() < 1.0, "naive would be xP the timed bytes");
}

#[test]
fn claim_moe_one_node_cluster_bit_identical_and_mx1_overlap_wins() {
    // (a) the cluster MoE builder on a 1-node cluster is bit-identical to
    // the single-node path (the regression guarantee of the delegation).
    use pk::exec::TimedExec;
    use pk::hw::spec::NodeSpec;
    use pk::hw::ClusterSpec;
    use pk::kernels::moe::{self, MoeCfg, MoeSchedule, Routing};

    let node = NodeSpec::hgx_h100();
    let cfg = MoeCfg::paper(node.clone(), 8192);
    let routing = Routing::uniform(&cfg, 11);
    let cluster = ClusterSpec::single(node.clone());
    let a = moe::build(&cfg, &routing, MoeSchedule::Overlapped, None);
    let b = moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, None);
    assert_eq!(a.total_ops(), b.total_ops());
    let ta = TimedExec::new(node).run(&a).total_time;
    let tb = TimedExec::on_cluster(cluster).run(&b).total_time;
    assert_eq!(ta.to_bits(), tb.to_bits(), "1-node cluster MoE must not drift");

    // (b) the mx1 exhibit: overlapped cluster MoE beats the sequential
    // schedule at every (nodes, NIC bandwidth) point, and PK stays inside
    // the Comet comparison band on the cluster rows.
    let t = run_exhibit("mx1", true).unwrap();
    assert_eq!(
        t.columns,
        vec!["nodes", "nic_GBps", "pk_ms", "seq_ms", "comet_ms", "tok_per_s", "nic_GB_per_dev", "nic_agg_x"]
    );
    for r in &t.rows {
        let pk: f64 = r[2].parse().unwrap();
        let seq: f64 = r[3].parse().unwrap();
        let comet: f64 = r[4].parse().unwrap();
        assert!(pk < seq, "overlap wins at nodes={} nic={}: {pk} vs {seq}", r[0], r[1]);
        let ratio = comet / pk;
        assert!(ratio > 0.8 && ratio < 1.6, "PK/Comet cluster band at nodes={}: {ratio}", r[0]);
    }
}

#[test]
fn claim_gemm_rs_rail_reduce_cuts_nic_traffic_by_p() {
    // The rail-extract acceptance bar: on the canonical config the
    // hierarchical (pre-reduce + per-node-pair rail flow) gemm_rs charges
    // each NIC exactly 1/P of the PR 1 locality-routed scatter's bytes —
    // pinned analytically and against the timed executor's ports.
    use pk::exec::TimedExec;
    use pk::hw::topology::Port;
    use pk::hw::{ClusterSpec, DeviceId};
    use pk::kernels::gemm_rs::{self, ClusterPath, Schedule};
    use pk::kernels::GemmKernelCfg;

    let cluster = ClusterSpec::hgx_h100_pod(2);
    let p = cluster.devices_per_node();
    let cfg = GemmKernelCfg::new(cluster.node.clone(), 32768, 4096, 4096);
    let rail = gemm_rs::nic_scatter_bytes(&cfg, &cluster, ClusterPath::RailReduce);
    let scatter = gemm_rs::nic_scatter_bytes(&cfg, &cluster, ClusterPath::Scatter);
    let (rail_tot, scatter_tot): (f64, f64) =
        (rail.iter().sum(), scatter.iter().sum());
    assert!(rail_tot > 0.0);
    assert!(
        (scatter_tot / rail_tot - p as f64).abs() < 1e-9,
        "rail reduce must cut NIC traffic exactly xP: {}",
        scatter_tot / rail_tot
    );
    // the built plans' NIC accounting matches the models
    for (path, want) in [(ClusterPath::RailReduce, &rail), (ClusterPath::Scatter, &scatter)] {
        let plan = gemm_rs::build_cluster_opts(&cfg, &cluster, Schedule::IntraSm, path, None);
        let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
        for g in 0..cluster.total_devices() {
            let got = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
            assert!(
                (got - want[g]).abs() / want[g] < 1e-6,
                "{path:?} dev {g}: {got} vs {}",
                want[g]
            );
        }
    }
}

#[test]
fn claim_two_level_a2a_runs_multi_node_and_one_node_delegates() {
    // The old fail-fast is gone: the two-level all-to-all runs on
    // multi-node clusters, charges NICs (not NVLink) for the cross-node
    // share, and the 1-node cluster still delegates to the single-node
    // builder bit-identically.
    use pk::exec::TimedExec;
    use pk::hw::topology::Port;
    use pk::hw::{ClusterSpec, DeviceId};
    use pk::kernels::collectives::{pk_all_to_all_4d, pk_all_to_all_4d_cluster, A2aCfg};
    use pk::plan::Plan;

    let node = pk::hw::spec::NodeSpec::hgx_h100();
    let cfg = A2aCfg { b_dim: 1, s_local: 1024, h: 128, d_head: 128 };
    let mut a = Plan::new();
    pk_all_to_all_4d_cluster(
        &mut a,
        &ClusterSpec::single(node.clone()),
        &cfg,
        None,
        None,
        None,
        pk::pk::rail::DEFAULT_RDMA_CHUNK,
        16.0,
    );
    let mut b = Plan::new();
    pk_all_to_all_4d(&mut b, &node, &cfg, None, None, 16.0);
    assert_eq!(a.total_ops(), b.total_ops());
    let ta = TimedExec::new(node.clone()).run(&a).total_time;
    let tb = TimedExec::new(node).run(&b).total_time;
    assert_eq!(ta.to_bits(), tb.to_bits(), "1-node a2a delegation must not drift");

    let cluster = ClusterSpec::hgx_h100_pod(2);
    let n = cluster.total_devices();
    let cfg2 = A2aCfg { b_dim: 1, s_local: 512, h: 128, d_head: 128 };
    let mut plan = Plan::new();
    pk_all_to_all_4d_cluster(
        &mut plan,
        &cluster,
        &cfg2,
        None,
        None,
        None,
        pk::pk::rail::DEFAULT_RDMA_CHUNK,
        16.0,
    );
    let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
    assert!(r.total_time.is_finite() && r.total_time > 0.0);
    let dev_bytes = (cfg2.b_dim * cfg2.s_local * cfg2.h * cfg2.d_head) as f64 * 2.0;
    let want = dev_bytes * (cluster.num_nodes - 1) as f64 / cluster.num_nodes as f64;
    for g in 0..n {
        let e = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
        assert!((e - want).abs() < 1.0, "dev {g}: NIC egress {e} vs {want}");
    }
}

#[test]
fn claim_fig5_partition_matters() {
    let t = run_exhibit("fig5", true).unwrap();
    // for the large problem, too many comm SMs must hurt
    let rows: Vec<(f64, f64, f64)> = t
        .rows
        .iter()
        .map(|r| (r[0].parse().unwrap(), r[1].parse().unwrap(), r[2].parse().unwrap()))
        .collect();
    let big_small_sms = rows.iter().find(|(n, c, _)| *n == 32768.0 && *c == 8.0).unwrap().2;
    let big_many_sms = rows.iter().find(|(n, c, _)| *n == 32768.0 && *c == 32.0).unwrap().2;
    assert!(big_many_sms >= big_small_sms, "more comm SMs slow the large problem");
}

#[test]
fn claim_parallel_tuner_sweep_byte_identical_to_serial() {
    // The scoped-thread sweep driver must never change a number: the
    // tuner result (which runs on `PK_THREADS`/available parallelism)
    // must match a hand-rolled serial loop over the same candidate plans
    // bit-for-bit — same times, same order, same winner.
    use pk::exec::TimedExec;
    use pk::hw::spec::NodeSpec;
    use pk::kernels::GemmKernelCfg;
    use pk::pk::tuner::tune_comm_sms_with;

    let node = NodeSpec::hgx_h100();
    let exec = TimedExec::new(node.clone());
    let cands = [4u32, 8, 16, 32];
    let build = |c: u32| {
        let mut cfg = GemmKernelCfg::new(node.clone(), 8192, 1024, 8192);
        cfg.opts.num_comm_sms = c;
        pk::kernels::ag_gemm::build(&cfg, None)
    };
    let r = tune_comm_sms_with(&exec, &cands, build);
    let serial: Vec<(u32, f64)> =
        cands.iter().map(|&c| (c, exec.run(&build(c)).total_time)).collect();
    assert_eq!(r.sweep.len(), serial.len());
    for ((c1, t1), (c2, t2)) in r.sweep.iter().zip(&serial) {
        assert_eq!(c1, c2);
        assert_eq!(t1.to_bits(), t2.to_bits(), "sweep point {c1} drifted under parallelism");
    }
    let (want_c, want_t) =
        serial.iter().copied().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
    assert_eq!(r.best_comm_sms, want_c);
    assert_eq!(r.best_time.to_bits(), want_t.to_bits());
}

#[test]
fn claim_parallel_exhibit_runner_byte_identical_to_serial() {
    // exhibit-level parallelism in `pk figures`: the rendered tables
    // (markdown and CSV — what lands on stdout and in --out) must be
    // byte-identical between 1 thread and many.
    use pk::report::run_exhibits;
    let ids = ["tab1", "fig2", "fig4", "fig5"];
    let serial = run_exhibits(true, Some(&ids), 1);
    let parallel = run_exhibits(true, Some(&ids), 4);
    assert_eq!(serial.len(), ids.len());
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.id, p.id, "registry order must be preserved");
        assert_eq!(s.table.to_csv(), p.table.to_csv(), "{} drifted under parallelism", s.id);
        assert_eq!(s.table.to_markdown(), p.table.to_markdown());
    }
}

#[test]
fn claim_solver_memoization_fires_on_symmetric_kernels() {
    // The perf claim behind the incremental engine: a symmetric kernel's
    // repeated phases present the same active-class multiset, so the
    // water-fill memo serves most solves, and the timed result is
    // unchanged run-to-run (determinism of the whole engine).
    use pk::exec::TimedExec;
    use pk::hw::spec::NodeSpec;
    use pk::kernels::gemm_rs::{self, Schedule};
    use pk::kernels::GemmKernelCfg;

    let node = NodeSpec::hgx_h100();
    let cfg = GemmKernelCfg::new(node.clone(), 16384, 16384, 2048);
    let plan = gemm_rs::build(&cfg, Schedule::IntraSm, None);
    let exec = TimedExec::new(node);
    let a = exec.run(&plan);
    let b = exec.run(&plan);
    assert_eq!(a.total_time.to_bits(), b.total_time.to_bits());
    assert_eq!(a.events, b.events);
    assert_eq!(a.solver, b.solver);
    assert!(a.solver.solves > 0);
    assert!(
        a.solver.memo_hits * 4 > a.solver.solves,
        "symmetric GEMM+RS phases should hit the memo on a meaningful fraction of solves: {:?}",
        a.solver
    );
}

#[test]
fn claim_cluster_gemm_family_rail_cuts_nic_traffic_by_p() {
    // The gx1 acceptance bar: gemm_ar and ag_gemm — the last GEMM-family
    // kernels to get a cluster story — charge each NIC exactly 1/P of
    // the naive per-device accounting, pinned analytically and against
    // the timed executor's ports.
    use pk::exec::TimedExec;
    use pk::hw::topology::Port;
    use pk::hw::{ClusterSpec, DeviceId};
    use pk::kernels::gemm_rs::{ClusterPath, Schedule};
    use pk::kernels::{ag_gemm, gemm_ar, GemmKernelCfg};

    let cluster = ClusterSpec::hgx_h100_pod(2);
    let p = cluster.devices_per_node();
    let exec = TimedExec::on_cluster(cluster.clone());

    let cfg = GemmKernelCfg::new(cluster.node.clone(), 32768, 8192, 4096);
    let rail = gemm_ar::nic_ar_bytes(&cfg, &cluster, ClusterPath::RailReduce);
    let naive = gemm_ar::nic_ar_bytes(&cfg, &cluster, ClusterPath::Scatter);
    let (rail_tot, naive_tot): (f64, f64) = (rail.iter().sum(), naive.iter().sum());
    assert!(rail_tot > 0.0);
    assert!(
        (naive_tot / rail_tot - p as f64).abs() < 1e-9,
        "gemm_ar rail must cut NIC traffic exactly xP: {}",
        naive_tot / rail_tot
    );
    for (path, want) in [(ClusterPath::RailReduce, &rail), (ClusterPath::Scatter, &naive)] {
        let plan = gemm_ar::build_cluster_opts(&cfg, &cluster, Schedule::InterSm, path, None);
        let r = exec.run(&plan);
        for g in 0..cluster.total_devices() {
            let got = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
            assert!(
                (got - want[g]).abs() / want[g] < 1e-6,
                "gemm_ar {path:?} dev {g}: {got} vs {}",
                want[g]
            );
        }
    }

    let acfg = GemmKernelCfg::new(cluster.node.clone(), 32768, 4096, 8192);
    let arail = ag_gemm::nic_ag_bytes(&acfg, &cluster, ClusterPath::RailReduce);
    let anaive = ag_gemm::nic_ag_bytes(&acfg, &cluster, ClusterPath::Scatter);
    let (at_r, at_n): (f64, f64) = (arail.iter().sum(), anaive.iter().sum());
    assert!(at_r > 0.0);
    assert!(
        (at_n / at_r - p as f64).abs() < 1e-9,
        "ag_gemm rail must cut NIC traffic exactly xP: {}",
        at_n / at_r
    );
    for (path, want) in [(ClusterPath::RailReduce, &arail), (ClusterPath::Scatter, &anaive)] {
        let plan = ag_gemm::build_cluster_opts(&acfg, &cluster, path, None);
        let r = exec.run(&plan);
        for g in 0..cluster.total_devices() {
            let got = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
            assert!(
                (got - want[g]).abs() / want[g] < 1e-6,
                "ag_gemm {path:?} dev {g}: {got} vs {}",
                want[g]
            );
        }
    }
}

#[test]
fn claim_cluster_gemm_family_one_node_delegates_bit_identically() {
    // Like every kernel in the repo: the cluster entry points reduce to
    // the single-node builders on one node, bit for bit.
    use pk::exec::TimedExec;
    use pk::hw::ClusterSpec;
    use pk::kernels::gemm_rs::Schedule;
    use pk::kernels::{ag_gemm, gemm_ar, GemmKernelCfg};

    let node = pk::hw::spec::NodeSpec::hgx_h100();
    let single = ClusterSpec::single(node.clone());

    let cfg = GemmKernelCfg::new(node.clone(), 32768, 32768, 4096);
    let a = gemm_ar::build(&cfg, Schedule::InterSm, None);
    let b = gemm_ar::build_cluster(&cfg, &single, Schedule::InterSm, None);
    assert_eq!(a.total_ops(), b.total_ops());
    let ta = TimedExec::new(node.clone()).run(&a).total_time;
    let tb = TimedExec::on_cluster(single.clone()).run(&b).total_time;
    assert_eq!(ta.to_bits(), tb.to_bits(), "1-node gemm_ar delegation must not drift");

    let acfg = GemmKernelCfg::new(node.clone(), 32768, 4096, 32768);
    let a = ag_gemm::build(&acfg, None);
    let b = ag_gemm::build_cluster(&acfg, &single, None);
    assert_eq!(a.total_ops(), b.total_ops());
    let ta = TimedExec::new(node.clone()).run(&a).total_time;
    let tb = TimedExec::on_cluster(single).run(&b).total_time;
    assert_eq!(ta.to_bits(), tb.to_bits(), "1-node ag_gemm delegation must not drift");
}

#[test]
fn claim_gx1_rail_wins_and_analytic_chunk_tracks_swept() {
    // The cluster-GEMM exhibit in fast mode: on every multi-node row the
    // rail transport beats both the naive per-device transport and the
    // baseline extrapolation, the modeled NIC reduction is exactly xP,
    // and the analytic rdma_chunk sits within 10% of the swept optimum.
    let t = run_exhibit("gx1", true).unwrap();
    assert_eq!(
        t.columns,
        vec!["kernel", "nodes", "nic_GBps", "rail_ms", "naive_ms", "baseline_ms", "nic_x", "an_vs_swept"]
    );
    let mut multi_rows = 0;
    for r in &t.rows {
        let rail: f64 = r[3].parse().unwrap();
        let naive: f64 = r[4].parse().unwrap();
        let base: f64 = r[5].parse().unwrap();
        assert!(rail > 0.0 && naive > 0.0 && base > 0.0, "degenerate gx1 row: {r:?}");
        if r[1] == "1" {
            assert_eq!(r[3], r[4], "{}: 1-node transports coincide", r[0]);
            assert_eq!(r[6], "-");
            assert_eq!(r[7], "-");
            continue;
        }
        multi_rows += 1;
        assert!(rail < naive, "{} nodes={}: rail vs naive {rail} vs {naive}", r[0], r[1]);
        assert!(rail < base, "{} nodes={}: rail vs baseline {rail} vs {base}", r[0], r[1]);
        let x: f64 = r[6].parse().unwrap();
        assert_eq!(x, 8.0, "{}: NIC reduction is exactly xP", r[0]);
        let ratio: f64 = r[7].parse().unwrap();
        assert!(ratio <= 1.10, "{}: analytic within 10% of swept, got {ratio}", r[0]);
    }
    assert!(multi_rows >= 2, "gx1 fast mode must cover both kernels multi-node");
}

#[test]
fn claim_vx1_pk_overlap_wins_p99_at_saturating_load() {
    // The serving exhibit in fast mode: the same open-loop trace stepped
    // on PK-overlapped kernels vs the non-overlapped baseline. At the
    // saturating load point (1.2x the PK engine's probed capacity) the
    // cheaper overlapped steps must show up end-to-end: better p99
    // latency and higher delivered tokens/s, on every node count.
    let t = run_exhibit("vx1", true).unwrap();
    assert_eq!(
        t.columns,
        vec![
            "nodes",
            "proc",
            "load_x",
            "offered_rps",
            "pk_tok_s",
            "base_tok_s",
            "pk_p50_ms",
            "base_p50_ms",
            "pk_p99_ms",
            "base_p99_ms",
            "pk_goodput_rps",
            "base_goodput_rps",
        ]
    );
    let mut saturating_rows = 0;
    for r in &t.rows {
        let offered: f64 = r[3].parse().unwrap();
        let pk_tok: f64 = r[4].parse().unwrap();
        let base_tok: f64 = r[5].parse().unwrap();
        let pk_p99: f64 = r[8].parse().unwrap();
        let base_p99: f64 = r[9].parse().unwrap();
        assert!(offered > 0.0 && pk_tok > 0.0 && base_tok > 0.0, "degenerate vx1 row: {r:?}");
        assert!(pk_p99 > 0.0 && base_p99 > 0.0, "degenerate p99: {r:?}");
        if r[1] == "poisson" && r[2] == "1.2" {
            saturating_rows += 1;
            assert!(
                pk_p99 < base_p99,
                "nodes={}: PK must beat non-overlap on p99 at saturating load: {pk_p99} vs {base_p99}",
                r[0]
            );
            assert!(
                pk_tok >= base_tok,
                "nodes={}: PK must deliver at least the baseline's tokens/s: {pk_tok} vs {base_tok}",
                r[0]
            );
        }
    }
    assert!(saturating_rows >= 2, "vx1 fast mode must cover the saturating load on >= 2 node counts");
}

#[test]
fn claim_vx1_p99_ordering_holds_under_bursty_arrivals() {
    // Satellite of the serving exhibit's arrival-process axis: the PK
    // vs non-overlap p99 ordering is not an artifact of smooth Poisson
    // arrivals. Under 4x on/off bursts at saturating load — the regime
    // where queues actually build — overlapped steps must still deliver
    // the better tail on every node count, and burstiness must register
    // at all (a bursty trace that reproduces the Poisson numbers exactly
    // would mean the axis is wired to nothing).
    let t = run_exhibit("vx1", true).unwrap();
    let mut bursty_saturating = 0;
    let mut procs_differ = false;
    for r in &t.rows {
        if r[1] != "bursty" {
            continue;
        }
        let pk_p99: f64 = r[8].parse().unwrap();
        let base_p99: f64 = r[9].parse().unwrap();
        assert!(pk_p99 > 0.0 && base_p99 > 0.0, "degenerate bursty row: {r:?}");
        // the matching poisson row at the same (nodes, load)
        let twin = t
            .rows
            .iter()
            .find(|q| q[0] == r[0] && q[1] == "poisson" && q[2] == r[2])
            .expect("every bursty row has a poisson twin");
        if twin[8] != r[8] || twin[6] != r[6] {
            procs_differ = true;
        }
        if r[2] == "1.2" {
            bursty_saturating += 1;
            assert!(
                pk_p99 < base_p99,
                "nodes={}: p99 ordering must survive burstiness: {pk_p99} vs {base_p99}",
                r[0]
            );
        }
    }
    assert!(
        bursty_saturating >= 2,
        "vx1 fast mode must cover bursty saturating load on >= 2 node counts"
    );
    assert!(procs_differ, "bursty traces must not reproduce the Poisson latencies exactly");
}

#[test]
fn claim_partitioned_net_byte_identical_to_serial() {
    // The partitioned parallel FlowNet (per-node partitions + NIC
    // boundary, merged deterministically) must be an *invisible*
    // substitution on a real multi-node kernel: same total time to the
    // bit, same event count, same per-port byte accounting. Solver stats
    // are excluded by design — a decomposed net legitimately performs a
    // different number of (smaller) solves.
    use pk::exec::TimedExec;
    use pk::hw::ClusterSpec;
    use pk::kernels::collectives::{hier_all_reduce, ClusterCollCtx};
    use pk::plan::Plan;
    let cluster = ClusterSpec::hgx_h100_pod(2);
    let views = pk::baselines::phantom_replicas(cluster.total_devices(), 2048, 4096);
    let mut plan = Plan::new();
    hier_all_reduce(&mut plan, &ClusterCollCtx::new(&cluster, views));
    let serial = TimedExec::on_cluster(cluster.clone()).run(&plan);
    let part = TimedExec::on_cluster(cluster).with_partitioned_net().run(&plan);
    assert_eq!(
        serial.total_time.to_bits(),
        part.total_time.to_bits(),
        "partitioned total_time must be bit-identical: {} vs {}",
        serial.total_time,
        part.total_time
    );
    assert_eq!(serial.events, part.events, "event counts must match");
    assert_eq!(serial.port_bytes.len(), part.port_bytes.len());
    for (p, v) in &serial.port_bytes {
        let w = part.port_bytes.get(p).copied().unwrap_or(f64::NAN);
        assert_eq!(v.to_bits(), w.to_bits(), "port {p:?}: {v} vs {w}");
    }
}

#[test]
fn claim_fx1_degraded_rail_slowdown_bounded() {
    // The robustness exhibit's graceful-degradation claim: with one NIC
    // hard-failed, the health-masked rail schedules lose at most the
    // capacity of the dead link — slowdown <= P/(P-1) x healthy + 15%
    // tolerance — while the no-reroute ablations stall until the link
    // heals (4x their healthy makespan by construction). Jitter rows can
    // only slow things down (the lognormal factor is capped at 1).
    let t = run_exhibit("fx1", true).unwrap();
    assert_eq!(
        t.columns,
        vec!["axis", "case", "fault", "healthy", "degraded", "slow_x", "naive_deg", "naive_x"]
    );
    let p = 8.0; // devices per node on the hgx pod
    let bound = p / (p - 1.0) * 1.15;
    let mut nic_rows = 0;
    let mut jitter_rows = 0;
    let mut serve_rows = 0;
    for r in &t.rows {
        match r[0].as_str() {
            "nic_fail" => {
                nic_rows += 1;
                let slow: f64 = r[5].parse().unwrap();
                let naive_slow: f64 = r[7].parse().unwrap();
                assert!(
                    slow <= bound,
                    "{}: degraded-rail slowdown must stay within P/(P-1) + 15%: {slow} vs {bound}",
                    r[1]
                );
                assert!(
                    naive_slow >= 3.0,
                    "{}: the no-reroute ablation must stall until the heal: {naive_slow}",
                    r[1]
                );
                assert!(naive_slow > slow, "{}: reroute must beat stalling", r[1]);
            }
            "jitter" => {
                jitter_rows += 1;
                let slow: f64 = r[5].parse().unwrap();
                let naive_slow: f64 = r[7].parse().unwrap();
                assert!(slow >= 1.0 - 1e-9 && naive_slow >= 1.0 - 1e-9, "jitter only slows: {r:?}");
            }
            "serve" => {
                serve_rows += 1;
                let degraded: f64 = r[4].parse().unwrap();
                assert!(degraded > 0.0 && degraded.is_finite(), "degenerate serve row: {r:?}");
            }
            other => panic!("unknown fx1 axis {other}"),
        }
    }
    assert_eq!(nic_rows, 3, "fast mode: one failed-NIC row per kernel");
    assert_eq!(jitter_rows, 3, "fast mode: one jitter row per kernel");
    assert_eq!(serve_rows, 2, "goodput + p99 serving rows");
}

#[test]
fn claim_fx1_serve_loses_nothing_under_mid_trace_nic_outage() {
    // The serving half of the robustness claim, pinned directly on the
    // engine: a mid-trace hard outage on the decode node's NIC delays
    // KV transfers but loses and duplicates zero requests (run_detailed
    // asserts exactly-once completion internally), and the makespan must
    // cross the restore time because stalled transfers wait it out.
    use pk::hw::ClusterSpec;
    use pk::sim::fault::{FaultSpec, LinkFault};
    use pk::sim::serve::{self, KernelMode, ServeCfg, StepCostModel};
    use pk::sim::workload::{generate, ArrivalProcess, TraceCfg};
    let cost = StepCostModel { knots: vec![(0.0, 1e-5), (1024.0, 1e-4)], layers: 10 };
    let trace = generate(&TraceCfg::chat(ArrivalProcess::Poisson, 100.0, 150, 77));
    let cfg = ServeCfg::reference(ClusterSpec::hgx_h100_pod(2), KernelMode::PkOverlap);
    let (healthy, comps0) = serve::run_detailed(&cfg, &cost, &trace);
    assert_eq!(comps0.len(), trace.len());
    let mut faulted_cfg = cfg.clone();
    faulted_cfg.fault = Some(FaultSpec::seeded(7).with_nic_fault(LinkFault {
        device: 1,
        at: 0.25 * healthy.duration,
        frac: 0.0,
        restore_at: Some(1.5 * healthy.duration),
    }));
    let (faulted, comps) = serve::run_detailed(&faulted_cfg, &cost, &trace);
    assert_eq!(comps.len(), trace.len(), "no request lost or duplicated under the outage");
    for (c, r) in comps.iter().zip(trace.iter()) {
        assert_eq!(c.id, r.id, "completions cover exactly the trace ids");
        assert_eq!(c.output_tokens, r.output_tokens);
    }
    assert!(
        faulted.duration >= 1.5 * healthy.duration * (1.0 - 1e-9),
        "stalled KV transfers must push the makespan past the restore: {} vs healthy {}",
        faulted.duration,
        healthy.duration
    );
    assert!(faulted.latency_p99 >= healthy.latency_p99);
}

#[test]
fn claim_deprecated_builder_wrappers_bit_identical_to_buildctx() {
    // The api_redesign guarantee: every legacy `build_cluster*` free
    // function is a one-line wrapper over its kernel's `KernelBuild` spec
    // built against a `BuildCtx`, emitting the *same plan, bit for bit*
    // (Debug forms compare f64 fields at full round-trip precision).
    // Extends the 1-node delegation pins to the whole deprecated surface:
    // default path, explicit opts, and health-masked variants.
    use pk::hw::ClusterSpec;
    use pk::kernels::gemm_rs::{ClusterPath, Schedule};
    use pk::kernels::moe::{MoeCfg, MoeDispatch, MoeLayer, MoeSchedule, Routing};
    use pk::kernels::ring_attention::{ClusterRingAttnCfg, RingAttn};
    use pk::kernels::ulysses::{Ulysses, UlyssesCfg};
    use pk::kernels::{ag_gemm, gemm_ar, gemm_rs, moe, ring_attention, ulysses};
    use pk::kernels::{BuildCtx, GemmKernelCfg, KernelBuild};
    use pk::pk::rail::{RailHealth, DEFAULT_RDMA_CHUNK};
    use pk::pk::template::LcscOpts;

    let cluster = ClusterSpec::test_cluster(2, 2);
    let healthy = RailHealth::all_healthy(&cluster);
    let degraded = RailHealth::all_healthy(&cluster).fail_nic(1);
    let ctx = BuildCtx::new(&cluster, &healthy);
    let ctx_deg = BuildCtx::new(&cluster, &degraded);
    let pin = |name: &str, a: &pk::plan::Plan, b: &pk::plan::Plan| {
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "{name} wrapper drifted from the BuildCtx path");
    };

    let cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);

    // ---- gemm_rs: default, explicit path, health-masked
    let spec = gemm_rs::GemmRs {
        cfg: cfg.clone(),
        schedule: Schedule::IntraSm,
        path: ClusterPath::RailReduce,
    };
    pin(
        "gemm_rs::build_cluster",
        &gemm_rs::build_cluster(&cfg, &cluster, Schedule::IntraSm, None),
        &spec.build(&ctx, None),
    );
    pin(
        "gemm_rs::build_cluster_opts(Scatter)",
        &gemm_rs::build_cluster_opts(&cfg, &cluster, Schedule::IntraSm, ClusterPath::Scatter, None),
        &gemm_rs::GemmRs { cfg: cfg.clone(), schedule: Schedule::IntraSm, path: ClusterPath::Scatter }
            .build(&ctx, None),
    );
    pin(
        "gemm_rs::build_cluster_health",
        &gemm_rs::build_cluster_health(
            &cfg,
            &cluster,
            Schedule::IntraSm,
            ClusterPath::RailReduce,
            &degraded,
            None,
        ),
        &spec.build(&ctx_deg, None),
    );

    // ---- gemm_ar: default, health-masked
    let spec = gemm_ar::GemmAr {
        cfg: cfg.clone(),
        schedule: Schedule::IntraSm,
        path: ClusterPath::RailReduce,
    };
    pin(
        "gemm_ar::build_cluster",
        &gemm_ar::build_cluster(&cfg, &cluster, Schedule::IntraSm, None),
        &spec.build(&ctx, None),
    );
    pin(
        "gemm_ar::build_cluster_opts",
        &gemm_ar::build_cluster_opts(
            &cfg,
            &cluster,
            Schedule::IntraSm,
            ClusterPath::RailReduce,
            None,
        ),
        &spec.build(&ctx, None),
    );
    pin(
        "gemm_ar::build_cluster_health",
        &gemm_ar::build_cluster_health(
            &cfg,
            &cluster,
            Schedule::IntraSm,
            ClusterPath::RailReduce,
            &degraded,
            None,
        ),
        &spec.build(&ctx_deg, None),
    );

    // ---- ag_gemm: default, explicit path, health-masked
    let mut acfg = cfg.clone();
    acfg.opts.num_comm_sms = 8;
    let spec = ag_gemm::AgGemm { cfg: acfg.clone(), path: ClusterPath::RailReduce };
    pin(
        "ag_gemm::build_cluster",
        &ag_gemm::build_cluster(&acfg, &cluster, None),
        &spec.build(&ctx, None),
    );
    pin(
        "ag_gemm::build_cluster_opts(Scatter)",
        &ag_gemm::build_cluster_opts(&acfg, &cluster, ClusterPath::Scatter, None),
        &ag_gemm::AgGemm { cfg: acfg.clone(), path: ClusterPath::Scatter }.build(&ctx, None),
    );
    pin(
        "ag_gemm::build_cluster_health",
        &ag_gemm::build_cluster_health(&acfg, &cluster, ClusterPath::RailReduce, &degraded, None),
        &spec.build(&ctx_deg, None),
    );

    // ---- moe: dispatch + full layer, healthy and masked
    let mcfg = MoeCfg {
        node: cluster.node.clone(),
        tokens: 24,
        hidden: 8,
        h_expert: 4,
        n_experts: 8,
        top_k: 2,
        comm_sms: 8,
        rdma_chunk: DEFAULT_RDMA_CHUNK,
    };
    let routing = Routing::uniform(&mcfg, 7);
    let spec = MoeDispatch { cfg: mcfg.clone(), routing: &routing, schedule: MoeSchedule::Overlapped };
    pin(
        "moe::build_cluster",
        &moe::build_cluster(&mcfg, &cluster, &routing, MoeSchedule::Overlapped, None),
        &spec.build(&ctx, None),
    );
    pin(
        "moe::build_cluster_health",
        &moe::build_cluster_health(&mcfg, &cluster, &routing, MoeSchedule::Overlapped, &degraded, None),
        &spec.build(&ctx_deg, None),
    );
    let spec = MoeLayer { cfg: mcfg.clone(), routing: &routing, schedule: MoeSchedule::Overlapped };
    pin(
        "moe::build_cluster_layer",
        &moe::build_cluster_layer(&mcfg, &cluster, &routing, MoeSchedule::Overlapped, None),
        &spec.build(&ctx, None),
    );
    pin(
        "moe::build_cluster_layer_health",
        &moe::build_cluster_layer_health(
            &mcfg,
            &cluster,
            &routing,
            MoeSchedule::Overlapped,
            &degraded,
            None,
        ),
        &spec.build(&ctx_deg, None),
    );

    // ---- ulysses: cfg-knob chunk and ctx-override chunk
    let ucfg = UlyssesCfg {
        node: cluster.node.clone(),
        b: 2,
        h: 4,
        s: 8,
        d: 4,
        flash_util: 0.75,
        rdma_chunk: pk::pk::rail::RDMA_CHUNK_AUTO,
    };
    pin(
        "ulysses::build_cluster",
        &ulysses::build_cluster(&ucfg, &cluster),
        &Ulysses { cfg: ucfg.clone() }.build(&ctx, None),
    );
    pin(
        "ulysses::build_cluster_opts(chunk)",
        &ulysses::build_cluster_opts(&ucfg, &cluster, 4096.0),
        &Ulysses { cfg: ucfg.clone() }.build(&ctx.with_rdma_chunk(4096.0), None),
    );

    // ---- ring attention: cluster wrapper vs spec
    let rcfg = ClusterRingAttnCfg {
        cluster: cluster.clone(),
        b: 2,
        h: 2,
        s: 32,
        d: 8,
        opts: LcscOpts {
            num_comm_sms: 4,
            workers_per_device: 2,
            comm_workers_per_device: 1,
            pipeline_stages: 2,
        },
        flash_util: 0.75,
        rdma_chunk: pk::pk::rail::RDMA_CHUNK_AUTO,
    };
    pin(
        "ring_attention::build_cluster",
        &ring_attention::build_cluster(&rcfg, None),
        &RingAttn { cfg: rcfg.clone() }.build(&ctx, None),
    );
}
