//! Integration tests asserting the paper's headline *claims* hold in the
//! reproduction — the qualitative shape of every major result (DESIGN.md
//! §4's "expected shape" column). These run the same harness as
//! `cargo bench --bench figures`, in fast mode.

use pk::report::run_exhibit;

fn col(t: &pk::report::Table, name: &str) -> Vec<f64> {
    t.col_f64(name)
}

#[test]
fn claim_table1_ordering_ce_tma_reg() {
    let t = run_exhibit("tab1", true).unwrap();
    let h100: Vec<f64> = col(&t, "H100 GB/s");
    assert!(h100[0] > h100[1] && h100[1] > h100[2], "CE > TMA > Reg: {h100:?}");
    // Table 1 values within 2%
    for (got, want) in h100.iter().zip([368.82, 350.01, 342.68]) {
        assert!((got - want).abs() / want < 0.02, "{got} vs {want}");
    }
}

#[test]
fn claim_fig2_ce_needs_large_messages() {
    let t = run_exhibit("fig2", true).unwrap();
    let msgs = col(&t, "msg_bytes");
    let ce = col(&t, "copy_engine");
    let tma = col(&t, "tma");
    for i in 0..msgs.len() {
        if msgs[i] <= 65536.0 {
            assert!(tma[i] > ce[i] * 2.0, "device-initiated wins small messages");
        }
        if msgs[i] >= 256e6 {
            assert!(ce[i] >= 0.80, "CE >= 80% at >= 256MB");
        }
    }
}

#[test]
fn claim_fig3_saturation_points() {
    let t = run_exhibit("fig3", true).unwrap();
    let sms = col(&t, "sms");
    let tma = col(&t, "tma");
    let reg = col(&t, "reg");
    for i in 0..sms.len() {
        if sms[i] as u32 == 15 {
            assert!(tma[i] >= 0.77, "TMA saturated by 15 SMs: {}", tma[i]);
        }
        if sms[i] as u32 == 76 {
            assert!(reg[i] >= 0.75, "reg saturated by 76 SMs: {}", reg[i]);
        }
        if sms[i] as u32 == 15 {
            assert!(reg[i] < 0.2, "reg far from saturation at 15 SMs");
        }
    }
}

#[test]
fn claim_fig4_schedule_tradeoffs() {
    let t = run_exhibit("fig4", true).unwrap();
    // rows: RS-intra, RS-inter, AR-intra, AR-inter
    let tf = col(&t, "tflops");
    let rs_ratio = tf[0] / tf[1];
    assert!(rs_ratio > 1.05 && rs_ratio < 1.5, "RS: intra ~1.2x inter, got {rs_ratio}");
    let ar_ratio = tf[3] / tf[2];
    assert!(ar_ratio > 2.5 && ar_ratio < 5.0, "AR: inter ~3.62x intra, got {ar_ratio}");
}

#[test]
fn claim_tab3_comm_hidden_past_k_threshold() {
    let t = run_exhibit("tab3", true).unwrap();
    let ks = col(&t, "K");
    let ratios: Vec<f64> = t
        .rows
        .iter()
        .map(|r| r[3].trim_end_matches('%').parse::<f64>().unwrap())
        .collect();
    for (k, ratio) in ks.iter().zip(&ratios) {
        if *k <= 1024.0 {
            assert!(*ratio > 40.0, "K={k}: comm dominates, got {ratio}%");
        }
        if *k >= 4096.0 {
            assert!(*ratio < 10.0, "K={k}: comm hidden past sR/2B ~ 2197, got {ratio}%");
        }
    }
}

#[test]
fn claim_fig6_pk_ar_up_to_1_79x_nccl() {
    let t = run_exhibit("fig6", true).unwrap();
    let sp = col(&t, "speedup");
    assert!(sp.iter().all(|s| *s > 1.0), "PK always wins: {sp:?}");
    assert!(sp.iter().any(|s| *s > 1.2), "meaningful gap somewhere: {sp:?}");
    assert!(sp.iter().all(|s| *s < 2.2), "bounded (paper: up to 1.79x): {sp:?}");
}

#[test]
fn claim_fig8_pk_geq_flux_and_nonoverlap() {
    let t = run_exhibit("fig8", true).unwrap();
    let pk = col(&t, "pk");
    let nonov = col(&t, "nonoverlap");
    let flux = col(&t, "flux");
    for i in 0..pk.len() {
        assert!(pk[i] > nonov[i], "PK beats non-overlap");
        assert!(pk[i] >= flux[i] * 0.95, "PK >= ~Flux (0.97-2.33x band)");
    }
}

#[test]
fn claim_fig9_pk_dominates_gemm_ar() {
    let t = run_exhibit("fig9", true).unwrap();
    let pk = col(&t, "pk");
    let nonov = col(&t, "nonoverlap");
    let td = col(&t, "triton_dist");
    for i in 0..pk.len() {
        assert!(pk[i] > nonov[i] && pk[i] > td[i], "PK wins GEMM+AR everywhere");
    }
}

#[test]
fn claim_fig11_modest_ulysses_gap() {
    let t = run_exhibit("fig11", true).unwrap();
    let sp = col(&t, "speedup");
    for s in &sp {
        assert!(*s >= 1.0 && *s <= 1.8, "PK 1.01-1.39x band-ish: {sp:?}");
    }
}

#[test]
fn claim_fig12_pk_comet_parity() {
    let t = run_exhibit("fig12", true).unwrap();
    let r = col(&t, "pk_vs_comet");
    for v in &r {
        assert!(*v > 0.8 && *v < 1.45, "PK 0.92-1.22x of Comet band-ish: {r:?}");
    }
}

#[test]
fn claim_fig13_b200_same_ordering() {
    let t = run_exhibit("fig13", true).unwrap();
    let pk = col(&t, "pk");
    let nonov = col(&t, "nonoverlap");
    for i in 0..pk.len() {
        assert!(pk[i] > nonov[i], "B200 preserves the ordering");
    }
    // B200 absolute throughput exceeds H100's fig8 at the same N
    let h = run_exhibit("fig8", true).unwrap();
    assert!(pk[pk.len() - 1] > col(&h, "pk")[h.rows.len() - 1]);
}

#[test]
fn claim_fig15_16_17_tensor_dim_wins() {
    for id in ["fig15", "fig16", "fig17"] {
        let t = run_exhibit(id, true).unwrap();
        let sp = col(&t, "speedup");
        for s in &sp {
            assert!(*s > 1.0, "{id}: PK beats NCCL+reshape: {sp:?}");
        }
    }
}

#[test]
fn claim_mu1_sync_costs() {
    let t = run_exhibit("mu1", true).unwrap();
    let lat = col(&t, "latency_ns");
    assert_eq!(lat[0], 64.0, "mbarrier 64 ns");
    assert_eq!(lat[1], 832.0, "HBM sync 832 ns");
}

#[test]
fn claim_mu2_nvshmem_tax() {
    let t = run_exhibit("mu2", true).unwrap();
    let lat = col(&t, "elementwise_latency_us");
    assert!((lat[0] / lat[1] - 4.5).abs() < 1e-6, "4.5x latency tax");
    let bw = col(&t, "bandwidth_GBps");
    assert!((bw[1] - bw[0] - 20.0).abs() < 0.5, "~20 GB/s bandwidth tax");
}

#[test]
fn claim_fig5_partition_matters() {
    let t = run_exhibit("fig5", true).unwrap();
    // for the large problem, too many comm SMs must hurt
    let rows: Vec<(f64, f64, f64)> = t
        .rows
        .iter()
        .map(|r| (r[0].parse().unwrap(), r[1].parse().unwrap(), r[2].parse().unwrap()))
        .collect();
    let big_small_sms = rows.iter().find(|(n, c, _)| *n == 32768.0 && *c == 8.0).unwrap().2;
    let big_many_sms = rows.iter().find(|(n, c, _)| *n == 32768.0 && *c == 32.0).unwrap().2;
    assert!(big_many_sms >= big_small_sms, "more comm SMs slow the large problem");
}
