//! Property-based invariants across the simulator and the kernels
//! (DESIGN.md §6): fair-share feasibility, collective semantics over random
//! shapes and device counts, token conservation, interleaving robustness,
//! and byte conservation in the timed executor.

use pk::exec::{FunctionalExec, TimedExec};
use pk::hw::spec::NodeSpec;
use pk::hw::topology::Port;
use pk::hw::{ClusterSpec, DeviceId};
use pk::kernels::collectives::{pk_all_gather, pk_all_reduce, pk_reduce_scatter, Axis, PkCollCtx};
use pk::kernels::moe::{MoeCfg, Routing};
use pk::mem::tile::Shape4;
use pk::mem::MemPool;
use pk::plan::{MatView, Op, Plan, Role, SyncScope, TransferSpec};
use pk::sim::flownet::{compute_rates, FlowSpec};
use pk::util::prop::{run_prop, Rng};
use pk::xfer::Mechanism;
use std::collections::HashMap;

/// Max-min fair allocation: feasibility, cap-respect, and the bottleneck
/// property (every flow is limited by its cap or by a saturated port).
#[test]
fn prop_fair_share_feasible_and_pareto() {
    run_prop("fair_share", 200, |rng| {
        let n_dev = rng.usize_in(2, 9);
        let n_flows = rng.usize_in(1, 40);
        let mut caps = HashMap::new();
        for d in 0..n_dev {
            caps.insert(Port::Egress(DeviceId(d)), 100.0 + 400.0 * rng.f64());
            caps.insert(Port::Ingress(DeviceId(d)), 100.0 + 400.0 * rng.f64());
        }
        let flows: Vec<FlowSpec> = (0..n_flows)
            .map(|_| {
                let src = rng.usize_in(0, n_dev);
                let mut dst = rng.usize_in(0, n_dev);
                if dst == src {
                    dst = (dst + 1) % n_dev;
                }
                FlowSpec {
                    active: rng.f64() > 0.1,
                    ports: vec![Port::Egress(DeviceId(src)), Port::Ingress(DeviceId(dst))],
                    cap: 10.0 + 500.0 * rng.f64(),
                }
            })
            .collect();
        let rates = compute_rates(&flows, &caps);
        // feasibility per port
        let mut port_load: HashMap<Port, f64> = HashMap::new();
        for (f, r) in flows.iter().zip(&rates) {
            if !f.active {
                if *r != 0.0 {
                    return Err("inactive flow got rate".into());
                }
                continue;
            }
            if *r > f.cap * (1.0 + 1e-9) {
                return Err(format!("rate {r} exceeds cap {}", f.cap));
            }
            if *r < 0.0 {
                return Err("negative rate".into());
            }
            for &p in &f.ports {
                *port_load.entry(p).or_insert(0.0) += r;
            }
        }
        for (p, load) in &port_load {
            let cap = caps[p];
            if *load > cap * (1.0 + 1e-6) {
                return Err(format!("port {p:?} overloaded: {load} > {cap}"));
            }
        }
        // bottleneck property
        for (f, r) in flows.iter().zip(&rates) {
            if !f.active {
                continue;
            }
            let capped = *r >= f.cap * (1.0 - 1e-9);
            let saturated = f.ports.iter().any(|p| port_load[p] >= caps[p] * (1.0 - 1e-6));
            if !capped && !saturated {
                return Err(format!("flow neither capped nor on a saturated port (rate {r})"));
            }
        }
        Ok(())
    });
}

/// PK all-reduce leaves the elementwise sum on every device, for random
/// shapes, device counts, and axes.
#[test]
fn prop_pk_all_reduce_is_sum() {
    run_prop("pk_all_reduce", 30, |rng| {
        let n = rng.usize_in(2, 9);
        let rows = n * rng.usize_in(1, 5);
        let cols = rng.usize_in(1, 12);
        let node = NodeSpec::test_node(n);
        let mut pool = MemPool::new();
        let mut bufs = vec![];
        let mut want = vec![0.0f32; rows * cols];
        for d in 0..n {
            let data = rng.vec_f32(rows * cols);
            for (w, v) in want.iter_mut().zip(&data) {
                *w += v;
            }
            bufs.push(pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), data));
        }
        let ctx = PkCollCtx::new(&node, bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect());
        let mut plan = Plan::new();
        pk_all_reduce(&mut plan, &ctx);
        FunctionalExec::new(&mut pool).run(&plan).map_err(|e| e.to_string())?;
        for &b in &bufs {
            for (g, w) in pool.get(b).data.iter().zip(&want) {
                if (g - w).abs() > 1e-4 {
                    return Err(format!("sum mismatch: {g} vs {w}"));
                }
            }
        }
        Ok(())
    });
}

/// All-gather then reduce-scatter on either axis preserves shard contents.
#[test]
fn prop_ag_rs_round_trip_semantics() {
    run_prop("ag_rs", 20, |rng| {
        let n = rng.usize_in(2, 7);
        let rows = n * rng.usize_in(1, 4);
        let cols = n * rng.usize_in(1, 4);
        let axis = *rng.choose(&[Axis::Row, Axis::Col]);
        let node = NodeSpec::test_node(n);
        let mut pool = MemPool::new();
        let global: Vec<f32> = rng.vec_f32(rows * cols);
        let mut bufs = vec![];
        for d in 0..n {
            // each device holds only its shard of the global tensor
            let mut data = vec![0.0f32; rows * cols];
            match axis {
                Axis::Row => {
                    let cr = rows / n;
                    data[d * cr * cols..(d + 1) * cr * cols]
                        .copy_from_slice(&global[d * cr * cols..(d + 1) * cr * cols]);
                }
                Axis::Col => {
                    let cc = cols / n;
                    for r in 0..rows {
                        for c in d * cc..(d + 1) * cc {
                            data[r * cols + c] = global[r * cols + c];
                        }
                    }
                }
            }
            bufs.push(pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), data));
        }
        let ctx = PkCollCtx::new(&node, bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect());
        let mut plan = Plan::new();
        pk_all_gather(&mut plan, &ctx, axis);
        FunctionalExec::new(&mut pool).run(&plan).map_err(|e| e.to_string())?;
        for &b in &bufs {
            if pool.get(b).data != global {
                return Err("all-gather did not reconstruct the global tensor".into());
            }
        }
        // reduce-scatter over the gathered replicas: shard d = n * global shard
        let ctx2 = PkCollCtx::new(&node, bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect());
        let mut plan2 = Plan::new();
        pk_reduce_scatter(&mut plan2, &ctx2, axis);
        FunctionalExec::new(&mut pool).run(&plan2).map_err(|e| e.to_string())?;
        let cr = rows / n;
        let cc = cols / n;
        for (d, &b) in bufs.iter().enumerate() {
            let data = &pool.get(b).data;
            let check = |r: usize, c: usize| -> Result<(), String> {
                let got = data[r * cols + c];
                let wanted = global[r * cols + c] * n as f32;
                if (got - wanted).abs() > 1e-4 {
                    return Err(format!("rs mismatch at ({r},{c}): {got} vs {wanted}"));
                }
                Ok(())
            };
            match axis {
                Axis::Row => {
                    for r in d * cr..(d + 1) * cr {
                        for c in 0..cols {
                            check(r, c)?;
                        }
                    }
                }
                Axis::Col => {
                    for r in 0..rows {
                        for c in d * cc..(d + 1) * cc {
                            check(r, c)?;
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// MoE routing: every routed token lands exactly once per chosen expert
/// (conservation), and counts() agrees with tokens_for().
#[test]
fn prop_moe_routing_conservation() {
    run_prop("moe_routing", 30, |rng| {
        let n_dev = rng.usize_in(2, 9);
        let cfg = MoeCfg {
            node: NodeSpec::test_node(n_dev),
            tokens: n_dev * rng.usize_in(2, 16),
            hidden: 8,
            h_expert: 8,
            n_experts: n_dev * rng.usize_in(1, 5),
            top_k: rng.usize_in(1, 4).min(n_dev),
            comm_sms: 8,
            rdma_chunk: pk::kernels::moe::DEFAULT_RDMA_CHUNK,
        };
        let routing = Routing::uniform(&cfg, rng.next_u64());
        let counts = routing.counts(cfg.n_experts);
        let total: u64 = counts.iter().sum();
        if total != (cfg.tokens * cfg.top_k) as u64 {
            return Err(format!("conservation: {total} != {}", cfg.tokens * cfg.top_k));
        }
        for e in 0..cfg.n_experts {
            if routing.tokens_for(e).len() as u64 != counts[e] {
                return Err("counts() disagrees with tokens_for()".into());
            }
        }
        Ok(())
    });
}

/// Functional execution must be interleaving-independent: the NCCL ring
/// all-reduce gives identical results under different worker rotations.
#[test]
fn prop_interleaving_independence() {
    run_prop("interleaving", 10, |rng| {
        let n = rng.usize_in(2, 6);
        let rows = n * 2;
        let cols = rng.usize_in(1, 6);
        let node = NodeSpec::test_node(n);
        let inits: Vec<Vec<f32>> = (0..n).map(|_| rng.vec_f32(rows * cols)).collect();
        let mut results = vec![];
        for rotation in [0usize, 1, 3] {
            let mut pool = MemPool::new();
            let bufs: Vec<_> = (0..n)
                .map(|d| pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), inits[d].clone()))
                .collect();
            let ctx = pk::comm::nccl::RingCtx {
                node: &node,
                model: pk::comm::nccl::NcclModel::default(),
                replicas: bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect(),
            };
            let mut plan = Plan::new();
            pk::comm::nccl::ring_all_reduce(&mut plan, &ctx);
            FunctionalExec::new(&mut pool).with_rotation(rotation).run(&plan).map_err(|e| e.to_string())?;
            results.push(pool.get(bufs[0]).data.clone());
        }
        if results[1] != results[0] || results[2] != results[0] {
            return Err("results depend on worker interleaving".into());
        }
        Ok(())
    });
}

/// Timed executor byte conservation: port byte counters equal the sum of
/// the plan's transfer bytes over the route's ports.
#[test]
fn prop_timed_byte_conservation() {
    run_prop("byte_conservation", 25, |rng| {
        let n = rng.usize_in(2, 9);
        let node = NodeSpec::test_node(n);
        let mut plan = Plan::new();
        let mut expect_egress = vec![0.0f64; n];
        let mut expect_ingress = vec![0.0f64; n];
        for d in 0..n {
            let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("w{d}"));
            for _ in 0..rng.usize_in(1, 6) {
                let mut dst = rng.usize_in(0, n);
                if dst == d {
                    dst = (dst + 1) % n;
                }
                let bytes = (rng.usize_in(1, 64) * 1024) as f64;
                expect_egress[d] += bytes;
                expect_ingress[dst] += bytes;
                plan.push(
                    w,
                    Op::Transfer {
                        spec: TransferSpec {
                            mech: Mechanism::Tma,
                            route: pk::plan::Route::P2p { src: DeviceId(d), dst: DeviceId(dst) },
                            bytes,
                            msg_bytes: 4096.0,
                            n_sms: 4.0,
                        },
                        blocking: true,
                        done_sem: None,
                        done_scope: SyncScope::IntraSm,
                        label: "prop_xfer",
                        effect: None,
                    },
                );
            }
        }
        let r = TimedExec::new(node).run(&plan);
        for d in 0..n {
            let got_e = r.port_bytes.get(&Port::Egress(DeviceId(d))).copied().unwrap_or(0.0);
            let got_i = r.port_bytes.get(&Port::Ingress(DeviceId(d))).copied().unwrap_or(0.0);
            if (got_e - expect_egress[d]).abs() > 1.0 || (got_i - expect_ingress[d]).abs() > 1.0 {
                return Err(format!(
                    "dev {d}: egress {got_e} vs {}, ingress {got_i} vs {}",
                    expect_egress[d], expect_ingress[d]
                ));
            }
        }
        if !(r.total_time.is_finite() && r.total_time > 0.0) {
            return Err("non-finite time".into());
        }
        Ok(())
    });
}

/// NIC byte conservation: transfers routed by locality charge exactly
/// their bytes to the endpoint NIC ports and nothing to NVLink ports (and
/// vice versa for intra-node transfers).
#[test]
fn prop_nic_byte_conservation() {
    run_prop("nic_byte_conservation", 25, |rng| {
        let k = rng.usize_in(2, 5);
        let p = rng.usize_in(2, 5);
        let cluster = ClusterSpec::test_cluster(k, p);
        let n = cluster.total_devices();
        let mut plan = Plan::new();
        let mut nic_egress = vec![0.0f64; n];
        let mut nic_ingress = vec![0.0f64; n];
        let mut nvl_egress = vec![0.0f64; n];
        for g in 0..n {
            let w = plan.add_worker(DeviceId(g), Role::CommSm, format!("w{g}"));
            for _ in 0..rng.usize_in(1, 5) {
                let mut dst = rng.usize_in(0, n);
                if dst == g {
                    dst = (dst + 1) % n;
                }
                let bytes = (rng.usize_in(1, 64) * 1024) as f64;
                let cross = !cluster.same_node(DeviceId(g), DeviceId(dst));
                let route = if cross {
                    nic_egress[g] += bytes;
                    nic_ingress[dst] += bytes;
                    pk::plan::Route::Rdma { src: DeviceId(g), dst: DeviceId(dst) }
                } else {
                    nvl_egress[g] += bytes;
                    pk::plan::Route::P2p { src: DeviceId(g), dst: DeviceId(dst) }
                };
                plan.push(
                    w,
                    Op::Transfer {
                        spec: TransferSpec { mech: Mechanism::Tma, route, bytes, msg_bytes: 8192.0, n_sms: 4.0 },
                        blocking: true,
                        done_sem: None,
                        done_scope: SyncScope::IntraSm,
                        label: "prop_routed",
                        effect: None,
                    },
                );
            }
        }
        let r = TimedExec::on_cluster(cluster).run(&plan);
        for g in 0..n {
            let ne = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
            let ni = r.port_bytes.get(&Port::NicIngress(DeviceId(g))).copied().unwrap_or(0.0);
            let ve = r.port_bytes.get(&Port::Egress(DeviceId(g))).copied().unwrap_or(0.0);
            if (ne - nic_egress[g]).abs() > 1.0 || (ni - nic_ingress[g]).abs() > 1.0 {
                return Err(format!("dev {g}: NIC {ne}/{ni} vs {}/{}", nic_egress[g], nic_ingress[g]));
            }
            if (ve - nvl_egress[g]).abs() > 1.0 {
                return Err(format!("dev {g}: NVLink egress {ve} vs {}", nvl_egress[g]));
            }
        }
        if !(r.total_time.is_finite() && r.total_time > 0.0) {
            return Err("non-finite time".into());
        }
        Ok(())
    });
}

/// Max-min fairness extends to NIC ports: mixed NVLink + NIC flows stay
/// feasible, cap-respecting, and bottlenecked (the new Port variants go
/// through the solver's class canonicalisation).
#[test]
fn prop_nic_fair_share() {
    run_prop("nic_fair_share", 100, |rng| {
        let n_dev = rng.usize_in(4, 17);
        let mut caps = HashMap::new();
        for d in 0..n_dev {
            caps.insert(Port::Egress(DeviceId(d)), 200.0 + 300.0 * rng.f64());
            caps.insert(Port::Ingress(DeviceId(d)), 200.0 + 300.0 * rng.f64());
            caps.insert(Port::NicEgress(DeviceId(d)), 20.0 + 80.0 * rng.f64());
            caps.insert(Port::NicIngress(DeviceId(d)), 20.0 + 80.0 * rng.f64());
        }
        let flows: Vec<FlowSpec> = (0..rng.usize_in(2, 40))
            .map(|_| {
                let src = rng.usize_in(0, n_dev);
                let mut dst = rng.usize_in(0, n_dev);
                if dst == src {
                    dst = (dst + 1) % n_dev;
                }
                let ports = if rng.f64() < 0.5 {
                    vec![Port::NicEgress(DeviceId(src)), Port::NicIngress(DeviceId(dst))]
                } else {
                    vec![Port::Egress(DeviceId(src)), Port::Ingress(DeviceId(dst))]
                };
                FlowSpec { active: true, ports, cap: 5.0 + 500.0 * rng.f64() }
            })
            .collect();
        let rates = compute_rates(&flows, &caps);
        let mut port_load: HashMap<Port, f64> = HashMap::new();
        for (f, r) in flows.iter().zip(&rates) {
            if *r > f.cap * (1.0 + 1e-9) || *r < 0.0 {
                return Err(format!("rate {r} outside [0, cap {}]", f.cap));
            }
            for &p in &f.ports {
                *port_load.entry(p).or_insert(0.0) += r;
            }
        }
        for (p, load) in &port_load {
            if *load > caps[p] * (1.0 + 1e-6) {
                return Err(format!("port {p:?} overloaded: {load} > {}", caps[p]));
            }
        }
        for (f, r) in flows.iter().zip(&rates) {
            let capped = *r >= f.cap * (1.0 - 1e-9);
            let saturated = f.ports.iter().any(|p| port_load[p] >= caps[p] * (1.0 - 1e-6));
            if !capped && !saturated {
                return Err(format!("flow neither capped nor on a saturated port (rate {r})"));
            }
        }
        Ok(())
    });
}

/// Timed RDMA throughput never exceeds the NIC bound: any number of
/// concurrent cross-node flows through one NIC deliver at most `nic_bw`
/// aggregate, and a single flow at most the RDMA curve's rate.
#[test]
fn prop_rdma_throughput_below_nic_bound() {
    run_prop("rdma_nic_bound", 20, |rng| {
        let k = rng.usize_in(2, 4);
        let p = rng.usize_in(2, 5);
        let nic_bw = (10.0 + 90.0 * rng.f64()) * 1e9;
        let cluster = ClusterSpec::test_cluster(k, p).with_nic_bw(nic_bw);
        let n = cluster.total_devices();
        // all senders target one NIC (device 0), from other nodes
        let n_senders = rng.usize_in(1, 6);
        let bytes = (rng.usize_in(8, 64) * 1024 * 1024) as f64;
        let msg = (rng.usize_in(4, 512) * 1024) as f64;
        let mut plan = Plan::new();
        for i in 0..n_senders {
            // any device on a node other than node 0
            let src = p + (i % (n - p));
            let w = plan.add_worker(DeviceId(src), Role::CommSm, format!("w{i}"));
            plan.push(
                w,
                Op::Transfer {
                    spec: TransferSpec {
                        mech: Mechanism::Tma,
                        route: pk::plan::Route::Rdma { src: DeviceId(src), dst: DeviceId(0) },
                        bytes,
                        msg_bytes: msg,
                        n_sms: 1.0,
                    },
                    blocking: true,
                    done_sem: None,
                    done_scope: SyncScope::IntraSm,
                    label: "rdma_flood",
                    effect: None,
                },
            );
        }
        let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
        let delivered = r.port_bytes[&Port::NicIngress(DeviceId(0))];
        let rate = delivered / r.total_time;
        if rate > nic_bw * (1.0 + 1e-6) {
            return Err(format!("aggregate {rate} exceeds NIC {nic_bw}"));
        }
        if n_senders == 1 {
            let curve = pk::xfer::curves::rdma_rate(&cluster, msg);
            // one flow can't beat its own curve (plus the flow-start latency
            // slack, which only slows it down)
            if rate > curve * (1.0 + 1e-6) {
                return Err(format!("single flow {rate} exceeds curve {curve}"));
            }
        }
        Ok(())
    });
}

/// Cluster MoE NIC byte conservation: under arbitrary routing tables the
/// timed per-rail dispatch charges each NIC exactly the aggregated bytes —
/// one copy of each distinct token per remote destination node on the
/// source's egress, and the matching rail-peer ingress on the other side.
#[test]
fn prop_cluster_moe_nic_byte_conservation() {
    use pk::kernels::moe::{self, MoeCfg, MoeSchedule, Routing, DEFAULT_RDMA_CHUNK};
    run_prop("cluster_moe_nic_bytes", 12, |rng| {
        let k = rng.usize_in(2, 4);
        let p = rng.usize_in(2, 4);
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let cfg = MoeCfg {
            node: NodeSpec::test_node(p),
            tokens: n * rng.usize_in(2, 8),
            hidden: 16,
            h_expert: 8,
            n_experts: n * rng.usize_in(1, 4),
            top_k: rng.usize_in(1, 4),
            comm_sms: 8,
            rdma_chunk: DEFAULT_RDMA_CHUNK,
        };
        let routing = Routing::uniform(&cfg, rng.next_u64());
        let plan = moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, None);
        let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
        if !(r.total_time.is_finite() && r.total_time > 0.0) {
            return Err("non-finite time".into());
        }
        let want = moe::nic_dispatch_bytes(&cfg, &cluster, &routing, true);
        for g in 0..n {
            let got = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
            if (got - want[g]).abs() > 1.0 {
                return Err(format!("dev {g}: NIC egress {got} vs {}", want[g]));
            }
        }
        // ingress: each device receives its rail peers' coalesced flows
        let tl = cfg.tokens_local_of(n);
        for g in 0..n {
            let my_node = g / p;
            let mut want_in = 0.0;
            for kn in 0..k {
                if kn == my_node {
                    continue;
                }
                let s = kn * p + g % p;
                let count = (0..tl)
                    .filter(|&lt| {
                        routing.experts[s * tl + lt]
                            .iter()
                            .any(|&e| cfg.expert_device_of(e, n) / p == my_node)
                    })
                    .count();
                want_in += count as f64 * cfg.token_bytes();
            }
            let got = r.port_bytes.get(&Port::NicIngress(DeviceId(g))).copied().unwrap_or(0.0);
            if (got - want_in).abs() > 1.0 {
                return Err(format!("dev {g}: NIC ingress {got} vs {want_in}"));
            }
        }
        Ok(())
    });
}

/// Cluster MoE functional conservation: every (expert, token) pair lands in
/// exactly its slot with the original row contents — no token is lost
/// crossing the rail, and the injective slot layout rules out duplication.
#[test]
fn prop_cluster_moe_no_token_loss_or_duplication() {
    use pk::kernels::moe::{self, MoeCfg, MoeClusterBufs, MoeSchedule, Routing, DEFAULT_RDMA_CHUNK};
    run_prop("cluster_moe_tokens", 8, |rng| {
        let k = rng.usize_in(2, 4);
        let p = rng.usize_in(2, 4);
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let cfg = MoeCfg {
            node: NodeSpec::test_node(p),
            tokens: n * rng.usize_in(2, 6),
            hidden: 8,
            h_expert: 4,
            n_experts: n * 2,
            top_k: rng.usize_in(1, 4),
            comm_sms: 8,
            rdma_chunk: DEFAULT_RDMA_CHUNK,
        };
        let routing = Routing::uniform(&cfg, rng.next_u64());
        let mut pool = MemPool::new();
        let bufs = MoeClusterBufs::alloc(&mut pool, &cfg, &cluster, &routing);
        let tl = cfg.tokens_local_of(n);
        let el = cfg.experts_local_of(n);
        for d in 0..n {
            pool.get_mut(bufs.moe.tokens[d]).data = pk::util::seeded_vec(d as u64 + 1, tl * cfg.hidden);
        }
        let plan = moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, Some(&bufs));
        FunctionalExec::new(&mut pool).run(&plan).map_err(|e| e.to_string())?;
        for e in 0..cfg.n_experts {
            let dev = cfg.expert_device_of(e, n);
            let le = e % el;
            for (slot, &t) in routing.tokens_for(e).iter().enumerate() {
                let src_dev = t / tl;
                let lt = t % tl;
                let want =
                    &pool.get(bufs.moe.tokens[src_dev]).data[lt * cfg.hidden..(lt + 1) * cfg.hidden];
                let ebuf = pool.get(bufs.moe.expert_in[dev]);
                let off = ebuf.shape.offset(le, 0, slot, 0);
                if &ebuf.data[off..off + cfg.hidden] != want {
                    return Err(format!("expert {e} slot {slot} (token {t}) mismatch"));
                }
            }
        }
        Ok(())
    });
}

/// On a cluster the Sequential schedule can never beat the Overlapped one:
/// both issue the identical dispatch flows; Sequential only adds upfront
/// waits before the expert GEMMs.
#[test]
fn prop_cluster_moe_sequential_geq_overlapped() {
    use pk::kernels::moe::{self, MoeCfg, MoeSchedule, Routing, DEFAULT_RDMA_CHUNK};
    run_prop("cluster_moe_seq_vs_ov", 6, |rng| {
        let k = rng.usize_in(2, 4);
        let p = 2;
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let cfg = MoeCfg {
            node: NodeSpec::test_node(p),
            tokens: n * 8 * rng.usize_in(1, 4),
            hidden: 64,
            h_expert: 32,
            n_experts: n * 2,
            top_k: 2,
            comm_sms: 8,
            rdma_chunk: DEFAULT_RDMA_CHUNK,
        };
        let routing = Routing::uniform(&cfg, rng.next_u64());
        let exec = TimedExec::on_cluster(cluster.clone());
        let t_ov = exec
            .run(&moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, None))
            .total_time;
        let t_seq = exec
            .run(&moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Sequential, None))
            .total_time;
        if t_seq < t_ov * (1.0 - 1e-9) {
            return Err(format!("Sequential ({t_seq}) must be >= Overlapped ({t_ov})"));
        }
        Ok(())
    });
}

/// Rail-reduced gemm_rs output is bit-identical to the naive per-device
/// scatter path: with integer-valued inputs (whose partial sums are exact
/// in f32 under any association), the node-local pre-reduce changes only
/// the summation tree — never the value.
#[test]
fn prop_gemm_rs_rail_reduce_bit_identical_to_scatter() {
    use pk::kernels::gemm_rs::{build_cluster_opts, ClusterPath, GemmRsBufs, Schedule};
    use pk::kernels::GemmKernelCfg;
    run_prop("gemm_rs_rail_vs_scatter", 6, |rng| {
        let k = rng.usize_in(2, 4);
        let p = 2;
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let m = n * 16 * rng.usize_in(1, 3);
        let cols = 16 * rng.usize_in(1, 3);
        let kdim = 8 * rng.usize_in(1, 3);
        let cfg = GemmKernelCfg::functional(cluster.node.clone(), m, cols, kdim);
        let mut results = vec![];
        for path in [ClusterPath::RailReduce, ClusterPath::Scatter] {
            let mut pool = MemPool::new();
            let bufs = GemmRsBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            for d in 0..n {
                // small-integer f32s: every sum is exactly representable
                pool.get_mut(bufs.gemm.a[d]).data =
                    (0..m * kdim).map(|i| ((i * 7 + d * 13) % 5) as f32 - 2.0).collect();
                pool.get_mut(bufs.gemm.b[d]).data =
                    (0..kdim * cols).map(|i| ((i * 11 + d * 3) % 7) as f32 - 3.0).collect();
            }
            let plan = build_cluster_opts(&cfg, &cluster, Schedule::IntraSm, path, Some(&bufs));
            FunctionalExec::new(&mut pool).run(&plan).map_err(|e| e.to_string())?;
            let mut out = vec![];
            for d in 0..n {
                out.extend_from_slice(&pool.get(bufs.out[d]).data);
            }
            results.push(out);
        }
        if results[0] != results[1] {
            return Err("rail-reduced output must be bit-identical to the scatter path".into());
        }
        Ok(())
    });
}

/// Graceful degradation: a gemm_rs plan built under a NIC health mask
/// (1–2 failed NICs, rail flows rerouted through healthy donors over
/// NVLink first) produces bit-identical reduced output to the healthy
/// schedule — only the transport moves, never the data — the rerouted
/// plan is `plan::verify`-clean, and the failed NICs carry zero bytes in
/// the timed run.
#[test]
fn prop_gemm_rs_degraded_rail_bit_identical_and_verify_clean() {
    use pk::kernels::gemm_rs::{build_cluster_health, ClusterPath, GemmRsBufs, Schedule};
    use pk::kernels::GemmKernelCfg;
    use pk::pk::rail::RailHealth;
    use pk::plan::verify::{verify, VerifyCtx};
    run_prop("gemm_rs_degraded_rail", 6, |rng| {
        let k = rng.usize_in(2, 3);
        let p = rng.usize_in(2, 3);
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let m = n * 16 * rng.usize_in(1, 2);
        let cols = 16 * rng.usize_in(1, 2);
        let kdim = 8 * rng.usize_in(1, 2);
        let cfg = GemmKernelCfg::functional(cluster.node.clone(), m, cols, kdim);
        // fail 1-2 NICs on distinct devices (never a whole node: p >= 2
        // and the second failure lands on a different node)
        let f1 = rng.usize_in(0, n - 1);
        let mut health = RailHealth::all_healthy(&cluster).fail_nic(f1);
        if rng.f64() < 0.5 {
            let other_node = (f1 / p + 1) % k;
            health = health.fail_nic(other_node * p + rng.usize_in(0, p - 1));
        }
        let failed = health.failed();
        let mut results = vec![];
        for mask in [RailHealth::all_healthy(&cluster), health] {
            let mut pool = MemPool::new();
            let bufs = GemmRsBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            for d in 0..n {
                // small-integer f32s: every sum is exactly representable,
                // so the value cannot depend on the summation tree
                pool.get_mut(bufs.gemm.a[d]).data =
                    (0..m * kdim).map(|i| ((i * 7 + d * 13) % 5) as f32 - 2.0).collect();
                pool.get_mut(bufs.gemm.b[d]).data =
                    (0..kdim * cols).map(|i| ((i * 11 + d * 3) % 7) as f32 - 3.0).collect();
            }
            let plan = build_cluster_health(
                &cfg,
                &cluster,
                Schedule::IntraSm,
                ClusterPath::RailReduce,
                &mask,
                Some(&bufs),
            );
            let ctx = VerifyCtx { pool: Some(&pool), devices_per_node: Some(p) };
            let report = verify(&plan, &ctx);
            if !report.is_clean() {
                return Err(format!(
                    "health-masked plan (failed {failed:?}) must verify clean:\n{}",
                    report.render()
                ));
            }
            FunctionalExec::new(&mut pool).run(&plan).map_err(|e| e.to_string())?;
            let mut out = vec![];
            for d in 0..n {
                out.extend_from_slice(&pool.get(bufs.out[d]).data);
            }
            results.push(out);
        }
        if results[0] != results[1] {
            return Err(format!(
                "degraded-rail output (failed {failed:?}) must be bit-identical to healthy"
            ));
        }
        // timed: the failed NICs carry nothing; their flows moved to donors
        let timed = build_cluster_health(
            &cfg,
            &cluster,
            Schedule::IntraSm,
            ClusterPath::RailReduce,
            &RailHealth::all_healthy(&cluster).fail_nic(failed[0]),
            None,
        );
        let r = TimedExec::on_cluster(cluster.clone()).run(&timed);
        if !(r.total_time.is_finite() && r.total_time > 0.0) {
            return Err("degraded timed run must finish".into());
        }
        let e = r.port_bytes.get(&Port::NicEgress(DeviceId(failed[0]))).copied().unwrap_or(0.0);
        let i = r.port_bytes.get(&Port::NicIngress(DeviceId(failed[0]))).copied().unwrap_or(0.0);
        if e != 0.0 || i != 0.0 {
            return Err(format!("failed NIC {} must carry zero bytes, got {e}/{i}", failed[0]));
        }
        Ok(())
    });
}

/// Two-level all-to-all NIC byte conservation under arbitrary shard
/// shapes: every device's NIC carries exactly the `(K-1)/K` share of its
/// exchange bytes in *each* direction, whatever the batch/sequence/head
/// shape and coalescing chunk — the rail flows neither lose nor duplicate
/// bytes, and the wave split always repartitions the payload exactly.
#[test]
fn prop_two_level_a2a_nic_byte_conservation() {
    use pk::kernels::collectives::{pk_all_to_all_4d_cluster, A2aCfg};
    run_prop("a2a_nic_bytes", 15, |rng| {
        let k = rng.usize_in(2, 5);
        let p = rng.usize_in(1, 5);
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let cfg = A2aCfg {
            b_dim: rng.usize_in(1, 4),
            s_local: rng.usize_in(1, 6),
            h: n * rng.usize_in(1, 4),
            d_head: 4 * rng.usize_in(1, 5),
        };
        let chunk = *rng.choose(&[2048.0, 65536.0, 4.0 * 1024.0 * 1024.0]);
        let mut plan = Plan::new();
        pk_all_to_all_4d_cluster(&mut plan, &cluster, &cfg, None, None, None, chunk, 8.0);
        let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
        if !(r.total_time.is_finite() && r.total_time > 0.0) {
            return Err("non-finite time".into());
        }
        let dev_bytes = (cfg.b_dim * cfg.s_local * cfg.h * cfg.d_head * 2) as f64;
        let want = dev_bytes * (k - 1) as f64 / k as f64;
        for g in 0..n {
            let e = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
            let i = r.port_bytes.get(&Port::NicIngress(DeviceId(g))).copied().unwrap_or(0.0);
            if (e - want).abs() > 1.0 || (i - want).abs() > 1.0 {
                return Err(format!("dev {g}: NIC {e}/{i} vs {want} (k={k} p={p})"));
            }
        }
        Ok(())
    });
}

/// GEMM+RS functional correctness over random shapes/device counts — both
/// schedules agree with the dense reference and with each other.
#[test]
fn prop_gemm_rs_schedules_agree() {
    use pk::kernels::gemm_rs::{build, GemmRsBufs, Schedule};
    use pk::kernels::GemmKernelCfg;
    run_prop("gemm_rs_schedules", 8, |rng| {
        let n = *rng.choose(&[2usize, 4]);
        let m = n * 16 * rng.usize_in(1, 3);
        let cols = 16 * rng.usize_in(1, 3);
        let k = 8 * rng.usize_in(1, 4);
        let node = NodeSpec::test_node(n);
        let mut results = vec![];
        for schedule in [Schedule::IntraSm, Schedule::InterSm] {
            let mut cfg = GemmKernelCfg::functional(node.clone(), m, cols, k);
            if schedule == Schedule::InterSm {
                cfg.opts.num_comm_sms = 8;
            }
            let mut pool = MemPool::new();
            let bufs = GemmRsBufs::alloc(&mut pool, &cfg);
            for d in 0..n {
                pool.get_mut(bufs.gemm.a[d]).data =
                    pk::util::seeded_vec(d as u64 + 1000, m * k);
                pool.get_mut(bufs.gemm.b[d]).data =
                    pk::util::seeded_vec(d as u64 + 2000, k * cols);
            }
            let plan = build(&cfg, schedule, Some(&bufs));
            FunctionalExec::new(&mut pool).run(&plan).map_err(|e| e.to_string())?;
            let mut out = vec![];
            for d in 0..n {
                out.extend_from_slice(&pool.get(bufs.out[d]).data);
            }
            results.push(out);
        }
        for (a, b) in results[0].iter().zip(&results[1]) {
            if (a - b).abs() > 1e-3 {
                return Err(format!("schedules disagree: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// The engine's incremental fair-share solver (route-class interning,
/// active list, memoized water-fill) must be **bit-identical** to the
/// retained naive reference under random flow churn: after every start
/// and every completion batch, each live flow's rate has the same f64
/// bits as `compute_rates` run from scratch on a mirror of the flow
/// population (dead slots inactive), and completions come out in
/// ascending slot order (the order the scheduler's event sequencing
/// depends on).
#[test]
fn prop_incremental_solver_bit_identical_to_naive() {
    use pk::sim::flownet::FlowNet;
    run_prop("incremental_vs_naive", 120, |rng| {
        let n_dev = rng.usize_in(2, 6);
        let mut net = FlowNet::new();
        let mut caps = HashMap::new();
        for d in 0..n_dev {
            for p in [Port::Egress(DeviceId(d)), Port::Ingress(DeviceId(d)), Port::Hbm(DeviceId(d))]
            {
                let c = 50.0 + 450.0 * rng.f64();
                net.set_capacity(p, c);
                caps.insert(p, c);
            }
        }
        // mirror of the net's slot table for the naive reference
        let mut specs: Vec<FlowSpec> = vec![];
        let mut live: Vec<pk::sim::flownet::FlowId> = vec![];
        // small pools so route classes recur and the memo actually serves
        // repeated multisets (a cache hit must still match the reference)
        let cap_pool = [40.0, 120.0, 333.25];
        let check = |net: &mut FlowNet, specs: &[FlowSpec], live: &[pk::sim::flownet::FlowId]| {
            let want = compute_rates(specs, &caps);
            for &id in live {
                let got = net.rate(id);
                if got.to_bits() != want[id.0].to_bits() {
                    return Err(format!(
                        "slot {}: incremental {got:e} != naive {:e}",
                        id.0, want[id.0]
                    ));
                }
            }
            Ok(())
        };
        for _ in 0..rng.usize_in(10, 50) {
            if live.is_empty() || rng.f64() < 0.55 {
                // start a flow over a random (often repeated) route
                let src = rng.usize_in(0, n_dev);
                let mut dst = rng.usize_in(0, n_dev);
                if dst == src {
                    dst = (dst + 1) % n_dev;
                }
                let ports = match rng.usize_in(0, 3) {
                    0 => vec![Port::Egress(DeviceId(src)), Port::Ingress(DeviceId(dst))],
                    1 => vec![Port::Ingress(DeviceId(dst)), Port::Egress(DeviceId(src))],
                    _ => vec![Port::Hbm(DeviceId(src))],
                };
                let cap = *rng.choose(&cap_pool);
                let bytes = 10.0 + 1000.0 * rng.f64();
                let id = net.start(bytes, ports.clone(), cap);
                let spec = FlowSpec { active: true, ports, cap };
                if id.0 == specs.len() {
                    specs.push(spec);
                } else {
                    specs[id.0] = spec;
                }
                live.push(id);
            } else {
                // advance to (or part-way to) the next completion
                let dt = net.next_completion().expect("live flows must progress");
                let frac = *rng.choose(&[1.0, 1.0, 0.5]);
                let done = net.advance(dt * frac).to_vec();
                for w in done.windows(2) {
                    if w[0].0 >= w[1].0 {
                        return Err(format!("completions out of slot order: {done:?}"));
                    }
                }
                for d in &done {
                    specs[d.0].active = false;
                    live.retain(|id| id != d);
                }
            }
            check(&mut net, &specs, &live)?;
        }
        Ok(())
    });
}

/// Lockstep rate-bit comparison for `prop_heap_engine_bit_identical_to_scan`.
fn continue_checks(
    scan: &mut pk::sim::flownet::FlowNet,
    heap: &mut pk::sim::flownet::FlowNet,
    live: &[pk::sim::flownet::FlowId],
) -> Result<(), String> {
    for &id in live {
        let (rs, rh) = (scan.rate(id), heap.rate(id));
        if rs.to_bits() != rh.to_bits() {
            return Err(format!("rate diverged on slot {}: {rs:e} vs {rh:e}", id.0));
        }
    }
    Ok(())
}

/// The epoch-keyed completion-heap engine must be **bit-identical** to
/// the retained scan reference under random churn: same next-completion
/// bits, same completion batches (same slots, same order), same per-flow
/// rate bits, and the same number of dirty solves — across starts,
/// partial/overshooting advances, and live capacity reconfiguration
/// (which invalidates heap entries via the lazy seq bump). The
/// reconfiguration mix includes **failure-shaped schedules**: capacity
/// drops to a degraded fraction, hard drops to exactly 0.0 (flows stall;
/// both engines must report `next_completion = None`), and later
/// restores — the churn pattern fault injection (`sim::fault`) leans on.
/// Mirrors the pure-Python protocol model in
/// `python/tests/test_des_engine_model.py`.
#[test]
fn prop_heap_engine_bit_identical_to_scan() {
    use pk::sim::flownet::{Engine, FlowNet};
    run_prop("heap_vs_scan", 100, |rng| {
        let n_dev = rng.usize_in(2, 6);
        let mut scan = FlowNet::with_engine(Engine::Scan);
        let mut heap = FlowNet::with_engine(Engine::Heap);
        let mut ports_used = vec![];
        for d in 0..n_dev {
            for p in [Port::Egress(DeviceId(d)), Port::Ingress(DeviceId(d)), Port::Hbm(DeviceId(d))]
            {
                let c = 50.0 + 450.0 * rng.f64();
                scan.set_capacity(p, c);
                heap.set_capacity(p, c);
                ports_used.push(p);
            }
        }
        let cap_pool = [40.0, 120.0, 333.25];
        let mut live: Vec<pk::sim::flownet::FlowId> = vec![];
        let mut failed: Vec<Port> = vec![];
        for _ in 0..rng.usize_in(20, 70) {
            let roll = rng.f64();
            if live.is_empty() || roll < 0.45 {
                let src = rng.usize_in(0, n_dev);
                let mut dst = rng.usize_in(0, n_dev);
                if dst == src {
                    dst = (dst + 1) % n_dev;
                }
                let ports = match rng.usize_in(0, 3) {
                    0 => vec![Port::Egress(DeviceId(src)), Port::Ingress(DeviceId(dst))],
                    1 => vec![Port::Ingress(DeviceId(dst)), Port::Egress(DeviceId(src))],
                    _ => vec![Port::Hbm(DeviceId(src))],
                };
                let cap = *rng.choose(&cap_pool);
                let bytes = 10.0 + 1000.0 * rng.f64();
                let a = scan.start(bytes, ports.clone(), cap);
                let b = heap.start(bytes, ports, cap);
                if a != b {
                    return Err(format!("slot allocation diverged: {a:?} vs {b:?}"));
                }
                live.push(a);
            } else if roll < 0.58 {
                // live reconfiguration: old heap entries go stale and the
                // next solve must re-key exactly the flows whose rate
                // bits change. The mix includes failure shapes: degrade
                // to a small fraction, fail hard to 0.0, restore a
                // previously failed port.
                let p = *rng.choose(&ports_used);
                let c = match rng.usize_in(0, 4) {
                    0 => 50.0 + 450.0 * rng.f64(),  // plain reconfig
                    1 => 5.0 + 20.0 * rng.f64(),    // degraded link
                    2 => 0.0,                       // hard failure
                    _ => {
                        // restore a failed port (or reconfig if none)
                        if let Some(q) = failed.pop() {
                            let c = 50.0 + 450.0 * rng.f64();
                            scan.set_capacity(q, c);
                            heap.set_capacity(q, c);
                            continue_checks(&mut scan, &mut heap, &live)?;
                            continue;
                        }
                        50.0 + 450.0 * rng.f64()
                    }
                };
                if c == 0.0 {
                    failed.push(p);
                }
                scan.set_capacity(p, c);
                heap.set_capacity(p, c);
            } else {
                match (scan.next_completion(), heap.next_completion()) {
                    (None, None) => {
                        // every live flow stalled on a failed port: both
                        // engines must agree, and time passing must move
                        // no bytes — then restore a port to resume.
                        let done_s = scan.advance(1.0).to_vec();
                        let done_h = heap.advance(1.0).to_vec();
                        if !done_s.is_empty() || !done_h.is_empty() {
                            return Err(format!(
                                "stalled nets completed flows: {done_s:?} vs {done_h:?}"
                            ));
                        }
                        let q = failed.pop().expect("all-stalled requires a failed port");
                        let c = 50.0 + 450.0 * rng.f64();
                        scan.set_capacity(q, c);
                        heap.set_capacity(q, c);
                    }
                    (Some(a), Some(b)) => {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!("next_completion diverged: {a:e} vs {b:e}"));
                        }
                        let frac = *rng.choose(&[1.0, 1.0, 1.0, 0.5, 0.25, 1.25]);
                        let done_s = scan.advance(a * frac).to_vec();
                        let done_h = heap.advance(a * frac).to_vec();
                        if done_s != done_h {
                            return Err(format!("completions diverged: {done_s:?} vs {done_h:?}"));
                        }
                        for d in &done_s {
                            live.retain(|id| id != d);
                        }
                    }
                    other => return Err(format!("stall detection diverged: {other:?}")),
                }
            }
            continue_checks(&mut scan, &mut heap, &live)?;
        }
        // restore every failed port so the drain can finish, then drain
        // both to empty: the batches must mirror to the end
        for q in failed.drain(..) {
            scan.set_capacity(q, 200.0);
            heap.set_capacity(q, 200.0);
        }
        while scan.n_active() > 0 {
            let a = scan.next_completion().expect("scan must drain");
            let b = heap.next_completion().expect("heap must drain");
            if a.to_bits() != b.to_bits() {
                return Err(format!("drain next_completion diverged: {a:e} vs {b:e}"));
            }
            let done_s = scan.advance(a).to_vec();
            let done_h = heap.advance(a).to_vec();
            if done_s != done_h {
                return Err(format!("drain completions diverged: {done_s:?} vs {done_h:?}"));
            }
        }
        if heap.n_active() != 0 {
            return Err(format!("heap retains {} flows after drain", heap.n_active()));
        }
        // lockstep drivers must have triggered the same dirty solves
        let (ss, hs) = (scan.solver_stats(), heap.solver_stats());
        if ss.solves != hs.solves || ss.memo_hits != hs.memo_hits {
            return Err(format!("solver stats diverged: {ss:?} vs {hs:?}"));
        }
        Ok(())
    });
}

/// The cluster GEMM+AR's hierarchical transport is numerically invisible:
/// the rail (pre-reduce → coalesced store-add → broadcast-back) replicas
/// are bit-identical to the naive per-device scatter path and to the
/// dense all-reduce reference (integer-valued f32s — every sum is exact
/// whatever the summation tree), over random (K, P, shape) combinations.
#[test]
fn prop_gemm_ar_cluster_paths_bit_identical_and_correct() {
    use pk::kernels::gemm_ar::{build_cluster_opts, ClusterPath, GemmArBufs, Schedule};
    use pk::kernels::GemmKernelCfg;
    use pk::util::linalg;
    run_prop("gemm_ar_cluster", 6, |rng| {
        let k = rng.usize_in(2, 3);
        let p = 2;
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let m = n * 16 * rng.usize_in(1, 2);
        let cols = 16 * rng.usize_in(1, 2);
        let kdim = 8 * rng.usize_in(1, 2);
        let cfg = GemmKernelCfg::functional(cluster.node.clone(), m, cols, kdim);
        let mut want: Vec<f32> = vec![];
        for path in [ClusterPath::RailReduce, ClusterPath::Scatter] {
            let mut pool = MemPool::new();
            let bufs = GemmArBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            for d in 0..n {
                pool.get_mut(bufs.gemm.a[d]).data =
                    (0..m * kdim).map(|i| ((i * 7 + d * 13) % 5) as f32 - 2.0).collect();
                pool.get_mut(bufs.gemm.b[d]).data =
                    (0..kdim * cols).map(|i| ((i * 11 + d * 3) % 7) as f32 - 3.0).collect();
            }
            if want.is_empty() {
                // dense reference: sum of every device's partial product
                want = vec![0.0f32; m * cols];
                for d in 0..n {
                    let prod = linalg::matmul(
                        &pool.get(bufs.gemm.a[d]).data,
                        &pool.get(bufs.gemm.b[d]).data,
                        m,
                        cols,
                        kdim,
                    );
                    for (f, pv) in want.iter_mut().zip(prod) {
                        *f += pv;
                    }
                }
            }
            let plan = build_cluster_opts(&cfg, &cluster, Schedule::IntraSm, path, Some(&bufs));
            FunctionalExec::new(&mut pool).run(&plan).map_err(|e| e.to_string())?;
            for d in 0..n {
                if pool.get(bufs.out[d]).data != want {
                    return Err(format!("device {d} replica diverges on {path:?}"));
                }
            }
        }
        Ok(())
    });
}

/// Cluster AG+GEMM gathers exactly: every device ends with the bitwise
/// global `A` (own shard + NVLink multicast + rail stage + forwarder
/// fan-out) and the bitwise `full_A @ B_d` output, on both transports,
/// over random (K, P, shape) combinations.
#[test]
fn prop_ag_gemm_cluster_gathers_exactly() {
    use pk::kernels::ag_gemm::{build_cluster_opts, AgGemmBufs, ClusterPath};
    use pk::kernels::GemmKernelCfg;
    use pk::util::linalg;
    run_prop("ag_gemm_cluster", 6, |rng| {
        let k = rng.usize_in(2, 3);
        let p = rng.usize_in(1, 3);
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let m = n * 16 * rng.usize_in(1, 2);
        let cols = 16;
        let kdim = 8 * rng.usize_in(1, 2);
        let mut cfg = GemmKernelCfg::functional(cluster.node.clone(), m, cols, kdim);
        cfg.opts.num_comm_sms = 8;
        for path in [ClusterPath::RailReduce, ClusterPath::Scatter] {
            let mut pool = MemPool::new();
            let bufs = AgGemmBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            let a_global: Vec<f32> = (0..m * kdim).map(|i| ((i * 5) % 9) as f32 - 4.0).collect();
            let shard = m / n;
            for d in 0..n {
                let (s, e) = (d * shard * kdim, (d + 1) * shard * kdim);
                pool.get_mut(bufs.a[d]).data[s..e].copy_from_slice(&a_global[s..e]);
                pool.get_mut(bufs.b[d]).data =
                    (0..kdim * cols).map(|i| ((i * 3 + d) % 7) as f32 - 3.0).collect();
            }
            let plan = build_cluster_opts(&cfg, &cluster, path, Some(&bufs));
            FunctionalExec::new(&mut pool).run(&plan).map_err(|e| e.to_string())?;
            for d in 0..n {
                if pool.get(bufs.a[d]).data != a_global {
                    return Err(format!("{path:?}: device {d} did not gather A exactly"));
                }
                let want = linalg::matmul(&a_global, &pool.get(bufs.b[d]).data, m, cols, kdim);
                if pool.get(bufs.c[d]).data != want {
                    return Err(format!("{path:?}: device {d} output mismatch"));
                }
            }
        }
        Ok(())
    });
}

/// NIC byte conservation for the two new cluster kernels: on the rail
/// path every device's NIC egress equals its ingress and both match the
/// modeled accounting ([`gemm_ar::nic_ar_bytes`], [`ag_gemm::nic_ag_bytes`])
/// exactly, across random pod shapes — the wave split neither loses nor
/// duplicates bytes.
#[test]
fn prop_cluster_gemm_family_nic_byte_conservation() {
    use pk::kernels::gemm_rs::Schedule;
    use pk::kernels::{ag_gemm, gemm_ar, GemmKernelCfg};
    run_prop("gemm_family_nic", 5, |rng| {
        let k = rng.usize_in(2, 4);
        let p = rng.usize_in(2, 4);
        let n = k * p;
        let cluster = ClusterSpec::test_cluster(k, p);
        let m = 128 * n * rng.usize_in(1, 2);
        let cfg = GemmKernelCfg::new(cluster.node.clone(), m, 256, 512);
        let exec = TimedExec::on_cluster(cluster.clone());
        // gemm_ar rail
        let plan = gemm_ar::build_cluster(&cfg, &cluster, Schedule::InterSm, None);
        let r = exec.run(&plan);
        let want = gemm_ar::nic_ar_bytes(&cfg, &cluster, gemm_ar::ClusterPath::RailReduce);
        for g in 0..n {
            let e = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
            let i = r.port_bytes.get(&Port::NicIngress(DeviceId(g))).copied().unwrap_or(0.0);
            if (e - want[g]).abs() / want[g] > 1e-6 || (i - want[g]).abs() / want[g] > 1e-6 {
                return Err(format!("gemm_ar dev {g}: NIC {e}/{i} vs {} (k={k} p={p})", want[g]));
            }
        }
        // ag_gemm rail
        let plan = ag_gemm::build_cluster(&cfg, &cluster, None);
        let r = exec.run(&plan);
        let want = ag_gemm::nic_ag_bytes(&cfg, &cluster, ag_gemm::ClusterPath::RailReduce);
        for g in 0..n {
            let e = r.port_bytes.get(&Port::NicEgress(DeviceId(g))).copied().unwrap_or(0.0);
            let i = r.port_bytes.get(&Port::NicIngress(DeviceId(g))).copied().unwrap_or(0.0);
            if (e - want[g]).abs() / want[g] > 1e-6 || (i - want[g]).abs() / want[g] > 1e-6 {
                return Err(format!("ag_gemm dev {g}: NIC {e}/{i} vs {} (k={k} p={p})", want[g]));
            }
        }
        Ok(())
    });
}

/// The analytic `rdma_chunk` policy tracks the swept optimum within a
/// fixed tolerance across the NIC grid (25–100 GB/s) — the acceptance
/// bar for making the closed form the default and demoting the chunk
/// sweep to an ablation/validation path.
#[test]
fn prop_analytic_rdma_chunk_within_tolerance_of_swept() {
    use pk::kernels::gemm_rs::{build_cluster, Schedule};
    use pk::kernels::GemmKernelCfg;
    let chunks = [262144.0, 1048576.0, 4194304.0, 16777216.0];
    for nic in [25e9, 50e9, 100e9] {
        let cluster = ClusterSpec::hgx_h100_pod(2).with_nic_bw(nic);
        let exec = TimedExec::on_cluster(cluster.clone());
        let cfg = GemmKernelCfg::new(cluster.node.clone(), 24576, 8192, 1024);
        // the default cfg carries RDMA_CHUNK_AUTO -> the analytic knee
        let t_auto = exec.run(&build_cluster(&cfg, &cluster, Schedule::IntraSm, None)).total_time;
        let best = chunks
            .iter()
            .map(|&c| {
                let mut cc = cfg.clone();
                cc.rdma_chunk = c;
                exec.run(&build_cluster(&cc, &cluster, Schedule::IntraSm, None)).total_time
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
            .unwrap();
        assert!(
            t_auto <= best * 1.10,
            "analytic chunk within 10% of the swept optimum at NIC {} GB/s: {t_auto} vs {best}",
            nic / 1e9
        );
        // and the analytic choice itself moves with the fabric
        let c = pk::pk::tuner::analytic_rdma_chunk(&cluster, 32.0 * 1024.0 * 1024.0);
        assert!(c > 0.0 && c.is_finite());
    }
}
