//! Mutation tests proving the static plan verifier is load-bearing: take
//! a known-good plan (the rail-backed cluster GEMM+AR — the kernel whose
//! `weakened-red_done` protocol model first showed these barriers only
//! fail dynamically), seed one defect class at a time, and assert the
//! matching checker fires.
//!
//! Each mutation edits the built `Plan` directly (ops are plain data), so
//! the defects are exactly the ones a buggy builder would emit: a dropped
//! completion signal, a stripped wave-credit wait, a downgraded sync
//! scope.

use pk::hw::ClusterSpec;
use pk::kernels::gemm_ar::{self, GemmArBufs};
use pk::kernels::gemm_rs::Schedule;
use pk::kernels::GemmKernelCfg;
use pk::mem::MemPool;
use pk::plan::verify::{verify, Rule, Severity, VerifyCtx, VerifyReport};
use pk::plan::{Op, Plan, SyncScope};

/// The known-good fixture: functional-size cluster GEMM+AR on a 2-node ×
/// 2-device cluster (rail pre-reduce + coalesced store-add + broadcast).
fn fixture() -> (MemPool, Plan, ClusterSpec) {
    let cluster = ClusterSpec::test_cluster(2, 2);
    let cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
    let mut pool = MemPool::new();
    let bufs = GemmArBufs::alloc_cluster(&mut pool, &cfg, &cluster);
    let plan = gemm_ar::build_cluster(&cfg, &cluster, Schedule::IntraSm, Some(&bufs));
    (pool, plan, cluster)
}

fn check(plan: &Plan, pool: &MemPool, cluster: &ClusterSpec) -> VerifyReport {
    let ctx = VerifyCtx { pool: Some(pool), devices_per_node: Some(cluster.devices_per_node()) };
    verify(plan, &ctx)
}

fn has_error(report: &VerifyReport, rule: Rule) -> bool {
    report.findings.iter().any(|f| f.rule == rule && f.severity == Severity::Error)
}

#[test]
fn unmutated_fixture_is_clean() {
    let (pool, plan, cluster) = fixture();
    let report = check(&plan, &pool, &cluster);
    assert_eq!(report.num_errors(), 0, "fixture must start clean:\n{}", report.render());
}

/// Drop every increment of the semaphore behind the plan's first real
/// wait (the buggy-builder failure where a completion signal is never
/// emitted): the liveness checker's signal-count accounting must report
/// the wait as unsatisfiable.
#[test]
fn dropped_completion_signals_trip_the_liveness_check() {
    let (pool, mut plan, cluster) = fixture();
    // first wait whose value exceeds the sem's initial value — its sem
    // needs at least one increment, all of which we now delete
    let victim = plan
        .workers
        .iter()
        .flat_map(|w| w.ops.iter())
        .find_map(|op| match op {
            Op::Wait { sem, value } if *value > plan.sems[sem.0] => Some(*sem),
            _ => None,
        })
        .expect("cluster plan has at least one non-trivial wait");
    for w in &mut plan.workers {
        w.ops.retain(|op| !matches!(op, Op::Signal { sem, .. } if *sem == victim));
        for op in &mut w.ops {
            if let Op::Transfer { done_sem, .. } = op {
                if *done_sem == Some(victim) {
                    *done_sem = None;
                }
            }
        }
    }
    let report = check(&plan, &pool, &cluster);
    assert!(
        has_error(&report, Rule::Deadlock),
        "dropping sem {victim:?}'s increments must be an unsatisfiable wait:\n{}",
        report.render()
    );
}

/// Strip single waits (the buggy-builder failure where one wave-credit /
/// barrier wait is forgotten): at least one wait in the plan must be
/// load-bearing for race-freedom, and the race detector must see its
/// removal as two unordered conflicting accesses.
#[test]
fn stripped_wait_trips_the_race_detector() {
    let (pool, base, cluster) = fixture();
    let mut race_hits = 0usize;
    let mut waits = 0usize;
    for wi in 0..base.workers.len() {
        for oi in 0..base.workers[wi].ops.len() {
            if !matches!(base.workers[wi].ops[oi], Op::Wait { .. }) {
                continue;
            }
            waits += 1;
            let mut plan = base.clone();
            plan.workers[wi].ops.remove(oi);
            if has_error(&check(&plan, &pool, &cluster), Rule::Race) {
                race_hits += 1;
            }
        }
    }
    assert!(waits > 0, "fixture has no waits to mutate");
    assert!(
        race_hits > 0,
        "no single-wait removal raced ({waits} waits tried) — detector is not load-bearing"
    );
}

/// Downgrade every `InterNode` signal/completion to `IntraSm` (the
/// buggy-builder failure where a cross-node fence is emitted with a
/// same-SM scope): the scope lint must report a wait whose only
/// satisfying increments are under-scoped.
#[test]
fn scope_downgrade_trips_the_scope_lint() {
    let (pool, mut plan, cluster) = fixture();
    let mut downgraded = 0usize;
    for w in &mut plan.workers {
        for op in &mut w.ops {
            match op {
                Op::Signal { scope, .. } if *scope == SyncScope::InterNode => {
                    *scope = SyncScope::IntraSm;
                    downgraded += 1;
                }
                Op::Transfer { done_scope, .. } if *done_scope == SyncScope::InterNode => {
                    *done_scope = SyncScope::IntraSm;
                    downgraded += 1;
                }
                _ => {}
            }
        }
    }
    assert!(downgraded > 0, "cluster fixture must carry InterNode-scoped syncs");
    let report = check(&plan, &pool, &cluster);
    assert!(
        has_error(&report, Rule::Scope),
        "downgrading {downgraded} InterNode syncs must trip the scope lint:\n{}",
        report.render()
    );
}

/// Drop one inter-stage activation credit from a 1F1B model plan (the
/// buggy-composer failure where a pipeline boundary transfer forgets its
/// completion signal): the consumer stage's gated wait counts `width·sp`
/// deliveries per edge and must now be reported unsatisfiable.
#[test]
fn dropped_pipeline_credit_trips_the_deadlock_check() {
    use pk::model::{pipeline, ModelCfg, ParallelSpec};
    use pk::pk::rail::RailHealth;

    let cluster = ClusterSpec::test_cluster(2, 2);
    let health = RailHealth::all_healthy(&cluster);
    let m = ModelCfg {
        hidden: 128,
        ffn: 256,
        seq: 256,
        n_heads: 2,
        n_layers: 2,
        microbatches: 2,
        moe: None,
        flash_util: 0.75,
    };
    let spec = ParallelSpec::dense(2, 2);
    let mut plan =
        pipeline::build_model(&m, &spec, &cluster, &health, pipeline::PipeSchedule::OneFOneB);
    let ctx = VerifyCtx { pool: None, devices_per_node: Some(cluster.devices_per_node()) };
    assert_eq!(
        verify(&plan, &ctx).num_errors(),
        0,
        "1F1B fixture must start clean:\n{}",
        verify(&plan, &ctx).render()
    );

    let mut dropped = false;
    'outer: for w in &mut plan.workers {
        for op in &mut w.ops {
            if let Op::Transfer { done_sem, label, .. } = op {
                if *label == "pipe_act" && done_sem.is_some() {
                    *done_sem = None;
                    dropped = true;
                    break 'outer;
                }
            }
        }
    }
    assert!(dropped, "1F1B plan must carry pipe_act boundary credits");
    let report = verify(&plan, &ctx);
    assert!(
        has_error(&report, Rule::Deadlock),
        "dropping an inter-stage credit must be an unsatisfiable gated wait:\n{}",
        report.render()
    );
}
