//! The three inter-GPU transfer mechanisms (§3.1.2) plus NVSwitch multimem.
//!
//! * **Copy engine** — host-initiated DMA; highest peak efficiency (82 %)
//!   but needs ≥256 MB messages to saturate and supports only contiguous
//!   transfers (Table 1 / Figure 2).
//! * **TMA** — device-initiated bulk async transfers; near-peak at ~2 KB
//!   messages, saturates NVLink with ~15 SMs, single-thread launch
//!   (the intra-SM overlap enabler).
//! * **Register ops** — `ld`/`st`/`multimem.*`; lowest peak (76 %), needs
//!   ~76 SMs, but the *only* mechanism with in-fabric reduction and
//!   elementwise access (Table 2).
//!
//! [`curves`] holds the calibrated bandwidth models; [`Mechanism`] the
//! functionality matrix.

pub mod curves;


/// A data-transfer mechanism (paper Table 2 rows are [`Functionality`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mechanism {
    /// Host-initiated per-GPU DMA engine.
    CopyEngine,
    /// Tensor Memory Accelerator bulk async transfers (device-initiated).
    Tma,
    /// Plain register-level `ld`/`st` instructions.
    RegOp,
    /// Register-level `multimem.*` through the NVSwitch reduction/multicast
    /// units (a register-op subtype; split out because its routing and
    /// rate differ).
    Multimem,
}

/// Functionality rows of Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Functionality {
    P2pTransfer,
    InFabricBroadcast,
    P2pReduction,
    InFabricReduction,
    ElementwiseTransfer,
}

impl Mechanism {
    /// The Table 2 functionality matrix.
    pub fn supports(&self, f: Functionality) -> bool {
        use Functionality::*;
        use Mechanism::*;
        match (self, f) {
            (CopyEngine, P2pTransfer) | (CopyEngine, InFabricBroadcast) => true,
            (CopyEngine, _) => false,
            (Tma, P2pTransfer) | (Tma, InFabricBroadcast) | (Tma, P2pReduction) => true,
            (Tma, _) => false,
            // RegOp and Multimem are both register-level instruction paths.
            (RegOp, _) | (Multimem, _) => true,
        }
    }

    /// Whether transfers can be issued asynchronously by a single thread
    /// (TMA's key property for intra-SM overlap, §3.1.2).
    pub fn single_thread_async(&self) -> bool {
        matches!(self, Mechanism::Tma | Mechanism::CopyEngine)
    }

    /// Whether the mechanism is driven by SMs (vs the host).
    pub fn device_initiated(&self) -> bool {
        !matches!(self, Mechanism::CopyEngine)
    }
}

#[cfg(test)]
mod tests {
    use super::Functionality::*;
    use super::Mechanism::*;
    use super::*;

    #[test]
    fn table2_matrix() {
        // Row 1: P2P transfer — all three.
        for m in [CopyEngine, Tma, RegOp] {
            assert!(m.supports(P2pTransfer));
        }
        // Row 2: in-fabric broadcast — all three.
        for m in [CopyEngine, Tma, RegOp] {
            assert!(m.supports(InFabricBroadcast));
        }
        // Row 3: P2P reduction — TMA and Reg only.
        assert!(!CopyEngine.supports(P2pReduction));
        assert!(Tma.supports(P2pReduction));
        assert!(RegOp.supports(P2pReduction));
        // Row 4: in-fabric reduction — Reg only.
        assert!(!CopyEngine.supports(InFabricReduction));
        assert!(!Tma.supports(InFabricReduction));
        assert!(RegOp.supports(InFabricReduction));
        // Row 5: elementwise — Reg only.
        assert!(!CopyEngine.supports(ElementwiseTransfer));
        assert!(!Tma.supports(ElementwiseTransfer));
        assert!(RegOp.supports(ElementwiseTransfer));
    }

    #[test]
    fn async_and_initiation_properties() {
        assert!(Tma.single_thread_async());
        assert!(!RegOp.single_thread_async());
        assert!(!CopyEngine.device_initiated());
        assert!(Tma.device_initiated());
        assert!(Multimem.device_initiated());
    }
}
