//! Calibrated bandwidth curves for each transfer mechanism.
//!
//! The model: each mechanism has a peak fraction of NVLink bandwidth
//! (Table 1), a message-size ramp (Figure 2) modelled as
//! `eff(msg) = msg / (msg + half)`, and — for device-initiated mechanisms —
//! an SM-count ramp (Figure 3) modelled as `min(1, n_sms / sat_sms)`.
//! A flow's intrinsic rate cap is the product of the three; port contention
//! on top of this is handled by [`crate::sim::FlowNet`].

use crate::hw::spec::GpuSpec;
use crate::xfer::Mechanism;

/// Message-size efficiency in `[0, 1)`: half of peak at `half` bytes.
#[inline]
pub fn msg_eff(half: f64, msg_bytes: f64) -> f64 {
    debug_assert!(msg_bytes > 0.0);
    msg_bytes / (msg_bytes + half)
}

/// SM-count ramp: linear until saturation (Figure 3's shape).
#[inline]
pub fn sm_frac(n_sms: f64, sat_sms: f64) -> f64 {
    (n_sms / sat_sms).min(1.0)
}

/// Copy-engine rate (bytes/s) for a transfer chopped into `msg_bytes`
/// pieces. Host-initiated: independent of SMs. Fine-grained CE transfers
/// pay per-invocation overhead, which is what makes it unusable for
/// all-to-all style patterns (§3.1.2).
pub fn ce_rate(spec: &GpuSpec, msg_bytes: f64) -> f64 {
    spec.nvlink_bw * spec.ce_peak_frac * msg_eff(spec.ce_half_msg, msg_bytes)
}

/// TMA rate (bytes/s) with `n_sms` SMs issuing messages of `msg_bytes`
/// (clamped to the 227 KB SMEM-bounded maximum, Figure 2).
pub fn tma_rate(spec: &GpuSpec, msg_bytes: f64, n_sms: f64) -> f64 {
    let msg = msg_bytes.min(spec.tma_max_msg as f64);
    spec.nvlink_bw * spec.tma_peak_frac * msg_eff(spec.tma_half_msg, msg) * sm_frac(n_sms, spec.tma_sat_sms)
}

/// Register-op rate (bytes/s) with `n_sms` SMs issuing.
pub fn reg_rate(spec: &GpuSpec, msg_bytes: f64, n_sms: f64) -> f64 {
    spec.nvlink_bw * spec.reg_peak_frac * msg_eff(spec.reg_half_msg, msg_bytes) * sm_frac(n_sms, spec.reg_sat_sms)
}

/// Multimem (in-fabric multicast / reduce) rate: a register-op instruction
/// path, so it shares the register-op ramps; warp-level participation is
/// required for throughput (§3.2.2).
pub fn multimem_rate(spec: &GpuSpec, msg_bytes: f64, n_sms: f64) -> f64 {
    reg_rate(spec, msg_bytes, n_sms)
}

/// GPUDirect RDMA rate (bytes/s) for cross-node transfers chopped into
/// `msg_bytes` writes. Shape mirrors the intra-node curves: a peak
/// fraction of the NIC line rate times a message-size ramp (verbs posting
/// overhead makes small writes inefficient; ~64 KB messages approach line
/// rate). Driven by the proxy, so — like the copy engine — it is
/// independent of issuing-SM count; unlike the copy engine its ramp knee
/// sits at tens of KB, not hundreds of MB.
pub fn rdma_rate(cluster: &crate::hw::ClusterSpec, msg_bytes: f64) -> f64 {
    cluster.nic_bw * cluster.nic_peak_frac * msg_eff(cluster.rdma_half_msg, msg_bytes)
}

/// Dispatch by mechanism.
pub fn rate(spec: &GpuSpec, mech: Mechanism, msg_bytes: f64, n_sms: f64) -> f64 {
    match mech {
        Mechanism::CopyEngine => ce_rate(spec, msg_bytes),
        Mechanism::Tma => tma_rate(spec, msg_bytes, n_sms),
        Mechanism::RegOp => reg_rate(spec, msg_bytes, n_sms),
        Mechanism::Multimem => multimem_rate(spec, msg_bytes, n_sms),
    }
}

/// Per-flow first-byte latency of a mechanism: host launch for the copy
/// engine, a TMA issue + NVLink propagation otherwise.
pub fn flow_latency(spec: &GpuSpec, mech: Mechanism) -> f64 {
    match mech {
        Mechanism::CopyEngine => spec.kernel_launch + spec.nvlink_latency,
        Mechanism::Tma => spec.nvlink_latency,
        Mechanism::RegOp | Mechanism::Multimem => spec.nvlink_latency,
    }
}

/// Time for a tuned local GEMM of `flops` FLOPs on `n_sms` compute SMs
/// (compute throughput scales linearly with SMs, §3.1.3).
pub fn gemm_time(spec: &GpuSpec, flops: f64, n_sms: u32) -> f64 {
    flops / spec.tc_flops_for_sms(n_sms)
}

/// Smallest number of SMs at which a device-initiated mechanism reaches
/// `frac` of its large-message rate — the Figure 3 "SMs to saturate" metric.
pub fn sms_to_saturate(spec: &GpuSpec, mech: Mechanism, frac: f64) -> u32 {
    let target = rate(spec, mech, (1 << 20) as f64, spec.num_sms as f64) * frac;
    for n in 1..=spec.num_sms {
        if rate(spec, mech, (1 << 20) as f64, n as f64) >= target {
            return n;
        }
    }
    spec.num_sms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn table1_bandwidths_reproduce() {
        // 1 GB transfer with all SMs (Table 1). TMA messages are capped at
        // 227 KB, matching the paper's measurement method.
        let g = GpuSpec::h100();
        let gb = 1e9;
        assert!(approx_eq(ce_rate(&g, gb), 368.82e9, 0.02), "{}", ce_rate(&g, gb));
        assert!(approx_eq(tma_rate(&g, gb, 132.0), 350.01e9, 0.02));
        assert!(approx_eq(reg_rate(&g, gb, 132.0), 342.68e9, 0.02));
        let b = GpuSpec::b200();
        assert!(approx_eq(ce_rate(&b, gb), 726.13e9, 0.02));
        assert!(approx_eq(tma_rate(&b, gb, 148.0), 669.12e9, 0.02));
        assert!(approx_eq(reg_rate(&b, gb, 148.0), 628.35e9, 0.02));
    }

    #[test]
    fn figure2_ce_needs_256mb() {
        // >=80% of theoretical max requires >=256 MB messages for the CE...
        let g = GpuSpec::h100();
        assert!(ce_rate(&g, 256e6) >= 0.80 * g.nvlink_bw);
        // ...but smaller messages fall below it.
        assert!(ce_rate(&g, 64e6) < 0.80 * g.nvlink_bw);
        // and fine-grained CE traffic collapses entirely:
        assert!(ce_rate(&g, 64e3) < 0.01 * g.nvlink_bw);
    }

    #[test]
    fn figure2_tma_near_peak_at_2kb() {
        let g = GpuSpec::h100();
        let full = tma_rate(&g, 227.0 * 1024.0, 132.0);
        assert!(tma_rate(&g, 2048.0, 132.0) >= 0.94 * full);
        // message sizes beyond 227 KB are clamped (held constant in Fig 2)
        assert_eq!(tma_rate(&g, 1e9, 132.0), tma_rate(&g, 227.0 * 1024.0, 132.0));
    }

    #[test]
    fn figure2_reg_efficient_at_128b() {
        let g = GpuSpec::h100();
        let full = reg_rate(&g, 1e6, 132.0);
        assert!(reg_rate(&g, 128.0, 132.0) >= 0.79 * full);
    }

    #[test]
    fn figure3_sms_to_saturate() {
        let g = GpuSpec::h100();
        let tma = sms_to_saturate(&g, Mechanism::Tma, 0.999);
        let reg = sms_to_saturate(&g, Mechanism::RegOp, 0.999);
        assert_eq!(tma, 15, "TMA saturates at ~15 SMs (Fig 3)");
        assert_eq!(reg, 76, "reg ops saturate at ~76 SMs (Fig 3)");
        // ratio 3.2-5.1x (paper §3.1.2)
        let ratio = reg as f64 / tma as f64;
        assert!((3.2..=5.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn rates_monotonic_in_msg_and_sms() {
        let g = GpuSpec::h100();
        let mut last = 0.0;
        for msg in [128.0, 1024.0, 8192.0, 65536.0] {
            let r = tma_rate(&g, msg, 8.0);
            assert!(r >= last);
            last = r;
        }
        let mut last = 0.0;
        for n in [1.0, 4.0, 16.0, 64.0, 132.0] {
            let r = reg_rate(&g, 4096.0, n);
            assert!(r >= last);
            last = r;
        }
    }

    #[test]
    fn gemm_time_matches_table3_scale() {
        // Table 3: 32768x32768x8192 BF16 GEMM measured at 23.285 ms.
        // flops = 2*M*N*K = 1.76e13 -> at 0.85*989e12 -> 20.9 ms. Within 15%.
        let g = GpuSpec::h100();
        let flops = 2.0 * 32768.0 * 32768.0 * 8192.0;
        let t = gemm_time(&g, flops, 132);
        assert!((t - 23.285e-3).abs() / 23.285e-3 < 0.15, "{t}");
    }

    #[test]
    fn flow_latency_ce_pays_launch() {
        let g = GpuSpec::h100();
        assert!(flow_latency(&g, Mechanism::CopyEngine) > flow_latency(&g, Mechanism::Tma));
    }

    #[test]
    fn rdma_curve_bounded_and_monotone() {
        let c = crate::hw::ClusterSpec::hgx_h100_pod(2);
        let mut last = 0.0;
        for msg in [512.0, 4096.0, 65536.0, 1e6, 64e6] {
            let r = rdma_rate(&c, msg);
            assert!(r > last, "monotone in message size");
            assert!(r < c.nic_bw, "never exceeds the NIC line rate");
            last = r;
        }
        // large messages approach the peak fraction of line rate
        assert!(rdma_rate(&c, 64e6) > 0.99 * c.nic_bw * c.nic_peak_frac);
        // fine-grained RDMA collapses like fine-grained CE traffic
        assert!(rdma_rate(&c, 256.0) < 0.05 * c.nic_bw);
    }

    #[test]
    fn rdma_far_below_nvlink() {
        // the cross-node cliff the scale-out exhibit shows: even a 100 GB/s
        // NIC delivers well under half of one NVLink port
        let c = crate::hw::ClusterSpec::hgx_h100_pod(2).with_nic_bw(100e9);
        let nvlink = tma_rate(&c.node.gpu, 1e6, 132.0);
        assert!(rdma_rate(&c, 1e6) < 0.4 * nvlink);
    }
}
