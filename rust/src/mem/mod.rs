//! Functional device memory: shapes, tiles, per-device buffers, and the
//! paper's **Parallel Global Layout (PGL)** (§3.2.1).
//!
//! The functional executor moves *real* `f32` data through these structures
//! so every kernel plan can be verified numerically; the timed executor
//! reads only sizes. BF16 is emulated by using BF16 element *sizes* in the
//! cost model while keeping f32 numerics (see DESIGN.md substitutions).

pub mod buffer;
pub mod pgl;
pub mod pool;
pub mod tile;

pub use buffer::BufId;
pub use pgl::{Pgl, PglId};
pub use pool::MemPool;
pub use tile::{Shape4, TileCoord, TileShape};

/// Element size in bytes used by the cost model (BF16, `s = 2` in §3.1.3).
pub const ELEM_BYTES: u64 = 2;
