//! Tile coordinates and 4-D layouts.
//!
//! PK operations are tile-granular: coordinates are `int4` values
//! `(b, d, r, c)` indexing tiles inside a 4-D global layout (§3.2.2).
//! The minimum tile is 16×16 (register tile); shared tiles go up to the
//! SMEM limit (~256×256, §3.2.2).


/// 4-D logical shape `(b, d, r, c)` in *elements*, row-major, matching the
/// paper's (batch, depth, row, col) global layout convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Shape4 {
    pub b: usize,
    pub d: usize,
    pub r: usize,
    pub c: usize,
}

impl Shape4 {
    /// A 2-D matrix layout `(1, 1, rows, cols)`.
    pub fn mat(rows: usize, cols: usize) -> Self {
        Shape4 { b: 1, d: 1, r: rows, c: cols }
    }

    pub fn numel(&self) -> usize {
        self.b * self.d * self.r * self.c
    }

    /// Flat element offset of `(b, d, r, c)`.
    pub fn offset(&self, b: usize, d: usize, r: usize, c: usize) -> usize {
        debug_assert!(b < self.b && d < self.d && r < self.r && c < self.c);
        ((b * self.d + d) * self.r + r) * self.c + c
    }
}

/// Tile dimensions in elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileShape {
    pub rows: usize,
    pub cols: usize,
}

impl TileShape {
    pub const fn new(rows: usize, cols: usize) -> Self {
        TileShape { rows, cols }
    }

    /// The paper's minimum (register) tile.
    pub const MIN: TileShape = TileShape::new(16, 16);

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Size in bytes at the cost model's element size.
    pub fn bytes(&self) -> u64 {
        (self.numel() as u64) * super::ELEM_BYTES
    }

    /// Whether a tile of this shape fits in shared memory (limits the
    /// largest single TMA message, Figure 2's 227 KB note).
    pub fn fits_smem(&self, smem_bytes: u64) -> bool {
        self.bytes() <= smem_bytes
    }
}

/// Tile index `(b, d, r, c)` — the paper's `coord` int4 (§3.2.2). `r`/`c`
/// count tiles, not elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileCoord {
    pub b: usize,
    pub d: usize,
    pub r: usize,
    pub c: usize,
}

impl TileCoord {
    pub fn rc(r: usize, c: usize) -> Self {
        TileCoord { b: 0, d: 0, r, c }
    }

    /// Element offset of this tile's top-left corner in `layout`, for tiles
    /// of shape `ts`.
    pub fn elem_offset(&self, layout: &Shape4, ts: TileShape) -> usize {
        layout.offset(self.b, self.d, self.r * ts.rows, self.c * ts.cols)
    }
}

/// Iterate all tile coords covering a layout with tile shape `ts`
/// (the last two dims must divide evenly — PK enforces tile alignment).
pub fn tile_grid(layout: &Shape4, ts: TileShape) -> impl Iterator<Item = TileCoord> {
    assert_eq!(layout.r % ts.rows, 0, "rows {} not divisible by tile {}", layout.r, ts.rows);
    assert_eq!(layout.c % ts.cols, 0, "cols {} not divisible by tile {}", layout.c, ts.cols);
    let (nb, nd) = (layout.b, layout.d);
    let (nr, nc) = (layout.r / ts.rows, layout.c / ts.cols);
    (0..nb).flat_map(move |b| {
        (0..nd).flat_map(move |d| {
            (0..nr).flat_map(move |r| (0..nc).map(move |c| TileCoord { b, d, r, c }))
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_row_major() {
        let s = Shape4::mat(4, 8);
        assert_eq!(s.offset(0, 0, 0, 0), 0);
        assert_eq!(s.offset(0, 0, 1, 0), 8);
        assert_eq!(s.offset(0, 0, 3, 7), 31);
        let s4 = Shape4 { b: 2, d: 3, r: 4, c: 5 };
        assert_eq!(s4.offset(1, 2, 3, 4), ((1 * 3 + 2) * 4 + 3) * 5 + 4);
    }

    #[test]
    fn tile_bytes_bf16() {
        assert_eq!(TileShape::MIN.bytes(), 16 * 16 * 2);
        let big = TileShape::new(256, 256);
        assert_eq!(big.bytes(), 256 * 256 * 2);
        // 256x256 bf16 = 128 KB fits in 227 KB SMEM; 512x512 does not.
        assert!(big.fits_smem(227 * 1024));
        assert!(!TileShape::new(512, 512).fits_smem(227 * 1024));
    }

    #[test]
    fn tile_grid_covers_layout() {
        let layout = Shape4::mat(64, 128);
        let ts = TileShape::new(16, 16);
        let tiles: Vec<_> = tile_grid(&layout, ts).collect();
        assert_eq!(tiles.len(), 4 * 8);
        assert_eq!(tiles[0], TileCoord::rc(0, 0));
        assert_eq!(*tiles.last().unwrap(), TileCoord::rc(3, 7));
    }

    #[test]
    fn tile_elem_offset() {
        let layout = Shape4::mat(64, 64);
        let ts = TileShape::new(16, 16);
        let t = TileCoord::rc(2, 1);
        assert_eq!(t.elem_offset(&layout, ts), 32 * 64 + 16);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn tile_grid_rejects_misaligned() {
        let layout = Shape4::mat(60, 64);
        let _ = tile_grid(&layout, TileShape::new(16, 16)).count();
    }
}
