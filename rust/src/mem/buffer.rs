//! Per-device buffers: the functional model of HBM allocations.

use super::tile::{Shape4, TileCoord, TileShape};
use crate::hw::DeviceId;

/// Handle to a buffer registered in a [`super::MemPool`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BufId(pub usize);

/// A device-resident tensor with a 4-D layout. The functional executor
/// reads and writes tiles of it; the timed executor only uses its metadata.
#[derive(Clone, Debug)]
pub struct DeviceBuffer {
    pub dev: DeviceId,
    pub shape: Shape4,
    pub data: Vec<f32>,
}

impl DeviceBuffer {
    pub fn zeros(dev: DeviceId, shape: Shape4) -> Self {
        DeviceBuffer { dev, shape, data: vec![0.0; shape.numel()] }
    }

    pub fn from_vec(dev: DeviceId, shape: Shape4, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.numel(), "data/shape mismatch");
        DeviceBuffer { dev, shape, data }
    }

    /// Copy a tile out into a dense row-major `rows×cols` vector.
    pub fn read_tile(&self, coord: TileCoord, ts: TileShape) -> Vec<f32> {
        let base = coord.elem_offset(&self.shape, ts);
        let mut out = Vec::with_capacity(ts.numel());
        for r in 0..ts.rows {
            let start = base + r * self.shape.c;
            out.extend_from_slice(&self.data[start..start + ts.cols]);
        }
        out
    }

    /// Write a dense `rows×cols` tile at `coord`.
    pub fn write_tile(&mut self, coord: TileCoord, ts: TileShape, tile: &[f32]) {
        assert_eq!(tile.len(), ts.numel());
        let base = coord.elem_offset(&self.shape, ts);
        for r in 0..ts.rows {
            let start = base + r * self.shape.c;
            self.data[start..start + ts.cols].copy_from_slice(&tile[r * ts.cols..(r + 1) * ts.cols]);
        }
    }

    /// Atomically-add semantics of `store_add_async`/`multimem.red`:
    /// `self[coord] += tile`.
    pub fn add_tile(&mut self, coord: TileCoord, ts: TileShape, tile: &[f32]) {
        assert_eq!(tile.len(), ts.numel());
        let base = coord.elem_offset(&self.shape, ts);
        for r in 0..ts.rows {
            let start = base + r * self.shape.c;
            for c in 0..ts.cols {
                self.data[start + c] += tile[r * ts.cols + c];
            }
        }
    }

    /// Elementwise max-reduce a tile in (multimem `max` op).
    pub fn max_tile(&mut self, coord: TileCoord, ts: TileShape, tile: &[f32]) {
        assert_eq!(tile.len(), ts.numel());
        let base = coord.elem_offset(&self.shape, ts);
        for r in 0..ts.rows {
            let start = base + r * self.shape.c;
            for c in 0..ts.cols {
                let v = &mut self.data[start + c];
                *v = v.max(tile[r * ts.cols + c]);
            }
        }
    }

    /// Contiguous range read (copy-engine semantics: flat regions).
    pub fn read_range(&self, start: usize, len: usize) -> &[f32] {
        &self.data[start..start + len]
    }

    /// Contiguous range write.
    pub fn write_range(&mut self, start: usize, src: &[f32]) {
        self.data[start..start + src.len()].copy_from_slice(src);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf_4x4() -> DeviceBuffer {
        let data: Vec<f32> = (0..16).map(|i| i as f32).collect();
        DeviceBuffer::from_vec(DeviceId(0), Shape4::mat(4, 4), data)
    }

    #[test]
    fn read_write_tile_roundtrip() {
        let mut b = DeviceBuffer::zeros(DeviceId(0), Shape4::mat(32, 32));
        let ts = TileShape::new(16, 16);
        let tile: Vec<f32> = (0..256).map(|i| i as f32).collect();
        b.write_tile(TileCoord::rc(1, 1), ts, &tile);
        assert_eq!(b.read_tile(TileCoord::rc(1, 1), ts), tile);
        // other tiles untouched
        assert!(b.read_tile(TileCoord::rc(0, 0), ts).iter().all(|v| *v == 0.0));
    }

    #[test]
    fn read_tile_strided() {
        let b = buf_4x4();
        let ts = TileShape::new(2, 2);
        // tile (1,1) of a 4x4 = elements [10,11,14,15]
        assert_eq!(b.read_tile(TileCoord::rc(1, 1), ts), vec![10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn add_tile_accumulates() {
        let mut b = buf_4x4();
        let ts = TileShape::new(2, 2);
        b.add_tile(TileCoord::rc(0, 0), ts, &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(b.read_tile(TileCoord::rc(0, 0), ts), vec![1.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn max_tile_takes_max() {
        let mut b = buf_4x4();
        let ts = TileShape::new(2, 2);
        b.max_tile(TileCoord::rc(0, 0), ts, &[100.0, -1.0, -1.0, 100.0]);
        assert_eq!(b.read_tile(TileCoord::rc(0, 0), ts), vec![100.0, 1.0, 4.0, 100.0]);
    }

    #[test]
    fn range_ops() {
        let mut b = buf_4x4();
        assert_eq!(b.read_range(4, 4), &[4.0, 5.0, 6.0, 7.0]);
        b.write_range(0, &[9.0, 9.0]);
        assert_eq!(b.read_range(0, 2), &[9.0, 9.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_rejects_bad_len() {
        let _ = DeviceBuffer::from_vec(DeviceId(0), Shape4::mat(2, 2), vec![0.0; 3]);
    }
}
