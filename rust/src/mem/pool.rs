//! The memory pool: owns all functional buffers of a run, addressed by
//! [`BufId`]. This is the functional stand-in for the VMM-allocated,
//! IPC-shared device memory of Appendix E — allocation happens up front
//! (PK's "pre-allocated destination buffers", §3.1.4), after which kernels
//! only reference handles.

use super::buffer::{BufId, DeviceBuffer};
use super::tile::Shape4;
use crate::hw::DeviceId;

/// Owns every buffer in a simulated node.
#[derive(Default, Debug)]
pub struct MemPool {
    bufs: Vec<DeviceBuffer>,
}

impl MemPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a zero-filled buffer on `dev`.
    pub fn alloc(&mut self, dev: DeviceId, shape: Shape4) -> BufId {
        self.bufs.push(DeviceBuffer::zeros(dev, shape));
        BufId(self.bufs.len() - 1)
    }

    /// Allocate a buffer with initial contents.
    pub fn alloc_init(&mut self, dev: DeviceId, shape: Shape4, data: Vec<f32>) -> BufId {
        self.bufs.push(DeviceBuffer::from_vec(dev, shape, data));
        BufId(self.bufs.len() - 1)
    }

    pub fn get(&self, id: BufId) -> &DeviceBuffer {
        &self.bufs[id.0]
    }

    pub fn get_mut(&mut self, id: BufId) -> &mut DeviceBuffer {
        &mut self.bufs[id.0]
    }

    /// Two distinct buffers mutably (for copy ops). Panics if `a == b`.
    pub fn get_pair_mut(&mut self, a: BufId, b: BufId) -> (&mut DeviceBuffer, &mut DeviceBuffer) {
        assert_ne!(a, b, "aliasing buffers");
        if a.0 < b.0 {
            let (lo, hi) = self.bufs.split_at_mut(b.0);
            (&mut lo[a.0], &mut hi[0])
        } else {
            let (lo, hi) = self.bufs.split_at_mut(a.0);
            (&mut hi[0], &mut lo[b.0])
        }
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Total functional bytes held (f32 storage).
    pub fn total_bytes(&self) -> usize {
        self.bufs.iter().map(|b| b.data.len() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_access() {
        let mut p = MemPool::new();
        let a = p.alloc(DeviceId(0), Shape4::mat(2, 2));
        let b = p.alloc_init(DeviceId(1), Shape4::mat(1, 3), vec![1.0, 2.0, 3.0]);
        assert_eq!(p.len(), 2);
        assert_eq!(p.get(a).data, vec![0.0; 4]);
        assert_eq!(p.get(b).dev, DeviceId(1));
        p.get_mut(a).data[0] = 5.0;
        assert_eq!(p.get(a).data[0], 5.0);
    }

    #[test]
    fn pair_mut_both_orders() {
        let mut p = MemPool::new();
        let a = p.alloc(DeviceId(0), Shape4::mat(1, 1));
        let b = p.alloc(DeviceId(0), Shape4::mat(1, 1));
        {
            let (x, y) = p.get_pair_mut(a, b);
            x.data[0] = 1.0;
            y.data[0] = 2.0;
        }
        let (y2, x2) = p.get_pair_mut(b, a);
        assert_eq!(y2.data[0], 2.0);
        assert_eq!(x2.data[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "aliasing")]
    fn pair_mut_rejects_alias() {
        let mut p = MemPool::new();
        let a = p.alloc(DeviceId(0), Shape4::mat(1, 1));
        let _ = p.get_pair_mut(a, a);
    }

    #[test]
    fn total_bytes_counts() {
        let mut p = MemPool::new();
        p.alloc(DeviceId(0), Shape4::mat(4, 4));
        assert_eq!(p.total_bytes(), 64);
    }
}
