//! The **Parallel Global Layout (PGL)** — the paper's central multi-GPU
//! data structure (§3.2.1): identically shaped and sized memory regions
//! allocated across all devices, addressable as one logical tensor with a
//! multicast address.
//!
//! Functionally a PGL is one [`BufId`] per device; writing through the
//! multicast view broadcasts to every device, and `ld_reduce` reads the
//! elementwise reduction across devices (NVSwitch multimem semantics,
//! Appendix F).

use super::buffer::BufId;
use super::pool::MemPool;
use super::tile::{Shape4, TileCoord, TileShape};
use crate::hw::DeviceId;

/// Handle identifying a PGL within a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PglId(pub usize);

/// Reduction op supported by multimem / `store_add_async` (§3.2.2 / App. C).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReduceOp {
    Add,
    Max,
    Min,
}

/// A parallel global layout: one same-shaped buffer per device.
#[derive(Clone, Debug)]
pub struct Pgl {
    pub id: PglId,
    pub shape: Shape4,
    /// `bufs[d]` is the replica on device `d`.
    pub bufs: Vec<BufId>,
}

impl Pgl {
    /// Allocate a PGL across `num_devices` devices.
    pub fn alloc(pool: &mut MemPool, id: PglId, shape: Shape4, num_devices: usize) -> Self {
        let bufs = (0..num_devices).map(|d| pool.alloc(DeviceId(d), shape)).collect();
        Pgl { id, shape, bufs }
    }

    pub fn num_devices(&self) -> usize {
        self.bufs.len()
    }

    /// Buffer on a specific device.
    pub fn on(&self, dev: DeviceId) -> BufId {
        self.bufs[dev.0]
    }

    /// Functional multicast store: write `tile` at `coord` on **every**
    /// device replica (in-fabric broadcast). With `Some(op)`, performs the
    /// reduction against existing contents instead (multimem `.red`).
    pub fn multicast_store(
        &self,
        pool: &mut MemPool,
        coord: TileCoord,
        ts: TileShape,
        tile: &[f32],
        reduce: Option<ReduceOp>,
    ) {
        for &b in &self.bufs {
            let buf = pool.get_mut(b);
            match reduce {
                None => buf.write_tile(coord, ts, tile),
                Some(ReduceOp::Add) => buf.add_tile(coord, ts, tile),
                Some(ReduceOp::Max) => buf.max_tile(coord, ts, tile),
                Some(ReduceOp::Min) => {
                    // min via negated max to keep buffer API small
                    let base = coord.elem_offset(&buf.shape, ts);
                    for r in 0..ts.rows {
                        let start = base + r * buf.shape.c;
                        for c in 0..ts.cols {
                            let v = &mut buf.data[start + c];
                            *v = v.min(tile[r * ts.cols + c]);
                        }
                    }
                }
            }
        }
    }

    /// Functional `multimem.ld_reduce`: elementwise reduction of the tile
    /// at `coord` across all device replicas.
    pub fn ld_reduce(&self, pool: &MemPool, coord: TileCoord, ts: TileShape, op: ReduceOp) -> Vec<f32> {
        let mut acc = pool.get(self.bufs[0]).read_tile(coord, ts);
        for &b in &self.bufs[1..] {
            let t = pool.get(b).read_tile(coord, ts);
            for (a, v) in acc.iter_mut().zip(t) {
                match op {
                    ReduceOp::Add => *a += v,
                    ReduceOp::Max => *a = a.max(v),
                    ReduceOp::Min => *a = a.min(v),
                }
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (MemPool, Pgl) {
        let mut pool = MemPool::new();
        let pgl = Pgl::alloc(&mut pool, PglId(0), Shape4::mat(32, 32), 4);
        (pool, pgl)
    }

    #[test]
    fn alloc_per_device() {
        let (pool, pgl) = setup();
        assert_eq!(pgl.num_devices(), 4);
        for (d, &b) in pgl.bufs.iter().enumerate() {
            assert_eq!(pool.get(b).dev, DeviceId(d));
            assert_eq!(pool.get(b).shape, Shape4::mat(32, 32));
        }
    }

    #[test]
    fn multicast_store_reaches_all() {
        let (mut pool, pgl) = setup();
        let ts = TileShape::new(16, 16);
        let tile = vec![3.0; 256];
        pgl.multicast_store(&mut pool, TileCoord::rc(1, 0), ts, &tile, None);
        for d in 0..4 {
            assert_eq!(pool.get(pgl.on(DeviceId(d))).read_tile(TileCoord::rc(1, 0), ts), tile);
        }
    }

    #[test]
    fn multicast_red_add_accumulates() {
        let (mut pool, pgl) = setup();
        let ts = TileShape::new(16, 16);
        pgl.multicast_store(&mut pool, TileCoord::rc(0, 0), ts, &vec![1.0; 256], Some(ReduceOp::Add));
        pgl.multicast_store(&mut pool, TileCoord::rc(0, 0), ts, &vec![2.0; 256], Some(ReduceOp::Add));
        for d in 0..4 {
            let t = pool.get(pgl.on(DeviceId(d))).read_tile(TileCoord::rc(0, 0), ts);
            assert!(t.iter().all(|v| *v == 3.0));
        }
    }

    #[test]
    fn ld_reduce_sums_across_devices() {
        let (mut pool, pgl) = setup();
        let ts = TileShape::new(16, 16);
        for d in 0..4 {
            let b = pgl.on(DeviceId(d));
            pool.get_mut(b).write_tile(TileCoord::rc(0, 1), ts, &vec![(d + 1) as f32; 256]);
        }
        let sum = pgl.ld_reduce(&pool, TileCoord::rc(0, 1), ts, ReduceOp::Add);
        assert!(sum.iter().all(|v| *v == 10.0)); // 1+2+3+4
        let mx = pgl.ld_reduce(&pool, TileCoord::rc(0, 1), ts, ReduceOp::Max);
        assert!(mx.iter().all(|v| *v == 4.0));
        let mn = pgl.ld_reduce(&pool, TileCoord::rc(0, 1), ts, ReduceOp::Min);
        assert!(mn.iter().all(|v| *v == 1.0));
    }
}
