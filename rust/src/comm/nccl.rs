//! NCCL-style ring collectives with the library's design overheads.
//!
//! Modelled behaviours (each traced to the paper):
//! * **two-way synchronization** (§3.1.4): sender and receiver rendezvous
//!   before data moves — a fixed setup delay per collective on every rank
//!   plus per-step handshakes;
//! * **intermediate buffering** (§3.1.4): data staged through preallocated
//!   channel buffers — an extra HBM pass on each side of every hop;
//! * **chunked SM-driven copies**: transfers move in `chunk_bytes` slots
//!   via register ops across `n_sms` channel SMs;
//! * **contiguity requirement** (Appendix B): collectives operate on
//!   contiguous partitions only, so tensor-dimension (last-dim) collectives
//!   pay full reshape passes before and after.
//!
//! The ring algorithms themselves are the textbook NCCL rings, and their
//! *functional* semantics are exact (the tests verify all-reduce = sum
//! etc.), so these builders double as a correctness oracle for PK's own
//! collectives.

use crate::hw::spec::NodeSpec;
use crate::hw::DeviceId;
use crate::mem::pgl::ReduceOp;
use crate::mem::ELEM_BYTES;
use crate::plan::{Effect, MatView, Op, Plan, Role, Route, SyncScope, TransferSpec};
use crate::xfer::Mechanism;

/// Tunable constants of the NCCL behavioural model.
#[derive(Clone, Copy, Debug)]
pub struct NcclModel {
    /// Two-way rendezvous cost per collective per rank (launch + handshake).
    pub rendezvous: f64,
    /// Channel slot size (bytes) — transfer granularity.
    pub chunk_bytes: f64,
    /// SMs driving the channels.
    pub n_sms: f64,
    /// Stage through intermediate buffers (HBM pass on both sides).
    pub staged: bool,
}

impl Default for NcclModel {
    fn default() -> Self {
        // n_sms calibrates the channel-SM parallelism so ring collectives
        // land at NCCL's measured intra-node busbw (~280 GB/s per hop on
        // HGX H100); the paper's Figure 6 gap then comes from the ring's
        // 2(N-1)/N traffic + rendezvous + staging, not from handicapping
        // NCCL's own transfer rate.
        NcclModel { rendezvous: 10e-6, chunk_bytes: 512.0 * 1024.0, n_sms: 64.0, staged: true }
    }
}

impl NcclModel {
    /// Point-to-point configuration: send/recv uses fewer channel SMs
    /// (what a stream-overlapped P2P steals from a concurrent kernel).
    pub fn p2p() -> Self {
        NcclModel { n_sms: 16.0, ..Default::default() }
    }
}

/// Whole-buffer replica set for a collective: `replicas[d]` is device `d`'s
/// buffer view (same shape everywhere), chunked by row blocks.
pub struct RingCtx<'a> {
    pub node: &'a NodeSpec,
    pub model: NcclModel,
    pub replicas: Vec<MatView>,
}

impl<'a> RingCtx<'a> {
    fn n(&self) -> usize {
        self.replicas.len()
    }

    fn chunk_rows(&self) -> usize {
        let rows = self.replicas[0].rows;
        assert_eq!(rows % self.n(), 0, "rows must divide by device count");
        rows / self.n()
    }

    fn chunk_view(&self, dev: usize, chunk: usize) -> MatView {
        let cr = self.chunk_rows();
        self.replicas[dev].sub(chunk * cr, 0, cr, self.replicas[dev].cols)
    }

    fn chunk_bytes_total(&self) -> f64 {
        (self.chunk_rows() * self.replicas[0].cols) as f64 * ELEM_BYTES as f64
    }

    /// Emit the staging HBM pass of one hop (channel buffer copy).
    fn stage_pass(&self, plan: &mut Plan, w: usize, dev: usize, bytes: f64) {
        if self.model.staged {
            plan.push(
                w,
                Op::Transfer {
                    spec: TransferSpec {
                        mech: Mechanism::RegOp,
                        route: Route::LocalHbm { dev: DeviceId(dev) },
                        bytes,
                        msg_bytes: self.model.chunk_bytes,
                        n_sms: self.model.n_sms,
                    },
                    blocking: true,
                    done_sem: None,
                    done_scope: SyncScope::IntraSm,
                    label: "nccl_stage",
                    effect: None, // staging copy is value-neutral
                },
            );
        }
    }
}

/// One ring hop: device `d` sends `chunk` to `d+1`, optionally reducing at
/// the destination; signals `done` (the receiver's step semaphore).
#[allow(clippy::too_many_arguments)]
fn ring_hop(
    ctx: &RingCtx,
    plan: &mut Plan,
    w: usize,
    d: usize,
    chunk: usize,
    reduce: Option<ReduceOp>,
    done: crate::plan::SemId,
) {
    let n = ctx.n();
    let next = (d + 1) % n;
    ctx.stage_pass(plan, w, d, ctx.chunk_bytes_total());
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::RegOp,
                route: Route::P2p { src: DeviceId(d), dst: DeviceId(next) },
                bytes: ctx.chunk_bytes_total(),
                msg_bytes: ctx.model.chunk_bytes,
                n_sms: ctx.model.n_sms,
            },
            blocking: true,
            done_sem: Some(done),
            done_scope: SyncScope::InterDevice,
            label: "nccl_ring_hop",
            effect: Some(Effect::CopyMat {
                src: ctx.chunk_view(d, chunk),
                dst: ctx.chunk_view(next, chunk),
                reduce,
            }),
        },
    );
    ctx.stage_pass(plan, w, next, ctx.chunk_bytes_total());
}

/// Ring all-reduce: reduce-scatter phase then all-gather phase
/// (`2(N-1)/N × S` per-device link traffic — the classic ring cost).
/// Appends one worker per device to `plan`.
pub fn ring_all_reduce(plan: &mut Plan, ctx: &RingCtx) {
    let n = ctx.n();
    assert!(n >= 2);
    // recv_done[d][k]: device d has received its step-k chunk.
    let steps = 2 * (n - 1);
    let recv_done: Vec<Vec<_>> =
        (0..n).map(|_| (0..steps).map(|_| plan.add_sem(0)).collect()).collect();
    for d in 0..n {
        let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("nccl_ar/d{d}"));
        plan.push(w, Op::Delay { dur: ctx.model.rendezvous, label: "nccl_rendezvous" });
        // --- reduce-scatter phase: send chunk (d - k), reduce-add at next.
        for k in 0..n - 1 {
            if k > 0 {
                plan.push(w, Op::Wait { sem: recv_done[d][k - 1], value: 1 });
            }
            let chunk = (d + n - k) % n;
            ring_hop(ctx, plan, w, d, chunk, Some(ReduceOp::Add), recv_done[(d + 1) % n][k]);
        }
        // after RS, device d owns complete chunk (d + 1) % n.
        // --- all-gather phase: circulate complete chunks (overwrite).
        for k in 0..n - 1 {
            plan.push(w, Op::Wait { sem: recv_done[d][n - 2 + k], value: 1 });
            let chunk = (d + 1 + n - k) % n;
            ring_hop(ctx, plan, w, d, chunk, None, recv_done[(d + 1) % n][n - 1 + k]);
        }
        // drain: wait for the final incoming chunk.
        plan.push(w, Op::Wait { sem: recv_done[d][steps - 1], value: 1 });
    }
}

/// Ring all-gather: `replicas[d]` initially holds shard `d` in chunk-row
/// block `d`; afterwards every device holds all shards.
pub fn ring_all_gather(plan: &mut Plan, ctx: &RingCtx) {
    let n = ctx.n();
    assert!(n >= 2);
    let recv_done: Vec<Vec<_>> =
        (0..n).map(|_| (0..n - 1).map(|_| plan.add_sem(0)).collect()).collect();
    for d in 0..n {
        let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("nccl_ag/d{d}"));
        plan.push(w, Op::Delay { dur: ctx.model.rendezvous, label: "nccl_rendezvous" });
        for k in 0..n - 1 {
            if k > 0 {
                plan.push(w, Op::Wait { sem: recv_done[d][k - 1], value: 1 });
            }
            let chunk = (d + n - k) % n;
            ring_hop(ctx, plan, w, d, chunk, None, recv_done[(d + 1) % n][k]);
        }
        plan.push(w, Op::Wait { sem: recv_done[d][n - 2], value: 1 });
    }
}

/// Ring reduce-scatter: afterwards device `d`'s chunk-row block `d` holds
/// the elementwise sum of all replicas' block `d`.
pub fn ring_reduce_scatter(plan: &mut Plan, ctx: &RingCtx) {
    let n = ctx.n();
    assert!(n >= 2);
    let recv_done: Vec<Vec<_>> =
        (0..n).map(|_| (0..n - 1).map(|_| plan.add_sem(0)).collect()).collect();
    for d in 0..n {
        let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("nccl_rs/d{d}"));
        plan.push(w, Op::Delay { dur: ctx.model.rendezvous, label: "nccl_rendezvous" });
        for k in 0..n - 1 {
            if k > 0 {
                plan.push(w, Op::Wait { sem: recv_done[d][k - 1], value: 1 });
            }
            // offset -1 so device d ends with complete chunk d
            let chunk = (d + 2 * n - k - 1) % n;
            ring_hop(ctx, plan, w, d, chunk, Some(ReduceOp::Add), recv_done[(d + 1) % n][k]);
        }
        plan.push(w, Op::Wait { sem: recv_done[d][n - 2], value: 1 });
    }
}

/// Pairwise all-to-all on contiguous row blocks: device `d` sends its row
/// block `j` to `dsts[j]`'s row block `d`. NCCL executes these as P2P
/// sends with the same rendezvous + staging overheads. `dsts` must be a
/// *separate* buffer set — an in-place exchange would race senders
/// against receivers (which is precisely why NCCL stages through channel
/// buffers). Pass `dsts = ctx.replicas` views over distinct buffers for
/// the functional path, or phantom views for timing-only runs.
pub fn all_to_all(plan: &mut Plan, ctx: &RingCtx, dsts: &[MatView]) {
    let n = ctx.n();
    assert_eq!(dsts.len(), n);
    let cr = ctx.chunk_rows();
    let dst_chunk = |dev: usize, chunk: usize| dsts[dev].sub(chunk * cr, 0, cr, dsts[dev].cols);
    for d in 0..n {
        let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("nccl_a2a/d{d}"));
        plan.push(w, Op::Delay { dur: ctx.model.rendezvous, label: "nccl_rendezvous" });
        for j in 0..n {
            if j == d {
                plan.push(
                    w,
                    Op::Compute {
                        dur: 0.0,
                        label: "nccl_a2a_local",
                        effect: Some(Effect::CopyMat {
                            src: ctx.chunk_view(d, j),
                            dst: dst_chunk(j, d),
                            reduce: None,
                        }),
                    },
                );
                continue;
            }
            ctx.stage_pass(plan, w, d, ctx.chunk_bytes_total());
            plan.push(
                w,
                Op::Transfer {
                    spec: TransferSpec {
                        mech: Mechanism::RegOp,
                        route: Route::P2p { src: DeviceId(d), dst: DeviceId(j) },
                        bytes: ctx.chunk_bytes_total(),
                        msg_bytes: ctx.model.chunk_bytes,
                        n_sms: ctx.model.n_sms / (n - 1) as f64,
                    },
                    blocking: false,
                    done_sem: None,
                    done_scope: SyncScope::InterDevice,
                    label: "nccl_a2a_send",
                    effect: Some(Effect::CopyMat {
                        src: ctx.chunk_view(d, j),
                        dst: dst_chunk(j, d),
                        reduce: None,
                    }),
                },
            );
        }
        // NCCL's grouped p2p completes when all sends/recvs land; model as
        // a trailing synchronization on the slowest link via blocking noop.
        plan.push(w, Op::Delay { dur: 0.0, label: "nccl_a2a_tail" });
    }
}

/// NVLS (NVSwitch multimem) collective paths. On Hopper+ NVSwitch, NCCL
/// implements all-reduce / reduce-scatter / all-gather through the same
/// in-network hardware PK uses (it is why the paper's Figure 6 gap tops
/// out at ~1.79x rather than the ring's 4x): the remaining difference is
/// NCCL's rendezvous, channel staging, and a less aggressive multimem
/// kernel. These builders emit that path; [`allreduce_time`] & friends
/// pick the faster of ring and NVLS like the library's tuner does.
const NVLS_EFF: f64 = 1.15; // extra bytes-equivalent of NCCL's NVLS kernel

fn nvls_worker(plan: &mut Plan, ctx: &RingCtx, d: usize, passes: &[(Route, f64)]) {
    let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("nccl_nvls/d{d}"));
    plan.push(w, Op::Delay { dur: ctx.model.rendezvous, label: "nccl_rendezvous" });
    ctx.stage_pass(plan, w, d, ctx.chunk_bytes_total());
    for (route, bytes) in passes {
        plan.push(
            w,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::Multimem,
                    route: *route,
                    bytes: *bytes,
                    msg_bytes: ctx.model.chunk_bytes,
                    n_sms: ctx.model.n_sms,
                },
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::IntraSm,
                label: "nccl_nvls",
                effect: None,
            },
        );
    }
}

/// Timing-only NVLS all-reduce: ld_reduce own shard + multicast it back.
pub fn nvls_all_reduce(plan: &mut Plan, ctx: &RingCtx) {
    let shard = ctx.chunk_bytes_total() * NVLS_EFF;
    for d in 0..ctx.n() {
        nvls_worker(plan, ctx, d, &[
            (Route::LdReduce { reader: DeviceId(d) }, shard),
            (Route::Multicast { src: DeviceId(d) }, shard),
        ]);
    }
}

/// Timing-only NVLS reduce-scatter: one ld_reduce pass per device.
pub fn nvls_reduce_scatter(plan: &mut Plan, ctx: &RingCtx) {
    let shard = ctx.chunk_bytes_total() * NVLS_EFF;
    for d in 0..ctx.n() {
        nvls_worker(plan, ctx, d, &[(Route::LdReduce { reader: DeviceId(d) }, shard)]);
    }
}

/// Timing-only NVLS all-gather: one multicast pass per device.
pub fn nvls_all_gather(plan: &mut Plan, ctx: &RingCtx) {
    let shard = ctx.chunk_bytes_total() * NVLS_EFF;
    for d in 0..ctx.n() {
        nvls_worker(plan, ctx, d, &[(Route::Multicast { src: DeviceId(d) }, shard)]);
    }
}

/// NCCL collective wall time: the faster of the ring and NVLS algorithms
/// (the library's internal tuner choice) for phantom `rows x cols` BF16
/// replicas.
fn coll_time(
    node: &NodeSpec,
    rows: usize,
    cols: usize,
    ring: fn(&mut Plan, &RingCtx),
    nvls: fn(&mut Plan, &RingCtx),
) -> f64 {
    use crate::exec::TimedExec;
    let mk_views = || {
        (0..node.num_devices)
            .map(|_| MatView {
                buf: crate::mem::BufId(0),
                b: 0,
                d: 0,
                row0: 0,
                col0: 0,
                rows,
                cols,
            })
            .collect::<Vec<_>>()
    };
    let mut t = f64::INFINITY;
    for f in [ring, nvls] {
        let ctx = RingCtx { node, model: NcclModel::default(), replicas: mk_views() };
        let mut plan = Plan::new();
        f(&mut plan, &ctx);
        // strip effects: timing only
        for w in &mut plan.workers {
            for op in &mut w.ops {
                if let Op::Transfer { effect, .. } = op {
                    *effect = None;
                }
            }
        }
        t = t.min(TimedExec::new(node.clone()).run(&plan).total_time);
    }
    t
}

/// NCCL all-reduce time (ring vs NVLS, whichever wins).
pub fn allreduce_time(node: &NodeSpec, rows: usize, cols: usize) -> f64 {
    coll_time(node, rows, cols, ring_all_reduce, nvls_all_reduce)
}

/// NCCL reduce-scatter time.
pub fn reducescatter_time(node: &NodeSpec, rows: usize, cols: usize) -> f64 {
    coll_time(node, rows, cols, ring_reduce_scatter, nvls_reduce_scatter)
}

/// NCCL all-gather time.
pub fn allgather_time(node: &NodeSpec, rows: usize, cols: usize) -> f64 {
    coll_time(node, rows, cols, ring_all_gather, nvls_all_gather)
}

/// Emit the reshape (pack or unpack) pass NCCL needs before/after a
/// collective whose logical partition is along the *tensor* (last)
/// dimension (Appendix B): a full read+write pass over the local buffer.
pub fn reshape_pass(plan: &mut Plan, node: &NodeSpec, model: &NcclModel, w: usize, dev: usize, bytes: f64) {
    let _ = node;
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::RegOp,
                route: Route::LocalHbm { dev: DeviceId(dev) },
                bytes,
                msg_bytes: model.chunk_bytes,
                n_sms: model.n_sms,
            },
            blocking: true,
            done_sem: None,
            done_scope: SyncScope::IntraSm,
            label: "nccl_reshape",
            effect: None,
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::TimedExec;
    use crate::util::prop::run_functional;
    use crate::mem::tile::Shape4;
    use crate::mem::MemPool;
    use crate::util::seeded_vec;

    fn setup(n: usize, rows: usize, cols: usize) -> (MemPool, Vec<crate::mem::BufId>, Vec<Vec<f32>>) {
        let mut pool = MemPool::new();
        let mut bufs = vec![];
        let mut inits = vec![];
        for d in 0..n {
            let data = seeded_vec(d as u64 + 10, rows * cols);
            inits.push(data.clone());
            bufs.push(pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), data));
        }
        (pool, bufs, inits)
    }

    fn elementwise_sum(inits: &[Vec<f32>]) -> Vec<f32> {
        let mut sum = vec![0.0; inits[0].len()];
        for v in inits {
            for (s, x) in sum.iter_mut().zip(v) {
                *s += x;
            }
        }
        sum
    }

    #[test]
    fn ring_all_reduce_is_sum_everywhere() {
        for n in [2, 4, 8] {
            let (rows, cols) = (n * 4, 6);
            let (mut pool, bufs, inits) = setup(n, rows, cols);
            let node = NodeSpec::test_node(n);
            let ctx = RingCtx {
                node: &node,
                model: NcclModel::default(),
                replicas: bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect(),
            };
            let mut plan = Plan::new();
            ring_all_reduce(&mut plan, &ctx);
            run_functional(&mut pool, &plan);
            let want = elementwise_sum(&inits);
            for &b in &bufs {
                crate::util::assert_allclose(&pool.get(b).data, &want, 1e-5, 1e-6);
            }
        }
    }

    #[test]
    fn ring_all_gather_distributes_shards() {
        let n = 4;
        let (rows, cols) = (n * 2, 3);
        let mut pool = MemPool::new();
        let node = NodeSpec::test_node(n);
        // each device starts with only its shard filled
        let mut bufs = vec![];
        let mut shards = vec![];
        for d in 0..n {
            let mut data = vec![0.0; rows * cols];
            let shard = seeded_vec(d as u64 + 50, 2 * cols);
            data[d * 2 * cols..(d + 1) * 2 * cols].copy_from_slice(&shard);
            shards.push(shard);
            bufs.push(pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), data));
        }
        let ctx = RingCtx {
            node: &node,
            model: NcclModel::default(),
            replicas: bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect(),
        };
        let mut plan = Plan::new();
        ring_all_gather(&mut plan, &ctx);
        run_functional(&mut pool, &plan);
        for &b in &bufs {
            for (d, shard) in shards.iter().enumerate() {
                assert_eq!(&pool.get(b).data[d * 2 * cols..(d + 1) * 2 * cols], &shard[..], "shard {d}");
            }
        }
    }

    #[test]
    fn ring_reduce_scatter_owns_chunk_d() {
        let n = 4;
        let (rows, cols) = (n * 2, 5);
        let (mut pool, bufs, inits) = setup(n, rows, cols);
        let node = NodeSpec::test_node(n);
        let ctx = RingCtx {
            node: &node,
            model: NcclModel::default(),
            replicas: bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect(),
        };
        let mut plan = Plan::new();
        ring_reduce_scatter(&mut plan, &ctx);
        run_functional(&mut pool, &plan);
        let want = elementwise_sum(&inits);
        for (d, &b) in bufs.iter().enumerate() {
            let got = &pool.get(b).data[d * 2 * cols..(d + 1) * 2 * cols];
            crate::util::assert_allclose(got, &want[d * 2 * cols..(d + 1) * 2 * cols], 1e-5, 1e-6);
        }
    }

    #[test]
    fn all_to_all_transposes_blocks() {
        let n = 4;
        let (rows, cols) = (n * 2, 3);
        let (mut pool, bufs, inits) = setup(n, rows, cols);
        let outs: Vec<_> = (0..n)
            .map(|d| pool.alloc(DeviceId(d), crate::mem::tile::Shape4::mat(rows, cols)))
            .collect();
        let node = NodeSpec::test_node(n);
        let ctx = RingCtx {
            node: &node,
            model: NcclModel::default(),
            replicas: bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect(),
        };
        let mut plan = Plan::new();
        let dst_views: Vec<MatView> = outs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect();
        all_to_all(&mut plan, &ctx, &dst_views);
        run_functional(&mut pool, &plan);
        let blk = 2 * cols;
        for d in 0..n {
            for j in 0..n {
                // out[j]'s block d == device d's original block j
                let got = &pool.get(outs[j]).data[d * blk..(d + 1) * blk];
                let want = &inits[d][j * blk..(j + 1) * blk];
                assert_eq!(got, want, "block {d}->{j}");
            }
        }
    }

    #[test]
    fn nccl_ar_time_scales_with_ring_traffic() {
        // Per-device link traffic for ring AR is 2S(N-1)/N; at 64 MB and
        // reg-op rate the transfer term alone is ~0.33 ms on H100s.
        let n = 8;
        let rows = 8 * 1024;
        let cols = 4096; // S = 64 Mi elements... keep it moderate: views are metadata-only for timing
        let node = NodeSpec::test_node(n);
        let mut pool = MemPool::new();
        let bufs: Vec<_> = (0..n).map(|d| pool.alloc(DeviceId(d), Shape4::mat(1, 1))).collect();
        // timing-only plan: views describe shapes, no effects needed
        let replicas: Vec<MatView> = bufs
            .iter()
            .map(|&b| MatView { buf: b, b: 0, d: 0, row0: 0, col0: 0, rows, cols })
            .collect();
        let ctx = RingCtx { node: &node, model: NcclModel { staged: true, ..Default::default() }, replicas };
        let mut plan = Plan::new();
        // strip effects: rebuild with effect-free hops by zeroing functional use
        ring_all_reduce(&mut plan, &ctx);
        for w in &mut plan.workers {
            for op in &mut w.ops {
                if let Op::Transfer { effect, .. } = op {
                    *effect = None;
                }
            }
        }
        let r = TimedExec::new(node.clone()).run(&plan);
        let s_bytes = (rows * cols) as f64 * 2.0;
        let ring_bytes = 2.0 * s_bytes * (n - 1) as f64 / n as f64;
        let floor = ring_bytes / (node.gpu.nvlink_bw * node.gpu.reg_peak_frac);
        assert!(r.total_time > floor, "must exceed pure ring traffic time");
        assert!(r.total_time < 4.0 * floor, "but not pathologically slow: {} vs {floor}", r.total_time);
    }
}
