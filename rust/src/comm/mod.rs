//! Communication-library baselines (§3.1.4 "design overheads").
//!
//! PK's analysis attributes concrete costs to the design choices of the
//! standard libraries; this module models those choices faithfully so the
//! paper's comparisons (Figure 6, Figures 15–17, the NVSHMEM latency
//! claims) arise from the same causes:
//!
//! * [`nccl`] — ring collectives with **two-way rendezvous** before every
//!   operation, **staged channel buffers** (extra HBM passes), chunked
//!   register-op transfers, and a **contiguity requirement** that forces
//!   reshape copies for tensor-dimension collectives (Appendix B).
//! * [`nvshmem`] — one-sided register-op transfers where every remote
//!   access pays a `__ldg` peer-address load plus a group sync, costing
//!   4.5× element-wise latency and ~20 GB/s of bandwidth (§3.1.4).

pub mod nccl;
pub mod nvshmem;

pub use nccl::NcclModel;
