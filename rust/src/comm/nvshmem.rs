//! NVSHMEM-style one-sided access model (§3.1.4 "peer-memory access and
//! synchronization").
//!
//! NVSHMEM's public API performs, on every remote access, a global-memory
//! load (`__ldg`) to fetch the peer address and a group synchronization
//! (`__syncthreads`). PK keeps peer addresses in registers and drops the
//! unnecessary syncs, which the paper measures as **4.5× lower
//! element-wise NVLink access latency and ~20 GB/s higher bandwidth
//! utilization**. This module encodes both costs so the µ2 exhibit can be
//! regenerated and so an NVSHMEM-flavoured transfer can be used as a
//! baseline inside kernels.

use crate::hw::spec::GpuSpec;
use crate::xfer::curves;

/// Library flavor for peer access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerApi {
    /// NVSHMEM public API: `__ldg` address fetch + group sync per access.
    Nvshmem,
    /// PK: peer address pinned in registers, no extra synchronization.
    Pk,
}

/// The latency-multiplier the `__ldg` + `__syncthreads` pair adds to an
/// element-wise remote access (paper: 4.5×).
pub const NVSHMEM_LATENCY_FACTOR: f64 = 4.5;

/// Bandwidth lost to per-access overheads (paper: ~20 GB/s).
pub const NVSHMEM_BW_PENALTY: f64 = 20e9;

/// Element-wise remote access latency (seconds) through each API.
/// The base access is one NVLink round trip.
pub fn elementwise_latency(spec: &GpuSpec, api: PeerApi) -> f64 {
    let base = spec.nvlink_latency;
    match api {
        PeerApi::Pk => base,
        PeerApi::Nvshmem => base * NVSHMEM_LATENCY_FACTOR,
    }
}

/// Achievable register-op bandwidth through each API (bytes/s), for
/// `msg_bytes` messages issued from `n_sms` SMs.
pub fn reg_bandwidth(spec: &GpuSpec, api: PeerApi, msg_bytes: f64, n_sms: f64) -> f64 {
    let pk = curves::reg_rate(spec, msg_bytes, n_sms);
    match api {
        PeerApi::Pk => pk,
        PeerApi::Nvshmem => (pk - NVSHMEM_BW_PENALTY).max(pk * 0.25),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_factor_matches_paper() {
        let g = GpuSpec::h100();
        let pk = elementwise_latency(&g, PeerApi::Pk);
        let nv = elementwise_latency(&g, PeerApi::Nvshmem);
        assert!((nv / pk - 4.5).abs() < 1e-12, "paper: 4.5x lower latency with PK");
    }

    #[test]
    fn bandwidth_penalty_about_20gbps() {
        let g = GpuSpec::h100();
        let pk = reg_bandwidth(&g, PeerApi::Pk, 1e6, 132.0);
        let nv = reg_bandwidth(&g, PeerApi::Nvshmem, 1e6, 132.0);
        assert!((pk - nv - 20e9).abs() < 1e6, "~20 GB/s gap, got {}", (pk - nv) / 1e9);
    }

    #[test]
    fn penalty_never_negative() {
        let g = GpuSpec::h100();
        // tiny message, single SM: pk rate is small but nvshmem stays positive
        let nv = reg_bandwidth(&g, PeerApi::Nvshmem, 64.0, 1.0);
        assert!(nv > 0.0);
    }
}
