//! Topology: device identities, NVLink ports, NVSwitch routing, and the
//! inter-node NIC ports of a cluster.
//!
//! On an HGX baseboard every GPU has one NVLink bundle into the NVSwitch
//! fabric, which is non-blocking (§2.1): any permutation of point-to-point
//! transfers proceeds at full per-port bandwidth; contention happens only at
//! the per-device *egress* and *ingress* ports, which is exactly what the
//! simulator's resource model charges.
//!
//! Across nodes the same argument holds for a rail-optimized RDMA fabric
//! (see [`crate::hw::cluster`]): every GPU owns one NIC, same-rank GPUs
//! connect through a non-blocking per-rail switch plane, and contention is
//! charged at the endpoint [`Port::NicEgress`] / [`Port::NicIngress`]
//! resources. NVSwitch services (multicast, in-fabric reduction) never
//! cross a node boundary, so their port sets are scoped to the device's
//! node.


/// Identifies one GPU within a node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

impl std::fmt::Display for DeviceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "gpu{}", self.0)
    }
}

/// A directed NVLink port: each device has one egress and one ingress port
/// into the NVSwitch fabric, each at `nvlink_bw` (unidirectional figure).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Port {
    Egress(DeviceId),
    Ingress(DeviceId),
    /// The per-device host-side PCIe link (copy-engine staging, launches).
    Pcie(DeviceId),
    /// The NVSwitch multimem reduction unit serving one destination device.
    /// In-fabric reductions consume switch-side bandwidth proportional to
    /// the reduced output, charged per reading device.
    SwitchReduce(DeviceId),
    /// Device HBM bandwidth (charged by staging copies and local
    /// reshape/pack passes — the §3.1.4 "intermediate buffering" overhead).
    Hbm(DeviceId),
    /// The per-device DMA copy engine (host-initiated transfers run
    /// through it serially; §3.1.2).
    CopyEngine(DeviceId),
    /// The device's NIC send side: every GPUDirect RDMA write leaving the
    /// device crosses it (per-GPU NIC, rail-optimized fabric).
    NicEgress(DeviceId),
    /// The device's NIC receive side.
    NicIngress(DeviceId),
}

/// Static topology of a node, or of a cluster of identical nodes
/// (node-major global device ids; `devices_per_node == num_devices` for a
/// single node).
#[derive(Clone, Debug)]
pub struct Topology {
    pub num_devices: usize,
    pub nvswitch: bool,
    pub devices_per_node: usize,
}

impl Topology {
    /// Single-node topology (the paper's HGX baseboard).
    pub fn new(num_devices: usize, nvswitch: bool) -> Self {
        assert!(num_devices >= 1);
        Self { num_devices, nvswitch, devices_per_node: num_devices }
    }

    /// Cluster topology: `num_nodes` × `devices_per_node` GPUs.
    pub fn cluster(num_nodes: usize, devices_per_node: usize, nvswitch: bool) -> Self {
        assert!(num_nodes >= 1 && devices_per_node >= 1);
        Self { num_devices: num_nodes * devices_per_node, nvswitch, devices_per_node }
    }

    /// All devices (across all nodes).
    pub fn devices(&self) -> impl Iterator<Item = DeviceId> + '_ {
        (0..self.num_devices).map(DeviceId)
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.num_devices / self.devices_per_node
    }

    /// Node index of a device.
    pub fn node_of(&self, d: DeviceId) -> usize {
        d.0 / self.devices_per_node
    }

    /// Whether two devices share a node (NVLink reachability).
    pub fn same_node(&self, a: DeviceId, b: DeviceId) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// The devices of one node.
    pub fn node_devices(&self, node: usize) -> impl Iterator<Item = DeviceId> + '_ {
        let base = node * self.devices_per_node;
        (base..base + self.devices_per_node).map(DeviceId)
    }

    /// Ring neighbour (used by NCCL-style ring collectives and Ring
    /// Attention): the next device in a fixed ring order.
    pub fn ring_next(&self, d: DeviceId) -> DeviceId {
        DeviceId((d.0 + 1) % self.num_devices)
    }

    /// Ring neighbour in the other direction.
    pub fn ring_prev(&self, d: DeviceId) -> DeviceId {
        DeviceId((d.0 + self.num_devices - 1) % self.num_devices)
    }

    /// The ports a point-to-point NVLink transfer occupies. With NVSwitch
    /// the fabric is non-blocking, so only the endpoint ports are charged;
    /// without it (direct-attached mesh) the same model holds for a single
    /// hop. A local (src == dst) copy occupies no interconnect ports.
    /// NVLink does not cross nodes — cross-node pairs must route over
    /// [`Topology::rdma_ports`].
    pub fn p2p_ports(&self, src: DeviceId, dst: DeviceId) -> Vec<Port> {
        if src == dst {
            vec![]
        } else {
            assert!(
                self.same_node(src, dst),
                "NVLink P2p {src} -> {dst} crosses a node boundary; use Route::Rdma"
            );
            vec![Port::Egress(src), Port::Ingress(dst)]
        }
    }

    /// The ports a cross-node GPUDirect RDMA transfer occupies: the source
    /// and destination NICs. With a rail-optimized fabric the middle is
    /// non-blocking, so — exactly like NVSwitch inside the node — only the
    /// endpoints are charged.
    pub fn rdma_ports(&self, src: DeviceId, dst: DeviceId) -> Vec<Port> {
        assert!(
            !self.same_node(src, dst),
            "RDMA {src} -> {dst} within one node; use Route::P2p over NVLink"
        );
        vec![Port::NicEgress(src), Port::NicIngress(dst)]
    }

    /// Ports occupied by an in-fabric multicast write from `src` to every
    /// device *of its node*: the source sends one copy to the switch, which
    /// replicates it to every destination's ingress port (NVSwitch
    /// broadcast, §2.1 / Appendix F). Multimem never crosses nodes.
    pub fn multicast_ports(&self, src: DeviceId) -> Vec<Port> {
        let mut ports = vec![Port::Egress(src)];
        for d in self.node_devices(self.node_of(src)) {
            ports.push(Port::Ingress(d));
        }
        ports
    }

    /// Ports occupied by an in-fabric `ld_reduce` performed by `reader`:
    /// to deliver S reduced bytes, the switch pulls S bytes from *every*
    /// device's egress within the reader's node, reduces in-fabric, and the
    /// result enters the reader's ingress port (multimem semantics,
    /// Appendix F). Charging all egresses makes concurrent readers contend
    /// there, which is what bounds in-network all-reduce at ~S bytes per
    /// port instead of N·S (§3.1.3 in-network acceleration).
    pub fn ld_reduce_ports(&self, reader: DeviceId) -> Vec<Port> {
        let mut ports = vec![Port::SwitchReduce(reader), Port::Ingress(reader)];
        for d in self.node_devices(self.node_of(reader)) {
            ports.push(Port::Egress(d));
        }
        ports
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps() {
        let t = Topology::new(8, true);
        assert_eq!(t.ring_next(DeviceId(7)), DeviceId(0));
        assert_eq!(t.ring_prev(DeviceId(0)), DeviceId(7));
        assert_eq!(t.ring_next(DeviceId(3)), DeviceId(4));
    }

    #[test]
    fn ring_next_prev_inverse() {
        let t = Topology::new(5, true);
        for d in t.devices() {
            assert_eq!(t.ring_prev(t.ring_next(d)), d);
        }
    }

    #[test]
    fn p2p_ports_endpoints_only() {
        let t = Topology::new(8, true);
        let ports = t.p2p_ports(DeviceId(1), DeviceId(5));
        assert_eq!(ports, vec![Port::Egress(DeviceId(1)), Port::Ingress(DeviceId(5))]);
        assert!(t.p2p_ports(DeviceId(2), DeviceId(2)).is_empty());
    }

    #[test]
    fn multicast_hits_all_ingress() {
        let t = Topology::new(4, true);
        let ports = t.multicast_ports(DeviceId(0));
        assert_eq!(ports.len(), 5); // 1 egress + 4 ingress
        assert!(ports.contains(&Port::Ingress(DeviceId(3))));
    }

    #[test]
    fn devices_enumerates_all() {
        let t = Topology::new(3, true);
        let ds: Vec<_> = t.devices().collect();
        assert_eq!(ds, vec![DeviceId(0), DeviceId(1), DeviceId(2)]);
    }

    #[test]
    fn cluster_node_scoping() {
        let t = Topology::cluster(3, 4, true);
        assert_eq!(t.num_devices, 12);
        assert_eq!(t.num_nodes(), 3);
        assert_eq!(t.node_of(DeviceId(7)), 1);
        assert!(t.same_node(DeviceId(4), DeviceId(7)));
        assert!(!t.same_node(DeviceId(3), DeviceId(4)));
        assert_eq!(t.node_devices(2).collect::<Vec<_>>(), vec![DeviceId(8), DeviceId(9), DeviceId(10), DeviceId(11)]);
    }

    #[test]
    fn rdma_ports_are_nic_endpoints() {
        let t = Topology::cluster(2, 4, true);
        let ports = t.rdma_ports(DeviceId(1), DeviceId(5));
        assert_eq!(ports, vec![Port::NicEgress(DeviceId(1)), Port::NicIngress(DeviceId(5))]);
    }

    #[test]
    #[should_panic(expected = "crosses a node boundary")]
    fn p2p_rejects_cross_node() {
        let t = Topology::cluster(2, 4, true);
        let _ = t.p2p_ports(DeviceId(0), DeviceId(4));
    }

    #[test]
    fn multicast_and_ld_reduce_stay_in_node() {
        let t = Topology::cluster(2, 4, true);
        let mc = t.multicast_ports(DeviceId(5));
        assert_eq!(mc.len(), 5); // 1 egress + 4 node-local ingress
        assert!(mc.contains(&Port::Ingress(DeviceId(7))));
        assert!(!mc.contains(&Port::Ingress(DeviceId(0))));
        let lr = t.ld_reduce_ports(DeviceId(2));
        assert!(lr.contains(&Port::Egress(DeviceId(3))));
        assert!(!lr.contains(&Port::Egress(DeviceId(4))));
    }

    #[test]
    fn single_node_topology_unchanged_by_cluster_fields() {
        // the devices_per_node default keeps every single-node port set
        // identical to the pre-cluster model (regression guard)
        let t = Topology::new(8, true);
        assert_eq!(t.num_nodes(), 1);
        assert_eq!(t.multicast_ports(DeviceId(0)).len(), 9);
        assert_eq!(t.ld_reduce_ports(DeviceId(0)).len(), 10);
    }
}
