//! Node topology: device identities, NVLink ports, and NVSwitch routing.
//!
//! On an HGX baseboard every GPU has one NVLink bundle into the NVSwitch
//! fabric, which is non-blocking (§2.1): any permutation of point-to-point
//! transfers proceeds at full per-port bandwidth; contention happens only at
//! the per-device *egress* and *ingress* ports, which is exactly what the
//! simulator's resource model charges.


/// Identifies one GPU within a node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

impl std::fmt::Display for DeviceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "gpu{}", self.0)
    }
}

/// A directed NVLink port: each device has one egress and one ingress port
/// into the NVSwitch fabric, each at `nvlink_bw` (unidirectional figure).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Port {
    Egress(DeviceId),
    Ingress(DeviceId),
    /// The per-device host-side PCIe link (copy-engine staging, launches).
    Pcie(DeviceId),
    /// The NVSwitch multimem reduction unit serving one destination device.
    /// In-fabric reductions consume switch-side bandwidth proportional to
    /// the reduced output, charged per reading device.
    SwitchReduce(DeviceId),
    /// Device HBM bandwidth (charged by staging copies and local
    /// reshape/pack passes — the §3.1.4 "intermediate buffering" overhead).
    Hbm(DeviceId),
    /// The per-device DMA copy engine (host-initiated transfers run
    /// through it serially; §3.1.2).
    CopyEngine(DeviceId),
}

/// Static topology of a node.
#[derive(Clone, Debug)]
pub struct Topology {
    pub num_devices: usize,
    pub nvswitch: bool,
}

impl Topology {
    pub fn new(num_devices: usize, nvswitch: bool) -> Self {
        assert!(num_devices >= 1);
        Self { num_devices, nvswitch }
    }

    /// All devices in the node.
    pub fn devices(&self) -> impl Iterator<Item = DeviceId> + '_ {
        (0..self.num_devices).map(DeviceId)
    }

    /// Ring neighbour (used by NCCL-style ring collectives and Ring
    /// Attention): the next device in a fixed ring order.
    pub fn ring_next(&self, d: DeviceId) -> DeviceId {
        DeviceId((d.0 + 1) % self.num_devices)
    }

    /// Ring neighbour in the other direction.
    pub fn ring_prev(&self, d: DeviceId) -> DeviceId {
        DeviceId((d.0 + self.num_devices - 1) % self.num_devices)
    }

    /// The ports a point-to-point transfer occupies. With NVSwitch the
    /// fabric is non-blocking, so only the endpoint ports are charged;
    /// without it (direct-attached mesh) the same model holds for a single
    /// hop. A local (src == dst) copy occupies no interconnect ports.
    pub fn p2p_ports(&self, src: DeviceId, dst: DeviceId) -> Vec<Port> {
        if src == dst {
            vec![]
        } else {
            vec![Port::Egress(src), Port::Ingress(dst)]
        }
    }

    /// Ports occupied by an in-fabric multicast write from `src` to every
    /// device: the source sends one copy to the switch, which replicates it
    /// to every destination's ingress port (NVSwitch broadcast, §2.1 /
    /// Appendix F).
    pub fn multicast_ports(&self, src: DeviceId) -> Vec<Port> {
        let mut ports = vec![Port::Egress(src)];
        for d in self.devices() {
            ports.push(Port::Ingress(d));
        }
        ports
    }

    /// Ports occupied by an in-fabric `ld_reduce` performed by `reader`:
    /// to deliver S reduced bytes, the switch pulls S bytes from *every*
    /// device's egress, reduces in-fabric, and the result enters the
    /// reader's ingress port (multimem semantics, Appendix F). Charging
    /// all egresses makes concurrent readers contend there, which is what
    /// bounds in-network all-reduce at ~S bytes per port instead of N·S
    /// (§3.1.3 in-network acceleration).
    pub fn ld_reduce_ports(&self, reader: DeviceId) -> Vec<Port> {
        let mut ports = vec![Port::SwitchReduce(reader), Port::Ingress(reader)];
        for d in self.devices() {
            ports.push(Port::Egress(d));
        }
        ports
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps() {
        let t = Topology::new(8, true);
        assert_eq!(t.ring_next(DeviceId(7)), DeviceId(0));
        assert_eq!(t.ring_prev(DeviceId(0)), DeviceId(7));
        assert_eq!(t.ring_next(DeviceId(3)), DeviceId(4));
    }

    #[test]
    fn ring_next_prev_inverse() {
        let t = Topology::new(5, true);
        for d in t.devices() {
            assert_eq!(t.ring_prev(t.ring_next(d)), d);
        }
    }

    #[test]
    fn p2p_ports_endpoints_only() {
        let t = Topology::new(8, true);
        let ports = t.p2p_ports(DeviceId(1), DeviceId(5));
        assert_eq!(ports, vec![Port::Egress(DeviceId(1)), Port::Ingress(DeviceId(5))]);
        assert!(t.p2p_ports(DeviceId(2), DeviceId(2)).is_empty());
    }

    #[test]
    fn multicast_hits_all_ingress() {
        let t = Topology::new(4, true);
        let ports = t.multicast_ports(DeviceId(0));
        assert_eq!(ports.len(), 5); // 1 egress + 4 ingress
        assert!(ports.contains(&Port::Ingress(DeviceId(3))));
    }

    #[test]
    fn devices_enumerates_all() {
        let t = Topology::new(3, true);
        let ds: Vec<_> = t.devices().collect();
        assert_eq!(ds, vec![DeviceId(0), DeviceId(1), DeviceId(2)]);
    }
}
