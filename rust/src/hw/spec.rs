//! GPU and node hardware specifications.
//!
//! The two presets, [`GpuSpec::h100`] and [`GpuSpec::b200`], carry the
//! paper's measured constants (Table 1, Figures 2–3, §2.1, §3.1.3). The
//! transfer-mechanism bandwidth *curves* derived from these constants live
//! in [`crate::xfer::curves`].


/// GPU architecture generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arch {
    /// H100 (SXM, HGX node, NVLink 4 / NVSwitch 3).
    Hopper,
    /// B200 (NVLink 5 / NVSwitch 4).
    Blackwell,
}

impl std::fmt::Display for Arch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Arch::Hopper => write!(f, "H100"),
            Arch::Blackwell => write!(f, "B200"),
        }
    }
}

/// Per-GPU hardware constants. All bandwidths in bytes/s, times in seconds.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub arch: Arch,
    /// Streaming multiprocessors per GPU.
    pub num_sms: u32,
    /// Dense BF16 tensor-core throughput, FLOP/s (paper §3.1.3: 989e12 for H100).
    pub tc_flops: f64,
    /// CUDA-core (elementwise f32) throughput, FLOP/s.
    pub cuda_core_flops: f64,
    /// HBM capacity in bytes.
    pub hbm_bytes: u64,
    /// HBM bandwidth (paper §2.1: ~3 TB/s on H100).
    pub hbm_bw: f64,
    /// L2 capacity (50 MB on H100) and bandwidth (~12 TB/s).
    pub l2_bytes: u64,
    pub l2_bw: f64,
    /// Shared memory per SM (227 KB usable on H100) and aggregate bandwidth.
    pub smem_per_sm: u64,
    pub smem_bw: f64,
    /// NVLink unidirectional bandwidth per GPU (450 GB/s H100, 900 GB/s B200).
    pub nvlink_bw: f64,
    /// PCIe bandwidth (host link, 64 GB/s gen5).
    pub pcie_bw: f64,

    // ---- transfer-mechanism calibration (Table 1, Figures 2-3) ----
    /// Peak achievable fraction of `nvlink_bw` per mechanism for large
    /// messages with enough SMs (Table 1).
    pub ce_peak_frac: f64,
    pub tma_peak_frac: f64,
    pub reg_peak_frac: f64,
    /// Message size at which each mechanism reaches half of its own peak
    /// (drives the Figure 2 ramp; see `xfer::curves` for the model).
    pub ce_half_msg: f64,
    pub tma_half_msg: f64,
    pub reg_half_msg: f64,
    /// SMs required to saturate NVLink with device-initiated transfers
    /// (Figure 3: ~15 for TMA, ~76 for register ops on H100).
    pub tma_sat_sms: f64,
    pub reg_sat_sms: f64,
    /// Maximum single TMA message (bounded by SMEM: 227 KB, Figure 2 note).
    pub tma_max_msg: u64,

    // ---- synchronization + launch (§3.1.1, §3.1.3) ----
    /// Intra-SM mbarrier synchronization latency (64 ns).
    pub mbarrier_sync: f64,
    /// Inter-SM synchronization through HBM (832 ns).
    pub hbm_sync: f64,
    /// Inter-device signal latency over NVLink (one-way flag write).
    pub nvlink_signal: f64,
    /// Kernel launch overhead, host side + setup/teardown.
    pub kernel_launch: f64,
    /// Per-flow NVLink base latency (first-byte).
    pub nvlink_latency: f64,
    /// Extra per-message destination-side cost of an *atomic* reduction
    /// (red/atom op) relative to a plain store; serialises at the
    /// destination port (§3.1.3 Table 3 discussion: residual comm near
    /// K=2048 "arises from atomic additions").
    pub atomic_overhead_frac: f64,
}

impl GpuSpec {
    /// Nvidia H100 80 GB SXM (HGX), the paper's primary platform.
    pub fn h100() -> Self {
        GpuSpec {
            arch: Arch::Hopper,
            num_sms: 132,
            tc_flops: 989e12,       // §3.1.3 (dense BF16)
            cuda_core_flops: 67e12, // FP32 CUDA cores
            hbm_bytes: 80 * (1 << 30),
            hbm_bw: 3.35e12, // §2.1 says ~3 TB/s; datasheet 3.35
            l2_bytes: 50 * (1 << 20),
            l2_bw: 12e12,
            smem_per_sm: 227 * 1024,
            smem_bw: 33e12,
            nvlink_bw: 450e9, // unidirectional, §2.1
            pcie_bw: 64e9,
            // Table 1 (H100 column): 368.82 / 350.01 / 342.68 GB/s observed
            ce_peak_frac: 0.82,
            tma_peak_frac: 0.78,
            reg_peak_frac: 0.76,
            // Figure 2: CE needs >=256 MB for >80% util -> half-size ~6.4 MB;
            // TMA near-peak at 2 KB -> half ~256 B; reg efficient at 128 B.
            ce_half_msg: 6.4e6,
            tma_half_msg: 96.0,
            reg_half_msg: 32.0,
            // Figure 3: TMA ~15 SMs, register ops ~76 SMs to saturate.
            tma_sat_sms: 15.0,
            reg_sat_sms: 76.0,
            tma_max_msg: 227 * 1024,
            // §3.1.3 microbenchmarks
            mbarrier_sync: 64e-9,
            hbm_sync: 832e-9,
            nvlink_signal: 1.2e-6,
            kernel_launch: 3.5e-6,
            nvlink_latency: 1.0e-6,
            atomic_overhead_frac: 0.15,
        }
    }

    /// Nvidia B200 (Appendix A platform).
    pub fn b200() -> Self {
        GpuSpec {
            arch: Arch::Blackwell,
            num_sms: 148,
            tc_flops: 2250e12, // dense BF16 (§1: 7.2x A100's 312)
            cuda_core_flops: 80e12,
            hbm_bytes: 192 * (1 << 30),
            hbm_bw: 8e12,
            l2_bytes: 126 * (1 << 20),
            l2_bw: 20e12,
            smem_per_sm: 227 * 1024,
            smem_bw: 40e12,
            nvlink_bw: 900e9, // NVLink 5, Appendix A
            pcie_bw: 64e9,
            // Table 1 (B200 column): 726.13 / 669.12 / 628.35 GB/s observed
            ce_peak_frac: 0.81,
            tma_peak_frac: 0.74,
            reg_peak_frac: 0.70,
            ce_half_msg: 12.8e6, // 2x link speed -> same time constant
            tma_half_msg: 192.0,
            reg_half_msg: 64.0,
            // Figure 3 scaling: per-SM issue rate grows less than link speed.
            tma_sat_sms: 18.0,
            reg_sat_sms: 92.0,
            tma_max_msg: 227 * 1024,
            mbarrier_sync: 64e-9,
            hbm_sync: 832e-9,
            nvlink_signal: 1.2e-6,
            kernel_launch: 3.5e-6,
            nvlink_latency: 1.0e-6,
            atomic_overhead_frac: 0.15,
        }
    }

    /// Sustained tensor-core throughput for a well-pipelined GEMM
    /// (fraction of peak actually achieved by a tuned kernel; the paper's
    /// own GEMM numbers in Table 3 imply ~0.85 of peak at large K).
    pub fn sustained_tc_flops(&self) -> f64 {
        0.85 * self.tc_flops
    }

    /// Per-SM share of the sustained tensor-core throughput when `n` of the
    /// `num_sms` SMs run compute. Compute scales linearly with SM count
    /// (§3.1.3 intra-SM discussion point 1).
    pub fn tc_flops_for_sms(&self, n: u32) -> f64 {
        self.sustained_tc_flops() * (n.min(self.num_sms) as f64) / (self.num_sms as f64)
    }
}

/// A multi-GPU node: `num_devices` identical GPUs on a non-blocking
/// NVSwitch fabric (the paper's HGX 8-GPU baseboard).
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub gpu: GpuSpec,
    pub num_devices: usize,
    /// NVSwitch present (non-blocking any-to-any; always true on HGX).
    pub nvswitch: bool,
    /// NVSwitch SHARP-style in-network multicast/reduction available
    /// (requires the multicast-object setup of Appendix F).
    pub multimem: bool,
}

impl NodeSpec {
    /// The paper's primary testbed: 8×H100 SXM with NVSwitch + multimem.
    pub fn hgx_h100() -> Self {
        NodeSpec { gpu: GpuSpec::h100(), num_devices: 8, nvswitch: true, multimem: true }
    }

    /// Appendix A testbed: 8×B200.
    pub fn hgx_b200() -> Self {
        NodeSpec { gpu: GpuSpec::b200(), num_devices: 8, nvswitch: true, multimem: true }
    }

    /// A smaller node for functional tests.
    pub fn test_node(num_devices: usize) -> Self {
        NodeSpec { gpu: GpuSpec::h100(), num_devices, nvswitch: true, multimem: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_matches_paper_constants() {
        let g = GpuSpec::h100();
        assert_eq!(g.num_sms, 132);
        assert_eq!(g.tc_flops, 989e12);
        assert_eq!(g.nvlink_bw, 450e9);
        // Table 1 observed bandwidths reproduce within 1%:
        assert!((g.ce_peak_frac * 450.0 - 368.82 / 1.0).abs() < 5.0);
        assert!((g.tma_peak_frac * 450.0 - 350.01).abs() < 5.0);
        assert!((g.reg_peak_frac * 450.0 - 342.68).abs() < 5.0);
        // §3.1.3 sync constants
        assert_eq!(g.mbarrier_sync, 64e-9);
        assert_eq!(g.hbm_sync, 832e-9);
    }

    #[test]
    fn b200_matches_paper_constants() {
        let g = GpuSpec::b200();
        assert_eq!(g.nvlink_bw, 900e9);
        assert!((g.ce_peak_frac * 900.0 - 726.13).abs() < 5.0);
        assert!((g.tma_peak_frac * 900.0 - 669.12).abs() < 5.0);
        assert!((g.reg_peak_frac * 900.0 - 628.35).abs() < 5.0);
    }

    #[test]
    fn compute_scales_linearly_with_sms() {
        let g = GpuSpec::h100();
        let full = g.tc_flops_for_sms(132);
        let half = g.tc_flops_for_sms(66);
        assert!((half * 2.0 - full).abs() / full < 1e-12);
        // clamped at num_sms
        assert_eq!(g.tc_flops_for_sms(200), full);
    }

    #[test]
    fn hidden_k_threshold_from_cost_model() {
        // §3.1.3: K >= sR/2B with s=2, R=989e12, B=450e9 -> K >= ~2197.
        let g = GpuSpec::h100();
        let k = 2.0 * g.tc_flops / (2.0 * g.nvlink_bw);
        assert!((k - 2197.0).abs() < 1.0, "got {k}");
    }

    #[test]
    fn node_presets() {
        let n = NodeSpec::hgx_h100();
        assert_eq!(n.num_devices, 8);
        assert!(n.nvswitch && n.multimem);
        assert_eq!(NodeSpec::hgx_b200().gpu.arch, Arch::Blackwell);
    }
}
