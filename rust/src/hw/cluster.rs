//! Multi-node cluster specification: `N` identical NVSwitch nodes joined by
//! a rail-optimized RDMA fabric.
//!
//! The paper's analysis (§3.1) stops at one HGX node; this layer extends
//! the same calibrated-resource methodology across nodes. Each GPU owns one
//! NIC (the rail-optimized reference pod: a 400 Gb/s ConnectX-7 per H100,
//! i.e. 50 GB/s unidirectional), and GPU `p` of node `k` reaches GPU `p`
//! of any other node through its rail's switch plane without
//! oversubscription — so, exactly as with NVSwitch inside the node,
//! contention is charged only at the endpoint resources
//! ([`Port::NicEgress`] / [`Port::NicIngress`]).
//!
//! Device identities are **global and node-major**: device `g` lives on
//! node `g / node.num_devices` at local rank `g % node.num_devices`. A
//! one-node cluster is bit-identical to the plain [`NodeSpec`] path — same
//! topology, same ports, same curves — which the integration tests pin
//! down as a regression guard.
//!
//! [`Port::NicEgress`]: crate::hw::topology::Port::NicEgress
//! [`Port::NicIngress`]: crate::hw::topology::Port::NicIngress

use crate::hw::spec::NodeSpec;
use crate::hw::topology::Topology;
use crate::hw::DeviceId;

/// A cluster of `num_nodes` identical [`NodeSpec`] nodes plus the NIC/RDMA
/// constants of the inter-node fabric. All bandwidths in bytes/s, times in
/// seconds.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// The (identical) per-node hardware.
    pub node: NodeSpec,
    pub num_nodes: usize,
    /// Per-GPU NIC unidirectional bandwidth (400 Gb/s ConnectX-7 = 50e9).
    pub nic_bw: f64,
    /// Peak achievable fraction of `nic_bw` for large-message GPUDirect
    /// RDMA (IB/RoCE header + protocol overhead).
    pub nic_peak_frac: f64,
    /// Message size at which RDMA reaches half of its own peak (verbs
    /// posting overhead dominates small writes; ~64 KB messages are needed
    /// to approach line rate).
    pub rdma_half_msg: f64,
    /// One-way first-byte latency across the inter-node fabric (GPUDirect
    /// write posted by the proxy, switch hops included).
    pub nic_latency: f64,
    /// Rail-optimized fabric: same-rank GPUs of different nodes connect
    /// through a non-blocking per-rail switch plane, so inter-node flows
    /// contend only at the endpoint NICs (mirrors the NVSwitch argument).
    pub rail_optimized: bool,
}

impl ClusterSpec {
    /// A cluster with the reference fabric constants.
    pub fn new(node: NodeSpec, num_nodes: usize, nic_bw: f64) -> Self {
        assert!(num_nodes >= 1);
        assert!(nic_bw > 0.0);
        ClusterSpec {
            node,
            num_nodes,
            nic_bw,
            nic_peak_frac: 0.92,
            rdma_half_msg: 8.0 * 1024.0,
            nic_latency: 3.0e-6,
            rail_optimized: true,
        }
    }

    /// Wrap a single node: the degenerate cluster every existing
    /// single-node code path runs on (no NIC ports are ever charged).
    pub fn single(node: NodeSpec) -> Self {
        Self::new(node, 1, 50e9)
    }

    /// Reference scale-out pod: `num_nodes` × HGX H100, 50 GB/s per GPU.
    pub fn hgx_h100_pod(num_nodes: usize) -> Self {
        Self::new(NodeSpec::hgx_h100(), num_nodes, 50e9)
    }

    /// Small cluster for functional tests.
    pub fn test_cluster(num_nodes: usize, devices_per_node: usize) -> Self {
        Self::new(NodeSpec::test_node(devices_per_node), num_nodes, 50e9)
    }

    /// Override the NIC bandwidth (the scale-out sweep's second axis).
    pub fn with_nic_bw(mut self, nic_bw: f64) -> Self {
        assert!(nic_bw > 0.0);
        self.nic_bw = nic_bw;
        self
    }

    /// GPUs per node.
    pub fn devices_per_node(&self) -> usize {
        self.node.num_devices
    }

    /// Total GPUs in the cluster.
    pub fn total_devices(&self) -> usize {
        self.num_nodes * self.node.num_devices
    }

    /// Node index of a global device id.
    pub fn node_of(&self, d: DeviceId) -> usize {
        d.0 / self.node.num_devices
    }

    /// Local rank (rail index) of a global device id within its node.
    pub fn local_rank(&self, d: DeviceId) -> usize {
        d.0 % self.node.num_devices
    }

    /// Whether two devices share a node (NVLink reachability).
    pub fn same_node(&self, a: DeviceId, b: DeviceId) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Global device id of `(node, rank)`.
    pub fn device(&self, node: usize, rank: usize) -> DeviceId {
        debug_assert!(node < self.num_nodes && rank < self.node.num_devices);
        DeviceId(node * self.node.num_devices + rank)
    }

    /// The cluster's port topology.
    pub fn topology(&self) -> Topology {
        Topology::cluster(self.num_nodes, self.node.num_devices, self.node.nvswitch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_indexing_is_node_major() {
        let c = ClusterSpec::test_cluster(3, 4);
        assert_eq!(c.total_devices(), 12);
        assert_eq!(c.device(2, 1), DeviceId(9));
        assert_eq!(c.node_of(DeviceId(9)), 2);
        assert_eq!(c.local_rank(DeviceId(9)), 1);
        assert!(c.same_node(DeviceId(4), DeviceId(7)));
        assert!(!c.same_node(DeviceId(3), DeviceId(4)));
    }

    #[test]
    fn single_node_cluster_matches_node() {
        let c = ClusterSpec::single(NodeSpec::hgx_h100());
        assert_eq!(c.num_nodes, 1);
        assert_eq!(c.total_devices(), 8);
        for a in 0..8 {
            for b in 0..8 {
                assert!(c.same_node(DeviceId(a), DeviceId(b)));
            }
        }
    }

    #[test]
    fn pod_preset_and_nic_override() {
        let c = ClusterSpec::hgx_h100_pod(4).with_nic_bw(100e9);
        assert_eq!(c.num_nodes, 4);
        assert_eq!(c.nic_bw, 100e9);
        assert!(c.rail_optimized);
        assert!(c.nic_bw < c.node.gpu.nvlink_bw, "NIC is the binding constraint");
    }
}
