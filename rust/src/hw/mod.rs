//! Hardware model: per-GPU specifications, node topology, and the
//! multi-node cluster layer.
//!
//! Every number here is taken from the paper (§1, §2.1, §3.1, Table 1,
//! Figures 2–3) or the vendor datasheets the paper cites; the simulator and
//! the analytical cost model both read *only* from these structs, so the
//! calibration has a single source of truth. [`cluster`] extends the node
//! model across an RDMA fabric (per-GPU NICs, rail-optimized) for the
//! scale-out scenarios the paper leaves open.

pub mod cluster;
pub mod spec;
pub mod topology;

pub use cluster::ClusterSpec;
pub use spec::{Arch, GpuSpec, NodeSpec};
pub use topology::{DeviceId, Topology};
