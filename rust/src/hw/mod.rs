//! Hardware model: per-GPU specifications and node topology.
//!
//! Every number here is taken from the paper (§1, §2.1, §3.1, Table 1,
//! Figures 2–3) or the vendor datasheets the paper cites; the simulator and
//! the analytical cost model both read *only* from these structs, so the
//! calibration has a single source of truth.

pub mod spec;
pub mod topology;

pub use spec::{Arch, GpuSpec, NodeSpec};
pub use topology::{DeviceId, Topology};
