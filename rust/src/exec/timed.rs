//! Timed (discrete-event) executor.
//!
//! Implements the paper's cost model (§3.1.1) operationally:
//! `T_kernel = T_launch + max(T_comp, T_mem, T_comm) + T_non-overlap + T_sync`
//! emerges from simulating workers, flows, and synchronization rather than
//! being asserted — overlap happens when the plan issues transfers
//! asynchronously, and serialization/backpressure happen through semaphores
//! and port contention.

use crate::hw::cluster::ClusterSpec;
use crate::hw::spec::NodeSpec;
use crate::hw::topology::{Port, Topology};
use crate::plan::{Op, Plan, Route, SyncScope, TransferSpec};
use crate::sim::fault::FaultSpec;
use crate::sim::flownet::{Engine, FlowId, FlowNet, SolverStats};
use crate::sim::partition::{partitioned_from_env, PartitionedFlowNet};
use crate::sim::trace::{SpanKind, Trace};
use crate::sim::EventQueue;
use crate::xfer::curves;
use std::collections::HashMap;

/// Result of a timed run.
#[derive(Debug)]
pub struct TimedResult {
    /// Total wall-clock time of the kernel (T_kernel).
    pub total_time: f64,
    /// Total compute-busy time across workers (Σ per-worker T_comp).
    pub compute_busy: f64,
    /// Total bytes that crossed each port.
    pub port_bytes: HashMap<Port, f64>,
    /// Optional execution trace.
    pub trace: Trace,
    /// Number of simulation events processed (perf instrumentation).
    pub events: u64,
    /// Fair-share solver instrumentation (solves vs memo hits).
    pub solver: SolverStats,
}

impl TimedResult {
    /// Bytes that left device `d` over NVLink.
    pub fn egress_bytes(&self, d: usize) -> f64 {
        self.port_bytes
            .get(&Port::Egress(crate::hw::DeviceId(d)))
            .copied()
            .unwrap_or(0.0)
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum WState {
    Ready,
    Running,     // compute/delay in flight
    BlockedSem,  // waiting on a semaphore
    BlockedFlow, // blocking transfer in flight
    Done,
}

enum Ev {
    WorkerDone(usize),
    SemBump { sem: usize, value: u64 },
    FlowStart { ctx: usize },
}

struct FlowCtx {
    spec: TransferSpec,
    done_sem: Option<usize>,
    done_scope: SyncScope,
    blocking_worker: Option<usize>,
    issuer: usize,
    issue_time: f64,
    label: &'static str,
}

/// `active_flows` sentinel: this flow slot has no context attached.
const NO_CTX: usize = usize::MAX;

/// The executor's flow network: monolithic by default, or split into
/// port-disjoint per-node partitions (parallel advance, bit-identical
/// output — see [`crate::sim::partition`]). An enum rather than a trait
/// object so the monolithic hot path stays devirtualized.
enum NetBox {
    Mono(FlowNet),
    Part(PartitionedFlowNet),
}

impl NetBox {
    fn start(&mut self, bytes: f64, ports: Vec<Port>, cap: f64) -> FlowId {
        match self {
            NetBox::Mono(n) => n.start(bytes, ports, cap),
            NetBox::Part(n) => n.start(bytes, ports, cap),
        }
    }

    fn advance(&mut self, dt: f64) -> &[FlowId] {
        match self {
            NetBox::Mono(n) => n.advance(dt),
            NetBox::Part(n) => n.advance(dt),
        }
    }

    fn next_completion(&mut self) -> Option<f64> {
        match self {
            NetBox::Mono(n) => n.next_completion(),
            NetBox::Part(n) => n.next_completion(),
        }
    }

    fn n_active(&self) -> usize {
        match self {
            NetBox::Mono(n) => n.n_active(),
            NetBox::Part(n) => n.n_active(),
        }
    }

    fn set_capacity(&mut self, port: Port, bytes_per_s: f64) {
        match self {
            NetBox::Mono(n) => n.set_capacity(port, bytes_per_s),
            NetBox::Part(n) => n.set_capacity(port, bytes_per_s),
        }
    }

    fn take_port_bytes(&mut self) -> HashMap<Port, f64> {
        match self {
            NetBox::Mono(n) => std::mem::take(&mut n.port_bytes),
            NetBox::Part(n) => n.take_port_bytes(),
        }
    }

    fn solver_stats(&self) -> SolverStats {
        match self {
            NetBox::Mono(n) => n.solver_stats(),
            NetBox::Part(n) => n.solver_stats(),
        }
    }
}

/// The timed executor. Runs on one node by default; [`TimedExec::on_cluster`]
/// extends the same resource model across an RDMA fabric. A one-node
/// cluster is bit-identical to the plain node path (regression-guarded).
pub struct TimedExec {
    pub cluster: ClusterSpec,
    pub trace_enabled: bool,
    /// Run on the partitioned parallel net (also enabled fleet-wide via
    /// `PK_NET_PARTITION=1`). Output is bit-identical to the monolithic
    /// net either way (claims-tested).
    pub partitioned_net: bool,
    /// Injected fault scenario ([`crate::sim::fault`]): compiled once per
    /// run against the declared baseline capacities and applied as timed
    /// `set_capacity` events, so both flow engines and both nets see the
    /// identical schedule.
    pub faults: Option<FaultSpec>,
    /// Pin the flow-event engine for this executor (`None` = the
    /// `PK_FLOWNET` env selection). Lets determinism pins race
    /// Scan/Heap × mono/partitioned in one process.
    pub engine: Option<Engine>,
}

impl TimedExec {
    pub fn new(node: NodeSpec) -> Self {
        TimedExec {
            cluster: ClusterSpec::single(node),
            trace_enabled: false,
            partitioned_net: false,
            faults: None,
            engine: None,
        }
    }

    /// Timed execution over a multi-node cluster (NIC ports + RDMA curve).
    pub fn on_cluster(cluster: ClusterSpec) -> Self {
        TimedExec {
            cluster,
            trace_enabled: false,
            partitioned_net: false,
            faults: None,
            engine: None,
        }
    }

    pub fn with_trace(mut self) -> Self {
        self.trace_enabled = true;
        self
    }

    /// Opt this executor into the partitioned parallel net.
    pub fn with_partitioned_net(mut self) -> Self {
        self.partitioned_net = true;
        self
    }

    /// Inject a fault scenario into every run of this executor. An empty
    /// spec is dropped (keeps the no-fault hot path branch-free).
    pub fn with_faults(mut self, spec: FaultSpec) -> Self {
        self.faults = (!spec.is_empty()).then_some(spec);
        self
    }

    /// Pin the flow-event engine (overrides the `PK_FLOWNET` selection).
    pub fn with_flow_engine(mut self, engine: Engine) -> Self {
        self.engine = Some(engine);
        self
    }

    fn scope_latency(&self, s: SyncScope) -> f64 {
        let g = &self.cluster.node.gpu;
        match s {
            SyncScope::IntraSm => g.mbarrier_sync,
            SyncScope::InterSm => g.hbm_sync,
            SyncScope::InterDevice => g.nvlink_signal,
            SyncScope::InterNode => self.cluster.nic_latency,
        }
    }

    fn flow_ports(&self, topo: &Topology, route: Route) -> Vec<Port> {
        match route {
            Route::P2p { src, dst } => topo.p2p_ports(src, dst),
            Route::Multicast { src } => topo.multicast_ports(src),
            Route::LdReduce { reader } => topo.ld_reduce_ports(reader),
            Route::LocalHbm { dev } => vec![Port::Hbm(dev)],
            Route::CopyEngineP2p { src, dst } => {
                let mut p = vec![Port::CopyEngine(src)];
                p.extend(topo.p2p_ports(src, dst));
                p
            }
            Route::Rdma { src, dst } => topo.rdma_ports(src, dst),
        }
    }

    fn flow_cap(&self, spec: &TransferSpec) -> f64 {
        match spec.route {
            // Staging/reshape passes are HBM-bound: one read + one write.
            Route::LocalHbm { .. } => self.cluster.node.gpu.hbm_bw / 2.0,
            // Cross-node transfers are rated by the NIC curve, independent
            // of the issuing mechanism (the proxy drives the NIC).
            Route::Rdma { .. } => curves::rdma_rate(&self.cluster, spec.msg_bytes),
            _ => curves::rate(&self.cluster.node.gpu, spec.mech, spec.msg_bytes, spec.n_sms),
        }
    }

    /// First-byte latency of a transfer: NIC fabric latency for RDMA,
    /// mechanism latency otherwise.
    fn transfer_latency(&self, spec: &TransferSpec) -> f64 {
        match spec.route {
            Route::Rdma { .. } => self.cluster.nic_latency,
            _ => curves::flow_latency(&self.cluster.node.gpu, spec.mech),
        }
    }

    /// Run the plan and return timing + accounting.
    pub fn run(&self, plan: &Plan) -> TimedResult {
        let g = &self.cluster.node.gpu;
        let topo = self.cluster.topology();
        let engine = self.engine.unwrap_or_else(Engine::from_env);
        let mut net = if self.partitioned_net || partitioned_from_env() {
            NetBox::Part(PartitionedFlowNet::with_engine(
                topo.num_nodes(),
                topo.devices_per_node,
                engine,
            ))
        } else {
            NetBox::Mono(FlowNet::with_engine(engine))
        };
        let mut baseline: Vec<(Port, f64)> = vec![];
        for d in topo.devices() {
            baseline.push((Port::Egress(d), g.nvlink_bw));
            baseline.push((Port::Ingress(d), g.nvlink_bw));
            baseline.push((Port::Pcie(d), g.pcie_bw));
            baseline.push((Port::Hbm(d), g.hbm_bw));
            baseline.push((Port::CopyEngine(d), g.nvlink_bw * g.ce_peak_frac));
            baseline.push((Port::SwitchReduce(d), g.nvlink_bw));
            if topo.num_nodes() > 1 {
                baseline.push((Port::NicEgress(d), self.cluster.nic_bw));
                baseline.push((Port::NicIngress(d), self.cluster.nic_bw));
            }
        }
        for &(p, c) in &baseline {
            net.set_capacity(p, c);
        }
        // Fault hook: compile the scenario once against the declared
        // baseline — a pure function of (spec, baseline), so Scan/Heap and
        // mono/partitioned nets all replay the identical schedule.
        let mut fault_plan =
            self.faults.as_ref().map(|s| s.compile(&baseline, self.cluster.total_devices()));
        // Per-worker compute-duration multiplier (straggler devices).
        let wslow: Vec<f64> = match &fault_plan {
            Some(f) => plan.workers.iter().map(|w| f.slowdown(w.device.0)).collect(),
            None => vec![],
        };

        let n = plan.workers.len();
        let mut pc = vec![0usize; n];
        let mut wstate = vec![WState::Ready; n];
        // Running count of retired workers: the termination test is O(1)
        // instead of an O(n) scan per event.
        let mut n_done = 0usize;
        let mut sems: Vec<u64> = plan.sems.clone();
        // sem -> waiting (worker, threshold)
        let mut waiters: Vec<Vec<(usize, u64)>> = vec![vec![]; plan.sems.len()];
        let mut queue: EventQueue<Ev> = EventQueue::new();
        // FlowCtx arena with slot recycling (a GEMM-scale plan issues tens
        // of thousands of transfers but keeps only the pipeline depth in
        // flight).
        let mut flow_ctxs: Vec<FlowCtx> = vec![];
        let mut free_ctxs: Vec<usize> = vec![];
        // flow slot -> ctx index. FlowNet recycles slots, so this stays as
        // dense as the peak concurrent flow count.
        let mut active_flows: Vec<usize> = vec![];
        let mut trace = Trace::new(self.trace_enabled);
        let mut now = plan.launch_overhead.max(0.0);
        let mut events: u64 = 0;
        let mut compute_busy = 0.0;

        // Ready queue avoids recursion when semaphore bumps cascade.
        let mut ready: std::collections::VecDeque<usize> = (0..n).collect();

        macro_rules! step_worker {
            ($w:expr) => {{
                let w: usize = $w;
                loop {
                    if pc[w] >= plan.workers[w].ops.len() {
                        wstate[w] = WState::Done;
                        n_done += 1;
                        break;
                    }
                    match &plan.workers[w].ops[pc[w]] {
                        Op::Compute { dur, label, .. } => {
                            // straggler devices run compute slower
                            let dur = if wslow.is_empty() { *dur } else { *dur * wslow[w] };
                            compute_busy += dur;
                            trace.record(w, SpanKind::Compute, label, now, now + dur);
                            wstate[w] = WState::Running;
                            queue.push(now + dur, Ev::WorkerDone(w));
                            break;
                        }
                        Op::Delay { dur, label } => {
                            trace.record(w, SpanKind::Launch, label, now, now + dur);
                            wstate[w] = WState::Running;
                            queue.push(now + dur, Ev::WorkerDone(w));
                            break;
                        }
                        Op::Transfer { spec, blocking, done_sem, done_scope, label, .. } => {
                            let lat = self.transfer_latency(spec);
                            let ctx = FlowCtx {
                                spec: spec.clone(),
                                done_sem: done_sem.map(|s| s.0),
                                done_scope: *done_scope,
                                blocking_worker: blocking.then_some(w),
                                issuer: w,
                                issue_time: now,
                                label,
                            };
                            let ci = if let Some(i) = free_ctxs.pop() {
                                flow_ctxs[i] = ctx;
                                i
                            } else {
                                flow_ctxs.push(ctx);
                                flow_ctxs.len() - 1
                            };
                            queue.push(now + lat, Ev::FlowStart { ctx: ci });
                            if *blocking {
                                wstate[w] = WState::BlockedFlow;
                                break;
                            } else {
                                pc[w] += 1;
                            }
                        }
                        Op::Wait { sem, value } => {
                            if sems[sem.0] >= *value {
                                pc[w] += 1;
                            } else {
                                waiters[sem.0].push((w, *value));
                                wstate[w] = WState::BlockedSem;
                                break;
                            }
                        }
                        Op::Signal { sem, value, scope } => {
                            let lat = self.scope_latency(*scope);
                            queue.push(now + lat, Ev::SemBump { sem: sem.0, value: *value });
                            pc[w] += 1;
                        }
                    }
                }
            }};
        }

        loop {
            // Drain the ready queue at the current time.
            while let Some(w) = ready.pop_front() {
                if wstate[w] == WState::Ready {
                    step_worker!(w);
                }
            }
            // The kernel is finished only when every worker has retired
            // *and* all in-flight asynchronous transfers have drained
            // (async stores issued without a completion wait still take
            // wall-clock time — the pipeline drain of §3.1.1's T_launch
            // teardown).
            if n_done == n && net.n_active() == 0 && queue.is_empty() {
                break;
            }
            // Find the next moment something happens. Work in *deltas*:
            // round-tripping completion times through absolute `now`
            // loses sub-ulp residues and can livelock the loop.
            let dt_timer = queue.peek_time().map(|t| (t - now).max(0.0));
            let dt_flow = net.next_completion();
            // Pending fault events are timed too. When neither a worker
            // timer nor a flow completion is due, only a pending
            // *link-state* change over a stalled net can make progress (a
            // restore un-stalls rate-0 flows); jitter resamples alone
            // cannot create work, so they don't mask a true deadlock.
            let dt_fault = fault_plan.as_ref().and_then(|f| {
                let t = if dt_timer.is_none() && dt_flow.is_none() {
                    (net.n_active() > 0).then(|| f.next_link_time()).flatten()
                } else {
                    f.next_time()
                };
                t.map(|t| (t - now).max(0.0))
            });
            let dt = match (dt_timer, dt_flow) {
                (Some(a), Some(b)) => a.min(b),
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => match dt_fault {
                    Some(f) => f,
                    None => {
                        let stuck: Vec<&str> = (0..n)
                            .filter(|&w| wstate[w] != WState::Done)
                            .map(|w| plan.workers[w].label.as_str())
                            .collect();
                        panic!("timed deadlock at t={now}: stuck workers {stuck:?}");
                    }
                },
            };
            let dt = match dt_fault {
                Some(f) => dt.min(f),
                None => dt,
            };
            // Advance flows by exactly dt (flows whose completion falls in
            // the window complete even if fp leaves a residue).
            let completed = net.advance(dt);
            now += dt;
            events += 1;
            for fid in completed {
                let ci = std::mem::replace(&mut active_flows[fid.0], NO_CTX);
                debug_assert_ne!(ci, NO_CTX, "completed flow without a context");
                let ctx = &flow_ctxs[ci];
                trace.record(ctx.issuer, SpanKind::Comm, ctx.label, ctx.issue_time, now);
                if let Some(s) = ctx.done_sem {
                    queue.push(now + self.scope_latency(ctx.done_scope), Ev::SemBump { sem: s, value: 1 });
                }
                if let Some(w) = ctx.blocking_worker {
                    pc[w] += 1;
                    wstate[w] = WState::Ready;
                    ready.push_back(w);
                }
                free_ctxs.push(ci);
            }
            // Process all timer events scheduled at exactly t_next. The
            // tie epsilon is *relative*: at multi-second simulated times a
            // fixed 1e-15 is below one ulp, and equal-time events would be
            // split across loop iterations.
            let tie_eps = now * 1e-12 + 1e-15;
            // Fire fault events due now (timed capacity changes), before
            // the timer drain so flows started at this instant already see
            // the degraded capacities. The same tie epsilon keeps
            // equal-time fault and timer events in one loop iteration.
            if let Some(f) = fault_plan.as_mut() {
                f.apply_due(now + tie_eps, &mut |port, cap| {
                    net.set_capacity(port, cap);
                    events += 1;
                });
            }
            while queue.peek_time().map(|t| t <= now + tie_eps).unwrap_or(false) {
                let (_, ev) = queue.pop().unwrap();
                events += 1;
                match ev {
                    Ev::WorkerDone(w) => {
                        pc[w] += 1;
                        wstate[w] = WState::Ready;
                        ready.push_back(w);
                    }
                    Ev::SemBump { sem, value } => {
                        sems[sem] += value;
                        // Wake satisfied waiters in place — no per-bump
                        // replacement vector.
                        let cur = sems[sem];
                        waiters[sem].retain(|&(w, thresh)| {
                            if cur >= thresh {
                                pc[w] += 1;
                                wstate[w] = WState::Ready;
                                ready.push_back(w);
                                false
                            } else {
                                true
                            }
                        });
                    }
                    Ev::FlowStart { ctx } => {
                        let c = &flow_ctxs[ctx];
                        let ports = self.flow_ports(&topo, c.spec.route);
                        if ports.is_empty() || c.spec.bytes <= 0.0 {
                            // Device-local zero-cost move: complete instantly.
                            if let Some(s) = c.done_sem {
                                let lat = self.scope_latency(c.done_scope);
                                queue.push(now + lat, Ev::SemBump { sem: s, value: 1 });
                            }
                            if let Some(w) = c.blocking_worker {
                                pc[w] += 1;
                                wstate[w] = WState::Ready;
                                ready.push_back(w);
                            }
                            free_ctxs.push(ctx);
                        } else {
                            let cap = self.flow_cap(&c.spec);
                            let id = net.start(c.spec.bytes, ports, cap);
                            if id.0 >= active_flows.len() {
                                active_flows.resize(id.0 + 1, NO_CTX);
                            }
                            active_flows[id.0] = ctx;
                        }
                    }
                }
            }
        }

        TimedResult {
            total_time: now,
            compute_busy,
            // the net is drained and about to drop — move the accounting
            // out instead of deep-cloning it
            port_bytes: net.take_port_bytes(),
            trace,
            events,
            solver: net.solver_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DeviceId;
    use crate::plan::{Role, SemId, TransferSpec};
    use crate::xfer::Mechanism;

    fn node() -> NodeSpec {
        NodeSpec::hgx_h100()
    }

    fn p2p_spec(bytes: f64, src: usize, dst: usize) -> TransferSpec {
        TransferSpec {
            mech: Mechanism::Tma,
            route: Route::P2p { src: DeviceId(src), dst: DeviceId(dst) },
            bytes,
            msg_bytes: 128.0 * 1024.0,
            n_sms: 132.0,
        }
    }

    #[test]
    fn compute_only_duration() {
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "c");
        plan.push(w, Op::Compute { dur: 1e-3, label: "mma", effect: None });
        let r = TimedExec::new(node()).run(&plan);
        assert!((r.total_time - 1e-3).abs() < 1e-12);
        assert!((r.compute_busy - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn launch_overhead_added() {
        let mut plan = Plan::new();
        plan.launch_overhead = 3.5e-6;
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "c");
        plan.push(w, Op::Compute { dur: 1e-3, label: "mma", effect: None });
        let r = TimedExec::new(node()).run(&plan);
        assert!((r.total_time - (1e-3 + 3.5e-6)).abs() < 1e-12);
    }

    #[test]
    fn blocking_transfer_time_matches_curve() {
        // 1 GB TMA transfer with all SMs: Table 1 says ~350 GB/s.
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "t");
        plan.push(
            w,
            Op::Transfer {
                spec: p2p_spec(1e9, 0, 1),
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::IntraSm,
                label: "p2p",
                effect: None,
            },
        );
        let r = TimedExec::new(node()).run(&plan);
        let expect = 1e9 / 350.01e9;
        assert!((r.total_time - expect).abs() / expect < 0.02, "{}", r.total_time);
        assert!((r.egress_bytes(0) - 1e9).abs() < 1.0);
    }

    #[test]
    fn async_transfer_overlaps_compute() {
        // compute 1 ms while a transfer of ~1 ms runs: total ≈ max, not sum.
        let g = node().gpu.clone();
        let bytes = 350.01e9 * 1e-3; // ~1 ms at TMA rate
        let mut plan = Plan::new();
        let s = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "c");
        plan.push(
            w,
            Op::Transfer {
                spec: p2p_spec(bytes, 0, 1),
                blocking: false,
                done_sem: Some(s),
                done_scope: SyncScope::IntraSm,
                label: "store",
                effect: None,
            },
        );
        plan.push(w, Op::Compute { dur: 1e-3, label: "mma", effect: None });
        plan.push(w, Op::Wait { sem: s, value: 1 });
        let r = TimedExec::new(node()).run(&plan);
        assert!(r.total_time < 1.1e-3, "should overlap: {}", r.total_time);
        assert!(r.total_time > 0.99e-3);
        let _ = g;
    }

    #[test]
    fn two_flows_share_ingress() {
        // Two devices write 100 MB each into device 0 concurrently:
        // ingress port serialises them (the §3.1.3 intra-SM AR effect).
        let mut plan = Plan::new();
        for src in 1..=2 {
            let w = plan.add_worker(DeviceId(src), Role::CommSm, format!("w{src}"));
            plan.push(
                w,
                Op::Transfer {
                    spec: p2p_spec(100e6, src, 0),
                    blocking: true,
                    done_sem: None,
                    done_scope: SyncScope::IntraSm,
                    label: "p2p",
                    effect: None,
                },
            );
        }
        let r = TimedExec::new(node()).run(&plan);
        // each flow capped by its own TMA rate (350), but sharing 450 GB/s
        // ingress -> 225 each -> 100e6/225e9 ≈ 0.44 ms
        let expect = 100e6 / 225e9;
        assert!((r.total_time - expect).abs() / expect < 0.05, "{}", r.total_time);
    }

    #[test]
    fn signal_wait_latency_interdevice() {
        let g = node().gpu.clone();
        let mut plan = Plan::new();
        let s = plan.add_sem(0);
        let w0 = plan.add_worker(DeviceId(0), Role::ComputeSm, "sig");
        let w1 = plan.add_worker(DeviceId(1), Role::ComputeSm, "wait");
        plan.push(w0, Op::Signal { sem: s, value: 1, scope: SyncScope::InterDevice });
        plan.push(w1, Op::Wait { sem: s, value: 1 });
        plan.push(w1, Op::Compute { dur: 1e-6, label: "c", effect: None });
        let r = TimedExec::new(node()).run(&plan);
        assert!((r.total_time - (g.nvlink_signal + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn copy_engine_flow_uses_ce_port() {
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::Host, "host");
        plan.push(
            w,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::CopyEngine,
                    route: Route::CopyEngineP2p { src: DeviceId(0), dst: DeviceId(1) },
                    bytes: 1e9,
                    msg_bytes: 1e9,
                    n_sms: 0.0,
                },
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::InterDevice,
                label: "ce",
                effect: None,
            },
        );
        let r = TimedExec::new(node()).run(&plan);
        let expect = 1e9 / 368.82e9;
        assert!((r.total_time - expect).abs() / expect < 0.03, "{}", r.total_time);
        assert!(r.port_bytes.contains_key(&Port::CopyEngine(DeviceId(0))));
    }

    #[test]
    fn rdma_transfer_matches_nic_curve() {
        // 1 GB cross-node transfer in 1 MB writes on a 50 GB/s NIC.
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "t");
        plan.push(
            w,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::Tma,
                    route: Route::Rdma { src: DeviceId(0), dst: DeviceId(8) },
                    bytes: 1e9,
                    msg_bytes: 1e6,
                    n_sms: 1.0,
                },
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::InterNode,
                label: "rdma",
                effect: None,
            },
        );
        let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
        let expect = 1e9 / curves::rdma_rate(&cluster, 1e6);
        assert!((r.total_time - expect).abs() / expect < 0.02, "{}", r.total_time);
        assert!((r.port_bytes[&Port::NicEgress(DeviceId(0))] - 1e9).abs() < 1.0);
        assert!((r.port_bytes[&Port::NicIngress(DeviceId(8))] - 1e9).abs() < 1.0);
        // NVLink ports untouched by a pure RDMA flow
        assert!(r.port_bytes.get(&Port::Egress(DeviceId(0))).is_none());
    }

    #[test]
    fn concurrent_rdma_flows_share_nic_ingress() {
        // two senders into one NIC: the ingress port serialises them.
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let mut plan = Plan::new();
        for src in [1usize, 2] {
            let w = plan.add_worker(DeviceId(src), Role::CommSm, format!("w{src}"));
            plan.push(
                w,
                Op::Transfer {
                    spec: TransferSpec {
                        mech: Mechanism::Tma,
                        route: Route::Rdma { src: DeviceId(src), dst: DeviceId(8) },
                        bytes: 100e6,
                        msg_bytes: 1e6,
                        n_sms: 1.0,
                    },
                    blocking: true,
                    done_sem: None,
                    done_scope: SyncScope::InterNode,
                    label: "rdma",
                    effect: None,
                },
            );
        }
        let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
        // each flow capped by the curve (~46 GB/s) but sharing the 50 GB/s
        // NIC ingress -> 25 GB/s each
        let expect = 100e6 / 25e9;
        assert!((r.total_time - expect).abs() / expect < 0.05, "{}", r.total_time);
    }

    #[test]
    fn internode_signal_pays_nic_latency() {
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let mut plan = Plan::new();
        let s = plan.add_sem(0);
        let w0 = plan.add_worker(DeviceId(0), Role::ComputeSm, "sig");
        let w1 = plan.add_worker(DeviceId(8), Role::ComputeSm, "wait");
        plan.push(w0, Op::Signal { sem: s, value: 1, scope: SyncScope::InterNode });
        plan.push(w1, Op::Wait { sem: s, value: 1 });
        let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
        assert!((r.total_time - cluster.nic_latency).abs() < 1e-12);
    }

    #[test]
    fn single_node_cluster_bit_identical_to_node_path() {
        // pins the constructor equivalence (new == on_cluster(single)):
        // fails if 1-node cluster execution ever diverges, e.g. if NIC
        // capacities were declared unconditionally.
        let node = node();
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "t");
        plan.push(
            w,
            Op::Transfer {
                spec: p2p_spec(64e6, 0, 3),
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::IntraSm,
                label: "p2p",
                effect: None,
            },
        );
        plan.push(w, Op::Compute { dur: 1e-4, label: "mma", effect: None });
        let a = TimedExec::new(node.clone()).run(&plan);
        let b = TimedExec::on_cluster(ClusterSpec::single(node)).run(&plan);
        assert_eq!(a.total_time.to_bits(), b.total_time.to_bits());
        assert_eq!(a.events, b.events);
    }

    fn rdma_xfer(src: usize, dst: usize, bytes: f64) -> Op {
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Tma,
                route: Route::Rdma { src: DeviceId(src), dst: DeviceId(dst) },
                bytes,
                msg_bytes: 1e6,
                n_sms: 1.0,
            },
            blocking: true,
            done_sem: None,
            done_scope: SyncScope::InterNode,
            label: "rdma",
            effect: None,
        }
    }

    /// A small 2-node plan with concurrent RDMA flows + overlapped compute
    /// — enough churn to exercise jitter resamples and a NIC degrade.
    fn faulted_plan() -> Plan {
        let mut plan = Plan::new();
        for src in 0..3usize {
            let w = plan.add_worker(DeviceId(src), Role::CommSm, format!("w{src}"));
            plan.push(w, rdma_xfer(src, 8 + src, 40e6));
            plan.push(w, Op::Compute { dur: 2e-4, label: "mma", effect: None });
            plan.push(w, rdma_xfer(src, 8 + (src + 1) % 3, 20e6));
        }
        plan
    }

    #[test]
    fn fault_schedule_identical_across_engines_and_nets() {
        // the tentpole determinism pin: the compiled fault schedule is a
        // pure function of (spec, baseline), so Scan/Heap × mono/
        // partitioned all replay it bit-identically.
        use crate::sim::fault::LinkFault;
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let plan = faulted_plan();
        let spec = FaultSpec::seeded(42).with_jitter(0.4).with_nic_fault(LinkFault {
            device: 8,
            at: 3e-4,
            frac: 0.25,
            restore_at: Some(9e-4),
        });
        let mut results = vec![];
        for engine in [Engine::Scan, Engine::Heap] {
            for part in [false, true] {
                let mut exec = TimedExec::on_cluster(cluster.clone())
                    .with_flow_engine(engine)
                    .with_faults(spec.clone());
                exec.partitioned_net = part;
                let r = exec.run(&plan);
                assert!(r.total_time.is_finite() && r.total_time > 0.0);
                results.push((engine, part, r.total_time.to_bits(), r.port_bytes));
            }
        }
        for w in results.windows(2) {
            assert_eq!(
                w[0].2, w[1].2,
                "total_time diverged between {:?}/part={} and {:?}/part={}",
                w[0].0, w[0].1, w[1].0, w[1].1
            );
            for (p, b) in &w[0].3 {
                assert_eq!(b.to_bits(), w[1].3[p].to_bits(), "port_bytes diverged at {p:?}");
            }
        }
    }

    #[test]
    fn jitter_and_degrade_only_slow_things_down() {
        use crate::sim::fault::LinkFault;
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let plan = faulted_plan();
        let healthy = TimedExec::on_cluster(cluster.clone()).run(&plan).total_time;
        let jittered = TimedExec::on_cluster(cluster.clone())
            .with_faults(FaultSpec::seeded(7).with_jitter(0.5))
            .run(&plan)
            .total_time;
        assert!(jittered >= healthy * (1.0 - 1e-9), "{jittered} vs {healthy}");
        let degraded = TimedExec::on_cluster(cluster.clone())
            .with_faults(FaultSpec::seeded(7).with_nic_fault(LinkFault {
                device: 8,
                at: 0.0,
                frac: 0.25,
                restore_at: None,
            }))
            .run(&plan)
            .total_time;
        assert!(degraded > healthy, "{degraded} vs {healthy}");
    }

    #[test]
    fn hard_nic_failure_stalls_until_restore() {
        // capacity → 0 mid-flight: the flow stalls (next_completion None);
        // the pending restore keeps the event loop alive (no deadlock
        // panic) and the run completes after the link returns.
        use crate::sim::fault::LinkFault;
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "t");
        plan.push(w, rdma_xfer(0, 8, 1e9)); // ~22 ms healthy
        let healthy = TimedExec::on_cluster(cluster.clone()).run(&plan).total_time;
        let restore_at = 0.05;
        let r = TimedExec::on_cluster(cluster.clone())
            .with_faults(FaultSpec::seeded(0).with_nic_fault(LinkFault {
                device: 8,
                at: 1e-3,
                frac: 0.0,
                restore_at: Some(restore_at),
            }))
            .run(&plan);
        // stalled from 1 ms to 50 ms, then finishes the remaining bytes
        assert!(r.total_time > restore_at, "must stall past the restore: {}", r.total_time);
        assert!(
            r.total_time < restore_at + healthy,
            "some bytes moved before the failure: {}",
            r.total_time
        );
    }

    #[test]
    fn straggler_stretches_compute_durations() {
        let mut plan = Plan::new();
        for d in 0..2 {
            let w = plan.add_worker(DeviceId(d), Role::ComputeSm, format!("c{d}"));
            plan.push(w, Op::Compute { dur: 1e-3, label: "mma", effect: None });
        }
        let r = TimedExec::new(node())
            .with_faults(FaultSpec::seeded(0).with_straggler(1, 0.5))
            .run(&plan);
        // device 1 computes at half rate → 2 ms critical path
        assert!((r.total_time - 2e-3).abs() < 1e-12, "{}", r.total_time);
        assert!((r.compute_busy - 3e-3).abs() < 1e-12, "1 ms + 2 ms busy");
    }

    #[test]
    fn empty_fault_spec_is_bit_identical_to_no_faults() {
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let plan = faulted_plan();
        let a = TimedExec::on_cluster(cluster.clone()).run(&plan);
        let b = TimedExec::on_cluster(cluster.clone())
            .with_faults(FaultSpec::seeded(123))
            .run(&plan);
        assert_eq!(a.total_time.to_bits(), b.total_time.to_bits());
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn pipelined_stores_backpressure() {
        // A worker produces 8 tiles; pipeline depth 2 (in-flight sem).
        // If comm is much slower than compute, total ≈ comm time (fill
        // hidden) — the Table 3 regime boundary.
        let tile_bytes = 128.0 * 256.0 * 2.0;
        let comm_t = tile_bytes / 350.01e9; // per-tile store time
        let comp_t = comm_t / 4.0; // compute faster than comm
        let mut plan = Plan::new();
        let slots = plan.add_sem(2); // 2 in-flight slots
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "sm");
        let mut acquired = 0u64;
        for _ in 0..8 {
            acquired += 1;
            plan.push(w, Op::Wait { sem: slots, value: acquired }); // acquire slot
            plan.push(w, Op::Compute { dur: comp_t, label: "mma", effect: None });
            plan.push(
                w,
                Op::Transfer {
                    spec: p2p_spec(tile_bytes, 0, 1),
                    blocking: false,
                    done_sem: Some(slots),
                    done_scope: SyncScope::IntraSm,
                    label: "store",
                    effect: None,
                },
            );
        }
        let r = TimedExec::new(node()).run(&plan);
        // bounded below by total comm, above by comm + one compute + sync.
        let comm_total = 8.0 * comm_t;
        assert!(r.total_time >= comm_total * 0.95, "{} vs {}", r.total_time, comm_total);
        assert!(r.total_time <= comm_total + comp_t + 8.0 * 2e-6, "{}", r.total_time);
    }
}
