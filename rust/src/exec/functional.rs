//! Functional (numerics) executor.
//!
//! Cooperatively schedules the plan's workers, applying each op's
//! [`Effect`] to the [`MemPool`]. Semaphores have exact counting semantics
//! with zero latency; transfers complete at issue. The executor therefore
//! checks two things at once: the kernel's *data* semantics, and that its
//! synchronization protocol admits a deadlock-free execution.
//!
//! Worker interleaving is deterministic round-robin by default; property
//! tests use [`FunctionalExec::with_rotation`] to explore different
//! interleavings (plans must be correct under all of them).

use crate::mem::pgl::ReduceOp;
use crate::mem::MemPool;
use crate::plan::{Effect, MatView, Op, Plan};
use crate::runtime::{ArtifactRunner, Runtime};
use crate::util::linalg::{self, OnlineSoftmaxState};
use crate::util::error::{bail, Context, Result};

/// Executes plans functionally against a memory pool.
pub struct FunctionalExec<'a> {
    pool: &'a mut MemPool,
    runtime: Option<&'a mut dyn ArtifactRunner>,
    /// Rotate worker stepping order by this much each round (interleaving
    /// exploration for tests).
    rotation: usize,
}

/// Read a view into a dense rows×cols vector.
pub fn read_view(pool: &MemPool, v: &MatView) -> Vec<f32> {
    let buf = pool.get(v.buf);
    let shape = buf.shape;
    assert!(v.row0 + v.rows <= shape.r, "view rows out of bounds: {v:?} in {shape:?}");
    assert!(v.col0 + v.cols <= shape.c, "view cols out of bounds: {v:?} in {shape:?}");
    let mut out = Vec::with_capacity(v.rows * v.cols);
    for r in 0..v.rows {
        let start = shape.offset(v.b, v.d, v.row0 + r, v.col0);
        out.extend_from_slice(&buf.data[start..start + v.cols]);
    }
    out
}

/// Write a dense rows×cols vector into a view, optionally reducing.
pub fn write_view(pool: &mut MemPool, v: &MatView, data: &[f32], reduce: Option<ReduceOp>) {
    assert_eq!(data.len(), v.rows * v.cols, "view write size mismatch");
    let buf = pool.get_mut(v.buf);
    let shape = buf.shape;
    assert!(v.row0 + v.rows <= shape.r && v.col0 + v.cols <= shape.c, "view out of bounds");
    for r in 0..v.rows {
        let start = shape.offset(v.b, v.d, v.row0 + r, v.col0);
        let dst = &mut buf.data[start..start + v.cols];
        let src = &data[r * v.cols..(r + 1) * v.cols];
        match reduce {
            None => dst.copy_from_slice(src),
            Some(ReduceOp::Add) => dst.iter_mut().zip(src).for_each(|(d, s)| *d += s),
            Some(ReduceOp::Max) => dst.iter_mut().zip(src).for_each(|(d, s)| *d = d.max(*s)),
            Some(ReduceOp::Min) => dst.iter_mut().zip(src).for_each(|(d, s)| *d = d.min(*s)),
        }
    }
}

impl<'a> FunctionalExec<'a> {
    pub fn new(pool: &'a mut MemPool) -> Self {
        FunctionalExec { pool, runtime: None, rotation: 0 }
    }

    /// Attach the PJRT runtime so `Effect::RunArtifact` ops can execute.
    pub fn with_runtime(pool: &'a mut MemPool, runtime: &'a mut Runtime) -> Self {
        FunctionalExec { pool, runtime: Some(runtime as &mut dyn ArtifactRunner), rotation: 0 }
    }

    /// Rotate the round-robin stepping order (interleaving exploration).
    pub fn with_rotation(mut self, rotation: usize) -> Self {
        self.rotation = rotation;
        self
    }

    /// Run the plan to completion. Errors on deadlock or on an effect that
    /// cannot be applied.
    pub fn run(&mut self, plan: &Plan) -> Result<()> {
        let n = plan.workers.len();
        let mut pc = vec![0usize; n];
        let mut sems: Vec<u64> = plan.sems.clone();
        let mut states: Vec<OnlineSoftmaxState> = Vec::new();
        let mut done = 0usize;
        let mut round = 0usize;
        while done < n {
            let mut progressed = false;
            for i in 0..n {
                let w = (i + self.rotation * round) % n;
                let ops = &plan.workers[w].ops;
                // Step this worker as far as it can go this round.
                while pc[w] < ops.len() {
                    match &ops[pc[w]] {
                        Op::Compute { effect, .. } | Op::Transfer { effect, .. } => {
                            if let Some(e) = effect.as_ref() {
                                self.apply(e, &mut states, plan)
                                    .with_context(|| format!("worker {} ({}) op {}", w, plan.workers[w].label, pc[w]))?;
                            }
                            // Transfers also signal their completion sem.
                            if let Op::Transfer { done_sem: Some(s), .. } = &ops[pc[w]] {
                                sems[s.0] += 1;
                            }
                            pc[w] += 1;
                            progressed = true;
                        }
                        Op::Wait { sem, value } => {
                            if sems[sem.0] >= *value {
                                pc[w] += 1;
                                progressed = true;
                            } else {
                                break; // blocked; try next worker
                            }
                        }
                        Op::Signal { sem, value, .. } => {
                            sems[sem.0] += value;
                            pc[w] += 1;
                            progressed = true;
                        }
                        Op::Delay { .. } => {
                            pc[w] += 1;
                            progressed = true;
                        }
                    }
                }
                if pc[w] == ops.len() {
                    // finished this round; count once
                }
            }
            done = (0..n).filter(|&w| pc[w] == plan.workers[w].ops.len()).count();
            if !progressed && done < n {
                let stuck: Vec<String> = (0..n)
                    .filter(|&w| pc[w] < plan.workers[w].ops.len())
                    .map(|w| format!("{}@op{}: {:?}", plan.workers[w].label, pc[w], plan.workers[w].ops[pc[w]]))
                    .collect();
                bail!("plan deadlock; stuck workers: {stuck:#?}");
            }
            round += 1;
        }
        Ok(())
    }

    fn apply(&mut self, e: &Effect, states: &mut Vec<OnlineSoftmaxState>, _plan: &Plan) -> Result<()> {
        apply_effect(self.pool, self.runtime.as_deref_mut().map(|r| r as &mut dyn ArtifactRunner), states, e)
    }
}

/// Apply one effect to the pool (shared by [`FunctionalExec`] and the
/// threaded [`crate::coordinator::Node`] executor).
pub fn apply_effect(
    pool: &mut MemPool,
    mut runtime: Option<&mut dyn ArtifactRunner>,
    states: &mut Vec<OnlineSoftmaxState>,
    e: &Effect,
) -> Result<()> {
    {
        match e {
            Effect::CopyMat { src, dst, reduce } => {
                let data = read_view(pool, src);
                write_view(pool, dst, &data, *reduce);
            }
            Effect::MulticastMat { src, dsts, reduce } => {
                let data = read_view(pool, src);
                for d in dsts {
                    write_view(pool, d, &data, *reduce);
                }
            }
            Effect::LdReduceMat { srcs, dst, op } => {
                let mut acc = read_view(pool, &srcs[0]);
                for s in &srcs[1..] {
                    let t = read_view(pool, s);
                    for (a, v) in acc.iter_mut().zip(t) {
                        match op {
                            ReduceOp::Add => *a += v,
                            ReduceOp::Max => *a = a.max(v),
                            ReduceOp::Min => *a = a.min(v),
                        }
                    }
                }
                write_view(pool, dst, &acc, None);
            }
            Effect::Gemm { a, b, c, accumulate } => {
                assert_eq!(a.cols, b.rows, "gemm inner dim");
                assert_eq!(c.rows, a.rows, "gemm m");
                assert_eq!(c.cols, b.cols, "gemm n");
                let av = read_view(pool, a);
                let bv = read_view(pool, b);
                let out = linalg::matmul(&av, &bv, a.rows, b.cols, a.cols);
                write_view(pool, c, &out, accumulate.then_some(ReduceOp::Add));
            }
            Effect::Gelu { x } => {
                let mut data = read_view(pool, x);
                linalg::gelu_inplace(&mut data);
                write_view(pool, x, &data, None);
            }
            Effect::AttnBlock { q, k, v, state } => {
                while states.len() <= state.0 {
                    states.push(OnlineSoftmaxState::new(q.rows, q.cols));
                }
                let st = &mut states[state.0];
                assert_eq!(st.s_q, q.rows);
                assert_eq!(st.d, q.cols);
                let qv = read_view(pool, q);
                let kv = read_view(pool, k);
                let vv = read_view(pool, v);
                st.update(&qv, &kv, &vv, k.rows);
            }
            Effect::AttnFinalize { state, out } => {
                let st = states
                    .get(state.0)
                    .context("attention state finalized before any block update")?;
                write_view(pool, out, &st.finalize(), None);
            }
            Effect::GatherRows { src, rows, dst } => {
                assert_eq!(rows.len(), dst.rows, "gather row count");
                for (i, &r) in rows.iter().enumerate() {
                    let row = read_view(pool, &src.sub(r, 0, 1, src.cols));
                    write_view(pool, &dst.sub(i, 0, 1, dst.cols), &row, None);
                }
            }
            Effect::ScatterRows { src, dst, rows, reduce } => {
                assert_eq!(rows.len(), src.rows, "scatter row count");
                for (i, &r) in rows.iter().enumerate() {
                    let row = read_view(pool, &src.sub(i, 0, 1, src.cols));
                    write_view(pool, &dst.sub(r, 0, 1, dst.cols), &row, *reduce);
                }
            }
            Effect::RunArtifact { name, inputs, outputs } => {
                let rt = runtime
                    .as_deref_mut()
                    .context("plan uses RunArtifact but no runtime attached")?;
                let ins: Vec<(Vec<f32>, Vec<usize>)> = inputs
                    .iter()
                    .map(|v| (read_view(pool, v), vec![v.rows, v.cols]))
                    .collect();
                let outs = rt.run_artifact(name, &ins)?;
                if outs.len() != outputs.len() {
                    bail!("artifact {name}: expected {} outputs, got {}", outputs.len(), outs.len());
                }
                for (view, data) in outputs.iter().zip(outs) {
                    if data.len() != view.rows * view.cols {
                        bail!("artifact {name}: output size {} != view {}x{}", data.len(), view.rows, view.cols);
                    }
                    write_view(pool, view, &data, None);
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DeviceId;
    use crate::mem::tile::Shape4;
    use crate::plan::{Role, SyncScope};
    use crate::util::seeded_vec;

    fn mk_pool() -> MemPool {
        MemPool::new()
    }

    #[test]
    fn view_read_write_roundtrip() {
        let mut pool = mk_pool();
        let b = pool.alloc(DeviceId(0), Shape4::mat(8, 8));
        let v = MatView::full2d(b, 8, 8).sub(2, 2, 4, 4);
        let data: Vec<f32> = (0..16).map(|i| i as f32).collect();
        write_view(&mut pool, &v, &data, None);
        assert_eq!(read_view(&pool, &v), data);
        // reduce add
        write_view(&mut pool, &v, &vec![1.0; 16], Some(ReduceOp::Add));
        assert_eq!(read_view(&pool, &v)[0], 1.0);
        assert_eq!(read_view(&pool, &v)[15], 16.0);
    }

    #[test]
    fn copy_between_devices() {
        let mut pool = mk_pool();
        let a = pool.alloc_init(DeviceId(0), Shape4::mat(4, 4), seeded_vec(1, 16));
        let b = pool.alloc(DeviceId(1), Shape4::mat(4, 4));
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "w0");
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "copy",
                effect: Some(Effect::CopyMat {
                    src: MatView::full2d(a, 4, 4),
                    dst: MatView::full2d(b, 4, 4),
                    reduce: None,
                }),
            },
        );
        FunctionalExec::new(&mut pool).run(&plan).unwrap();
        assert_eq!(pool.get(a).data, pool.get(b).data);
    }

    #[test]
    fn semaphores_order_cross_worker_ops() {
        // w1 waits for w0's signal before copying; under any rotation the
        // result must be the post-increment value.
        for rot in 0..3 {
            let mut pool = mk_pool();
            let a = pool.alloc(DeviceId(0), Shape4::mat(1, 1));
            let b = pool.alloc(DeviceId(1), Shape4::mat(1, 1));
            let mut plan = Plan::new();
            let s = plan.add_sem(0);
            let w0 = plan.add_worker(DeviceId(0), Role::ComputeSm, "w0");
            let w1 = plan.add_worker(DeviceId(1), Role::ComputeSm, "w1");
            // w0: write 42 into a, then signal
            plan.push(
                w0,
                Op::Compute {
                    dur: 0.0,
                    label: "init",
                    effect: Some(Effect::CopyMat {
                        src: MatView::full2d(a, 1, 1), // will be overwritten below
                        dst: MatView::full2d(a, 1, 1),
                        reduce: None,
                    }),
                },
            );
            pool.get_mut(a).data[0] = 42.0;
            plan.push(w0, Op::Signal { sem: s, value: 1, scope: SyncScope::InterDevice });
            // w1: wait then copy a -> b
            plan.push(w1, Op::Wait { sem: s, value: 1 });
            plan.push(
                w1,
                Op::Compute {
                    dur: 0.0,
                    label: "copy",
                    effect: Some(Effect::CopyMat {
                        src: MatView::full2d(a, 1, 1),
                        dst: MatView::full2d(b, 1, 1),
                        reduce: None,
                    }),
                },
            );
            FunctionalExec::new(&mut pool).with_rotation(rot).run(&plan).unwrap();
            assert_eq!(pool.get(b).data[0], 42.0);
        }
    }

    #[test]
    fn deadlock_is_detected() {
        let mut pool = mk_pool();
        let mut plan = Plan::new();
        let s = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "w0");
        plan.push(w, Op::Wait { sem: s, value: 1 }); // never signalled
        let err = FunctionalExec::new(&mut pool).run(&plan).unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn gemm_effect_matches_linalg() {
        let mut pool = mk_pool();
        let (m, n, k) = (8, 12, 16);
        let a = pool.alloc_init(DeviceId(0), Shape4::mat(m, k), seeded_vec(1, m * k));
        let b = pool.alloc_init(DeviceId(0), Shape4::mat(k, n), seeded_vec(2, k * n));
        let c = pool.alloc(DeviceId(0), Shape4::mat(m, n));
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "mm");
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "mma",
                effect: Some(Effect::Gemm {
                    a: MatView::full2d(a, m, k),
                    b: MatView::full2d(b, k, n),
                    c: MatView::full2d(c, m, n),
                    accumulate: false,
                }),
            },
        );
        FunctionalExec::new(&mut pool).run(&plan).unwrap();
        let want = linalg::matmul(&pool.get(a).data, &pool.get(b).data, m, n, k);
        crate::util::assert_allclose(&pool.get(c).data, &want, 1e-6, 1e-7);
    }

    #[test]
    fn attention_effects_match_reference() {
        let mut pool = mk_pool();
        let (s_q, s_kv, d) = (8, 24, 16);
        let q = pool.alloc_init(DeviceId(0), Shape4::mat(s_q, d), seeded_vec(3, s_q * d));
        let k = pool.alloc_init(DeviceId(0), Shape4::mat(s_kv, d), seeded_vec(4, s_kv * d));
        let v = pool.alloc_init(DeviceId(0), Shape4::mat(s_kv, d), seeded_vec(5, s_kv * d));
        let o = pool.alloc(DeviceId(0), Shape4::mat(s_q, d));
        let mut plan = Plan::new();
        let st = plan.add_state();
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "attn");
        for blk in 0..3 {
            plan.push(
                w,
                Op::Compute {
                    dur: 0.0,
                    label: "attn_blk",
                    effect: Some(Effect::AttnBlock {
                        q: MatView::full2d(q, s_q, d),
                        k: MatView::full2d(k, s_kv, d).sub(blk * 8, 0, 8, d),
                        v: MatView::full2d(v, s_kv, d).sub(blk * 8, 0, 8, d),
                        state: st,
                    }),
                },
            );
        }
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "attn_fin",
                effect: Some(Effect::AttnFinalize { state: st, out: MatView::full2d(o, s_q, d) }),
            },
        );
        FunctionalExec::new(&mut pool).run(&plan).unwrap();
        let want = linalg::attention_ref(&pool.get(q).data, &pool.get(k).data, &pool.get(v).data, s_q, s_kv, d);
        crate::util::assert_allclose(&pool.get(o).data, &want, 1e-5, 1e-6);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut pool = mk_pool();
        let src = pool.alloc_init(DeviceId(0), Shape4::mat(6, 4), seeded_vec(6, 24));
        let mid = pool.alloc(DeviceId(1), Shape4::mat(3, 4));
        let dst = pool.alloc(DeviceId(0), Shape4::mat(6, 4));
        let rows = vec![4usize, 0, 2];
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "gs");
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "gather",
                effect: Some(Effect::GatherRows {
                    src: MatView::full2d(src, 6, 4),
                    rows: rows.clone(),
                    dst: MatView::full2d(mid, 3, 4),
                }),
            },
        );
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "scatter",
                effect: Some(Effect::ScatterRows {
                    src: MatView::full2d(mid, 3, 4),
                    dst: MatView::full2d(dst, 6, 4),
                    rows: rows.clone(),
                    reduce: None,
                }),
            },
        );
        FunctionalExec::new(&mut pool).run(&plan).unwrap();
        for &r in &rows {
            let a = read_view(&pool, &MatView::full2d(src, 6, 4).sub(r, 0, 1, 4));
            let b = read_view(&pool, &MatView::full2d(dst, 6, 4).sub(r, 0, 1, 4));
            assert_eq!(a, b);
        }
    }
}
