//! Plan executors.
//!
//! * [`functional`] — applies every op's [`crate::plan::Effect`] to real
//!   buffers, cooperatively scheduling workers through the plan's
//!   semaphores. It is the *numerical* semantics of a kernel (and also
//!   validates that the plan's synchronization is deadlock-free).
//! * [`timed`] — the discrete-event timing semantics: compute durations,
//!   max-min fair bandwidth sharing over NVLink ports, copy engines, HBM,
//!   and the sync latencies of §3.1.3.

pub mod functional;
pub mod timed;

pub use functional::FunctionalExec;
pub use timed::{TimedExec, TimedResult};
