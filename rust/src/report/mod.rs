//! Regenerates every table and figure of the paper (see DESIGN.md §4 for
//! the experiment index). Each `fig*`/`tab*` function returns a
//! [`Table`] whose rows mirror the paper's exhibit; the `pk figures` CLI
//! and `cargo bench --bench figures` print them.

pub mod ablations;
pub mod exhibits;
pub mod lint;
pub mod table;

pub use exhibits::{
    all_exhibits, run_exhibit, run_exhibits, run_exhibits_checked, set_fault_scenario,
    set_fault_seed, Exhibit, ExhibitResult,
};
pub use table::Table;
