//! Ablations of the design choices the analysis singles out (§3.1) — each
//! knob toggled in isolation on the same workload, quantifying *why* the
//! PK design wins rather than just that it does.
//!
//! | id | knob | paper's claim |
//! |----|------|---------------|
//! | abl-staging    | NCCL channel staging on/off       | §3.1.4: staging + 2-way sync cost up to 1.79× on pure comm |
//! | abl-rendezvous | NCCL rendezvous on/off            | §3.1.4: one-way signalling into preallocated buffers |
//! | abl-multicast  | AG via in-fabric broadcast vs N−1 unicasts | §3.1.3: in-network acceleration (1.57× claim for AG) |
//! | abl-atomics    | atomic-overhead sweep on GEMM+RS  | §3.1.3: residual comm near the K threshold comes from atomics |
//! | abl-swizzle    | tile-order swizzle on/off         | implementation choice every fused RS kernel makes |
//! | abl-pipeline   | pipeline depth sweep              | LCSC template stage count |

use super::table::{ms, Table};
use crate::comm::nccl::{self, NcclModel, RingCtx};
use crate::exec::TimedExec;
use crate::hw::spec::NodeSpec;
use crate::hw::DeviceId;
use crate::kernels::gemm_rs::{self, Schedule};
use crate::kernels::GemmKernelCfg;
use crate::plan::{MatView, Op, Plan, Role, Route, SyncScope, TransferSpec};
use crate::xfer::Mechanism;

fn phantom(n: usize, rows: usize, cols: usize) -> Vec<MatView> {
    (0..n)
        .map(|_| MatView { buf: crate::mem::BufId(0), b: 0, d: 0, row0: 0, col0: 0, rows, cols })
        .collect()
}

fn time_of(node: &NodeSpec, plan: &Plan) -> f64 {
    TimedExec::new(node.clone()).run(plan).total_time
}

/// NCCL ring all-reduce with staging / rendezvous toggled.
pub fn ablate_nccl_overheads() -> Table {
    let node = NodeSpec::hgx_h100();
    let (rows, cols) = (8192, 8192); // 128 MB bf16
    let mut t = Table::new(
        "Ablation: NCCL design overheads on ring all-reduce (128 MB BF16)",
        &["staging", "rendezvous_us", "time_ms", "vs_lean"],
    );
    let mut base = 0.0;
    for (staged, rendezvous) in [(false, 0.0), (false, 10e-6), (true, 0.0), (true, 10e-6)] {
        let model = NcclModel { staged, rendezvous, ..Default::default() };
        let mut plan = Plan::new();
        nccl::ring_all_reduce(&mut plan, &RingCtx { node: &node, model, replicas: phantom(8, rows, cols) });
        let time = time_of(&node, &plan);
        if base == 0.0 {
            base = time;
        }
        t.row(vec![
            staged.to_string(),
            format!("{:.0}", rendezvous * 1e6),
            ms(time),
            format!("{:.2}x", time / base),
        ]);
    }
    t
}

/// All-gather of a shard: one in-fabric multicast vs N−1 unicast stores,
/// at a **fixed communicator budget** (4 SMs per device — the inter-SM
/// partition a fused kernel can actually spare). The broadcast sends each
/// byte once; unicasts push 7× the egress bytes through the same SMs,
/// which is where the §3.1.3 in-network-acceleration win comes from.
pub fn ablate_multicast() -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Ablation: in-fabric broadcast vs N−1 unicasts (all-gather, 4 comm SMs/device)",
        &["shard_MB", "multicast_ms", "unicast_ms", "speedup"],
    );
    for shard_mb in [8usize, 32, 128] {
        let bytes = (shard_mb << 20) as f64;
        let build = |multicast: bool| {
            let mut plan = Plan::new();
            for d in 0..8 {
                let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("d{d}"));
                if multicast {
                    plan.push(w, Op::Transfer {
                        spec: TransferSpec {
                            mech: Mechanism::Tma,
                            route: Route::Multicast { src: DeviceId(d) },
                            bytes,
                            msg_bytes: 65536.0,
                            n_sms: 4.0,
                        },
                        blocking: true,
                        done_sem: None,
                        done_scope: SyncScope::IntraSm,
                        label: "mc",
                        effect: None,
                    });
                } else {
                    for o in 0..8 {
                        if o == d {
                            continue;
                        }
                        plan.push(w, Op::Transfer {
                            spec: TransferSpec {
                                mech: Mechanism::Tma,
                                route: Route::P2p { src: DeviceId(d), dst: DeviceId(o) },
                                bytes,
                                msg_bytes: 65536.0,
                                n_sms: 4.0 / 7.0,
                            },
                            blocking: false,
                            done_sem: None,
                            done_scope: SyncScope::IntraSm,
                            label: "p2p",
                            effect: None,
                        });
                    }
                }
            }
            plan
        };
        let t_mc = time_of(&node, &build(true));
        let t_uni = time_of(&node, &build(false));
        t.row(vec![shard_mb.to_string(), ms(t_mc), ms(t_uni), format!("{:.2}", t_uni / t_mc)]);
    }
    t
}

/// GEMM+RS with the atomic destination overhead swept (the Table 3
/// residual-communication mechanism).
pub fn ablate_atomics() -> Table {
    let mut t = Table::new(
        "Ablation: atomic-add destination overhead on GEMM+RS (N=32768, K=2048)",
        &["atomic_overhead", "fused_ms", "comm_ratio"],
    );
    for frac in [0.0, 0.15, 0.3, 0.6] {
        let mut node = NodeSpec::hgx_h100();
        node.gpu.atomic_overhead_frac = frac;
        let cfg = GemmKernelCfg::new(node.clone(), 32768, 32768, 2048);
        let fused = time_of(&node, &gemm_rs::build(&cfg, Schedule::IntraSm, None));
        let gemm = time_of(&node, &crate::kernels::gemm::build(&cfg, None));
        t.row(vec![
            format!("{:.2}", frac),
            ms(fused),
            format!("{:.1}%", (fused - gemm) / fused * 100.0),
        ]);
    }
    t
}

/// Pipeline-stage sweep on the intra-SM GEMM+RS (the LCSC template knob).
pub fn ablate_pipeline_depth() -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Ablation: LCSC pipeline stages on GEMM+RS (N=32768, K=2048)",
        &["stages", "fused_ms"],
    );
    for stages in [1u64, 2, 4, 8] {
        let mut cfg = GemmKernelCfg::new(node.clone(), 32768, 32768, 2048);
        cfg.opts.pipeline_stages = stages;
        let fused = time_of(&node, &gemm_rs::build(&cfg, Schedule::IntraSm, None));
        t.row(vec![stages.to_string(), ms(fused)]);
    }
    t
}

/// All ablations, for the bench harness.
pub fn all_ablations() -> Vec<(&'static str, Table)> {
    vec![
        ("abl-nccl-overheads", ablate_nccl_overheads()),
        ("abl-multicast", ablate_multicast()),
        ("abl-atomics", ablate_atomics()),
        ("abl-pipeline", ablate_pipeline_depth()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nccl_overheads_cost_something() {
        let t = ablate_nccl_overheads();
        // the fully-loaded configuration must be the slowest
        let times = t.col_f64("time_ms");
        assert!(times[3] > times[0], "staging + rendezvous must cost: {times:?}");
    }

    #[test]
    fn multicast_beats_unicasts() {
        let t = ablate_multicast();
        for s in t.col_f64("speedup") {
            assert!(s > 1.3, "broadcast should win clearly: {s}");
        }
    }

    #[test]
    fn atomics_create_residual_comm() {
        let t = ablate_atomics();
        let times = t.col_f64("fused_ms");
        assert!(times[3] > times[0], "higher atomic overhead -> slower: {times:?}");
    }

    #[test]
    fn deeper_pipeline_helps_until_plateau() {
        let t = ablate_pipeline_depth();
        let times = t.col_f64("fused_ms");
        assert!(times[0] >= times[2], "1 stage cannot beat 4: {times:?}");
    }
}
