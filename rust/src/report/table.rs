//! Table formatting: markdown + CSV emitters for the figure harness.

/// A simple column-oriented table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    /// Markdown rendering (what the harness prints).
    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.columns.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.columns.len())));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// CSV rendering (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Fetch a column as f64 (test helper).
    pub fn col_f64(&self, name: &str) -> Vec<f64> {
        let i = self.columns.iter().position(|c| c == name).unwrap_or_else(|| panic!("no column {name}"));
        self.rows.iter().map(|r| r[i].parse::<f64>().unwrap_or(f64::NAN)).collect()
    }
}

/// Format seconds as milliseconds with 3 decimals (the paper's tables).
pub fn ms(t: f64) -> String {
    format!("{:.3}", t * 1e3)
}

/// Format a throughput in TFLOP/s.
pub fn tflops(flops: f64, t: f64) -> String {
    format!("{:.1}", flops / t / 1e12)
}

/// Format a ratio as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv() {
        let mut t = Table::new("Table X", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Table X"));
        assert!(md.contains("| 1 | 2 |"));
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,2\n");
        assert_eq!(t.col_f64("b"), vec![2.0]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(0.0235), "23.500");
        assert_eq!(pct(0.26), "26.0%");
        assert_eq!(tflops(989e12, 1.0), "989.0");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
