//! The `pk lint` sweep: run the static plan verifier
//! ([`crate::plan::verify`]) over every kernel in the zoo — each
//! `build`/`build_cluster` variant on representative 1-node and
//! multi-node `ClusterSpec`s, in both functional (buffers allocated,
//! bounds checked) and timed (effect-free) modes — and report a
//! per-kernel table of what was checked plus a machine-readable JSON
//! document for the CI gate (`tools/check_lint.py`, schema
//! `pk-lint-v1`).
//!
//! Configurations mirror the kernels' own functional tests: small shapes
//! that exercise every code path (rail flows, forwarders, multimem,
//! credit loops) while keeping each plan a few hundred ops, so the whole
//! sweep verifies in well under a second.

use crate::hw::{ClusterSpec, DeviceId, NodeSpec};
use crate::kernels::ag_gemm::AgGemmBufs;
use crate::kernels::collectives::{
    a2a_cluster_stage, hier_all_gather, hier_all_reduce, hier_reduce_scatter, pk_all_gather,
    pk_all_reduce, pk_all_to_all_4d, pk_all_to_all_4d_cluster, pk_reduce_scatter, A2aCfg, Axis,
    ClusterCollCtx, PkCollCtx,
};
use crate::kernels::gemm::GemmBufs;
use crate::kernels::gemm_ar::GemmArBufs;
use crate::kernels::gemm_rs::{ClusterPath, GemmRsBufs, Schedule};
use crate::kernels::moe::{MoeBufs, MoeCfg, MoeClusterBufs, MoeCombineBufs, MoeSchedule, Routing};
use crate::kernels::ring_attention::{ClusterRingAttnCfg, RingAttnBufs, RingAttnCfg};
use crate::kernels::ulysses::{UlyssesBufs, UlyssesCfg};
use crate::kernels::{ag_gemm, gemm, gemm_ar, gemm_rs, moe, ring_attention, ulysses, GemmKernelCfg};
use crate::mem::{MemPool, Shape4};
use crate::pk::rail::{RailHealth, DEFAULT_RDMA_CHUNK};
use crate::pk::template::LcscOpts;
use crate::plan::verify::{verify, VerifyCtx, VerifyReport};
use crate::plan::{MatView, Plan};
use crate::report::Table;
use crate::util::json::{obj, Json};

/// One verified zoo entry.
pub struct LintResult {
    pub name: &'static str,
    pub report: VerifyReport,
}

fn check(plan: &Plan, pool: Option<&MemPool>, devices_per_node: usize) -> VerifyReport {
    let ctx = VerifyCtx { pool, devices_per_node: Some(devices_per_node) };
    verify(plan, &ctx)
}

fn full_views(bufs: &[crate::mem::BufId], rows: usize, cols: usize) -> Vec<MatView> {
    bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect()
}

type Builder = Box<dyn FnOnce() -> VerifyReport>;

fn gemm_cfg_fn(n_dev: usize, m: usize, n: usize, k: usize) -> GemmKernelCfg {
    GemmKernelCfg::functional(NodeSpec::test_node(n_dev), m, n, k)
}

fn ring_cfg() -> RingAttnCfg {
    RingAttnCfg {
        node: NodeSpec::test_node(4),
        b: 2,
        h: 2,
        s: 32,
        d: 8,
        opts: LcscOpts {
            num_comm_sms: 4,
            workers_per_device: 2,
            comm_workers_per_device: 1,
            pipeline_stages: 2,
        },
        flash_util: 0.75,
    }
}

fn ring_cluster_cfg() -> ClusterRingAttnCfg {
    ClusterRingAttnCfg {
        cluster: ClusterSpec::test_cluster(2, 2),
        b: 2,
        h: 2,
        s: 32,
        d: 8,
        opts: LcscOpts {
            num_comm_sms: 4,
            workers_per_device: 2,
            comm_workers_per_device: 1,
            pipeline_stages: 2,
        },
        flash_util: 0.75,
        rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO,
    }
}

fn ulysses_cfg() -> UlyssesCfg {
    UlyssesCfg {
        node: NodeSpec::test_node(2),
        b: 2,
        h: 4,
        s: 8,
        d: 4,
        flash_util: 0.75,
        rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO,
    }
}

fn moe_cfg(n_dev: usize) -> MoeCfg {
    MoeCfg {
        node: NodeSpec::test_node(n_dev),
        tokens: n_dev * 6,
        hidden: 8,
        h_expert: 4,
        n_experts: n_dev * 2,
        top_k: 2,
        comm_sms: 8,
        rdma_chunk: DEFAULT_RDMA_CHUNK,
    }
}

/// Cluster MoE config: `p` devices per node, `k` nodes.
fn moe_cluster_cfg(k: usize, p: usize) -> (MoeCfg, ClusterSpec) {
    let cluster = ClusterSpec::test_cluster(k, p);
    let n = k * p;
    let cfg = MoeCfg {
        node: NodeSpec::test_node(p),
        tokens: n * 6,
        hidden: 8,
        h_expert: 4,
        n_experts: n * 2,
        top_k: 2,
        comm_sms: 8,
        rdma_chunk: DEFAULT_RDMA_CHUNK,
    };
    (cfg, cluster)
}

/// The full registry: every kernel's build/build_cluster variants, both
/// functional (pool + bounds checks) and timed (effect-free) where the
/// builder supports it.
#[allow(clippy::too_many_lines, clippy::vec_init_then_push)]
fn registry() -> Vec<(&'static str, Builder)> {
    let mut v: Vec<(&'static str, Builder)> = Vec::new();

    v.push((
        "gemm/functional",
        Box::new(|| {
            let cfg = gemm_cfg_fn(2, 32, 32, 48);
            let mut pool = MemPool::new();
            let bufs = GemmBufs::alloc(&mut pool, &cfg);
            let plan = gemm::build(&cfg, Some(&bufs));
            check(&plan, Some(&pool), 2)
        }),
    ));
    v.push((
        "gemm/timed",
        Box::new(|| {
            let cfg = gemm_cfg_fn(2, 32, 32, 48);
            let plan = gemm::build(&cfg, None);
            check(&plan, None, 2)
        }),
    ));

    for (name, schedule) in
        [("gemm_rs/intra-sm", Schedule::IntraSm), ("gemm_rs/inter-sm", Schedule::InterSm)]
    {
        v.push((
            name,
            Box::new(move || {
                let mut cfg = gemm_cfg_fn(4, 64, 32, 24);
                if schedule == Schedule::InterSm {
                    cfg.opts.num_comm_sms = 8;
                }
                let mut pool = MemPool::new();
                let bufs = GemmRsBufs::alloc(&mut pool, &cfg);
                let plan = gemm_rs::build(&cfg, schedule, Some(&bufs));
                check(&plan, Some(&pool), 4)
            }),
        ));
    }
    v.push((
        "gemm_rs/cluster",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
            let mut pool = MemPool::new();
            let bufs = GemmRsBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            let plan = gemm_rs::build_cluster(&cfg, &cluster, Schedule::IntraSm, Some(&bufs));
            check(&plan, Some(&pool), cluster.devices_per_node())
        }),
    ));
    v.push((
        "gemm_rs/cluster-timed",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
            let plan = gemm_rs::build_cluster(&cfg, &cluster, Schedule::IntraSm, None);
            check(&plan, None, cluster.devices_per_node())
        }),
    ));
    v.push((
        "gemm_rs/cluster-degraded",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
            let health = RailHealth::all_healthy(&cluster).fail_nic(1);
            let mut pool = MemPool::new();
            let bufs = GemmRsBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            let plan = gemm_rs::build_cluster_health(
                &cfg,
                &cluster,
                Schedule::IntraSm,
                ClusterPath::RailReduce,
                &health,
                Some(&bufs),
            );
            check(&plan, Some(&pool), cluster.devices_per_node())
        }),
    ));
    v.push((
        // One NIC down on each node: exercises TX-donor and RX-donor
        // reroute simultaneously in both directions.
        "gemm_rs/cluster-degraded-both-nodes",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
            let health = RailHealth::all_healthy(&cluster).fail_nic(1).fail_nic(2);
            let mut pool = MemPool::new();
            let bufs = GemmRsBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            let plan = gemm_rs::build_cluster_health(
                &cfg,
                &cluster,
                Schedule::IntraSm,
                ClusterPath::RailReduce,
                &health,
                Some(&bufs),
            );
            check(&plan, Some(&pool), cluster.devices_per_node())
        }),
    ));

    for (name, schedule) in
        [("gemm_ar/intra-sm", Schedule::IntraSm), ("gemm_ar/inter-sm", Schedule::InterSm)]
    {
        v.push((
            name,
            Box::new(move || {
                let mut cfg = gemm_cfg_fn(4, 64, 32, 16);
                cfg.opts.num_comm_sms = if schedule == Schedule::InterSm { 8 } else { 0 };
                let mut pool = MemPool::new();
                let bufs = GemmArBufs::alloc(&mut pool, &cfg);
                let plan = gemm_ar::build(&cfg, schedule, Some(&bufs));
                check(&plan, Some(&pool), 4)
            }),
        ));
    }
    for (name, schedule) in [
        ("gemm_ar/cluster-intra-sm", Schedule::IntraSm),
        ("gemm_ar/cluster-inter-sm", Schedule::InterSm),
    ] {
        v.push((
            name,
            Box::new(move || {
                let cluster = ClusterSpec::test_cluster(2, 2);
                let mut cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
                if schedule == Schedule::InterSm {
                    cfg.opts.num_comm_sms = 8;
                }
                let mut pool = MemPool::new();
                let bufs = GemmArBufs::alloc_cluster(&mut pool, &cfg, &cluster);
                let plan = gemm_ar::build_cluster(&cfg, &cluster, schedule, Some(&bufs));
                check(&plan, Some(&pool), cluster.devices_per_node())
            }),
        ));
    }
    v.push((
        "gemm_ar/cluster-timed",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
            let plan = gemm_ar::build_cluster(&cfg, &cluster, Schedule::IntraSm, None);
            check(&plan, None, cluster.devices_per_node())
        }),
    ));
    v.push((
        "gemm_ar/cluster-degraded",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
            let health = RailHealth::all_healthy(&cluster).fail_nic(1);
            let mut pool = MemPool::new();
            let bufs = GemmArBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            let plan = gemm_ar::build_cluster_health(
                &cfg,
                &cluster,
                Schedule::IntraSm,
                ClusterPath::RailReduce,
                &health,
                Some(&bufs),
            );
            check(&plan, Some(&pool), cluster.devices_per_node())
        }),
    ));

    v.push((
        "ag_gemm/functional",
        Box::new(|| {
            let mut cfg = gemm_cfg_fn(4, 64, 32, 24);
            cfg.opts.num_comm_sms = 8;
            let mut pool = MemPool::new();
            let bufs = AgGemmBufs::alloc(&mut pool, &cfg);
            let plan = ag_gemm::build(&cfg, Some(&bufs));
            check(&plan, Some(&pool), 4)
        }),
    ));
    v.push((
        "ag_gemm/cluster",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let mut cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
            cfg.opts.num_comm_sms = 8;
            let mut pool = MemPool::new();
            let bufs = AgGemmBufs::alloc_cluster(&mut pool, &cfg, &cluster);
            let plan = ag_gemm::build_cluster(&cfg, &cluster, Some(&bufs));
            check(&plan, Some(&pool), cluster.devices_per_node())
        }),
    ));
    v.push((
        "ag_gemm/cluster-timed",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let mut cfg = GemmKernelCfg::functional(cluster.node.clone(), 64, 32, 24);
            cfg.opts.num_comm_sms = 8;
            let plan = ag_gemm::build_cluster(&cfg, &cluster, None);
            check(&plan, None, cluster.devices_per_node())
        }),
    ));

    v.push((
        "ring_attention/functional",
        Box::new(|| {
            let cfg = ring_cfg();
            let mut pool = MemPool::new();
            let bufs = RingAttnBufs::alloc(&mut pool, &cfg);
            let plan = ring_attention::build(&cfg, Some(&bufs));
            check(&plan, Some(&pool), 4)
        }),
    ));
    v.push((
        "ring_attention/cluster",
        Box::new(|| {
            let cfg = ring_cluster_cfg();
            let mut pool = MemPool::new();
            let bufs = RingAttnBufs::alloc_cluster(&mut pool, &cfg);
            let plan = ring_attention::build_cluster(&cfg, Some(&bufs));
            check(&plan, Some(&pool), cfg.cluster.devices_per_node())
        }),
    ));
    v.push((
        "ring_attention/cluster-timed",
        Box::new(|| {
            let cfg = ring_cluster_cfg();
            let plan = ring_attention::build_cluster(&cfg, None);
            check(&plan, None, cfg.cluster.devices_per_node())
        }),
    ));

    v.push((
        "ulysses/functional",
        Box::new(|| {
            let cfg = ulysses_cfg();
            let mut pool = MemPool::new();
            let bufs = UlyssesBufs::alloc(&mut pool, &cfg);
            let plan = ulysses::build(&cfg, Some(&bufs));
            check(&plan, Some(&pool), 2)
        }),
    ));
    v.push((
        "ulysses/cluster-timed",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let cfg = ulysses_cfg();
            let plan = ulysses::build_cluster(&cfg, &cluster);
            check(&plan, None, cluster.devices_per_node())
        }),
    ));

    v.push((
        "moe/overlapped",
        Box::new(|| {
            let cfg = moe_cfg(4);
            let routing = Routing::uniform(&cfg, 7);
            let mut pool = MemPool::new();
            let bufs = MoeBufs::alloc(&mut pool, &cfg, &routing);
            let plan = moe::build(&cfg, &routing, MoeSchedule::Overlapped, Some(&bufs));
            check(&plan, Some(&pool), 4)
        }),
    ));
    // the Sequential schedule has no functional-test coverage with
    // buffers, so verify its sync structure in timed (effect-free) mode
    v.push((
        "moe/sequential-timed",
        Box::new(|| {
            let cfg = moe_cfg(4);
            let routing = Routing::uniform(&cfg, 7);
            let plan = moe::build(&cfg, &routing, MoeSchedule::Sequential, None);
            check(&plan, None, 4)
        }),
    ));
    v.push((
        "moe/cluster",
        Box::new(|| {
            let (cfg, cluster) = moe_cluster_cfg(2, 2);
            let routing = Routing::uniform(&cfg, 17);
            let mut pool = MemPool::new();
            let bufs = MoeClusterBufs::alloc(&mut pool, &cfg, &cluster, &routing);
            let plan =
                moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, Some(&bufs));
            check(&plan, Some(&pool), cluster.devices_per_node())
        }),
    ));
    v.push((
        "moe/cluster-layer",
        Box::new(|| {
            let (cfg, cluster) = moe_cluster_cfg(2, 2);
            let routing = Routing::uniform(&cfg, 31);
            let mut pool = MemPool::new();
            let bufs = MoeClusterBufs::alloc(&mut pool, &cfg, &cluster, &routing);
            let comb = MoeCombineBufs::alloc(&mut pool, &cfg, &cluster, &routing);
            let plan = moe::build_cluster_layer(
                &cfg,
                &cluster,
                &routing,
                MoeSchedule::Overlapped,
                Some((&bufs, &comb)),
            );
            check(&plan, Some(&pool), cluster.devices_per_node())
        }),
    ));
    v.push((
        "moe/cluster-layer-degraded",
        Box::new(|| {
            let (cfg, cluster) = moe_cluster_cfg(2, 2);
            let routing = Routing::uniform(&cfg, 31);
            let health = RailHealth::all_healthy(&cluster).fail_nic(1);
            let mut pool = MemPool::new();
            let bufs = MoeClusterBufs::alloc(&mut pool, &cfg, &cluster, &routing);
            let comb = MoeCombineBufs::alloc(&mut pool, &cfg, &cluster, &routing);
            let plan = moe::build_cluster_layer_health(
                &cfg,
                &cluster,
                &routing,
                MoeSchedule::Overlapped,
                &health,
                Some((&bufs, &comb)),
            );
            check(&plan, Some(&pool), cluster.devices_per_node())
        }),
    ));
    v.push((
        "moe/cluster-timed",
        Box::new(|| {
            let (cfg, cluster) = moe_cluster_cfg(2, 2);
            let routing = Routing::uniform(&cfg, 17);
            let plan = moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, None);
            check(&plan, None, cluster.devices_per_node())
        }),
    ));

    v.push((
        "coll/all_reduce",
        Box::new(|| {
            let n = 8;
            let (rows, cols) = (n * 2, 4);
            let node = NodeSpec::test_node(n);
            let mut pool = MemPool::new();
            let bufs: Vec<_> =
                (0..n).map(|d| pool.alloc(DeviceId(d), Shape4::mat(rows, cols))).collect();
            let ctx = PkCollCtx::new(&node, full_views(&bufs, rows, cols));
            let mut plan = Plan::new();
            pk_all_reduce(&mut plan, &ctx);
            check(&plan, Some(&pool), n)
        }),
    ));
    v.push((
        "coll/all_gather",
        Box::new(|| {
            let n = 4;
            let (rows, cols) = (4, n * 3);
            let node = NodeSpec::test_node(n);
            let mut pool = MemPool::new();
            let bufs: Vec<_> =
                (0..n).map(|d| pool.alloc(DeviceId(d), Shape4::mat(rows, cols))).collect();
            let ctx = PkCollCtx::new(&node, full_views(&bufs, rows, cols));
            let mut plan = Plan::new();
            pk_all_gather(&mut plan, &ctx, Axis::Col);
            check(&plan, Some(&pool), n)
        }),
    ));
    v.push((
        "coll/reduce_scatter",
        Box::new(|| {
            let n = 4;
            let (rows, cols) = (4, n * 2);
            let node = NodeSpec::test_node(n);
            let mut pool = MemPool::new();
            let bufs: Vec<_> =
                (0..n).map(|d| pool.alloc(DeviceId(d), Shape4::mat(rows, cols))).collect();
            let ctx = PkCollCtx::new(&node, full_views(&bufs, rows, cols));
            let mut plan = Plan::new();
            pk_reduce_scatter(&mut plan, &ctx, Axis::Col);
            check(&plan, Some(&pool), n)
        }),
    ));
    v.push((
        "coll/all_to_all",
        Box::new(|| {
            let n = 4;
            let cfg = A2aCfg { b_dim: 2, s_local: 3, h: 8, d_head: 4 };
            let node = NodeSpec::test_node(n);
            let mut pool = MemPool::new();
            let mut srcs = vec![];
            let mut dsts = vec![];
            for d in 0..n {
                srcs.push(pool.alloc(
                    DeviceId(d),
                    Shape4 { b: cfg.b_dim, d: cfg.s_local, r: cfg.h, c: cfg.d_head },
                ));
                dsts.push(pool.alloc(
                    DeviceId(d),
                    Shape4 { b: cfg.b_dim, d: cfg.s_local * n, r: cfg.h / n, c: cfg.d_head },
                ));
            }
            let mut plan = Plan::new();
            pk_all_to_all_4d(&mut plan, &node, &cfg, Some(&srcs), Some(&dsts), 8.0);
            check(&plan, Some(&pool), n)
        }),
    ));
    v.push((
        "coll/hier_all_reduce",
        Box::new(|| {
            let (k, p) = (2usize, 2usize);
            let n = k * p;
            let (rows, cols) = (n * 2, 6);
            let cluster = ClusterSpec::test_cluster(k, p);
            let mut pool = MemPool::new();
            let bufs: Vec<_> =
                (0..n).map(|d| pool.alloc(DeviceId(d), Shape4::mat(rows, cols))).collect();
            let ctx = ClusterCollCtx::new(&cluster, full_views(&bufs, rows, cols));
            let mut plan = Plan::new();
            hier_all_reduce(&mut plan, &ctx);
            check(&plan, Some(&pool), p)
        }),
    ));
    v.push((
        "coll/hier_all_gather",
        Box::new(|| {
            let (k, p) = (2usize, 2usize);
            let n = k * p;
            let (rows, cols) = (n * 2, n * 3);
            let cluster = ClusterSpec::test_cluster(k, p);
            let mut pool = MemPool::new();
            let bufs: Vec<_> =
                (0..n).map(|d| pool.alloc(DeviceId(d), Shape4::mat(rows, cols))).collect();
            let ctx = ClusterCollCtx::new(&cluster, full_views(&bufs, rows, cols));
            let mut plan = Plan::new();
            hier_all_gather(&mut plan, &ctx, Axis::Row);
            check(&plan, Some(&pool), p)
        }),
    ));
    v.push((
        "coll/hier_reduce_scatter",
        Box::new(|| {
            let (k, p) = (2usize, 3usize);
            let n = k * p;
            let (rows, cols) = (n * 2, 5);
            let cluster = ClusterSpec::test_cluster(k, p);
            let mut pool = MemPool::new();
            let bufs: Vec<_> =
                (0..n).map(|d| pool.alloc(DeviceId(d), Shape4::mat(rows, cols))).collect();
            let ctx = ClusterCollCtx::new(&cluster, full_views(&bufs, rows, cols));
            let mut plan = Plan::new();
            hier_reduce_scatter(&mut plan, &ctx, Axis::Row);
            check(&plan, Some(&pool), p)
        }),
    ));
    v.push((
        "coll/all_to_all-cluster",
        Box::new(|| {
            let (k, p) = (2usize, 2usize);
            let n = k * p;
            let cluster = ClusterSpec::test_cluster(k, p);
            let cfg = A2aCfg { b_dim: 2, s_local: 3, h: 2 * n, d_head: 4 };
            let mut pool = MemPool::new();
            let mut srcs = vec![];
            let mut dsts = vec![];
            for d in 0..n {
                srcs.push(pool.alloc(
                    DeviceId(d),
                    Shape4 { b: cfg.b_dim, d: cfg.s_local, r: cfg.h, c: cfg.d_head },
                ));
                dsts.push(pool.alloc(
                    DeviceId(d),
                    Shape4 { b: cfg.b_dim, d: cfg.s_local * n, r: cfg.h / n, c: cfg.d_head },
                ));
            }
            let stage = a2a_cluster_stage(&mut pool, &cluster, &cfg);
            let mut plan = Plan::new();
            pk_all_to_all_4d_cluster(
                &mut plan,
                &cluster,
                &cfg,
                Some(&srcs),
                Some(&dsts),
                Some(&stage),
                DEFAULT_RDMA_CHUNK,
                8.0,
            );
            check(&plan, Some(&pool), p)
        }),
    ));

    // ---- model layer: whole-model plans through the kernel-builder API.
    // Timed-only (the composition layer never carries buffers); shapes are
    // the smallest that satisfy every kernel divisibility constraint at
    // tile_m = 128 and stage width 2.
    v.push((
        "model/dense-1node",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(1, 2);
            let health = RailHealth::all_healthy(&cluster);
            let plan = crate::model::pipeline::build_model(
                &model_cfg_small(false),
                &crate::model::ParallelSpec::dense(2, 1),
                &cluster,
                &health,
                crate::model::pipeline::PipeSchedule::OneFOneB,
            );
            check(&plan, None, cluster.devices_per_node())
        }),
    ));
    v.push((
        "model/dense-cluster",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let health = RailHealth::all_healthy(&cluster);
            let plan = crate::model::pipeline::build_model(
                &model_cfg_small(false),
                &crate::model::ParallelSpec::dense(2, 2),
                &cluster,
                &health,
                crate::model::pipeline::PipeSchedule::OneFOneB,
            );
            check(&plan, None, cluster.devices_per_node())
        }),
    ));
    v.push((
        "model/moe-cluster",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let health = RailHealth::all_healthy(&cluster);
            let plan = crate::model::pipeline::build_model(
                &model_cfg_small(true),
                &crate::model::ParallelSpec::moe(2, 2),
                &cluster,
                &health,
                crate::model::pipeline::PipeSchedule::OneFOneB,
            );
            check(&plan, None, cluster.devices_per_node())
        }),
    ));
    v.push((
        // one multi-node expert-parallel stage (ep spans both nodes) with
        // a failed NIC: the MoE dispatch/combine rail reroute and the
        // wave-level credit chaining between the stage's two layers both
        // run under the degraded mask
        "model/moe-multinode-stage-degraded",
        Box::new(|| {
            let cluster = ClusterSpec::test_cluster(2, 2);
            let health = RailHealth::all_healthy(&cluster).fail_nic(1);
            let plan = crate::model::pipeline::build_model(
                &model_cfg_small(true),
                &crate::model::ParallelSpec::moe(4, 1),
                &cluster,
                &health,
                crate::model::pipeline::PipeSchedule::OneFOneB,
            );
            check(&plan, None, cluster.devices_per_node())
        }),
    ));

    v
}

/// Smallest model shape that satisfies every kernel constraint at stage
/// width 2 (`seq % 256`, `ffn/2 % 128`, `hidden % 128`).
fn model_cfg_small(moe: bool) -> crate::model::ModelCfg {
    crate::model::ModelCfg {
        hidden: 128,
        ffn: 256,
        seq: 256,
        n_heads: 2,
        n_layers: 2,
        microbatches: 2,
        moe: moe.then_some(crate::model::MoeParams { n_experts: 4, top_k: 2, h_expert: 32 }),
        flash_util: 0.75,
    }
}

/// Run the sweep. `only` filters entry names by substring.
pub fn run_lint(only: Option<&str>) -> Vec<LintResult> {
    registry()
        .into_iter()
        .filter(|(name, _)| only.is_none_or(|pat| name.contains(pat)))
        .map(|(name, build)| LintResult { name, report: build() })
        .collect()
}

/// Per-kernel coverage/finding table for the CLI.
pub fn lint_table(results: &[LintResult]) -> Table {
    let mut t = Table::new(
        "plan lint — static verification of the kernel zoo",
        &["kernel", "workers", "ops", "sems", "edges", "accesses", "pairs", "errors", "warnings"],
    );
    for r in results {
        let s = &r.report.stats;
        t.row(vec![
            r.name.to_string(),
            s.workers.to_string(),
            s.ops.to_string(),
            s.sems.to_string(),
            s.sync_edges.to_string(),
            s.accesses.to_string(),
            s.pairs_checked.to_string(),
            r.report.num_errors().to_string(),
            r.report.num_warnings().to_string(),
        ]);
    }
    t
}

/// Machine-readable sweep document (consumed by `tools/check_lint.py`).
pub fn lint_json(results: &[LintResult]) -> Json {
    let kernels: Vec<Json> = results
        .iter()
        .map(|r| {
            let s = &r.report.stats;
            obj(vec![
                ("name", Json::Str(r.name.to_string())),
                ("workers", Json::Num(s.workers as f64)),
                ("ops", Json::Num(s.ops as f64)),
                ("sems", Json::Num(s.sems as f64)),
                ("sync_edges", Json::Num(s.sync_edges as f64)),
                ("accesses", Json::Num(s.accesses as f64)),
                ("pairs_checked", Json::Num(s.pairs_checked as f64)),
                ("rdma_bytes", Json::Num(s.rdma_bytes)),
                ("errors", Json::Num(r.report.num_errors() as f64)),
                ("warnings", Json::Num(r.report.num_warnings() as f64)),
                (
                    "findings",
                    Json::Arr(
                        r.report.findings.iter().map(|f| Json::Str(f.to_string())).collect(),
                    ),
                ),
            ])
        })
        .collect();
    obj(vec![("schema", Json::Str("pk-lint-v1".to_string())), ("kernels", Json::Arr(kernels))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_sweep_is_error_free() {
        let results = run_lint(None);
        assert!(results.len() >= 33, "zoo registry shrank: {}", results.len());
        for r in &results {
            assert_eq!(
                r.report.num_errors(),
                0,
                "{} has verifier errors:\n{}",
                r.name,
                r.report.render()
            );
            assert!(r.report.stats.ops > 0, "{} built an empty plan", r.name);
        }
    }

    #[test]
    fn sweep_filter_and_json_shape() {
        let results = run_lint(Some("gemm_rs"));
        assert!(!results.is_empty() && results.iter().all(|r| r.name.contains("gemm_rs")));
        let doc = lint_json(&results);
        assert_eq!(doc.get("schema").and_then(|s| s.as_str()), Some("pk-lint-v1"));
        let kernels = doc.get("kernels").and_then(|k| k.as_arr()).expect("kernels array");
        assert_eq!(kernels.len(), results.len());
        let table = lint_table(&results).to_markdown();
        assert!(table.contains("gemm_rs/cluster"));
    }
}
