//! One function per paper exhibit. Sweep points follow the paper's axes;
//! `fast` mode trims sweeps for CI.

use super::table::{ms, pct, Table};
use crate::baselines::{self, phantom_replicas};
use crate::comm::nccl::{self, NcclModel, RingCtx};
use crate::comm::nvshmem::{self, PeerApi};
use crate::exec::TimedExec;
use crate::hw::spec::{GpuSpec, NodeSpec};
use crate::hw::ClusterSpec;
use crate::kernels::collectives::{self, Axis, ClusterCollCtx, PkCollCtx};
use crate::kernels::gemm_rs::Schedule;
use crate::kernels::moe::{MoeCfg, MoeSchedule, Routing};
use crate::kernels::ring_attention::RingAttnCfg;
use crate::kernels::ulysses::UlyssesCfg;
use crate::kernels::{ag_gemm, gemm, gemm_ar, gemm_rs, moe, ring_attention, ulysses, GemmKernelCfg};
use crate::model::{pipeline, ParallelSpec};
use crate::pk::rail::RailHealth;
use crate::plan::Plan;
use crate::sim::fault::{FaultSpec, LinkFault};
use crate::sim::serve::{self, KernelMode, ModelCfg, ServeCfg, StepCostModel};
use crate::sim::workload::{self, ArrivalProcess, TraceCfg};
use crate::xfer::{curves, Functionality, Mechanism};

/// An exhibit of the paper: id, caption, generator.
pub struct Exhibit {
    pub id: &'static str,
    pub caption: &'static str,
    pub run: fn(fast: bool) -> Table,
}

// CLI overrides for the fx1 robustness exhibit (`pk figures
// --fault-seed` / `--fault`). Exhibit generators are plain `fn(bool)`
// pointers, so the flags travel through process-wide cells: set once
// before the first exhibit runs, first write wins, never re-read races
// (`run_exhibits` only reads them from inside fx1).
static FX1_FAULT_SEED: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
static FX1_FAULT_SCENARIO: std::sync::OnceLock<FaultSpec> = std::sync::OnceLock::new();

/// Override the splitmix64 seed fx1 feeds every generated [`FaultSpec`]
/// (default 7). Call before running exhibits; later calls are no-ops.
pub fn set_fault_seed(seed: u64) {
    let _ = FX1_FAULT_SEED.set(seed);
}

/// Supply a user fault scenario; fx1 appends a `custom` axis running
/// every kernel under it (rail plans health-masked against the
/// scenario's permanently dead NICs). Call before running exhibits.
pub fn set_fault_scenario(spec: FaultSpec) {
    let _ = FX1_FAULT_SCENARIO.set(spec);
}

fn fault_seed() -> u64 {
    *FX1_FAULT_SEED.get().unwrap_or(&7)
}

/// The full registry, in paper order.
pub fn all_exhibits() -> Vec<Exhibit> {
    vec![
        Exhibit { id: "tab1", caption: "Table 1: NVLink bandwidth utilization by mechanism", run: tab1 },
        Exhibit { id: "fig2", caption: "Figure 2: bandwidth vs message size (1 GB P2P)", run: fig2 },
        Exhibit { id: "fig3", caption: "Figure 3: SMs required to saturate NVLink", run: fig3 },
        Exhibit { id: "tab2", caption: "Table 2: mechanism functionality matrix", run: tab2 },
        Exhibit { id: "fig4", caption: "Figure 4: GEMM+RS / GEMM+AR across overlap schedules", run: fig4 },
        Exhibit { id: "tab3", caption: "Table 3: GEMM vs GEMM+RS vs K (comm hiding)", run: tab3 },
        Exhibit { id: "fig5", caption: "Figure 5: AG+GEMM communicator-SM partition sweep", run: fig5 },
        Exhibit { id: "fig6", caption: "Figure 6: all-reduce PK vs NCCL (BF16)", run: fig6 },
        Exhibit { id: "fig7", caption: "Figure 7: AG+GEMM vs baselines", run: fig7 },
        Exhibit { id: "fig8", caption: "Figure 8: GEMM+RS vs baselines", run: fig8 },
        Exhibit { id: "fig9", caption: "Figure 9: GEMM+AR vs baselines", run: fig9 },
        Exhibit { id: "fig10", caption: "Figure 10: Ring Attention vs xDiT", run: fig10 },
        Exhibit { id: "fig11", caption: "Figure 11: DeepSpeed-Ulysses vs YunChang", run: fig11 },
        Exhibit { id: "fig12", caption: "Figure 12: MoE dispatch+GEMM vs Comet", run: fig12 },
        Exhibit { id: "fig13", caption: "Figure 13: GEMM+RS on B200", run: fig13 },
        Exhibit { id: "fig14", caption: "Figure 14: Ulysses on B200", run: fig14 },
        Exhibit { id: "fig15", caption: "Figure 15: tensor-dim all-gather vs NCCL", run: fig15 },
        Exhibit { id: "fig16", caption: "Figure 16: tensor-dim reduce-scatter vs NCCL", run: fig16 },
        Exhibit { id: "fig17", caption: "Figure 17: 4-D (B,S,H,D) all-to-all vs NCCL", run: fig17 },
        Exhibit { id: "mu1", caption: "§3.1.3 sync microbenchmark (mbarrier vs HBM)", run: mu1 },
        Exhibit { id: "mu2", caption: "§3.1.4 NVSHMEM peer-access overheads", run: mu2 },
        Exhibit { id: "sx1", caption: "Scale-out sweep: hierarchical collectives, 1→4 nodes, NIC 25–100 GB/s", run: sx1 },
        Exhibit { id: "mx1", caption: "Cluster MoE sweep: expert-parallel dispatch over the NIC, 1→4 nodes, NIC 25–100 GB/s", run: mx1 },
        Exhibit { id: "rx1", caption: "pk::rail sweep: hierarchical gemm_rs + two-level Ulysses, 1→4 nodes, NIC 25–100 GB/s, rail vs naive vs baseline", run: rx1 },
        Exhibit { id: "gx1", caption: "Cluster GEMM family: gemm_ar + ag_gemm, 1→4 nodes, NIC 25–100 GB/s, rail vs naive vs baseline + analytic-vs-swept chunk", run: gx1 },
        Exhibit { id: "vx1", caption: "Serving layer: tokens/s, goodput, p50/p99 latency vs offered load under Poisson/bursty/diurnal arrivals, PK-overlapped vs non-overlapped step kernels, 1→4 nodes (disaggregated prefill/decode past 1 node)", run: vx1 },
        Exhibit { id: "fx1", caption: "Robustness: slowdown under bandwidth jitter and NIC failure — health-masked rail reroute vs no-reroute ablations on gemm_rs/gemm_ar/MoE, plus serving goodput/p99 under a mid-trace decode-NIC outage", run: fx1 },
        Exhibit { id: "px1", caption: "Model layer: whole-model training-step time vs parallelism layout (tp/ep x pp), 1->4 nodes, NIC 25-100 GB/s — non-overlapped sequential baseline vs 1F1B vs interleaved pipeline", run: px1 },
    ]
}

/// Run one exhibit by id.
pub fn run_exhibit(id: &str, fast: bool) -> Option<Table> {
    all_exhibits().iter().find(|e| e.id == id).map(|e| (e.run)(fast))
}

/// One regenerated exhibit plus its generation wall time.
pub struct ExhibitResult {
    pub id: &'static str,
    pub caption: &'static str,
    pub table: Table,
    /// Wall-clock seconds this exhibit took to generate.
    pub wall: f64,
}

/// Regenerate the selected exhibits (`ids: None` = all, in paper order)
/// on up to `threads` scoped worker threads, returning tables + per-
/// exhibit wall times in registry order. Exhibit generators are pure
/// functions of `(id, fast)`, so the tables are byte-identical whatever
/// the thread count — pinned by a determinism test; `pk figures` and the
/// figures bench both drive this.
pub fn run_exhibits(fast: bool, ids: Option<&[&str]>, threads: usize) -> Vec<ExhibitResult> {
    let selected: Vec<Exhibit> = all_exhibits()
        .into_iter()
        .filter(|e| ids.map(|ids| ids.contains(&e.id)).unwrap_or(true))
        .collect();
    crate::util::par::par_map_with(threads, &selected, |_, e| {
        // progress goes to stderr as exhibits start/finish (interleaved
        // across workers); the stdout/CSV tables stay deterministic
        eprintln!("running {} ...", e.id);
        let t0 = std::time::Instant::now();
        let table = (e.run)(fast);
        let wall = t0.elapsed().as_secs_f64();
        eprintln!("done    {} ({wall:.2}s)", e.id);
        (table, wall)
    })
    .into_iter()
    .zip(selected)
    .map(|((table, wall), e)| ExhibitResult { id: e.id, caption: e.caption, table, wall })
    .collect()
}

/// Like [`run_exhibits`], but validates the id selection first: an
/// unknown exhibit id is a clean [`crate::util::error::Error`] listing
/// the valid ids, not a silently-empty result set (the CLI's
/// `--only typo` used to print nothing and exit 0).
pub fn run_exhibits_checked(
    fast: bool,
    ids: Option<&[&str]>,
    threads: usize,
) -> crate::util::error::Result<Vec<ExhibitResult>> {
    if let Some(ids) = ids {
        let registry = all_exhibits();
        for id in ids {
            if !registry.iter().any(|e| e.id == *id) {
                let valid: Vec<&str> = registry.iter().map(|e| e.id).collect();
                return Err(crate::anyhow!(
                    "unknown exhibit id '{id}' (valid: {})",
                    valid.join(", ")
                ));
            }
        }
    }
    Ok(run_exhibits(fast, ids, threads))
}

fn time_of(node: &NodeSpec, plan: &Plan) -> f64 {
    TimedExec::new(node.clone()).run(plan).total_time
}

// ---------------------------------------------------------------- Table 1
fn tab1(_fast: bool) -> Table {
    let mut t = Table::new(
        "Table 1: observed NVLink bandwidth (GB/s) for a 1 GB transfer, all SMs",
        &["method", "H100 GB/s", "H100 ratio", "B200 GB/s", "B200 ratio"],
    );
    let h = GpuSpec::h100();
    let b = GpuSpec::b200();
    let gb = 1e9;
    for (name, mech) in [("copy engine", Mechanism::CopyEngine), ("TMA op", Mechanism::Tma), ("register op", Mechanism::RegOp)] {
        let rh = curves::rate(&h, mech, gb, h.num_sms as f64);
        let rb = curves::rate(&b, mech, gb, b.num_sms as f64);
        t.row(vec![
            name.into(),
            format!("{:.2}", rh / 1e9),
            pct(rh / h.nvlink_bw),
            format!("{:.2}", rb / 1e9),
            pct(rb / b.nvlink_bw),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Figure 2
fn fig2(fast: bool) -> Table {
    let mut t = Table::new(
        "Figure 2: bandwidth utilization vs message size (H100, fraction of 450 GB/s)",
        &["msg_bytes", "copy_engine", "tma", "reg"],
    );
    let g = GpuSpec::h100();
    let sizes: Vec<f64> = if fast {
        vec![128.0, 2048.0, 65536.0, 1e6, 256e6, 1e9]
    } else {
        (7..31).map(|p| (1u64 << p) as f64).collect()
    };
    for msg in sizes {
        t.row(vec![
            format!("{msg:.0}"),
            format!("{:.4}", curves::ce_rate(&g, msg) / g.nvlink_bw),
            format!("{:.4}", curves::tma_rate(&g, msg, g.num_sms as f64) / g.nvlink_bw),
            format!("{:.4}", curves::reg_rate(&g, msg, g.num_sms as f64) / g.nvlink_bw),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Figure 3
fn fig3(fast: bool) -> Table {
    let mut t = Table::new(
        "Figure 3: NVLink utilization vs issuing SMs (H100, 1 MB messages)",
        &["sms", "tma", "reg"],
    );
    let g = GpuSpec::h100();
    let points: Vec<u32> =
        if fast { vec![1, 8, 15, 32, 76, 132] } else { (1..=132).collect() };
    for n in points {
        t.row(vec![
            n.to_string(),
            format!("{:.4}", curves::tma_rate(&g, 1e6, n as f64) / g.nvlink_bw),
            format!("{:.4}", curves::reg_rate(&g, 1e6, n as f64) / g.nvlink_bw),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Table 2
fn tab2(_fast: bool) -> Table {
    let mut t = Table::new(
        "Table 2: functionality by mechanism",
        &["functionality", "CE", "TMA", "Reg"],
    );
    use Functionality::*;
    for (name, f) in [
        ("P2P transfer", P2pTransfer),
        ("in-fabric broadcast", InFabricBroadcast),
        ("P2P reduction", P2pReduction),
        ("in-fabric reduction", InFabricReduction),
        ("elementwise transfer", ElementwiseTransfer),
    ] {
        let mark = |m: Mechanism| if m.supports(f) { "yes" } else { "no" }.to_string();
        t.row(vec![name.into(), mark(Mechanism::CopyEngine), mark(Mechanism::Tma), mark(Mechanism::RegOp)]);
    }
    t
}

// ---------------------------------------------------------------- Figure 4
fn fig4(_fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let n = 32768;
    let cfg = GemmKernelCfg::new(node.clone(), n, n, n / 8);
    let mut t = Table::new(
        "Figure 4: overlap schedules, local GEMM N×N×N/8, N=32768 (TFLOP/s)",
        &["kernel", "schedule", "time_ms", "tflops"],
    );
    for (kname, intra, inter) in [
        (
            "GEMM+RS",
            time_of(&node, &gemm_rs::build(&cfg, Schedule::IntraSm, None)),
            time_of(&node, &gemm_rs::build(&cfg, Schedule::InterSm, None)),
        ),
        (
            "GEMM+AR",
            time_of(&node, &gemm_ar::build(&cfg, Schedule::IntraSm, None)),
            time_of(&node, &gemm_ar::build(&cfg, Schedule::InterSm, None)),
        ),
    ] {
        t.row(vec![kname.into(), "intra-SM".into(), ms(intra), super::table::tflops(cfg.local_flops(), intra)]);
        t.row(vec![kname.into(), "inter-SM".into(), ms(inter), super::table::tflops(cfg.local_flops(), inter)]);
    }
    t
}

// ---------------------------------------------------------------- Table 3
fn tab3(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Table 3: measured BF16 GEMM and GEMM+RS (ms), M=N=32768",
        &["K", "GEMM_ms", "GEMM+RS_ms", "comm_ratio"],
    );
    let ks: &[usize] = if fast { &[512, 2048, 8192] } else { &[512, 1024, 2048, 4096, 8192] };
    for &k in ks {
        let cfg = GemmKernelCfg::new(node.clone(), 32768, 32768, k);
        let t_gemm = time_of(&node, &gemm::build(&cfg, None));
        let t_fused = time_of(&node, &gemm_rs::build(&cfg, Schedule::IntraSm, None));
        t.row(vec![k.to_string(), ms(t_gemm), ms(t_fused), pct((t_fused - t_gemm) / t_fused)]);
    }
    t
}

// ---------------------------------------------------------------- Figure 5
fn fig5(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 5: AG+GEMM time vs communicator SMs (local N×N/8×N)",
        &["N", "comm_sms", "time_ms", "tflops"],
    );
    let ns: &[usize] = if fast { &[8192, 32768] } else { &[8192, 16384, 32768] };
    let sms: &[u32] = if fast { &[8, 32] } else { &[4, 8, 16, 32, 48, 64] };
    for &n in ns {
        for &c in sms {
            let mut cfg = GemmKernelCfg::new(node.clone(), n, n / 8, n);
            cfg.opts.num_comm_sms = c;
            let time = time_of(&node, &ag_gemm::build(&cfg, None));
            t.row(vec![n.to_string(), c.to_string(), ms(time), super::table::tflops(cfg.local_flops(), time)]);
        }
    }
    t
}

// ---------------------------------------------------------------- Figure 6
fn fig6(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 6: all-reduce (BF16) PK vs NCCL — algorithm bandwidth GB/s",
        &["bytes", "pk_ms", "nccl_ms", "speedup"],
    );
    let sizes: &[usize] = if fast { &[1 << 24, 1 << 28] } else { &[1 << 22, 1 << 24, 1 << 26, 1 << 28, 1 << 30] };
    for &bytes in sizes {
        // rows*cols*2 = bytes; rows divisible by 8
        let rows = 1024;
        let cols = bytes / 2 / rows;
        let views = phantom_replicas(node.num_devices, rows, cols);
        let mut pk_plan = Plan::new();
        collectives::pk_all_reduce(&mut pk_plan, &PkCollCtx { node: &node, replicas: views.clone(), n_sms: 76.0, msg_bytes: 65536.0 });
        let t_pk = time_of(&node, &pk_plan);
        let t_nccl = nccl::allreduce_time(&node, rows, cols);
        let _ = views;
        t.row(vec![bytes.to_string(), ms(t_pk), ms(t_nccl), format!("{:.2}", t_nccl / t_pk)]);
    }
    t
}

// ------------------------------------------------------- Figures 7, 8, 9
fn gemm_sweep(node: &NodeSpec, fast: bool) -> Vec<usize> {
    if fast {
        vec![4096, 32768]
    } else {
        let _ = node;
        vec![4096, 8192, 16384, 24576, 32768]
    }
}

fn fig7(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 7: AG+GEMM throughput (TFLOP/s), local N×N/8×N",
        &["N", "pk", "nonoverlap", "flux", "triton_dist", "cutlass"],
    );
    for n in gemm_sweep(&node, fast) {
        let cfg = GemmKernelCfg::new(node.clone(), n, n / 8, n);
        let fl = cfg.local_flops();
        let tf = |time: f64| format!("{:.1}", fl / time / 1e12);
        // PK auto-tunes its communicator partition at runtime (§3.1.3)
        let tuned = crate::pk::tuner::tune_comm_sms(&node, &[2, 4, 8, 16, 32], |c| {
            let mut cfg = cfg.clone();
            cfg.opts.num_comm_sms = c;
            ag_gemm::build(&cfg, None)
        });
        t.row(vec![
            n.to_string(),
            tf(tuned.best_time),
            tf(baselines::nonoverlap::ag_gemm(&cfg)),
            tf(baselines::flux::ag_gemm(&cfg)),
            tf(baselines::triton_dist::ag_gemm(&cfg)),
            tf(baselines::cutlass_dist::ag_gemm(&cfg)),
        ]);
    }
    t
}

fn fig8(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 8: GEMM+RS throughput (TFLOP/s), local N×N×N/8",
        &["N", "pk", "nonoverlap", "flux", "triton_dist", "cutlass"],
    );
    for n in gemm_sweep(&node, fast) {
        let cfg = GemmKernelCfg::new(node.clone(), n, n, n / 8);
        let fl = cfg.local_flops();
        let tf = |time: f64| format!("{:.1}", fl / time / 1e12);
        t.row(vec![
            n.to_string(),
            tf(time_of(&node, &gemm_rs::build(&cfg, Schedule::IntraSm, None))),
            tf(baselines::nonoverlap::gemm_rs(&cfg)),
            tf(baselines::flux::gemm_rs(&cfg)),
            tf(baselines::triton_dist::gemm_rs(&cfg)),
            tf(baselines::cutlass_dist::gemm_rs(&cfg)),
        ]);
    }
    t
}

fn fig9(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 9: GEMM+AR throughput (TFLOP/s), local N×N×N/8 — Flux/CUTLASS provide no AR kernels",
        &["N", "pk", "nonoverlap", "triton_dist"],
    );
    for n in gemm_sweep(&node, fast) {
        let cfg = GemmKernelCfg::new(node.clone(), n, n, n / 8);
        let fl = cfg.local_flops();
        let tf = |time: f64| format!("{:.1}", fl / time / 1e12);
        t.row(vec![
            n.to_string(),
            tf(time_of(&node, &gemm_ar::build(&cfg, Schedule::InterSm, None))),
            tf(baselines::nonoverlap::gemm_ar(&cfg)),
            tf(baselines::triton_dist::gemm_ar(&cfg)),
        ]);
    }
    t
}

// --------------------------------------------------------------- Figure 10
fn fig10(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 10: Ring Attention (B=16, H=16, D=128) — TFLOP/s",
        &["S_total", "pk", "xdit", "speedup"],
    );
    let seqs: &[usize] = if fast { &[6144, 49152] } else { &[6144, 12288, 24576, 49152, 98304] };
    for &s in seqs {
        let cfg = RingAttnCfg::paper(node.clone(), s);
        let t_pk = time_of(&node, &ring_attention::build(&cfg, None));
        let t_x = baselines::xdit::ring_attention(&cfg);
        let fl = cfg.total_flops();
        t.row(vec![
            s.to_string(),
            format!("{:.1}", fl / t_pk / 1e12),
            format!("{:.1}", fl / t_x / 1e12),
            format!("{:.2}", t_x / t_pk),
        ]);
    }
    t
}

// --------------------------------------------------------------- Figure 11
fn fig11(fast: bool) -> Table {
    ulysses_table(NodeSpec::hgx_h100(), "Figure 11: Ulysses attention (B=16, H=128, D=128) — TFLOP/s", fast)
}

fn ulysses_table(node: NodeSpec, title: &str, fast: bool) -> Table {
    let mut t = Table::new(title, &["S_total", "pk", "yunchang", "speedup"]);
    let seqs: &[usize] = if fast { &[8192, 65536] } else { &[8192, 16384, 32768, 65536, 131072] };
    for &s in seqs {
        let cfg = UlyssesCfg::paper(node.clone(), s);
        let t_pk = time_of(&node, &ulysses::build(&cfg, None));
        let t_yc = baselines::yunchang::ulysses(&cfg);
        let fl = cfg.attn_flops();
        t.row(vec![
            s.to_string(),
            format!("{:.1}", fl / t_pk / 1e12),
            format!("{:.1}", fl / t_yc / 1e12),
            format!("{:.2}", t_yc / t_pk),
        ]);
    }
    t
}

// --------------------------------------------------------------- Figure 12
fn fig12(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 12: MoE dispatch+GEMM (TopK=8, E=256, H=7168, He=2048) — TFLOP/s",
        &["tokens", "pk", "comet", "nonoverlap", "pk_vs_comet"],
    );
    let toks: &[usize] = if fast { &[4096, 32768] } else { &[4096, 8192, 16384, 32768, 65536] };
    for &tok in toks {
        let cfg = MoeCfg::paper(node.clone(), tok);
        let routing = Routing::uniform(&cfg, 11);
        let t_pk = time_of(&node, &moe::build(&cfg, &routing, MoeSchedule::Overlapped, None));
        let t_comet = baselines::comet::moe(&cfg, &routing);
        let t_seq = time_of(&node, &moe::build(&cfg, &routing, MoeSchedule::Sequential, None));
        let fl = cfg.gemm_flops_per_device();
        t.row(vec![
            tok.to_string(),
            format!("{:.1}", fl / t_pk / 1e12),
            format!("{:.1}", fl / t_comet / 1e12),
            format!("{:.1}", fl / t_seq / 1e12),
            format!("{:.2}", t_comet / t_pk),
        ]);
    }
    t
}

// --------------------------------------------------------------- Figure 13
fn fig13(fast: bool) -> Table {
    let node = NodeSpec::hgx_b200();
    let mut t = Table::new(
        "Figure 13: GEMM+RS on B200 (TFLOP/s), local N×N×N/8",
        &["N", "pk", "nonoverlap", "triton_dist"],
    );
    for n in gemm_sweep(&node, fast) {
        let cfg = GemmKernelCfg::new(node.clone(), n, n, n / 8);
        let fl = cfg.local_flops();
        let tf = |time: f64| format!("{:.1}", fl / time / 1e12);
        t.row(vec![
            n.to_string(),
            tf(time_of(&node, &gemm_rs::build(&cfg, Schedule::IntraSm, None))),
            tf(baselines::nonoverlap::gemm_rs(&cfg)),
            tf(baselines::triton_dist::gemm_rs(&cfg)),
        ]);
    }
    t
}

// --------------------------------------------------------------- Figure 14
fn fig14(fast: bool) -> Table {
    ulysses_table(NodeSpec::hgx_b200(), "Figure 14: Ulysses attention on B200 (B=16, H=128, D=128) — TFLOP/s", fast)
}

// ------------------------------------------------------- Figures 15, 16
/// Time of an NCCL collective along the tensor dimension: pack + ring +
/// unpack on every device (Appendix B).
fn nccl_tensor_dim(node: &NodeSpec, rows: usize, cols: usize, rs: bool) -> f64 {
    let t_coll = if rs {
        nccl::reducescatter_time(node, rows, cols)
    } else {
        nccl::allgather_time(node, rows, cols)
    };
    let bytes = (rows * cols * 2) as f64;
    let reshape = 2.0 * bytes / node.gpu.hbm_bw + node.gpu.kernel_launch;
    reshape + t_coll + reshape
}

fn fig15(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 15: tensor-dimension all-gather (BF16), gathered N×N",
        &["N", "pk_ms", "nccl_ms", "speedup"],
    );
    let ns: &[usize] = if fast { &[2048, 16384] } else { &[2048, 4096, 8192, 16384, 32768] };
    for &n in ns {
        let views = phantom_replicas(node.num_devices, n, n);
        let mut plan = Plan::new();
        collectives::pk_all_gather(&mut plan, &PkCollCtx::new(&node, views), Axis::Col);
        let t_pk = time_of(&node, &plan);
        let t_nccl = nccl_tensor_dim(&node, n, n, false);
        t.row(vec![n.to_string(), ms(t_pk), ms(t_nccl), format!("{:.2}", t_nccl / t_pk)]);
    }
    t
}

fn fig16(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 16: tensor-dimension reduce-scatter (BF16), scattered N×N/8",
        &["N", "pk_ms", "nccl_ms", "speedup"],
    );
    let ns: &[usize] = if fast { &[2048, 16384] } else { &[2048, 4096, 8192, 16384, 32768] };
    for &n in ns {
        let views = phantom_replicas(node.num_devices, n, n);
        let mut plan = Plan::new();
        collectives::pk_reduce_scatter(&mut plan, &PkCollCtx::new(&node, views), Axis::Col);
        let t_pk = time_of(&node, &plan);
        let t_nccl = nccl_tensor_dim(&node, n, n, true);
        t.row(vec![n.to_string(), ms(t_pk), ms(t_nccl), format!("{:.2}", t_nccl / t_pk)]);
    }
    t
}

// --------------------------------------------------------------- Figure 17
fn fig17(fast: bool) -> Table {
    let node = NodeSpec::hgx_h100();
    let mut t = Table::new(
        "Figure 17: 4-D (B=1, S, H=128, D=128) all-to-all (BF16): S gathered, H scattered",
        &["S", "pk_ms", "nccl_ms", "speedup"],
    );
    let seqs: &[usize] = if fast { &[8192, 65536] } else { &[8192, 16384, 32768, 65536, 131072] };
    for &s in seqs {
        let a2a = collectives::A2aCfg { b_dim: 1, s_local: s / node.num_devices, h: 128, d_head: 128 };
        let mut plan = Plan::new();
        collectives::pk_all_to_all_4d(&mut plan, &node, &a2a, None, None, 16.0);
        let t_pk = time_of(&node, &plan);
        // NCCL path: pack + contiguous a2a + unpack
        let bytes = (a2a.s_local * a2a.h * a2a.d_head * 2) as f64;
        let rows = node.num_devices * 8;
        let cols = (bytes / 2.0 / rows as f64) as usize;
        let mut nccl_plan = Plan::new();
        let a2a_views = phantom_replicas(node.num_devices, rows, cols);
        nccl::all_to_all(&mut nccl_plan, &RingCtx { node: &node, model: NcclModel::default(), replicas: a2a_views.clone() }, &a2a_views);
        let reshape = 2.0 * bytes / node.gpu.hbm_bw + node.gpu.kernel_launch;
        let t_nccl = reshape + time_of(&node, &nccl_plan) + reshape;
        t.row(vec![s.to_string(), ms(t_pk), ms(t_nccl), format!("{:.2}", t_nccl / t_pk)]);
    }
    t
}

// ------------------------------------------------------------ Scale-out
/// The cluster-layer exhibit: two-level all-reduce / all-gather /
/// reduce-scatter swept over node count and NIC bandwidth, at a fixed
/// per-device payload (weak scaling). `agg_GBps` is the aggregate
/// algorithm bandwidth `N·S / t`; `per_dev_GBps` is `S / t`. The 1-node
/// rows run the single-node PK collectives (the NVLink-only baseline):
/// crossing to 2 nodes drops *per-device* bandwidth — the NIC cliff —
/// while *aggregate* bandwidth keeps growing with node count because the
/// rail ring bounds per-NIC traffic by `2·S/P` regardless of `K`.
fn sx1(fast: bool) -> Table {
    let mut t = Table::new(
        "Scale-out sweep: hierarchical collectives (BF16, 72 MiB per device)",
        &["collective", "nodes", "nic_GBps", "time_ms", "agg_GBps", "per_dev_GBps"],
    );
    // rows must divide by P·K for every sweep point (P=8, K∈{1..4}):
    // 4608 = 48·96 is divisible by lcm(8,16,24,32) = 96.
    let (rows, cols) = (4608usize, 8192usize); // 36 Mi elem = 72 MiB bf16
    let nodes: &[usize] = if fast { &[1, 2, 4] } else { &[1, 2, 3, 4] };
    let nics: &[f64] = if fast { &[50e9] } else { &[25e9, 50e9, 100e9] };
    fn run_ar(p: &mut Plan, c: &ClusterCollCtx) {
        collectives::hier_all_reduce(p, c)
    }
    fn run_ag(p: &mut Plan, c: &ClusterCollCtx) {
        collectives::hier_all_gather(p, c, Axis::Row)
    }
    fn run_rs(p: &mut Plan, c: &ClusterCollCtx) {
        collectives::hier_reduce_scatter(p, c, Axis::Row)
    }
    type Builder = fn(&mut Plan, &ClusterCollCtx);
    let builders: [(&str, Builder); 3] =
        [("all_reduce", run_ar), ("all_gather", run_ag), ("reduce_scatter", run_rs)];
    for (name, build) in builders {
        for &k in nodes {
            // the 1-node row is NVLink-only (NIC-independent): emit it once
            let nic_points: &[f64] = if k == 1 { &nics[..1] } else { nics };
            for &nic in nic_points {
                let cluster = ClusterSpec::hgx_h100_pod(k).with_nic_bw(nic);
                let n = cluster.total_devices();
                let views = phantom_replicas(n, rows, cols);
                let mut plan = Plan::new();
                build(&mut plan, &ClusterCollCtx::new(&cluster, views));
                let time = TimedExec::on_cluster(cluster).run(&plan).total_time;
                let per_dev = (rows * cols * 2) as f64;
                t.row(vec![
                    name.into(),
                    k.to_string(),
                    if k == 1 { "nvlink-only".into() } else { format!("{:.0}", nic / 1e9) },
                    ms(time),
                    format!("{:.1}", per_dev * n as f64 / time / 1e9),
                    format!("{:.1}", per_dev / time / 1e9),
                ]);
            }
        }
    }
    t
}

// ------------------------------------------------------- Cluster MoE
/// The cluster MoE exhibit: expert-parallel dispatch + grouped GEMM swept
/// over node count and NIC bandwidth (weak scaling, 2048 tokens per GPU).
/// `nic_agg_x` is the NIC-byte reduction of the per-rail aggregated
/// dispatch versus naive per-device RDMA sends (×P in the worst case,
/// ≈ TopK/K under uniform routing); `nic_GB_per_dev` the aggregated bytes
/// each NIC actually carries. The 1-node row is the NVLink-only Figure-12
/// regime the paper measures.
fn mx1(fast: bool) -> Table {
    let mut t = Table::new(
        "Cluster MoE sweep: dispatch+GEMM over the NIC (TopK=8, E=256, H=7168, He=2048, 2048 tok/GPU)",
        &["nodes", "nic_GBps", "pk_ms", "seq_ms", "comet_ms", "tok_per_s", "nic_GB_per_dev", "nic_agg_x"],
    );
    let nodes: &[usize] = if fast { &[1, 2] } else { &[1, 2, 3, 4] };
    let nics: &[f64] = if fast { &[50e9] } else { &[25e9, 50e9, 100e9] };
    for &k in nodes {
        // the 1-node row is NVLink-only (NIC-independent): emit it once
        let nic_points: &[f64] = if k == 1 { &nics[..1] } else { nics };
        for &nic in nic_points {
            let cluster = ClusterSpec::hgx_h100_pod(k).with_nic_bw(nic);
            let n_dev = cluster.total_devices();
            let cfg = MoeCfg::paper(cluster.node.clone(), 2048 * n_dev);
            let routing = Routing::uniform(&cfg, 11);
            let exec = TimedExec::on_cluster(cluster.clone());
            let t_pk = exec
                .run(&moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, None))
                .total_time;
            let t_seq = exec
                .run(&moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Sequential, None))
                .total_time;
            let t_comet = baselines::comet::moe_cluster(&cluster, &cfg, &routing);
            let agg: f64 = moe::nic_dispatch_bytes(&cfg, &cluster, &routing, true).iter().sum();
            let naive: f64 = moe::nic_dispatch_bytes(&cfg, &cluster, &routing, false).iter().sum();
            t.row(vec![
                k.to_string(),
                if k == 1 { "nvlink-only".into() } else { format!("{:.0}", nic / 1e9) },
                ms(t_pk),
                ms(t_seq),
                ms(t_comet),
                format!("{:.0}", cfg.tokens as f64 / t_pk),
                format!("{:.2}", agg / n_dev as f64 / 1e9),
                if k == 1 { "-".into() } else { format!("{:.2}", naive / agg) },
            ]);
        }
    }
    t
}

// ------------------------------------------------------- pk::rail sweep
/// The `pk::rail` exhibit: the two kernels the extracted rail subsystem
/// unlocked — hierarchical GEMM+RS (node-local pre-reduce + one coalesced
/// flow per node pair) and the two-level Ulysses all-to-all — swept over
/// node count × NIC bandwidth. Each kernel runs three ways: `rail` (the
/// hierarchical default), `naive` (gemm_rs: the PR 1 per-device scatter;
/// Ulysses: the uncoalesced per-tile-message ablation), and `baseline`
/// (Flux / YunChang cluster extrapolations). `nic_x` is the modeled
/// NIC-byte reduction of rail vs naive — exactly ×P for gemm_rs; "-" for
/// the all-to-all, whose payload is not reducible (the rail win there is
/// message coalescing, not byte elimination).
fn rx1(fast: bool) -> Table {
    let mut t = Table::new(
        "pk::rail sweep: hierarchical gemm_rs + two-level Ulysses (rail vs naive vs baseline)",
        &["kernel", "nodes", "nic_GBps", "rail_ms", "naive_ms", "baseline_ms", "nic_x"],
    );
    let nodes: &[usize] = if fast { &[1, 2] } else { &[1, 2, 3, 4] };
    let nics: &[f64] = if fast { &[50e9] } else { &[25e9, 50e9, 100e9] };
    for &k in nodes {
        // the 1-node row is NVLink-only (NIC-independent): emit it once
        let nic_points: &[f64] = if k == 1 { &nics[..1] } else { nics };
        for &nic in nic_points {
            let cluster = ClusterSpec::hgx_h100_pod(k).with_nic_bw(nic);
            let n_dev = cluster.total_devices();
            let exec = TimedExec::on_cluster(cluster.clone());
            let nic_label =
                if k == 1 { "nvlink-only".to_string() } else { format!("{:.0}", nic / 1e9) };
            // --- gemm_rs, cluster-sharded K axis. m = 24576 gives
            // grid_m = 192 tile rows — divisible by every device count of
            // the sweep (lcm(8,16,24,32) = 96), like sx1's payload sizing.
            let cfg = GemmKernelCfg::new(cluster.node.clone(), 24576, 8192, 1024);
            let t_rail = exec
                .run(&gemm_rs::build_cluster(&cfg, &cluster, Schedule::IntraSm, None))
                .total_time;
            let t_naive = exec
                .run(&gemm_rs::build_cluster_opts(
                    &cfg,
                    &cluster,
                    Schedule::IntraSm,
                    gemm_rs::ClusterPath::Scatter,
                    None,
                ))
                .total_time;
            let t_base = baselines::flux::gemm_rs_cluster(&cfg, &cluster);
            let rail_b: f64 =
                gemm_rs::nic_scatter_bytes(&cfg, &cluster, gemm_rs::ClusterPath::RailReduce).iter().sum();
            let naive_b: f64 =
                gemm_rs::nic_scatter_bytes(&cfg, &cluster, gemm_rs::ClusterPath::Scatter).iter().sum();
            t.row(vec![
                "gemm_rs".into(),
                k.to_string(),
                nic_label.clone(),
                ms(t_rail),
                ms(t_naive),
                ms(t_base),
                if k == 1 { "-".into() } else { format!("{:.2}", naive_b / rail_b) },
            ]);
            // --- Ulysses: weak scaling, 2048 sequence positions per GPU;
            // H = 96 divides every device count of the sweep
            let ucfg = UlyssesCfg {
                node: cluster.node.clone(),
                b: 16,
                h: 96,
                s: 2048 * n_dev,
                d: 128,
                flash_util: 0.75,
                rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO,
            };
            let t_urail = exec.run(&ulysses::build_cluster(&ucfg, &cluster)).total_time;
            let tile_bytes =
                (ucfg.h_local_of(n_dev) * ucfg.d) as f64 * crate::mem::ELEM_BYTES as f64;
            let t_unaive = exec
                .run(&ulysses::build_cluster_opts(&ucfg, &cluster, tile_bytes))
                .total_time;
            let t_ubase = baselines::yunchang::ulysses_cluster(&ucfg, &cluster);
            t.row(vec![
                "ulysses".into(),
                k.to_string(),
                nic_label,
                ms(t_urail),
                ms(t_unaive),
                ms(t_ubase),
                "-".into(),
            ]);
        }
    }
    t
}

// ------------------------------------------------- Cluster GEMM family
/// Best swept time over `chunks` for a rail kernel at a fixed grid point,
/// or `None` on one node (no rail flows — nothing to sweep).
fn best_chunk_time(k: usize, chunks: &[f64], mut time_at: impl FnMut(f64) -> f64) -> Option<f64> {
    if k == 1 {
        return None;
    }
    chunks.iter().map(|&c| time_at(c)).min_by(|a, b| a.partial_cmp(b).unwrap())
}

/// `an_vs_swept` column: analytic-chunk time over the best swept-chunk
/// time (≈1.0 when the closed form matches the grid optimum).
fn an_vs_swept(t_analytic: f64, swept: Option<f64>) -> String {
    match swept {
        Some(best) => format!("{:.3}", t_analytic / best),
        None => "-".into(),
    }
}

/// The cluster GEMM-family exhibit: the last two kernels to get a rail
/// story — gemm_ar (node-local pre-reduce → one coalesced RDMA store-add
/// per node pair → multimem broadcast-back) and ag_gemm (one coalesced
/// shard flow per node pair + forwarder multicast) — swept over node
/// count × NIC bandwidth. Each kernel runs three ways: `rail` (the
/// hierarchical default with the analytic `rdma_chunk`), `naive` (the
/// per-device scatter/unicast transport — ×P more NIC traffic), and
/// `baseline` (gemm_ar: hierarchical non-overlap; ag_gemm: the Flux
/// CE/per-device-RDMA gather extrapolation). `nic_x` is the modeled
/// NIC-byte reduction of rail vs naive (exactly ×P); `an_vs_swept`
/// compares the analytic chunk against the best chunk of a swept grid —
/// the closed form should sit within a few percent of the sweep, which
/// is what lets the tuner skip the chunk axis entirely.
fn gx1(fast: bool) -> Table {
    let mut t = Table::new(
        "Cluster GEMM family: gemm_ar + ag_gemm (rail vs naive vs baseline, analytic vs swept chunk)",
        &["kernel", "nodes", "nic_GBps", "rail_ms", "naive_ms", "baseline_ms", "nic_x", "an_vs_swept"],
    );
    let nodes: &[usize] = if fast { &[1, 2] } else { &[1, 2, 3, 4] };
    let nics: &[f64] = if fast { &[50e9] } else { &[25e9, 50e9, 100e9] };
    let chunks: &[f64] = if fast {
        &[1048576.0, 4194304.0]
    } else {
        &[262144.0, 1048576.0, 4194304.0, 16777216.0]
    };
    for &k in nodes {
        // the 1-node row is NVLink-only (NIC-independent): emit it once
        let nic_points: &[f64] = if k == 1 { &nics[..1] } else { nics };
        for &nic in nic_points {
            let cluster = ClusterSpec::hgx_h100_pod(k).with_nic_bw(nic);
            let exec = TimedExec::on_cluster(cluster.clone());
            let nic_label =
                if k == 1 { "nvlink-only".to_string() } else { format!("{:.0}", nic / 1e9) };
            // --- gemm_ar: m = 24576 gives 192 tile rows — divisible by
            // every device count of the sweep (lcm(8,16,24,32) = 96)
            let cfg = GemmKernelCfg::new(cluster.node.clone(), 24576, 8192, 4096);
            let t_rail = exec
                .run(&gemm_ar::build_cluster(&cfg, &cluster, Schedule::InterSm, None))
                .total_time;
            let t_naive = exec
                .run(&gemm_ar::build_cluster_opts(
                    &cfg,
                    &cluster,
                    Schedule::InterSm,
                    gemm_ar::ClusterPath::Scatter,
                    None,
                ))
                .total_time;
            let t_base = baselines::nonoverlap::gemm_ar_cluster(&cfg, &cluster);
            let swept = best_chunk_time(k, chunks, |chunk| {
                let mut c = cfg.clone();
                c.rdma_chunk = chunk;
                exec.run(&gemm_ar::build_cluster(&c, &cluster, Schedule::InterSm, None)).total_time
            });
            let rail_b: f64 =
                gemm_ar::nic_ar_bytes(&cfg, &cluster, gemm_ar::ClusterPath::RailReduce).iter().sum();
            let naive_b: f64 =
                gemm_ar::nic_ar_bytes(&cfg, &cluster, gemm_ar::ClusterPath::Scatter).iter().sum();
            t.row(vec![
                "gemm_ar".into(),
                k.to_string(),
                nic_label.clone(),
                ms(t_rail),
                ms(t_naive),
                ms(t_base),
                if k == 1 { "-".into() } else { format!("{:.2}", naive_b / rail_b) },
                an_vs_swept(t_rail, swept),
            ]);
            // --- ag_gemm: same m; local n = 2048 columns, full k = 8192
            let acfg = GemmKernelCfg::new(cluster.node.clone(), 24576, 2048, 8192);
            let t_arail = exec.run(&ag_gemm::build_cluster(&acfg, &cluster, None)).total_time;
            let t_anaive = exec
                .run(&ag_gemm::build_cluster_opts(
                    &acfg,
                    &cluster,
                    ag_gemm::ClusterPath::Scatter,
                    None,
                ))
                .total_time;
            let t_abase = baselines::flux::ag_gemm_cluster(&acfg, &cluster);
            let aswept = best_chunk_time(k, chunks, |chunk| {
                let mut c = acfg.clone();
                c.rdma_chunk = chunk;
                exec.run(&ag_gemm::build_cluster(&c, &cluster, None)).total_time
            });
            let arail_b: f64 =
                ag_gemm::nic_ag_bytes(&acfg, &cluster, ag_gemm::ClusterPath::RailReduce).iter().sum();
            let anaive_b: f64 =
                ag_gemm::nic_ag_bytes(&acfg, &cluster, ag_gemm::ClusterPath::Scatter).iter().sum();
            t.row(vec![
                "ag_gemm".into(),
                k.to_string(),
                nic_label,
                ms(t_arail),
                ms(t_anaive),
                ms(t_abase),
                if k == 1 { "-".into() } else { format!("{:.2}", anaive_b / arail_b) },
                an_vs_swept(t_arail, aswept),
            ]);
        }
    }
    t
}

// ------------------------------------------------- vx1 (serving layer)
/// The serving exhibit: the same open-loop trace replayed against an
/// engine stepping on PK-overlapped kernels vs the non-overlapped
/// baseline kernels, over a load grid expressed as fractions of the PK
/// engine's probed capacity (so the `1.2×` row is saturating by
/// construction). Past one node the engine disaggregates prefill and
/// decode, with KV riding the RDMA fabric.
fn vx1(fast: bool) -> Table {
    let mut t = Table::new(
        "Serving: PK-overlapped vs non-overlapped engine steps under open-loop load",
        &[
            "nodes",
            "proc",
            "load_x",
            "offered_rps",
            "pk_tok_s",
            "base_tok_s",
            "pk_p50_ms",
            "base_p50_ms",
            "pk_p99_ms",
            "base_p99_ms",
            "pk_goodput_rps",
            "base_goodput_rps",
        ],
    );
    let nodes: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4] };
    let loads: &[f64] = if fast { &[0.8, 1.2] } else { &[0.4, 0.8, 1.2] };
    // arrival-process axis: smooth Poisson plus the modulated generators
    // (4x on/off bursts, sinusoidal diurnal swing). Fast mode keeps
    // bursty — the tail-latency stressor the claims tests pin.
    let procs: &[&str] = if fast {
        &["poisson", "bursty"]
    } else {
        &["poisson", "bursty", "diurnal"]
    };
    let n_req = if fast { 160 } else { 400 };
    let node = NodeSpec::hgx_h100();
    let model = ModelCfg::reference();
    let pk_cost = StepCostModel::calibrate(&node, KernelMode::PkOverlap, &model);
    let base_cost = StepCostModel::calibrate(&node, KernelMode::Nonoverlap, &model);
    for &k in nodes {
        let cluster = ClusterSpec::hgx_h100_pod(k);
        let pk_cfg = ServeCfg::reference(cluster.clone(), KernelMode::PkOverlap);
        let base_cfg = ServeCfg::reference(cluster, KernelMode::Nonoverlap);
        // both modes face the same absolute offered load, anchored to the
        // PK engine's capacity — the baseline saturates harder, which is
        // exactly the claim the p99 columns carry
        let cap = serve::capacity_probe(&pk_cfg, &pk_cost, n_req / 2, 1234);
        for &proc in procs {
            for &lx in loads {
                let rate = cap * lx;
                // modulation periods scale with the trace: ~8 bursts /
                // ~2 diurnal swings over the offered window, whatever
                // the node count's absolute capacity
                let window = n_req as f64 / rate;
                let process = match proc {
                    "poisson" => ArrivalProcess::Poisson,
                    "bursty" => {
                        ArrivalProcess::Bursty { burst: 4.0, on_frac: 0.2, period: window / 8.0 }
                    }
                    _ => ArrivalProcess::Diurnal { depth: 0.8, period: window / 2.0 },
                };
                let trace = workload::generate(&TraceCfg::chat(process, rate, n_req, 99));
                let rp = serve::run_with_cost(&pk_cfg, &pk_cost, &trace);
                let rb = serve::run_with_cost(&base_cfg, &base_cost, &trace);
                t.row(vec![
                    k.to_string(),
                    proc.to_string(),
                    format!("{lx:.1}"),
                    format!("{rate:.1}"),
                    format!("{:.0}", rp.tokens_per_s),
                    format!("{:.0}", rb.tokens_per_s),
                    ms(rp.latency_p50),
                    ms(rb.latency_p50),
                    ms(rp.latency_p99),
                    ms(rb.latency_p99),
                    format!("{:.1}", rp.goodput_rps),
                    format!("{:.1}", rb.goodput_rps),
                ]);
            }
        }
    }
    t
}

// ------------------------------------------------- fx1 (robustness)
/// The robustness exhibit: the fault-injection layer ([`crate::sim::fault`])
/// and the degraded-rail reroute ([`RailHealth`]) quantified on the 2-node
/// pod. Three axes share one schema (`slow_x` = degraded / healthy for
/// times; healthy / degraded for goodput):
///
/// * `jitter` — seeded lognormal per-port bandwidth jitter at strength σ,
///   identical fault schedules for the rail schedule and its no-reroute
///   ablation (gemm_rs/gemm_ar: the `Scatter` transport; MoE: the
///   `Sequential` non-overlap schedule).
/// * `nic_fail` — `f` hard NIC failures injected at t = 0. The rail
///   column re-plans with the matching [`RailHealth`] mask, so its flows
///   never touch the dead links (slowdown ≤ P/(P−1) + tolerance,
///   claims-tested); the ablation has no reroute story and stalls until
///   the link heals at 4× its healthy makespan.
/// * `serve` — a mid-trace outage on the decode node's NIC (middle third
///   of the healthy makespan): goodput and p99 for the PK-overlapped
///   engine, with the non-overlapped engine under the same outage in the
///   naive columns. No request is lost or duplicated (claims-tested).
fn fx1(fast: bool) -> Table {
    let seed = fault_seed();
    let mut t = Table::new(
        format!(
            "Robustness: jitter, NIC failure, mid-trace serving outage \
             (2-node pod, NIC 50 GB/s, fault seed {seed})"
        ),
        &["axis", "case", "fault", "healthy", "degraded", "slow_x", "naive_deg", "naive_x"],
    );
    let cluster = ClusterSpec::hgx_h100_pod(2).with_nic_bw(50e9);
    let p = cluster.devices_per_node();
    let timed = |plan: &Plan, spec: Option<FaultSpec>| {
        let mut ex = TimedExec::on_cluster(cluster.clone());
        if let Some(s) = spec {
            ex = ex.with_faults(s);
        }
        ex.run(plan).total_time
    };
    // the three rail kernels at their rx1/gx1/mx1 grid points
    let gcfg = GemmKernelCfg::new(cluster.node.clone(), 24576, 8192, 1024);
    let mcfg = MoeCfg::paper(cluster.node.clone(), 2048 * cluster.total_devices());
    let routing = Routing::uniform(&mcfg, 11);
    let kernels: Vec<(&str, Plan, Plan)> = vec![
        (
            "gemm_rs",
            gemm_rs::build_cluster(&gcfg, &cluster, Schedule::IntraSm, None),
            gemm_rs::build_cluster_opts(
                &gcfg,
                &cluster,
                Schedule::IntraSm,
                gemm_rs::ClusterPath::Scatter,
                None,
            ),
        ),
        (
            "gemm_ar",
            gemm_ar::build_cluster(&gcfg, &cluster, Schedule::IntraSm, None),
            gemm_ar::build_cluster_opts(
                &gcfg,
                &cluster,
                Schedule::IntraSm,
                gemm_ar::ClusterPath::Scatter,
                None,
            ),
        ),
        (
            "moe",
            moe::build_cluster_layer(&mcfg, &cluster, &routing, MoeSchedule::Overlapped, None),
            moe::build_cluster_layer(&mcfg, &cluster, &routing, MoeSchedule::Sequential, None),
        ),
    ];
    let health_plan = |name: &str, health: &RailHealth| match name {
        "gemm_rs" => gemm_rs::build_cluster_health(
            &gcfg,
            &cluster,
            Schedule::IntraSm,
            gemm_rs::ClusterPath::RailReduce,
            health,
            None,
        ),
        "gemm_ar" => gemm_ar::build_cluster_health(
            &gcfg,
            &cluster,
            Schedule::IntraSm,
            gemm_ar::ClusterPath::RailReduce,
            health,
            None,
        ),
        _ => moe::build_cluster_layer_health(
            &mcfg,
            &cluster,
            &routing,
            MoeSchedule::Overlapped,
            health,
            None,
        ),
    };
    let sigmas: &[f64] = if fast { &[0.3] } else { &[0.1, 0.3, 0.6] };
    let fails: &[usize] = if fast { &[1] } else { &[1, 2] };
    for &(name, ref rail_plan, ref naive_plan) in &kernels {
        let t0r = timed(rail_plan, None);
        let t0n = timed(naive_plan, None);
        // --- axis (a): bandwidth jitter, identical schedules both columns
        for &s in sigmas {
            let spec = FaultSpec::seeded(seed).with_jitter(s);
            let tr = timed(rail_plan, Some(spec.clone()));
            let tn = timed(naive_plan, Some(spec));
            t.row(vec![
                "jitter".into(),
                name.to_string(),
                format!("sigma={s:.1}"),
                ms(t0r),
                ms(tr),
                format!("{:.2}", tr / t0r),
                ms(tn),
                format!("{:.2}", tn / t0n),
            ]);
        }
        // --- axis (b): hard NIC failures at t = 0; the rail plan reroutes
        // around them (the injected fault proves it: a rerouted plan that
        // still touched the dead NIC would stall to the heal time), the
        // ablation stalls until the link heals
        for &f in fails {
            // one failed NIC per node, never a whole node: device 1 on
            // node 0, then device p+2 on node 1
            let devs: Vec<usize> = (0..f).map(|i| i * p + 1 + i).collect();
            let mut health = RailHealth::all_healthy(&cluster);
            for &d in &devs {
                health = health.fail_nic(d);
            }
            let heal = 4.0 * t0n;
            let mut spec = FaultSpec::seeded(seed);
            for &d in &devs {
                spec = spec.with_nic_fault(LinkFault {
                    device: d,
                    at: 0.0,
                    frac: 0.0,
                    restore_at: Some(heal),
                });
            }
            let tr = timed(&health_plan(name, &health), Some(spec.clone()));
            let tn = timed(naive_plan, Some(spec));
            t.row(vec![
                "nic_fail".into(),
                name.to_string(),
                format!("f={f}"),
                ms(t0r),
                ms(tr),
                format!("{:.2}", tr / t0r),
                ms(tn),
                format!("{:.2}", tn / t0n),
            ]);
        }
    }
    // --- optional axis: a user scenario from `pk figures --fault`. Rail
    // plans are health-masked against the scenario's permanently dead
    // NICs; the no-reroute ablation would deadlock on one, so its
    // columns go blank in that case.
    if let Some(user) = FX1_FAULT_SCENARIO.get() {
        let mut health = RailHealth::all_healthy(&cluster);
        let mut permanent = false;
        for lf in &user.nic_faults {
            if lf.frac <= 1e-9 && lf.restore_at.is_none() && lf.device < cluster.total_devices() {
                health = health.fail_nic(lf.device);
                permanent = true;
            }
        }
        for &(name, ref rail_plan, ref naive_plan) in &kernels {
            let t0r = timed(rail_plan, None);
            let t0n = timed(naive_plan, None);
            let tr = if health.any_failed() {
                timed(&health_plan(name, &health), Some(user.clone()))
            } else {
                timed(rail_plan, Some(user.clone()))
            };
            let (ncol, nslow) = if permanent {
                ("-".into(), "-".into())
            } else {
                let tn = timed(naive_plan, Some(user.clone()));
                (ms(tn), format!("{:.2}", tn / t0n))
            };
            t.row(vec![
                "custom".into(),
                name.to_string(),
                "cli scenario".into(),
                ms(t0r),
                ms(tr),
                format!("{:.2}", tr / t0r),
                ncol,
                nslow,
            ]);
        }
    }
    // --- axis (c): serving under a mid-trace decode-NIC outage (vx1 grid
    // point: 2 nodes, Poisson arrivals, 0.8× probed capacity)
    let node = NodeSpec::hgx_h100();
    let model = ModelCfg::reference();
    let pk_cost = StepCostModel::calibrate(&node, KernelMode::PkOverlap, &model);
    let base_cost = StepCostModel::calibrate(&node, KernelMode::Nonoverlap, &model);
    let n_req = if fast { 120 } else { 300 };
    let pk_cfg = ServeCfg::reference(cluster.clone(), KernelMode::PkOverlap);
    let base_cfg = ServeCfg::reference(cluster.clone(), KernelMode::Nonoverlap);
    let cap = serve::capacity_probe(&pk_cfg, &pk_cost, n_req / 2, 1234);
    let trace = workload::generate(&TraceCfg::chat(ArrivalProcess::Poisson, 0.8 * cap, n_req, 99));
    let rp0 = serve::run_with_cost(&pk_cfg, &pk_cost, &trace);
    let rb0 = serve::run_with_cost(&base_cfg, &base_cost, &trace);
    // the outage covers the middle third of each engine's healthy run;
    // node 1 is the decode node of the 2-node disaggregated pair
    let outage = |dur: f64| {
        FaultSpec::seeded(seed).with_nic_fault(LinkFault {
            device: 1,
            at: dur / 3.0,
            frac: 0.0,
            restore_at: Some(2.0 * dur / 3.0),
        })
    };
    let mut pk_f = pk_cfg.clone();
    pk_f.fault = Some(outage(rp0.duration));
    let mut base_f = base_cfg.clone();
    base_f.fault = Some(outage(rb0.duration));
    let rp1 = serve::run_with_cost(&pk_f, &pk_cost, &trace);
    let rb1 = serve::run_with_cost(&base_f, &base_cost, &trace);
    t.row(vec![
        "serve".into(),
        "goodput_rps".into(),
        "nic outage".into(),
        format!("{:.1}", rp0.goodput_rps),
        format!("{:.1}", rp1.goodput_rps),
        format!("{:.2}", rp0.goodput_rps / rp1.goodput_rps.max(1e-9)),
        format!("{:.1}", rb1.goodput_rps),
        format!("{:.2}", rb0.goodput_rps / rb1.goodput_rps.max(1e-9)),
    ]);
    t.row(vec![
        "serve".into(),
        "p99_ms".into(),
        "nic outage".into(),
        ms(rp0.latency_p99),
        ms(rp1.latency_p99),
        format!("{:.2}", rp1.latency_p99 / rp0.latency_p99),
        ms(rb1.latency_p99),
        format!("{:.2}", rb1.latency_p99 / rb0.latency_p99),
    ]);
    t
}

// --------------------------------------------------------------- µ1, µ2
fn mu1(_fast: bool) -> Table {
    let g = GpuSpec::h100();
    let mut t = Table::new("§3.1.3 synchronization microbenchmark", &["mechanism", "latency_ns"]);
    t.row(vec!["intra-SM mbarrier".into(), format!("{:.0}", g.mbarrier_sync * 1e9)]);
    t.row(vec!["inter-SM via HBM".into(), format!("{:.0}", g.hbm_sync * 1e9)]);
    t.row(vec!["inter-device NVLink flag".into(), format!("{:.0}", g.nvlink_signal * 1e9)]);
    t
}

fn mu2(_fast: bool) -> Table {
    let g = GpuSpec::h100();
    let mut t = Table::new(
        "§3.1.4 NVSHMEM vs PK peer access",
        &["api", "elementwise_latency_us", "bandwidth_GBps"],
    );
    for api in [PeerApi::Nvshmem, PeerApi::Pk] {
        t.row(vec![
            format!("{api:?}"),
            format!("{:.2}", nvshmem::elementwise_latency(&g, api) * 1e6),
            format!("{:.1}", nvshmem::reg_bandwidth(&g, api, 1e6, 132.0) / 1e9),
        ]);
    }
    t
}

// --------------------------------------------------------------- px1
/// Build + simulate one whole-model step plan, asserting it verify-clean
/// first — every plan the model layer emits must pass `plan::verify`.
fn px1_step_time(
    m: &crate::model::ModelCfg,
    spec: &ParallelSpec,
    cluster: &ClusterSpec,
    sched: pipeline::PipeSchedule,
) -> f64 {
    let health = RailHealth::all_healthy(cluster);
    let plan = pipeline::build_model(m, spec, cluster, &health, sched);
    let ctx = crate::plan::verify::VerifyCtx {
        pool: None,
        devices_per_node: Some(cluster.devices_per_node()),
    };
    let report = crate::plan::verify::verify(&plan, &ctx);
    assert!(
        report.is_clean(),
        "model plan ({spec:?}, {sched:?}) must be verify-clean: {report:?}"
    );
    TimedExec::on_cluster(cluster.clone()).run(&plan).total_time
}

fn px1(fast: bool) -> Table {
    let mut t = Table::new(
        "Model layer: training-step time vs parallel layout — non-overlapped sequential baseline vs 1F1B vs interleaved pipeline",
        &["model", "layout", "nodes", "nic_GBps", "seq_ms", "1f1b_ms", "intl_ms", "speedup"],
    );
    let nodes: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4] };
    let nics: &[f64] = if fast { &[50e9] } else { &[25e9, 50e9, 100e9] };
    for &k in nodes {
        // the 1-node row is NVLink-only (NIC-independent): emit it once
        let nic_points: &[f64] = if k == 1 { &nics[..1] } else { nics };
        for &nic in nic_points {
            let cluster = ClusterSpec::hgx_h100_pod(k).with_nic_bw(nic);
            let nic_label =
                if k == 1 { "nvlink-only".to_string() } else { format!("{:.0}", nic / 1e9) };
            let n = cluster.total_devices();
            // widest stage with 2 pipeline stages, plus a deeper 4-stage
            // variant in full mode (narrower stages, more boundary hops)
            let mut layouts =
                vec![("dense", ParallelSpec::dense(n / 2, 2), crate::model::ModelCfg::dense_example())];
            if !fast {
                layouts.push((
                    "dense",
                    ParallelSpec::dense(n / 4, 4),
                    crate::model::ModelCfg::dense_example(),
                ));
            }
            layouts.push(("moe", ParallelSpec::moe(n / 2, 2), crate::model::ModelCfg::moe_example()));
            for (name, spec, m) in layouts {
                let seq = px1_step_time(&m, &spec, &cluster, pipeline::PipeSchedule::Sequential);
                let ofob = px1_step_time(&m, &spec, &cluster, pipeline::PipeSchedule::OneFOneB);
                let intl = px1_step_time(&m, &spec, &cluster, pipeline::PipeSchedule::Interleaved);
                t.row(vec![
                    name.into(),
                    format!("{}{}xpp{}", if name == "moe" { "ep" } else { "tp" }, spec.stage_width(), spec.pp),
                    k.to_string(),
                    nic_label.clone(),
                    ms(seq),
                    ms(ofob),
                    ms(intl),
                    format!("{:.2}", seq / ofob),
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_complete_and_runnable_fast() {
        let ex = all_exhibits();
        assert_eq!(
            ex.len(),
            28,
            "17 figures/tables + 2 micro + tab1/tab2 + scale-out + cluster MoE + rail + cluster GEMM + serving + robustness + model layer"
        );
        for e in &ex {
            let t = (e.run)(true);
            assert!(!t.rows.is_empty(), "{} produced no rows", e.id);
        }
    }

    #[test]
    fn rx1_rail_beats_naive_and_baseline_on_every_multi_node_row() {
        // fast mode: 1-node + 2-node rows at 50 GB/s for both kernels.
        let t = rx1(true);
        assert_eq!(
            t.columns,
            vec!["kernel", "nodes", "nic_GBps", "rail_ms", "naive_ms", "baseline_ms", "nic_x"]
        );
        let mut saw = (false, false);
        for r in &t.rows {
            let rail: f64 = r[3].parse().unwrap();
            let naive: f64 = r[4].parse().unwrap();
            let base: f64 = r[5].parse().unwrap();
            assert!(rail < base, "{} @ {} nodes: rail must beat the baseline: {rail} vs {base}", r[0], r[1]);
            if r[1] == "1" {
                // one node: rail and naive are the same plan
                assert_eq!(r[3], r[4], "{}: 1-node rail == naive", r[0]);
            } else {
                assert!(rail < naive, "{} @ {} nodes: rail must beat naive: {rail} vs {naive}", r[0], r[1]);
                if r[0] == "gemm_rs" {
                    let x: f64 = r[6].parse().unwrap();
                    assert_eq!(x, 8.0, "gemm_rs NIC reduction is exactly xP");
                    saw.0 = true;
                } else {
                    assert_eq!(r[6], "-", "a2a bytes are not reducible");
                    saw.1 = true;
                }
            }
        }
        assert!(saw.0 && saw.1, "both kernels swept multi-node");
    }

    // gx1's acceptance assertions (rail < naive/baseline, nic_x == P,
    // an_vs_swept <= 1.10) live in the claims suite —
    // claim_gx1_rail_wins_and_analytic_chunk_tracks_swept — so the
    // expensive sweep isn't re-simulated by a duplicate in-module test;
    // registry_complete_and_runnable_fast still smoke-runs it.

    #[test]
    fn sx1_shows_the_nic_cliff_and_scaleout_recovery() {
        // full (non-fast) mode so the checks cover every NIC level; the
        // monotonicity claim is per NIC value, never across NIC values.
        let t = sx1(false);
        for name in ["all_reduce", "all_gather", "reduce_scatter"] {
            let one = t
                .rows
                .iter()
                .find(|r| r[0] == name && r[1] == "1")
                .expect("1-node row")[5]
                .parse::<f64>()
                .unwrap();
            for nic in ["25", "50", "100"] {
                // (nodes, agg, per_dev) at this NIC level
                let mut series: Vec<(f64, f64, f64)> = vec![];
                for r in &t.rows {
                    if r[0] == name && r[2] == nic {
                        series.push((r[1].parse().unwrap(), r[4].parse().unwrap(), r[5].parse().unwrap()));
                    }
                }
                assert!(series.len() >= 3, "{name}@{nic}: 2->4 nodes covered");
                // the NIC cliff: per-device bandwidth drops when the first
                // cross-node hop appears
                let two = series.iter().find(|(n, _, _)| *n == 2.0).unwrap().2;
                assert!(one > two, "{name}@{nic}: per-device cliff ({one} vs {two} GB/s)");
                // scale-out recovery: aggregate bandwidth is monotone
                // non-decreasing in node count at a fixed NIC bandwidth
                for w in series.windows(2) {
                    assert!(w[1].1 >= w[0].1 * 0.999, "{name}@{nic}: scale-out monotone: {series:?}");
                }
            }
        }
    }

    #[test]
    fn mx1_overlap_beats_sequential_at_every_point_and_aggregation_pays() {
        // acceptance: overlapped cluster MoE beats the sequential schedule
        // at every (nodes, NIC bandwidth) point of the full sweep, and the
        // per-rail aggregation strictly reduces NIC bytes on every
        // multi-node row.
        let t = mx1(false);
        assert_eq!(t.rows.len(), 10, "1 nvlink-only row + 3 node counts x 3 NIC levels");
        for r in &t.rows {
            let pk: f64 = r[2].parse().unwrap();
            let seq: f64 = r[3].parse().unwrap();
            assert!(
                pk < seq,
                "overlap must win at nodes={} nic={}: {pk} vs {seq}",
                r[0],
                r[1]
            );
            if r[1] != "nvlink-only" {
                let red: f64 = r[7].parse().unwrap();
                assert!(red > 1.5, "aggregation must cut NIC bytes at {}x{}: {red}", r[0], r[1]);
            }
        }
    }

    #[test]
    fn px1_overlapped_pipeline_beats_sequential_at_every_point() {
        // acceptance: the 1F1B schedule (with the MoE wave-credit overlap
        // inside its cells) is strictly faster than the non-overlapped
        // sequential-pipeline baseline at every swept point, dense and
        // MoE alike; px1_step_time also asserts every plan verify-clean.
        let t = px1(true);
        assert!(t.rows.len() >= 4, "1-node + 2-node rows, dense + moe");
        let mut saw = (false, false);
        for r in &t.rows {
            let seq: f64 = r[4].parse().unwrap();
            let ofob: f64 = r[5].parse().unwrap();
            let intl: f64 = r[6].parse().unwrap();
            assert!(
                ofob < seq,
                "{} {} @ {} nodes: 1F1B must beat sequential: {ofob} vs {seq}",
                r[0],
                r[1],
                r[2]
            );
            assert!(
                intl < seq,
                "{} {} @ {} nodes: interleaved must beat sequential: {intl} vs {seq}",
                r[0],
                r[1],
                r[2]
            );
            match r[0].as_str() {
                "dense" => saw.0 = true,
                "moe" => saw.1 = true,
                other => panic!("unexpected model kind {other}"),
            }
        }
        assert!(saw.0 && saw.1, "both model kinds swept");
    }

    #[test]
    fn run_exhibit_by_id() {
        assert!(run_exhibit("tab1", true).is_some());
        assert!(run_exhibit("nope", true).is_none());
    }

    #[test]
    fn checked_runner_rejects_unknown_ids_cleanly() {
        // the CLI path: an unknown --only id must produce an error that
        // names the bad id and lists the registry, not an empty run
        let err = run_exhibits_checked(true, Some(&["nope"]), 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown exhibit id 'nope'"), "{msg}");
        assert!(msg.contains("tab1") && msg.contains("vx1"), "must list valid ids: {msg}");
        // a valid selection still runs
        let ok = run_exhibits_checked(true, Some(&["mu1"]), 1).unwrap();
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].id, "mu1");
    }

    #[test]
    fn fig7_pk_wins_and_crossovers_match_paper() {
        let t = fig7(true);
        let pk = t.col_f64("pk");
        let nonov = t.col_f64("nonoverlap");
        let flux = t.col_f64("flux");
        let td = t.col_f64("triton_dist");
        // PK above non-overlap everywhere (1.06-1.68x)
        for (p, n) in pk.iter().zip(&nonov) {
            assert!(p > n, "PK must beat non-overlap: {pk:?} vs {nonov:?}");
        }
        // small N: CE-based baselines below non-overlap (the paper's crossover)
        assert!(flux[0] < nonov[0], "Flux below baseline at N=4096: {flux:?} vs {nonov:?}");
        assert!(td[0] < nonov[0], "TD below baseline at N=4096");
        // large N: flux competitive with PK (within 20%)
        let last = pk.len() - 1;
        assert!(flux[last] > 0.8 * pk[last], "Flux competitive at large N");
    }

    #[test]
    fn fig10_speedup_shrinks_with_s() {
        let t = fig10(true);
        let sp = t.col_f64("speedup");
        assert!(sp[0] > sp[sp.len() - 1], "gap shrinks with sequence length: {sp:?}");
        assert!(sp.iter().all(|s| *s >= 1.0), "PK never loses: {sp:?}");
    }

    #[test]
    fn fig15_pk_beats_nccl_tensor_dim() {
        let t = fig15(true);
        for s in t.col_f64("speedup") {
            assert!(s > 1.0, "PK wins tensor-dim AG: {s}");
        }
    }
}
