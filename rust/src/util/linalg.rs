//! Native tile math for the functional executor.
//!
//! These are the CPU reference implementations of the per-tile compute that
//! the paper's consumer workers issue to tensor/CUDA cores. They are used to
//! *verify* kernel plans at small sizes; the PJRT runtime (`crate::runtime`)
//! executes the AOT-lowered Pallas/XLA versions of the same math on the
//! example / end-to-end paths.

/// `c += a @ b` where `a` is `m×k`, `b` is `k×n`, `c` is `m×n`, row-major.
pub fn matmul_accum(c: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    for i in 0..m {
        for l in 0..k {
            let av = a[i * k + l];
            if av == 0.0 {
                continue;
            }
            let brow = &b[l * n..(l + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// `c = a @ b` (zero-initialising convenience wrapper).
pub fn matmul(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0.0; m * n];
    matmul_accum(&mut c, a, b, m, n, k);
    c
}

/// tanh-approximation GeLU, matching `jax.nn.gelu` (approximate=True),
/// which is what the L2 model uses.
pub fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

/// In-place GeLU over a slice.
pub fn gelu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu(*v);
    }
}

/// Numerically stable softmax over the last dimension of an `m×n` row-major
/// matrix, in place.
pub fn softmax_rows(x: &mut [f32], m: usize, n: usize) {
    assert_eq!(x.len(), m * n);
    for i in 0..m {
        let row = &mut x[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Full (non-causal) single-head attention reference:
/// `out = softmax(q k^T / sqrt(d)) v` with `q: s_q×d`, `k,v: s_kv×d`.
pub fn attention_ref(q: &[f32], k: &[f32], v: &[f32], s_q: usize, s_kv: usize, d: usize) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    // scores = q @ k^T
    let mut scores = vec![0.0f32; s_q * s_kv];
    for i in 0..s_q {
        for j in 0..s_kv {
            let mut acc = 0.0;
            for l in 0..d {
                acc += q[i * d + l] * k[j * d + l];
            }
            scores[i * s_kv + j] = acc * scale;
        }
    }
    softmax_rows(&mut scores, s_q, s_kv);
    matmul(&scores, v, s_q, d, s_kv)
}

/// State for blockwise (FlashAttention-style) online-softmax accumulation.
/// One instance per query block; KV blocks are folded in one at a time.
/// This mirrors exactly what the L1 Pallas attention kernel does per grid
/// step, and is the functional semantics of the Ring Attention plan's
/// per-block consumer op.
#[derive(Clone, Debug)]
pub struct OnlineSoftmaxState {
    pub s_q: usize,
    pub d: usize,
    /// Running row maxima `m_i`.
    pub row_max: Vec<f32>,
    /// Running row exp-sums `l_i`.
    pub row_sum: Vec<f32>,
    /// Un-normalised output accumulator `s_q×d`.
    pub acc: Vec<f32>,
}

impl OnlineSoftmaxState {
    pub fn new(s_q: usize, d: usize) -> Self {
        Self {
            s_q,
            d,
            row_max: vec![f32::NEG_INFINITY; s_q],
            row_sum: vec![0.0; s_q],
            acc: vec![0.0; s_q * d],
        }
    }

    /// Fold one KV block (`k,v: s_kv×d`) into the running state.
    pub fn update(&mut self, q: &[f32], k: &[f32], v: &[f32], s_kv: usize) {
        let (s_q, d) = (self.s_q, self.d);
        let scale = 1.0 / (d as f32).sqrt();
        for i in 0..s_q {
            // scores for row i against this block
            let mut scores = vec![0.0f32; s_kv];
            let mut blk_max = f32::NEG_INFINITY;
            for j in 0..s_kv {
                let mut acc = 0.0;
                for l in 0..d {
                    acc += q[i * d + l] * k[j * d + l];
                }
                let s = acc * scale;
                scores[j] = s;
                blk_max = blk_max.max(s);
            }
            let new_max = self.row_max[i].max(blk_max);
            let correction = if self.row_max[i] == f32::NEG_INFINITY {
                0.0
            } else {
                (self.row_max[i] - new_max).exp()
            };
            // rescale previous accumulator and sum
            self.row_sum[i] *= correction;
            for l in 0..d {
                self.acc[i * d + l] *= correction;
            }
            // fold in this block
            for j in 0..s_kv {
                let p = (scores[j] - new_max).exp();
                self.row_sum[i] += p;
                for l in 0..d {
                    self.acc[i * d + l] += p * v[j * d + l];
                }
            }
            self.row_max[i] = new_max;
        }
    }

    /// Normalise and return the attention output.
    pub fn finalize(&self) -> Vec<f32> {
        let mut out = self.acc.clone();
        for i in 0..self.s_q {
            let inv = 1.0 / self.row_sum[i];
            for l in 0..self.d {
                out[i * self.d + l] *= inv;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{assert_allclose, seeded_vec};

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known_values() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0; 4];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // 1x3 @ 3x2
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 1, 2, 3), vec![4.0, 5.0]);
    }

    #[test]
    fn matmul_accum_accumulates() {
        let mut c = vec![10.0; 4];
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![1.0, 2.0, 3.0, 4.0];
        matmul_accum(&mut c, &a, &b, 2, 2, 2);
        assert_eq!(c, vec![11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn gelu_reference_points() {
        // gelu(0) = 0, gelu(large) ≈ large, gelu(-large) ≈ 0
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
        // tanh-approx value at 1.0 (matches jax.nn.gelu approximate=True)
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = seeded_vec(3, 4 * 7);
        softmax_rows(&mut x, 4, 7);
        for i in 0..4 {
            let s: f32 = x[i * 7..(i + 1) * 7].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn online_softmax_matches_full_attention() {
        let (s_q, s_kv, d) = (8, 32, 16);
        let q = seeded_vec(1, s_q * d);
        let k = seeded_vec(2, s_kv * d);
        let v = seeded_vec(3, s_kv * d);
        let want = attention_ref(&q, &k, &v, s_q, s_kv, d);

        // fold KV in 4 blocks of 8
        let mut st = OnlineSoftmaxState::new(s_q, d);
        for blk in 0..4 {
            let kb = &k[blk * 8 * d..(blk + 1) * 8 * d];
            let vb = &v[blk * 8 * d..(blk + 1) * 8 * d];
            st.update(&q, kb, vb, 8);
        }
        assert_allclose(&st.finalize(), &want, 1e-5, 1e-6);
    }

    #[test]
    fn online_softmax_block_order_invariant() {
        let (s_q, s_kv, d) = (4, 16, 8);
        let q = seeded_vec(4, s_q * d);
        let k = seeded_vec(5, s_kv * d);
        let v = seeded_vec(6, s_kv * d);
        let mut fwd = OnlineSoftmaxState::new(s_q, d);
        let mut rev = OnlineSoftmaxState::new(s_q, d);
        for blk in 0..2 {
            fwd.update(&q, &k[blk * 8 * d..(blk + 1) * 8 * d], &v[blk * 8 * d..(blk + 1) * 8 * d], 8);
        }
        for blk in (0..2).rev() {
            rev.update(&q, &k[blk * 8 * d..(blk + 1) * 8 * d], &v[blk * 8 * d..(blk + 1) * 8 * d], 8);
        }
        assert_allclose(&fwd.finalize(), &rev.finalize(), 1e-5, 1e-6);
    }
}
