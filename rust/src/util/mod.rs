//! Small shared utilities: approximate comparison, formatting, statistics,
//! and native tile math used by the functional executor's fallback path
//! (the PJRT runtime is used where an AOT artifact exists).

pub mod error;
pub mod json;
pub mod linalg;
pub mod par;
pub mod prop;
pub mod stats;

pub use par::{par_map, par_map_with};

/// Relative-tolerance float comparison used throughout the test suite.
pub fn approx_eq(a: f64, b: f64, rtol: f64) -> bool {
    let denom = a.abs().max(b.abs()).max(1e-30);
    (a - b).abs() / denom <= rtol
}

/// Assert two f32 slices match within `rtol` relative tolerance plus a tiny
/// absolute floor (mirrors `numpy.testing.assert_allclose`).
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "mismatch at {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

/// Maximum absolute elementwise error.
pub fn max_abs_err(got: &[f32], want: &[f32]) -> f32 {
    got.iter()
        .zip(want.iter())
        .map(|(g, w)| (g - w).abs())
        .fold(0.0, f32::max)
}

/// Pretty-print a byte count (e.g. `256 MB`, `2 KB`).
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let bf = b as f64;
    if bf >= K * K * K {
        format!("{:.0} GB", bf / (K * K * K))
    } else if bf >= K * K {
        format!("{:.0} MB", bf / (K * K))
    } else if bf >= K {
        format!("{:.0} KB", bf / K)
    } else {
        format!("{b} B")
    }
}

/// Pretty-print seconds as a human unit (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Pretty-print FLOP/s as TFLOP/s.
pub fn fmt_tflops(flops_per_s: f64) -> String {
    format!("{:.1} TFLOP/s", flops_per_s / 1e12)
}

/// Deterministic pseudo-random f32 vector in [-1, 1) from a seed
/// (splitmix64, no external dependency needed on hot init paths).
pub fn seeded_vec(seed: u64, n: usize) -> Vec<f32> {
    let mut state = seed.wrapping_add(0x9E3779B97F4A7C15);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        state = state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        // 24 high bits -> [0,1) -> [-1,1)
        out.push(((z >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_basics() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6));
        assert!(!approx_eq(1.0, 1.1, 1e-6));
        assert!(approx_eq(0.0, 0.0, 1e-12));
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2 KB");
        assert_eq!(fmt_bytes(256 * 1024 * 1024), "256 MB");
        assert_eq!(fmt_bytes(1 << 30), "1 GB");
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(64e-9), "64 ns");
        assert_eq!(fmt_time(832e-9), "832 ns");
        assert!(fmt_time(1.5e-3).ends_with("ms"));
    }

    #[test]
    fn seeded_vec_deterministic_and_bounded() {
        let a = seeded_vec(7, 1000);
        let b = seeded_vec(7, 1000);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| *v >= -1.0 && *v < 1.0));
        // not constant
        assert!(a.iter().any(|v| (*v - a[0]).abs() > 1e-3));
    }

    #[test]
    fn seeded_vec_different_seeds_differ() {
        assert_ne!(seeded_vec(1, 16), seeded_vec(2, 16));
    }
}
