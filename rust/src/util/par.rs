//! Deterministic scoped-thread fan-out for embarrassingly parallel
//! sweeps (tuner grids, exhibit regeneration, bench drivers).
//!
//! `TimedExec::run` is `&self` over immutable state, so sweep points are
//! independent; the only thing parallelism must not change is the
//! *output*. [`par_map_with`] therefore writes each result into the slot
//! of its input index — the returned `Vec` is byte-identical to a serial
//! `map` regardless of thread scheduling (pinned by the determinism tests
//! in `tests/integration_paper_claims.rs`).
//!
//! No external dependencies: plain `std::thread::scope` workers pulling
//! indices off an atomic counter. A worker panic propagates out of the
//! scope, so failures are not silently dropped.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// True while this thread is a `par_map_with` worker. Nested fan-outs
    /// (an exhibit worker calling the tuner, which calls `par_map`)
    /// degrade to serial instead of oversubscribing ~threads² OS threads
    /// of GEMM-scale simulations.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Worker-thread count for parallel sweeps: `PK_THREADS` if set (a value
/// of `1` forces serial execution), else the machine's available
/// parallelism.
pub fn default_threads() -> usize {
    match std::env::var("PK_THREADS") {
        Ok(s) => s.parse().ok().filter(|&n| n >= 1).unwrap_or(1),
        Err(_) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Map `f` over `items` on up to `threads` scoped threads, returning
/// results in input order. `threads <= 1` degenerates to a plain serial
/// map (no threads spawned), which parallel runs are byte-identical to.
pub fn par_map_with<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = if IN_POOL.with(|p| p.get()) { 1 } else { threads.clamp(1, n.max(1)) };
    if threads == 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                IN_POOL.with(|p| p.set(true));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i, &items[i]);
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("parallel worker filled its slot"))
        .collect()
}

/// [`par_map_with`] at [`default_threads`].
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    par_map_with(default_threads(), items, f)
}

/// Like [`par_map_with`] but over *mutable* items — the fan-out for
/// stateful shards (the partitioned `FlowNet` advances every partition
/// in place on each event). Items are split into contiguous chunks, one
/// scoped thread per chunk, and results are joined in input order, so
/// the output (and every mutation) is byte-identical to a serial
/// `iter_mut().map()` regardless of scheduling. Nested calls from inside
/// any pool worker degrade to serial (same [`IN_POOL`] guard), and a
/// worker panic is re-raised with its original payload.
pub fn par_map_mut<T, R, F>(threads: usize, items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    let threads = if IN_POOL.with(|p| p.get()) { 1 } else { threads.clamp(1, n.max(1)) };
    if threads == 1 || n <= 1 {
        return items.iter_mut().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let chunk = n.div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, ch)| {
                s.spawn(move || {
                    IN_POOL.with(|p| p.set(true));
                    ch.iter_mut()
                        .enumerate()
                        .map(|(j, it)| f(ci * chunk + j, it))
                        .collect::<Vec<R>>()
                })
            })
            .collect();
        let mut out = Vec::with_capacity(n);
        for h in handles {
            match h.join() {
                Ok(v) => out.extend(v),
                Err(e) => std::panic::resume_unwind(e),
            }
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let serial = par_map_with(1, &items, |i, &x| (i, x * x));
        let parallel = par_map_with(8, &items, |i, &x| (i, x * x));
        assert_eq!(serial, parallel);
        assert_eq!(parallel[17], (17, 289));
    }

    #[test]
    fn handles_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert_eq!(par_map_with(4, &empty, |_, &x| x).len(), 0);
        assert_eq!(par_map_with(4, &[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn more_threads_than_items() {
        let items = [1u64, 2, 3];
        assert_eq!(par_map_with(64, &items, |_, &x| x * 10), vec![10, 20, 30]);
    }

    #[test]
    fn nested_fan_out_degrades_to_serial() {
        // inner par_map calls made from a worker thread must not spawn a
        // second level of pools — and must still return correct results
        let outer: Vec<usize> = (0..8).collect();
        let got = par_map_with(4, &outer, |_, &x| {
            let inner: Vec<usize> = (0..16).collect();
            par_map_with(4, &inner, |_, &y| x * 100 + y).iter().sum::<usize>()
        });
        let want: Vec<usize> =
            outer.iter().map(|&x| (0..16).map(|y| x * 100 + y).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_mut_mutates_in_place_and_orders_results() {
        let mut serial: Vec<u64> = (0..37).collect();
        let mut parallel = serial.clone();
        let rs = par_map_mut(1, &mut serial, |i, x| {
            *x += 1;
            *x * i as u64
        });
        let rp = par_map_mut(8, &mut parallel, |i, x| {
            *x += 1;
            *x * i as u64
        });
        assert_eq!(serial, parallel);
        assert_eq!(rs, rp);
        assert_eq!(parallel[5], 6);
    }

    #[test]
    fn par_map_mut_nested_degrades_to_serial() {
        let mut outer: Vec<u64> = (0..6).collect();
        let got = par_map_mut(3, &mut outer, |_, x| {
            let mut inner: Vec<u64> = (0..4).collect();
            par_map_mut(4, &mut inner, |_, y| *y + *x).iter().sum::<u64>()
        });
        let want: Vec<u64> = (0..6u64).map(|x| (0..4u64).map(|y| y + x).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic(expected = "mut boom")]
    fn par_map_mut_panic_propagates() {
        let mut items: Vec<usize> = (0..8).collect();
        let _ = par_map_mut(4, &mut items, |_, x| {
            if *x == 3 {
                panic!("mut boom");
            }
            *x
        });
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        let items: Vec<usize> = (0..8).collect();
        let _ = par_map_with(4, &items, |_, &x| {
            if x == 5 {
                panic!("boom");
            }
            x
        });
    }
}
