//! Tiny statistics helpers for the benchmark harness and tuner.

/// Summary statistics of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub std: f64,
}

/// Compute [`Summary`] over a non-empty sample.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    Summary { n, mean, min, max, std: var.sqrt() }
}

/// Geometric mean of positive values (used for speedup aggregation,
/// matching how the paper reports speedup ranges).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|x| {
            assert!(*x > 0.0, "geomean needs positive values");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Argmin over `(key, value)` pairs; returns the key of the smallest value.
pub fn argmin_by<K: Copy>(items: impl IntoIterator<Item = (K, f64)>) -> Option<K> {
    let mut best: Option<(K, f64)> = None;
    for (k, v) in items {
        match best {
            Some((_, bv)) if v >= bv => {}
            _ => best = Some((k, v)),
        }
    }
    best.map(|(k, _)| k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = summarize(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_mixed() {
        let s = summarize(&[1.0, 3.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.std - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[8.0]) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn argmin_picks_smallest() {
        let r = argmin_by([(1usize, 5.0), (2, 3.0), (3, 4.0)]);
        assert_eq!(r, Some(2));
        assert_eq!(argmin_by(Vec::<(usize, f64)>::new()), None);
    }
}
