//! Tiny statistics helpers for the benchmark harness, tuner, and the
//! serving-layer latency reports.
//!
//! All of these are robust to the degenerate inputs the serving exhibits
//! legitimately produce: empty samples (a latency bucket with no
//! requests at low load) return `None` instead of panicking, and
//! non-finite values can never win an argmin (a NaN sweep point used to
//! silently poison a tuner grid).

/// Summary statistics of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub std: f64,
}

/// Compute [`Summary`] over a sample; `None` on an empty one (e.g. an
/// SLO-violator latency bucket with no violators).
pub fn summarize(xs: &[f64]) -> Option<Summary> {
    if xs.is_empty() {
        return None;
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    Some(Summary { n, mean, min, max, std: var.sqrt() })
}

/// Geometric mean of positive values (used for speedup aggregation,
/// matching how the paper reports speedup ranges); `None` on an empty
/// sample. Still asserts positivity — a non-positive speedup is a caller
/// bug, not a legitimate low-load condition.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let log_sum: f64 = xs
        .iter()
        .map(|x| {
            assert!(*x > 0.0, "geomean needs positive values");
            x.ln()
        })
        .sum();
    Some((log_sum / xs.len() as f64).exp())
}

/// Percentile `q` in `[0, 100]` of a sample, with linear interpolation
/// between closest ranks (`q = 50` is the median; the convention matches
/// `numpy.percentile`'s default). Non-finite values are ignored; `None`
/// when nothing finite remains.
pub fn percentile(xs: &[f64], q: f64) -> Option<f64> {
    assert!((0.0..=100.0).contains(&q), "percentile q out of [0, 100]: {q}");
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return None;
    }
    v.sort_by(f64::total_cmp);
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    Some(v[lo] + (v[hi] - v[lo]) * frac)
}

/// Argmin over `(key, value)` pairs; returns the key of the smallest
/// **finite** value. Non-finite values are skipped entirely — under the
/// old `v >= bv` comparison a NaN after index 0 compared false and
/// *replaced* the best, so one NaN sweep point silently won the grid.
pub fn argmin_by<K: Copy>(items: impl IntoIterator<Item = (K, f64)>) -> Option<K> {
    let mut best: Option<(K, f64)> = None;
    for (k, v) in items {
        if !v.is_finite() {
            continue;
        }
        match best {
            Some((_, bv)) if v >= bv => {}
            _ => best = Some((k, v)),
        }
    }
    best.map(|(k, _)| k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = summarize(&[2.0, 2.0, 2.0]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_mixed() {
        let s = summarize(&[1.0, 3.0]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.std - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_and_geomean_of_empty_are_none() {
        assert_eq!(summarize(&[]), None);
        assert_eq!(geomean(&[]), None);
    }

    #[test]
    fn geomean_powers() {
        assert!((geomean(&[1.0, 4.0]).unwrap() - 2.0).abs() < 1e-12);
        assert!((geomean(&[8.0]).unwrap() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_pins_known_samples() {
        // median of an even-length sample interpolates halfway
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), Some(2.5));
        // endpoints are exact
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.0), Some(1.0));
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 100.0), Some(4.0));
        // p99 of 1..=100: rank 98.01 -> 99 + 0.01
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 99.0).unwrap() - 99.01).abs() < 1e-9);
        // order-independent (sorts internally)
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), Some(2.0));
        // single element: every percentile is that element
        assert_eq!(percentile(&[7.0], 99.0), Some(7.0));
    }

    #[test]
    fn percentile_of_empty_or_all_nan_is_none() {
        assert_eq!(percentile(&[], 50.0), None);
        assert_eq!(percentile(&[f64::NAN, f64::INFINITY], 50.0), None);
        // NaN mixed in is ignored, not propagated
        assert_eq!(percentile(&[f64::NAN, 2.0], 50.0), Some(2.0));
    }

    #[test]
    fn argmin_picks_smallest() {
        let r = argmin_by([(1usize, 5.0), (2, 3.0), (3, 4.0)]);
        assert_eq!(r, Some(2));
        assert_eq!(argmin_by(Vec::<(usize, f64)>::new()), None);
    }

    #[test]
    fn argmin_skips_non_finite_at_every_position() {
        // regression: a NaN after index 0 used to *win* (v >= bv is false
        // for NaN, so the match arm replaced the best)
        let nan = f64::NAN;
        assert_eq!(argmin_by([(1usize, nan), (2, 3.0), (3, 4.0)]), Some(2), "NaN at head");
        assert_eq!(argmin_by([(1usize, 3.0), (2, nan), (3, 4.0)]), Some(1), "NaN in middle");
        assert_eq!(argmin_by([(1usize, 3.0), (2, 2.0), (3, nan)]), Some(2), "NaN at tail");
        assert_eq!(argmin_by([(1usize, f64::INFINITY), (2, 5.0)]), Some(2), "inf skipped");
        assert_eq!(argmin_by([(1usize, nan), (2, nan)]), None, "all non-finite");
    }
}
