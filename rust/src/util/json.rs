//! Minimal JSON support (no external dependencies are available in this
//! environment): enough to parse/emit `artifacts/manifest.json` and the
//! report layer's figure data files.

use crate::util::error::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Build an object from pairs (ergonomic constructor for emitters).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.get("d"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"artifacts":[{"file":"g.hlo.txt","inputs":[[8,8],[8,8]],"name":"gemm"}]}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
        let s = Json::Str("a\"b\\c\n".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\n"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
