//! Miniature property-testing harness (the vendored environment has no
//! proptest): deterministic splitmix64 case generation with seed reporting
//! on failure, so any failing case is reproducible from the panic message.
//! Also home to [`run_functional`], the shared run-a-plan shorthand of the
//! test suites.

use crate::exec::FunctionalExec;
use crate::mem::MemPool;
use crate::plan::verify::{verify, VerifyCtx};
use crate::plan::Plan;

/// Run a plan to completion on the functional executor, panicking on
/// deadlock or on an effect error — the shared shorthand that replaces
/// the `FunctionalExec::new(&mut pool).run(&plan).unwrap()` boilerplate
/// across the test suites.
///
/// Before executing, the plan is statically verified
/// ([`crate::plan::verify`]) against the pool: any deadlock, data race,
/// out-of-bounds view, or shape-mismatched effect panics here with the
/// finding list, so every functional test doubles as a verifier fixture.
pub fn run_functional(pool: &mut MemPool, plan: &Plan) {
    verify(plan, &VerifyCtx::functional(pool)).assert_clean("functional plan");
    FunctionalExec::new(pool).run(plan).unwrap();
}

/// Deterministic RNG for property cases.
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32_pm1(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }

    /// Random f32 vector in [-1, 1).
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_pm1()).collect()
    }
}

/// Run `cases` deterministic property cases; the case seed is passed so a
/// failure can be replayed (`case(Rng::new(seed))`).
pub fn run_prop(name: &str, cases: u64, mut case: impl FnMut(&mut Rng) -> Result<(), String>) {
    for i in 0..cases {
        let seed = 0xC0FFEE ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = case(&mut rng) {
            panic!("property '{name}' failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let v = r.usize_in(3, 17);
            assert!((3..17).contains(&v));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    #[should_panic(expected = "property 'demo' failed")]
    fn failures_report_seed() {
        run_prop("demo", 10, |rng| {
            if rng.usize_in(0, 4) == 3 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }
}
