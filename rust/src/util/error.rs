//! Minimal string-backed error type with the `anyhow` surface this crate
//! actually uses (`anyhow!`, `bail!`, `Context`, `Result`).
//!
//! Replacing the `anyhow` dependency makes the workspace build with
//! **zero registry dependencies**: the committed `Cargo.lock` is exact
//! without any network access, CI's cargo cache key
//! (`hashFiles('**/Cargo.lock')`) is meaningful, and nothing is ever
//! re-resolved against crates.io. The crate never downcast errors or
//! walked cause chains — every use site formats a message — so a string
//! payload loses nothing.

use std::fmt;

/// A message-carrying error. Like `anyhow::Error`, this intentionally
/// does **not** implement `std::error::Error` — that is what permits the
/// blanket `From` conversion below without colliding with the identity
/// `From<Error>`.
pub struct Error(String);

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands
    /// to).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// Any concrete `std::error::Error` converts by formatting — this is
/// what makes `?` work on `Utf8Error`, `ParseFloatError`, `io::Error`,
/// channel errors, ….
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a failure, mirroring `anyhow::Context` for both
/// `Result` (context is prepended: `"{ctx}: {err}"`) and `Option`
/// (context becomes the whole message).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// `anyhow!(...)` — build an [`Error`] from a format string, or from any
/// single displayable expression (the three arms mirror `anyhow`'s).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `bail!(fmt, ...)` — return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

// re-export the crate-root macros under this module's path, so call
// sites can `use crate::util::error::{anyhow, bail}` like they did with
// the external crate
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_both(a: &str, b: &str) -> Result<(f64, usize)> {
        // exercises the blanket From conversions through `?`
        let x: f64 = a.parse()?;
        let y: usize = b.parse()?;
        if y == 0 {
            bail!("y must be positive, got {y}");
        }
        Ok((x, y))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_both("1.5", "3").unwrap(), (1.5, 3));
        let e = parse_both("nope", "3").unwrap_err();
        assert!(e.to_string().contains("invalid float"), "{e}");
    }

    #[test]
    fn bail_and_anyhow_format() {
        let e = parse_both("1.0", "0").unwrap_err();
        assert_eq!(e.to_string(), "y must be positive, got 0");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e:?}"), "code 7");
    }

    #[test]
    fn expr_arm_takes_preformatted_messages() {
        // the `anyhow!(msg)` form used by coordinator::node
        let msg = String::from("already formatted");
        let e = anyhow!(msg);
        assert_eq!(e.to_string(), "already formatted");
    }

    #[test]
    fn context_on_results_and_options() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing table").unwrap_err();
        assert!(e.to_string().starts_with("writing table: "));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing slot {}", 4)).unwrap_err();
        assert_eq!(e.to_string(), "missing slot 4");
        assert_eq!(Some(5).context("fine").unwrap(), 5);
    }
}
