//! The P2P and network-accelerated communication primitives (§3.2.2).
//!
//! All are tile-granular and device-initiated. P2P primitives are
//! *asynchronous and single-threaded* (TMA): the issuing worker proceeds
//! immediately and an optional semaphore fires at completion — this is what
//! makes intra-SM overlap possible. Network-accelerated primitives
//! (multimem) require warp participation and are *blocking* on the issuing
//! (communicator) worker, matching the paper's API.

use crate::hw::cluster::ClusterSpec;
use crate::hw::spec::GpuSpec;
use crate::hw::DeviceId;
use crate::mem::pgl::ReduceOp;
use crate::mem::ELEM_BYTES;
use crate::plan::{Effect, MatView, Op, Plan, Route, SemId, SyncScope, TransferSpec};
use crate::xfer::Mechanism;

/// A tile view plus the device that owns the underlying buffer.
#[derive(Clone, Copy, Debug)]
pub struct TileRef {
    pub view: MatView,
    pub dev: DeviceId,
}

impl TileRef {
    pub fn new(view: MatView, dev: DeviceId) -> Self {
        TileRef { view, dev }
    }

    fn bytes(&self) -> f64 {
        (self.view.rows * self.view.cols) as f64 * ELEM_BYTES as f64
    }
}

/// TMA message size for a tile: one message per tile, clamped to the SMEM
/// bound (larger tiles are chopped into max-size messages by hardware).
fn tma_msg(spec: &GpuSpec, bytes: f64) -> f64 {
    bytes.min(spec.tma_max_msg as f64)
}

/// `store_async(dst, src, coord)` — asynchronously store a shared tile to
/// (possibly peer) memory via TMA. Single-thread launch; `done` (if given)
/// is signalled on completion with intra-SM (mbarrier) latency.
pub fn store_async(
    plan: &mut Plan,
    spec: &GpuSpec,
    w: usize,
    src: TileRef,
    dst: TileRef,
    done: Option<SemId>,
) {
    let bytes = src.bytes();
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Tma,
                route: Route::P2p { src: src.dev, dst: dst.dev },
                bytes,
                msg_bytes: tma_msg(spec, bytes),
                n_sms: 1.0, // single SM issues; rate cap is per-SM TMA
            },
            blocking: false,
            done_sem: done,
            done_scope: SyncScope::IntraSm,
            label: "store_async",
            effect: Some(Effect::CopyMat { src: src.view, dst: dst.view, reduce: None }),
        },
    );
}

/// Locality-routed `store_async`: NVLink P2P when `src` and `dst` share a
/// node, GPUDirect RDMA across nodes. On a one-node cluster this emits
/// exactly what [`store_async`] emits (the regression guarantee every
/// single-node kernel relies on). RDMA keeps TMA's issue semantics — the
/// proxy posts the write and the worker proceeds — but the completion
/// signal pays the fabric's latency, and the rate comes from the NIC
/// curve, not the NVLink mechanism curves.
pub fn store_async_routed(
    plan: &mut Plan,
    cluster: &ClusterSpec,
    w: usize,
    src: TileRef,
    dst: TileRef,
    done: Option<SemId>,
) {
    if cluster.same_node(src.dev, dst.dev) {
        store_async(plan, &cluster.node.gpu, w, src, dst, done);
        return;
    }
    let bytes = src.bytes();
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Tma,
                route: Route::Rdma { src: src.dev, dst: dst.dev },
                bytes,
                msg_bytes: bytes, // one RDMA write per tile
                n_sms: 1.0,
            },
            blocking: false,
            done_sem: done,
            done_scope: SyncScope::InterNode,
            label: "store_async_rdma",
            effect: Some(Effect::CopyMat { src: src.view, dst: dst.view, reduce: None }),
        },
    );
}

/// Locality-routed `store_add_async` (see [`store_async_routed`]). The
/// cross-node path lands the payload with an RDMA write and performs the
/// addition on the destination GPU, so it pays the same atomic
/// destination-side inflation as the NVLink path.
pub fn store_add_async_routed(
    plan: &mut Plan,
    cluster: &ClusterSpec,
    w: usize,
    src: TileRef,
    dst: TileRef,
    done: Option<SemId>,
) {
    if cluster.same_node(src.dev, dst.dev) {
        store_add_async(plan, &cluster.node.gpu, w, src, dst, done);
        return;
    }
    let bytes = src.bytes() * (1.0 + cluster.node.gpu.atomic_overhead_frac);
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Tma,
                route: Route::Rdma { src: src.dev, dst: dst.dev },
                bytes,
                msg_bytes: src.bytes(),
                n_sms: 1.0,
            },
            blocking: false,
            done_sem: done,
            done_scope: SyncScope::InterNode,
            label: "store_add_async_rdma",
            effect: Some(Effect::CopyMat { src: src.view, dst: dst.view, reduce: Some(ReduceOp::Add) }),
        },
    );
}

/// `store_add_async(dst, src, coord)` — asynchronous TMA store with atomic
/// add at the destination. The atomic pays extra destination-side cost
/// (§3.1.3: the residual communication near the K threshold in Table 3
/// comes from these), modelled by inflating the transferred bytes.
pub fn store_add_async(
    plan: &mut Plan,
    spec: &GpuSpec,
    w: usize,
    src: TileRef,
    dst: TileRef,
    done: Option<SemId>,
) {
    store_add_async_scoped(plan, spec, w, src, dst, done, SyncScope::IntraSm)
}

/// [`store_add_async`] with an explicit completion-flag scope. The default
/// primitive signals its own SM's mbarrier; when the completion is
/// consumed by a worker on *another* device — the node-aggregator pattern
/// of [`crate::pk::rail`]'s pre-reduce stage, where contributors add
/// partials into the aggregator's staging area and the aggregator's rail
/// worker waits for them — the flag must instead pay the
/// [`SyncScope::InterDevice`] NVLink-flag latency. The transfer itself is
/// identical.
pub fn store_add_async_scoped(
    plan: &mut Plan,
    spec: &GpuSpec,
    w: usize,
    src: TileRef,
    dst: TileRef,
    done: Option<SemId>,
    done_scope: SyncScope,
) {
    let bytes = src.bytes() * (1.0 + spec.atomic_overhead_frac);
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Tma,
                route: Route::P2p { src: src.dev, dst: dst.dev },
                bytes,
                msg_bytes: tma_msg(spec, src.bytes()),
                n_sms: 1.0,
            },
            blocking: false,
            done_sem: done,
            done_scope,
            label: "store_add_async",
            effect: Some(Effect::CopyMat { src: src.view, dst: dst.view, reduce: Some(ReduceOp::Add) }),
        },
    );
}

/// Asynchronous in-fabric multicast store: writes `src` to the same region
/// of every replica in `dsts` with one egress-side message (NVSwitch
/// broadcast; §3.2.1 "multicast to multiple devices").
pub fn multicast_store_async(
    plan: &mut Plan,
    spec: &GpuSpec,
    w: usize,
    src: TileRef,
    dsts: Vec<MatView>,
    reduce: Option<ReduceOp>,
    done: Option<SemId>,
) {
    let bytes = src.bytes() * if reduce.is_some() { 1.0 + spec.atomic_overhead_frac } else { 1.0 };
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Tma,
                route: Route::Multicast { src: src.dev },
                bytes,
                msg_bytes: tma_msg(spec, src.bytes()),
                n_sms: 1.0,
            },
            blocking: false,
            done_sem: done,
            done_scope: SyncScope::IntraSm,
            label: "multicast_store",
            effect: Some(Effect::MulticastMat { src: src.view, dsts, reduce }),
        },
    );
}

/// `reduce(dst, dst_coord, src, src_coord)` — in-fabric reduction from
/// multicast memory (`srcs`: the per-device replicas of a PGL region) into
/// local HBM. Collectively launched by `n_sms` worth of warps on the
/// calling worker; blocking (register-level multimem.ld_reduce).
pub fn reduce(
    plan: &mut Plan,
    _spec: &GpuSpec,
    w: usize,
    srcs: Vec<MatView>,
    dst: TileRef,
    op: ReduceOp,
    n_sms: f64,
) {
    let bytes = dst.bytes();
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Multimem,
                route: Route::LdReduce { reader: dst.dev },
                bytes,
                msg_bytes: 128.0 * 8.0, // multimem.ld_reduce vector width per warp access
                n_sms,
            },
            blocking: true,
            done_sem: None,
            done_scope: SyncScope::IntraSm,
            label: "reduce",
            effect: Some(Effect::LdReduceMat { srcs, dst: dst.view, op }),
        },
    );
}

/// `all_reduce(dst_and_src, coord)` — in-fabric all-reduce of a PGL tile:
/// `ld_reduce` the replicas, then multicast the reduced tile back, leaving
/// every device with the sum. Blocking, warp-collective (§3.2.2).
///
/// `replicas[d]` must be the view of the tile on device `d`; `me` is the
/// executing device (the reader/writer).
pub fn all_reduce(
    plan: &mut Plan,
    spec: &GpuSpec,
    w: usize,
    replicas: Vec<MatView>,
    me: DeviceId,
    op: ReduceOp,
    n_sms: f64,
) {
    let mine = replicas[me.0];
    let bytes = (mine.rows * mine.cols) as f64 * ELEM_BYTES as f64;
    // Phase 1: in-fabric reduce into the local replica.
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Multimem,
                route: Route::LdReduce { reader: me },
                bytes,
                msg_bytes: 128.0 * 8.0,
                n_sms,
            },
            blocking: true,
            done_sem: None,
            done_scope: SyncScope::IntraSm,
            label: "all_reduce/ld",
            effect: Some(Effect::LdReduceMat { srcs: replicas.clone(), dst: mine, op }),
        },
    );
    // Phase 2: multicast the reduced tile back to every replica.
    let others: Vec<MatView> = replicas
        .iter()
        .enumerate()
        .filter(|(d, _)| *d != me.0)
        .map(|(_, v)| *v)
        .collect();
    plan.push(
        w,
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Multimem,
                route: Route::Multicast { src: me },
                bytes,
                msg_bytes: 128.0 * 8.0,
                n_sms,
            },
            blocking: true,
            done_sem: None,
            done_scope: SyncScope::IntraSm,
            label: "all_reduce/mc",
            effect: Some(Effect::MulticastMat { src: mine, dsts: others, reduce: None }),
        },
    );
    let _ = spec;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::TimedExec;
    use crate::util::prop::run_functional;
    use crate::hw::spec::NodeSpec;
    use crate::mem::tile::Shape4;
    use crate::mem::MemPool;
    use crate::plan::Role;
    use crate::util::seeded_vec;

    #[test]
    fn store_async_moves_tile_and_signals() {
        let mut pool = MemPool::new();
        let a = pool.alloc_init(DeviceId(0), Shape4::mat(16, 16), seeded_vec(1, 256));
        let b = pool.alloc(DeviceId(1), Shape4::mat(16, 16));
        let node = NodeSpec::test_node(2);
        let mut plan = Plan::new();
        let done = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "sm");
        store_async(
            &mut plan,
            &node.gpu,
            w,
            TileRef::new(MatView::full2d(a, 16, 16), DeviceId(0)),
            TileRef::new(MatView::full2d(b, 16, 16), DeviceId(1)),
            Some(done),
        );
        plan.push(w, Op::Wait { sem: done, value: 1 });
        run_functional(&mut pool, &plan);
        assert_eq!(pool.get(a).data, pool.get(b).data);
        // timed run completes and moves the right bytes
        let r = TimedExec::new(node).run(&plan);
        assert!((r.egress_bytes(0) - 512.0).abs() < 1.0); // 16*16*2 bytes
    }

    #[test]
    fn store_add_async_accumulates_and_inflates_bytes() {
        let mut pool = MemPool::new();
        let a = pool.alloc_init(DeviceId(0), Shape4::mat(16, 16), vec![1.0; 256]);
        let b = pool.alloc_init(DeviceId(1), Shape4::mat(16, 16), vec![2.0; 256]);
        let node = NodeSpec::test_node(2);
        let mut plan = Plan::new();
        let done = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "sm");
        store_add_async(
            &mut plan,
            &node.gpu,
            w,
            TileRef::new(MatView::full2d(a, 16, 16), DeviceId(0)),
            TileRef::new(MatView::full2d(b, 16, 16), DeviceId(1)),
            Some(done),
        );
        plan.push(w, Op::Wait { sem: done, value: 1 });
        run_functional(&mut pool, &plan);
        assert!(pool.get(b).data.iter().all(|v| *v == 3.0));
        let r = TimedExec::new(node).run(&plan);
        let expect = 512.0 * 1.15; // atomic inflation
        assert!((r.egress_bytes(0) - expect).abs() < 1.0, "{}", r.egress_bytes(0));
    }

    #[test]
    fn routed_store_picks_nvlink_or_rdma_by_locality() {
        use crate::hw::topology::Port;
        let cluster = ClusterSpec::test_cluster(2, 2);
        let mut pool = MemPool::new();
        let a = pool.alloc_init(DeviceId(0), Shape4::mat(16, 16), seeded_vec(7, 256));
        let local = pool.alloc(DeviceId(1), Shape4::mat(16, 16)); // same node
        let remote = pool.alloc(DeviceId(2), Shape4::mat(16, 16)); // other node
        let mut plan = Plan::new();
        let done = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "sm");
        let src = TileRef::new(MatView::full2d(a, 16, 16), DeviceId(0));
        store_async_routed(&mut plan, &cluster, w, src, TileRef::new(MatView::full2d(local, 16, 16), DeviceId(1)), Some(done));
        store_async_routed(&mut plan, &cluster, w, src, TileRef::new(MatView::full2d(remote, 16, 16), DeviceId(2)), Some(done));
        plan.push(w, Op::Wait { sem: done, value: 2 });
        run_functional(&mut pool, &plan);
        assert_eq!(pool.get(a).data, pool.get(local).data);
        assert_eq!(pool.get(a).data, pool.get(remote).data);
        let r = crate::exec::TimedExec::on_cluster(cluster).run(&plan);
        // one tile over NVLink, one over the NIC
        assert!((r.port_bytes[&Port::Egress(DeviceId(0))] - 512.0).abs() < 1.0);
        assert!((r.port_bytes[&Port::NicEgress(DeviceId(0))] - 512.0).abs() < 1.0);
        assert!((r.port_bytes[&Port::NicIngress(DeviceId(2))] - 512.0).abs() < 1.0);
    }

    #[test]
    fn routed_store_add_accumulates_across_nodes() {
        let cluster = ClusterSpec::test_cluster(2, 2);
        let mut pool = MemPool::new();
        let a = pool.alloc_init(DeviceId(0), Shape4::mat(16, 16), vec![1.0; 256]);
        let b = pool.alloc_init(DeviceId(3), Shape4::mat(16, 16), vec![2.0; 256]);
        let mut plan = Plan::new();
        let done = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "sm");
        store_add_async_routed(
            &mut plan,
            &cluster,
            w,
            TileRef::new(MatView::full2d(a, 16, 16), DeviceId(0)),
            TileRef::new(MatView::full2d(b, 16, 16), DeviceId(3)),
            Some(done),
        );
        plan.push(w, Op::Wait { sem: done, value: 1 });
        run_functional(&mut pool, &plan);
        assert!(pool.get(b).data.iter().all(|v| *v == 3.0));
    }

    #[test]
    fn multicast_store_reaches_all_devices() {
        let mut pool = MemPool::new();
        let n_dev = 4;
        let src = pool.alloc_init(DeviceId(0), Shape4::mat(16, 16), seeded_vec(2, 256));
        let dsts: Vec<_> = (0..n_dev).map(|d| pool.alloc(DeviceId(d), Shape4::mat(16, 16))).collect();
        let node = NodeSpec::test_node(n_dev);
        let mut plan = Plan::new();
        let done = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "comm");
        multicast_store_async(
            &mut plan,
            &node.gpu,
            w,
            TileRef::new(MatView::full2d(src, 16, 16), DeviceId(0)),
            dsts.iter().map(|&b| MatView::full2d(b, 16, 16)).collect(),
            None,
            Some(done),
        );
        plan.push(w, Op::Wait { sem: done, value: 1 });
        run_functional(&mut pool, &plan);
        for &b in &dsts {
            assert_eq!(pool.get(b).data, pool.get(src).data);
        }
        // one egress message, N ingress deliveries
        let r = TimedExec::new(node).run(&plan);
        assert!((r.egress_bytes(0) - 512.0).abs() < 1.0);
    }

    #[test]
    fn all_reduce_sums_replicas_everywhere() {
        let mut pool = MemPool::new();
        let n_dev = 8;
        let bufs: Vec<_> = (0..n_dev)
            .map(|d| pool.alloc_init(DeviceId(d), Shape4::mat(16, 16), vec![(d + 1) as f32; 256]))
            .collect();
        let node = NodeSpec::test_node(n_dev);
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(3), Role::CommSm, "comm");
        all_reduce(
            &mut plan,
            &node.gpu,
            w,
            bufs.iter().map(|&b| MatView::full2d(b, 16, 16)).collect(),
            DeviceId(3),
            ReduceOp::Add,
            2.0,
        );
        run_functional(&mut pool, &plan);
        let want = (1..=n_dev).sum::<usize>() as f32; // 36
        for &b in &bufs {
            assert!(pool.get(b).data.iter().all(|v| *v == want), "device missing reduced value");
        }
    }

    #[test]
    fn reduce_into_local_hbm() {
        let mut pool = MemPool::new();
        let n_dev = 4;
        let bufs: Vec<_> = (0..n_dev)
            .map(|d| pool.alloc_init(DeviceId(d), Shape4::mat(16, 16), vec![2.0 * (d + 1) as f32; 256]))
            .collect();
        let out = pool.alloc(DeviceId(1), Shape4::mat(16, 16));
        let node = NodeSpec::test_node(n_dev);
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(1), Role::CommSm, "comm");
        reduce(
            &mut plan,
            &node.gpu,
            w,
            bufs.iter().map(|&b| MatView::full2d(b, 16, 16)).collect(),
            TileRef::new(MatView::full2d(out, 16, 16), DeviceId(1)),
            ReduceOp::Max,
            2.0,
        );
        run_functional(&mut pool, &plan);
        assert!(pool.get(out).data.iter().all(|v| *v == 8.0));
    }
}
