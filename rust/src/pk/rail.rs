//! `pk::rail` — the reusable hierarchical-transport subsystem.
//!
//! Every cross-node kernel in this codebase moves data the same way: flows
//! bound for a *remote* node are **coalesced into one GPUDirect RDMA write
//! per (source device, destination node) pair**, sent along the source's
//! rail to its rail peer (the same-rank GPU of the destination node), and
//! a *forwarder* worker on the peer fans the payload out to its final
//! destinations over NVLink, crediting consumers as pieces land. The
//! pattern was introduced by the cluster MoE dispatch
//! ([`crate::kernels::moe::build_cluster`]); this module lifts it into the
//! framework layer so gemm_rs, the two-level all-to-all / Ulysses, and the
//! MoE combine hop share one implementation instead of hand-rolling it —
//! the paper's thesis (a small set of reusable primitives, not
//! operator-specific tricks) applied to the scale-out layer.
//!
//! The pieces:
//!
//! * [`RailPlanner`] — per-(source device, remote node) coalesced RDMA
//!   flows along the source's rail, wave-chunked by an `rdma_chunk` target
//!   write size ([`RailPlanner::send`] / [`RailPlanner::send_add`],
//!   [`RailPlanner::waves`]).
//! * [`RailSems`] — the per-(source device, destination node) wave
//!   counters every rail protocol synchronizes on: bumped once per wave
//!   (even empty waves, so thresholds stay uniform), waited on by both the
//!   source's wave barrier and the rail-peer forwarder.
//! * [`WaveCredits`] — the wave-barrier bookkeeping of a fan-out stage:
//!   async transfers drain into per-transfer semaphores, and `flush` waits
//!   for each and posts its per-destination credits.
//! * [`wave_share`] / [`rail_waves`] — the exact wave-split arithmetic
//!   (last wave takes the remainder, so per-wave waits never starve on
//!   rounding).
//! * [`RailHealth`] — a per-device NIC health mask for degraded fabrics:
//!   when a flow's source or destination rail endpoint is marked failed,
//!   the planner reroutes it **over NVLink first** to a healthy same-node
//!   donor, ships it on the donor's rail, and (if the receiving endpoint
//!   was the failed one) fans it back over NVLink on the destination node.
//!   Reroutes round-robin across the `P-1` healthy rails so a NIC-bound
//!   schedule degrades by `P/(P-1)`, not `×2`; the rerouted plan stays
//!   [`crate::plan::verify`]-clean and bit-identical in functional output
//!   to the healthy schedule (only the transport moved, never the data).
//! * An optional **node-local pre-reduce** stage for reducible payloads
//!   (gemm_rs partial sums, MoE combine rows): contributors
//!   `store_add_async` their partials over NVLink into the node
//!   aggregator's staging area
//!   ([`crate::pk::primitives::store_add_async_scoped`], crediting the
//!   aggregator with [`SyncScope::InterDevice`] flags), and the aggregator
//!   ships one pre-reduced flow per node pair — ×P less NIC traffic than
//!   per-device sends.

use crate::hw::cluster::ClusterSpec;
use crate::hw::DeviceId;
use crate::plan::{Effect, Op, Plan, Role, Route, SemId, SyncScope, TransferSpec};
use crate::xfer::Mechanism;
use std::cell::RefCell;
use std::collections::HashMap;

/// Default coalesced RDMA write target: 4 MiB sits on the flat part of the
/// RDMA message-size curve while still giving several overlap waves at
/// realistic payload sizes. Kept as the fixed-chunk reference; kernel
/// configs now default to [`RDMA_CHUNK_AUTO`] instead.
pub const DEFAULT_RDMA_CHUNK: f64 = 4.0 * 1024.0 * 1024.0;

/// Sentinel for the `rdma_chunk` knob of every rail kernel: resolve the
/// coalesced write size analytically at build time from the cluster's
/// RDMA curve — the knee located by
/// [`crate::pk::tuner::analytic_rdma_chunk`], threaded through
/// [`crate::pk::tuner::resolve_rdma_chunk`]. Explicit positive values
/// (tuner sweeps, ablations) bypass the analytic policy.
pub const RDMA_CHUNK_AUTO: f64 = 0.0;

/// Upper bound on rail-flow waves (keeps event counts tractable at
/// paper-scale payloads).
pub const MAX_WAVES: usize = 16;

/// Wave `wave`'s share of `total` units split over `waves` waves: every
/// wave takes `total / waves`, the last additionally takes the remainder —
/// so the shares partition `total` exactly and cumulative-count waiters
/// never starve on rounding.
pub fn wave_share(total: u64, wave: usize, waves: usize) -> u64 {
    debug_assert!(wave < waves);
    let base = total / waves as u64;
    if wave == waves - 1 {
        total - base * (waves as u64 - 1)
    } else {
        base
    }
}

/// Wave count targeting one `rdma_chunk`-sized write per rail flow per
/// wave, clamped to `[min_waves, max_waves]`. Smaller chunks mean more
/// waves — finer compute/comm overlap but less efficient NIC messages;
/// the cluster tuner co-tunes the chunk with the SM partition
/// ([`crate::pk::tuner::tune_comm_sms_rdma_chunk`]).
pub fn rail_waves(max_flow_bytes: f64, rdma_chunk: f64, min_waves: usize, max_waves: usize) -> usize {
    assert!(rdma_chunk > 0.0, "rdma_chunk must be positive");
    assert!(min_waves >= 1 && min_waves <= max_waves);
    ((max_flow_bytes / rdma_chunk).ceil() as usize).clamp(min_waves, max_waves)
}

/// One non-empty wave of a rail flow (see [`live_waves`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LiveWave {
    /// Index among the *live* (non-empty) waves, 0-based — the wave
    /// counter value a consumer waits for is `idx + 1`.
    pub idx: u64,
    /// This wave's share of the flow's units ([`wave_share`]).
    pub share: u64,
    /// Cumulative units through this wave — producers gate on
    /// per-unit contribution counters at `contributors × cum`.
    pub cum: u64,
}

/// The non-empty waves of a flow of `total` units split over `waves`
/// waves, with the cumulative/counter arithmetic every rail protocol
/// repeats (sender wave loops, forwarder wave waits, wave-count targets).
/// Centralizing it keeps a producer's send count and its consumers' wait
/// thresholds from drifting apart at different call sites.
pub fn live_waves(total: u64, waves: usize) -> Vec<LiveWave> {
    let mut out = Vec::with_capacity(waves);
    let mut cum = 0u64;
    for w in 0..waves {
        let share = wave_share(total, w, waves);
        cum += share;
        if share > 0 {
            out.push(LiveWave { idx: out.len() as u64, share, cum });
        }
    }
    out
}

/// Per-(source device, destination node) wave counters for the rail flows
/// of one kernel: `done[src][node]` is bumped once per wave by the source's
/// coalesced RDMA write landing, and waited on by both the source's own
/// wave barrier and the rail-peer forwarder.
pub struct RailSems {
    pub done: Vec<Vec<SemId>>,
}

impl RailSems {
    /// One counter per (global device, node), allocated in device-major
    /// order.
    pub fn alloc(plan: &mut Plan, cluster: &ClusterSpec) -> Self {
        let n = cluster.total_devices();
        let k = cluster.num_nodes;
        RailSems {
            done: (0..n).map(|_| (0..k).map(|_| plan.add_sem(0)).collect()).collect(),
        }
    }
}

/// Per-device NIC health mask. A failed NIC takes the device's rail out
/// of service in **both** directions (its GPUDirect engine serves egress
/// and ingress alike); the device itself — SMs, HBM, NVLink ports — stays
/// healthy, which is exactly what makes NVLink-first rerouting possible.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RailHealth {
    nic_ok: Vec<bool>,
}

impl RailHealth {
    /// Every NIC up — the mask [`RailPlanner::new`] starts from.
    pub fn all_healthy(cluster: &ClusterSpec) -> Self {
        RailHealth { nic_ok: vec![true; cluster.total_devices()] }
    }

    /// Mark device `dev`'s NIC failed (builder-style).
    pub fn fail_nic(mut self, dev: usize) -> Self {
        assert!(dev < self.nic_ok.len(), "no device {dev} in this cluster");
        self.nic_ok[dev] = false;
        self
    }

    pub fn is_healthy(&self, d: DeviceId) -> bool {
        self.nic_ok[d.0]
    }

    pub fn any_failed(&self) -> bool {
        self.nic_ok.iter().any(|ok| !ok)
    }

    /// Global indices of the failed-NIC devices.
    pub fn failed(&self) -> Vec<usize> {
        (0..self.nic_ok.len()).filter(|&d| !self.nic_ok[d]).collect()
    }

    /// Restrict the mask to the contiguous device window
    /// `[dev0, dev0 + n_dev)` — the view a pipeline stage occupying that
    /// slice of the cluster sees, in the stage's own device numbering.
    pub fn restrict(&self, dev0: usize, n_dev: usize) -> RailHealth {
        assert!(dev0 + n_dev <= self.nic_ok.len(), "window exceeds cluster");
        RailHealth { nic_ok: self.nic_ok[dev0..dev0 + n_dev].to_vec() }
    }

    /// Local ranks with a healthy NIC on `node` — the reroute donor pool.
    fn healthy_ranks(&self, cluster: &ClusterSpec, node: usize) -> Vec<usize> {
        (0..cluster.devices_per_node())
            .filter(|&r| self.nic_ok[cluster.device(node, r).0])
            .collect()
    }
}

/// A lazily created reroute worker on a donor device: waits on a
/// cumulative handoff counter and forwards each landed piece (RDMA on the
/// source side, NVLink delivery on the destination side). Ops are pushed
/// in planner-call order, so per-forwarder waits are monotone — the
/// reroute protocol cannot deadlock.
struct Forwarder {
    w: usize,
    sem: SemId,
    cnt: u64,
}

/// Side tags for the forwarder map (one device can forward for both).
const FWD_TX: u8 = 0;
const FWD_RX: u8 = 1;

#[derive(Default)]
struct RerouteState {
    /// Round-robin cursor over donor ranks — spreads a failed rail's
    /// flows across all healthy rails instead of doubling one NIC.
    rr: usize,
    fwd: HashMap<(u8, usize), Forwarder>,
}

/// Planner for per-rail coalesced RDMA flows: one flow per (source device,
/// remote node) pair, addressed to the source's rail peer, with messages
/// capped at `rdma_chunk`. With a [`RailHealth`] mask attached
/// ([`RailPlanner::with_health`]), flows whose rail endpoint NICs are
/// failed are transparently rerouted; a planner instance accumulates
/// forwarder workers in the plan it is used with, so use one planner per
/// plan.
pub struct RailPlanner<'a> {
    pub cluster: &'a ClusterSpec,
    pub rdma_chunk: f64,
    health: RailHealth,
    reroute: RefCell<RerouteState>,
}

impl<'a> RailPlanner<'a> {
    pub fn new(cluster: &'a ClusterSpec, rdma_chunk: f64) -> Self {
        assert!(rdma_chunk > 0.0, "rdma_chunk must be positive");
        RailPlanner {
            cluster,
            rdma_chunk,
            health: RailHealth::all_healthy(cluster),
            reroute: RefCell::new(RerouteState::default()),
        }
    }

    /// Attach a NIC health mask; flows touching failed rails reroute.
    pub fn with_health(mut self, health: RailHealth) -> Self {
        assert_eq!(
            health.nic_ok.len(),
            self.cluster.total_devices(),
            "health mask sized for a different cluster"
        );
        self.health = health;
        self
    }

    pub fn health(&self) -> &RailHealth {
        &self.health
    }

    /// The source's rail peer on `dst_node`: the same-rank GPU, reachable
    /// through the rail's switch plane without crossing rails.
    pub fn peer(&self, src: DeviceId, dst_node: usize) -> DeviceId {
        self.cluster.device(dst_node, self.cluster.local_rank(src))
    }

    /// [`rail_waves`] against this planner's chunk size.
    pub fn waves(&self, max_flow_bytes: f64, min_waves: usize, max_waves: usize) -> usize {
        rail_waves(max_flow_bytes, self.rdma_chunk, min_waves, max_waves)
    }

    /// Emit one coalesced RDMA write along the source's rail: `bytes` to
    /// the rail peer of `src` on `dst_node`, in `rdma_chunk`-capped
    /// messages. Asynchronous; `done` (if any) is bumped with
    /// [`SyncScope::InterNode`] latency — the wave counter both the
    /// source's barrier and the peer's forwarder consume.
    #[allow(clippy::too_many_arguments)]
    pub fn send(
        &self,
        plan: &mut Plan,
        w: usize,
        src: DeviceId,
        dst_node: usize,
        bytes: f64,
        n_sms: f64,
        done: Option<SemId>,
        label: &'static str,
        effect: Option<Effect>,
    ) {
        self.emit(plan, w, src, dst_node, bytes, bytes, n_sms, done, label, effect);
    }

    /// [`RailPlanner::send`] with store-add semantics at the destination
    /// (the rail hop of a pre-reduced payload): the landed bytes pay the
    /// same atomic destination-side inflation as
    /// [`crate::pk::primitives::store_add_async`], while message sizing
    /// stays on the raw payload.
    #[allow(clippy::too_many_arguments)]
    pub fn send_add(
        &self,
        plan: &mut Plan,
        w: usize,
        src: DeviceId,
        dst_node: usize,
        raw_bytes: f64,
        n_sms: f64,
        done: Option<SemId>,
        label: &'static str,
        effect: Option<Effect>,
    ) {
        let wire = raw_bytes * (1.0 + self.cluster.node.gpu.atomic_overhead_frac);
        self.emit(plan, w, src, dst_node, raw_bytes, wire, n_sms, done, label, effect);
    }

    /// Shared emission path of [`RailPlanner::send`] / [`RailPlanner::send_add`]:
    /// `raw_bytes` sizes messages, `wire_bytes` is what actually crosses
    /// each link (atomic-inflated for store-add payloads). Healthy rails
    /// emit the single coalesced RDMA write unchanged; a failed endpoint
    /// triggers the NVLink-first reroute (see [`RailHealth`]).
    #[allow(clippy::too_many_arguments)]
    fn emit(
        &self,
        plan: &mut Plan,
        w: usize,
        src: DeviceId,
        dst_node: usize,
        raw_bytes: f64,
        wire_bytes: f64,
        n_sms: f64,
        done: Option<SemId>,
        label: &'static str,
        effect: Option<Effect>,
    ) {
        let final_dst = self.peer(src, dst_node);
        let msg = raw_bytes.min(self.rdma_chunk);
        let xfer = |route, bytes, done_sem, scope, label, effect| {
            Op::Transfer {
                spec: TransferSpec { mech: Mechanism::Tma, route, bytes, msg_bytes: msg, n_sms },
                blocking: false,
                done_sem,
                done_scope: scope,
                label,
                effect,
            }
        };
        if self.health.is_healthy(src) && self.health.is_healthy(final_dst) {
            let rail = Route::Rdma { src, dst: final_dst };
            plan.push(w, xfer(rail, wire_bytes, done, SyncScope::InterNode, label, effect));
            return;
        }
        // Degraded rail: pick healthy donor endpoints. A failed source NIC
        // hands the payload to a healthy same-node donor over NVLink; a
        // failed destination NIC lands the RDMA on a healthy device of the
        // destination node, which delivers over NVLink. Donors rotate
        // round-robin so the extra load spreads over all healthy rails.
        let mut st = self.reroute.borrow_mut();
        let mut donor = |node: usize| -> DeviceId {
            let ranks = self.health.healthy_ranks(self.cluster, node);
            assert!(!ranks.is_empty(), "every NIC on node {node} failed: rail flow cannot be rerouted");
            let r = ranks[st.rr % ranks.len()];
            st.rr += 1;
            self.cluster.device(node, r)
        };
        let tx = if self.health.is_healthy(src) { src } else { donor(self.cluster.node_of(src)) };
        let rx = if self.health.is_healthy(final_dst) { final_dst } else { donor(dst_node) };
        // (1) NVLink handoff to the sending donor, counted on the donor's
        // cumulative forwarder semaphore.
        let rdma_w = if tx == src {
            w
        } else {
            let f = forwarder(plan, &mut st, FWD_TX, tx, "rail_fwd");
            let hop = Route::P2p { src, dst: tx };
            plan.push(
                w,
                xfer(hop, raw_bytes, Some(f.sem), SyncScope::InterDevice, "rail_reroute_hop", None),
            );
            f.cnt += 1;
            let (fw, sem, cnt) = (f.w, f.sem, f.cnt);
            plan.push(fw, Op::Wait { sem, value: cnt });
            fw
        };
        // (2) the rail hop proper, on the donor's NIC. If the receiving
        // endpoint is the final destination this is also the delivery:
        // it carries the payload effect and bumps `done` exactly as the
        // healthy path would.
        let rail = Route::Rdma { src: tx, dst: rx };
        if rx == final_dst {
            plan.push(rdma_w, xfer(rail, wire_bytes, done, SyncScope::InterNode, label, effect));
            return;
        }
        let g = forwarder(plan, &mut st, FWD_RX, rx, "rail_deliver");
        let landed = g.sem;
        plan.push(rdma_w, xfer(rail, wire_bytes, Some(landed), SyncScope::InterNode, label, None));
        // (3) NVLink delivery on the destination node: the receiving donor
        // forwards into the failed device's memory. The store-add
        // inflation (if any) is paid here too — the destination-side
        // atomic cost moved from the NIC to the NVLink port.
        g.cnt += 1;
        let (gw, cnt) = (g.w, g.cnt);
        plan.push(gw, Op::Wait { sem: landed, value: cnt });
        let deliver = Route::P2p { src: rx, dst: final_dst };
        plan.push(gw, xfer(deliver, wire_bytes, done, SyncScope::InterNode, label, effect));
    }
}

/// Fetch (or lazily create) the reroute forwarder for `dev` on `side`.
fn forwarder<'s>(
    plan: &mut Plan,
    st: &'s mut RerouteState,
    side: u8,
    dev: DeviceId,
    tag: &str,
) -> &'s mut Forwarder {
    st.fwd.entry((side, dev.0)).or_insert_with(|| {
        let w = plan.add_worker(dev, Role::CommSm, format!("{tag}/d{}", dev.0));
        let sem = plan.add_sem(0);
        Forwarder { w, sem, cnt: 0 }
    })
}

/// Wave-barrier bookkeeping of a fan-out stage: each `defer` records one
/// asynchronous transfer's drain semaphore plus the credits to post once
/// it fires; `flush` waits for each drain in defer order and posts its
/// credits — so consumers (e.g. experts) are credited as soon as *their*
/// pieces land, never before.
#[derive(Default)]
pub struct WaveCredits {
    pending: Vec<(SemId, Vec<(SemId, u64)>)>,
}

impl WaveCredits {
    pub fn new() -> Self {
        WaveCredits { pending: vec![] }
    }

    /// Record one drained transfer and the `(semaphore, value)` credits it
    /// unlocks.
    pub fn defer(&mut self, drain: SemId, credits: Vec<(SemId, u64)>) {
        self.pending.push((drain, credits));
    }

    /// Wait for every deferred drain (in defer order) and post its
    /// credits at `scope` latency. Leaves the tracker empty for the next
    /// wave.
    pub fn flush(&mut self, plan: &mut Plan, w: usize, scope: SyncScope) {
        for (drain, credits) in self.pending.drain(..) {
            plan.push(w, Op::Wait { sem: drain, value: 1 });
            for (sem, value) in credits {
                plan.push(w, Op::Signal { sem, value, scope });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::TimedExec;
    use crate::util::prop::run_functional;
    use crate::hw::topology::Port;
    use crate::mem::tile::Shape4;
    use crate::mem::MemPool;
    use crate::plan::{MatView, Role};
    use crate::util::seeded_vec;

    #[test]
    fn wave_share_partitions_exactly() {
        for total in [0u64, 1, 5, 17, 1000, 12345] {
            for waves in 1..=MAX_WAVES {
                let shares: Vec<u64> = (0..waves).map(|w| wave_share(total, w, waves)).collect();
                assert_eq!(shares.iter().sum::<u64>(), total, "{total} over {waves}");
            }
        }
    }

    #[test]
    fn live_waves_partition_and_index_consistently() {
        for total in [0u64, 1, 5, 17, 1000] {
            for waves in 1..=MAX_WAVES {
                let lws = live_waves(total, waves);
                assert_eq!(lws.iter().map(|l| l.share).sum::<u64>(), total);
                assert!(lws.iter().all(|l| l.share > 0));
                let mut cum = 0;
                for (i, l) in lws.iter().enumerate() {
                    cum += l.share;
                    assert_eq!(l.cum, cum, "cumulative tracks shares");
                    assert_eq!(l.idx, i as u64, "idx counts live waves only");
                }
                if total > 0 {
                    assert_eq!(lws.last().unwrap().cum, total);
                }
            }
        }
        assert!(live_waves(0, 4).is_empty(), "empty flows have no live waves");
    }

    #[test]
    fn rail_waves_clamps_to_bounds() {
        let chunk = 1024.0;
        assert_eq!(rail_waves(0.0, chunk, 4, 16), 4, "empty flow takes the floor");
        assert_eq!(rail_waves(100.0, chunk, 1, 16), 1, "sub-chunk flow is one wave");
        assert_eq!(rail_waves(8.0 * chunk, chunk, 1, 16), 8);
        assert_eq!(rail_waves(1e9, chunk, 1, 16), 16, "huge flows hit the ceiling");
    }

    #[test]
    fn peer_is_same_rank_on_destination_node() {
        let cluster = ClusterSpec::test_cluster(3, 4);
        let rail = RailPlanner::new(&cluster, DEFAULT_RDMA_CHUNK);
        assert_eq!(rail.peer(DeviceId(1), 2), DeviceId(9));
        assert_eq!(rail.peer(DeviceId(7), 0), DeviceId(3));
    }

    #[test]
    fn send_gathers_into_stage_and_charges_the_nics() {
        // functional: a GatherRows effect lands selected rows in the rail
        // peer's stage; timed: exactly the bytes cross both endpoint NICs
        // and no NVLink port.
        let cluster = ClusterSpec::test_cluster(2, 2);
        let rail = RailPlanner::new(&cluster, DEFAULT_RDMA_CHUNK);
        let mut pool = MemPool::new();
        let src = pool.alloc_init(DeviceId(0), Shape4::mat(6, 4), seeded_vec(3, 24));
        let stage = pool.alloc(DeviceId(2), Shape4::mat(2, 4));
        let rows = vec![4usize, 1];
        let mut plan = Plan::new();
        let done = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "rail");
        rail.send(
            &mut plan,
            w,
            DeviceId(0),
            1,
            2.0 * 4.0 * crate::mem::ELEM_BYTES as f64,
            8.0,
            Some(done),
            "rail_send",
            Some(Effect::GatherRows {
                src: MatView::full2d(src, 6, 4),
                rows: rows.clone(),
                dst: MatView::full2d(stage, 2, 4),
            }),
        );
        plan.push(w, Op::Wait { sem: done, value: 1 });
        run_functional(&mut pool, &plan);
        for (i, &r) in rows.iter().enumerate() {
            let want = &pool.get(src).data[r * 4..(r + 1) * 4];
            let got = &pool.get(stage).data[i * 4..(i + 1) * 4];
            assert_eq!(got, want, "row {i}");
        }
        let r = TimedExec::on_cluster(cluster).run(&plan);
        let bytes = 2.0 * 4.0 * crate::mem::ELEM_BYTES as f64;
        assert!((r.port_bytes[&Port::NicEgress(DeviceId(0))] - bytes).abs() < 1.0);
        assert!((r.port_bytes[&Port::NicIngress(DeviceId(2))] - bytes).abs() < 1.0);
        assert!(r.port_bytes.get(&Port::Egress(DeviceId(0))).is_none());
    }

    #[test]
    fn send_add_inflates_bytes_and_reduces() {
        let cluster = ClusterSpec::test_cluster(2, 2);
        let rail = RailPlanner::new(&cluster, DEFAULT_RDMA_CHUNK);
        let mut pool = MemPool::new();
        let src = pool.alloc_init(DeviceId(1), Shape4::mat(4, 4), vec![1.5; 16]);
        let dst = pool.alloc_init(DeviceId(3), Shape4::mat(4, 4), vec![2.0; 16]);
        let raw = 16.0 * crate::mem::ELEM_BYTES as f64;
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(1), Role::CommSm, "rail");
        rail.send_add(
            &mut plan,
            w,
            DeviceId(1),
            1,
            raw,
            8.0,
            None,
            "rail_send_add",
            Some(Effect::CopyMat {
                src: MatView::full2d(src, 4, 4),
                dst: MatView::full2d(dst, 4, 4),
                reduce: Some(crate::mem::pgl::ReduceOp::Add),
            }),
        );
        run_functional(&mut pool, &plan);
        assert!(pool.get(dst).data.iter().all(|v| *v == 3.5));
        let r = TimedExec::on_cluster(cluster.clone()).run(&plan);
        let want = raw * (1.0 + cluster.node.gpu.atomic_overhead_frac);
        let got = r.port_bytes[&Port::NicEgress(DeviceId(1))];
        assert!((got - want).abs() < 1.0, "{got} vs {want}");
    }

    /// One rerouted GatherRows send, shared by the degraded-rail tests:
    /// builds the same flow as `send_gathers_into_stage_and_charges_the_nics`
    /// but under `health`, checks the functional output is bit-identical to
    /// the healthy schedule, verifies the plan node-aware, and returns the
    /// timed port-byte map for transport assertions.
    fn rerouted_gather(health: RailHealth) -> std::collections::HashMap<Port, f64> {
        let cluster = ClusterSpec::test_cluster(2, 2);
        let rail = RailPlanner::new(&cluster, DEFAULT_RDMA_CHUNK).with_health(health);
        let mut pool = MemPool::new();
        let src = pool.alloc_init(DeviceId(0), Shape4::mat(6, 4), seeded_vec(3, 24));
        let stage = pool.alloc(DeviceId(2), Shape4::mat(2, 4));
        let rows = vec![4usize, 1];
        let mut plan = Plan::new();
        let done = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "rail");
        rail.send(
            &mut plan,
            w,
            DeviceId(0),
            1,
            2.0 * 4.0 * crate::mem::ELEM_BYTES as f64,
            8.0,
            Some(done),
            "rail_send",
            Some(Effect::GatherRows {
                src: MatView::full2d(src, 6, 4),
                rows: rows.clone(),
                dst: MatView::full2d(stage, 2, 4),
            }),
        );
        plan.push(w, Op::Wait { sem: done, value: 1 });
        run_functional(&mut pool, &plan);
        for (i, &r) in rows.iter().enumerate() {
            let want = &pool.get(src).data[r * 4..(r + 1) * 4];
            let got = &pool.get(stage).data[i * 4..(i + 1) * 4];
            assert_eq!(got, want, "rerouted output must be bit-identical, row {i}");
        }
        let ctx = crate::plan::verify::VerifyCtx { pool: Some(&pool), devices_per_node: Some(2) };
        crate::plan::verify::verify(&plan, &ctx).assert_clean("rerouted rail plan");
        TimedExec::on_cluster(cluster).run(&plan).port_bytes
    }

    #[test]
    fn reroute_failed_source_rides_donor_nic() {
        // d0's NIC is down: the flow hops d0 -> d1 over NVLink and ships on
        // d1's rail straight to the original destination d2.
        let cluster = ClusterSpec::test_cluster(2, 2);
        let pb = rerouted_gather(RailHealth::all_healthy(&cluster).fail_nic(0));
        let bytes = 2.0 * 4.0 * crate::mem::ELEM_BYTES as f64;
        assert!(pb.get(&Port::NicEgress(DeviceId(0))).is_none(), "failed NIC must carry nothing");
        assert!((pb[&Port::NicEgress(DeviceId(1))] - bytes).abs() < 1.0, "donor NIC carries the flow");
        assert!((pb[&Port::Egress(DeviceId(0))] - bytes).abs() < 1.0, "NVLink handoff src->donor");
        assert!((pb[&Port::NicIngress(DeviceId(2))] - bytes).abs() < 1.0, "destination unchanged");
    }

    #[test]
    fn reroute_failed_destination_delivers_over_nvlink() {
        // d2's NIC is down: the RDMA lands on d3 and d3 forwards over
        // NVLink into d2's memory.
        let cluster = ClusterSpec::test_cluster(2, 2);
        let pb = rerouted_gather(RailHealth::all_healthy(&cluster).fail_nic(2));
        let bytes = 2.0 * 4.0 * crate::mem::ELEM_BYTES as f64;
        assert!((pb[&Port::NicEgress(DeviceId(0))] - bytes).abs() < 1.0, "source rail unchanged");
        assert!(pb.get(&Port::NicIngress(DeviceId(2))).is_none(), "failed NIC must carry nothing");
        assert!((pb[&Port::NicIngress(DeviceId(3))] - bytes).abs() < 1.0, "receiving donor");
        assert!((pb[&Port::Egress(DeviceId(3))] - bytes).abs() < 1.0, "NVLink delivery donor->dst");
    }

    #[test]
    fn reroute_both_endpoints_failed_takes_three_hops() {
        let cluster = ClusterSpec::test_cluster(2, 2);
        let health = RailHealth::all_healthy(&cluster).fail_nic(0).fail_nic(2);
        let pb = rerouted_gather(health);
        let bytes = 2.0 * 4.0 * crate::mem::ELEM_BYTES as f64;
        assert!((pb[&Port::Egress(DeviceId(0))] - bytes).abs() < 1.0, "handoff d0->d1");
        assert!((pb[&Port::NicEgress(DeviceId(1))] - bytes).abs() < 1.0, "donor rail d1->d3");
        assert!((pb[&Port::NicIngress(DeviceId(3))] - bytes).abs() < 1.0);
        assert!((pb[&Port::Egress(DeviceId(3))] - bytes).abs() < 1.0, "delivery d3->d2");
        assert!(pb.get(&Port::NicEgress(DeviceId(0))).is_none());
        assert!(pb.get(&Port::NicIngress(DeviceId(2))).is_none());
    }

    #[test]
    fn reroute_round_robins_across_healthy_rails() {
        // P=4, one failed rail: successive sends from the failed device
        // rotate over the three healthy donors — no single NIC doubles.
        let cluster = ClusterSpec::test_cluster(2, 4);
        let rail = RailPlanner::new(&cluster, DEFAULT_RDMA_CHUNK)
            .with_health(RailHealth::all_healthy(&cluster).fail_nic(0));
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "rail");
        let bytes = 4096.0;
        for _ in 0..3 {
            rail.send(&mut plan, w, DeviceId(0), 1, bytes, 8.0, None, "rail_send", None);
        }
        let r = TimedExec::on_cluster(cluster).run(&plan);
        for donor in 1..4 {
            let got = r.port_bytes[&Port::NicEgress(DeviceId(donor))];
            assert!((got - bytes).abs() < 1.0, "donor d{donor} carries exactly one flow, got {got}");
        }
        assert!(r.port_bytes.get(&Port::NicEgress(DeviceId(0))).is_none());
    }

    #[test]
    #[should_panic(expected = "cannot be rerouted")]
    fn reroute_panics_when_a_whole_node_is_dark() {
        let cluster = ClusterSpec::test_cluster(2, 2);
        let rail = RailPlanner::new(&cluster, DEFAULT_RDMA_CHUNK)
            .with_health(RailHealth::all_healthy(&cluster).fail_nic(0).fail_nic(1));
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "rail");
        rail.send(&mut plan, w, DeviceId(0), 1, 1024.0, 8.0, None, "rail_send", None);
    }

    #[test]
    fn healthy_mask_emits_the_exact_healthy_plan() {
        // with an all-healthy mask attached the planner must not add
        // forwarder workers or change a single op.
        let cluster = ClusterSpec::test_cluster(2, 2);
        let mk = |health: Option<RailHealth>| {
            let mut rail = RailPlanner::new(&cluster, DEFAULT_RDMA_CHUNK);
            if let Some(h) = health {
                rail = rail.with_health(h);
            }
            let mut plan = Plan::new();
            let w = plan.add_worker(DeviceId(0), Role::CommSm, "rail");
            rail.send(&mut plan, w, DeviceId(0), 1, 4096.0, 8.0, None, "rail_send", None);
            rail.send_add(&mut plan, w, DeviceId(0), 1, 4096.0, 8.0, None, "rail_send_add", None);
            plan
        };
        let a = mk(None);
        let b = mk(Some(RailHealth::all_healthy(&cluster)));
        assert_eq!(a.workers.len(), b.workers.len());
        assert_eq!(format!("{:?}", a.workers[0].ops), format!("{:?}", b.workers[0].ops));
    }

    #[test]
    fn wave_credits_post_after_drain() {
        // consumer credited only once the fan-out transfer drained; flush
        // leaves the tracker reusable for the next wave.
        let mut pool = MemPool::new();
        let mut plan = Plan::new();
        let drain = plan.add_sem(0);
        let credit = plan.add_sem(0);
        let w = plan.add_worker(DeviceId(0), Role::CommSm, "fwd");
        let consumer = plan.add_worker(DeviceId(1), Role::ComputeSm, "gemm");
        let mut credits = WaveCredits::new();
        plan.push(w, Op::Signal { sem: drain, value: 1, scope: SyncScope::InterDevice });
        credits.defer(drain, vec![(credit, 3)]);
        credits.flush(&mut plan, w, SyncScope::InterDevice);
        plan.push(consumer, Op::Wait { sem: credit, value: 3 });
        run_functional(&mut pool, &plan);
        // the flush emitted exactly one wait + one signal
        assert_eq!(plan.workers[w].ops.len(), 3);
    }

    #[test]
    fn rail_sems_cover_every_device_node_pair() {
        let cluster = ClusterSpec::test_cluster(3, 2);
        let mut plan = Plan::new();
        let sems = RailSems::alloc(&mut plan, &cluster);
        assert_eq!(sems.done.len(), 6);
        assert!(sems.done.iter().all(|row| row.len() == 3));
        assert_eq!(plan.sems.len(), 18);
    }
}
