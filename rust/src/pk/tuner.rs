//! Runtime SM-partition auto-tuner (§3.1.3 "SM partitioning", Figure 5).
//!
//! Inter-SM overlap trades compute SMs for communication SMs; the optimum
//! depends on problem size (larger workloads favour more compute SMs). PK
//! "allows users to automatically search for the optimal SM allocation at
//! runtime through a unified program template" — this module is that
//! search: it times candidate partitions with the timed executor and picks
//! the fastest.

use crate::exec::TimedExec;
use crate::hw::spec::NodeSpec;
use crate::plan::Plan;

/// Result of a partition sweep.
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// Best number of communicator SMs.
    pub best_comm_sms: u32,
    /// Kernel time at the best partition.
    pub best_time: f64,
    /// Full sweep: `(num_comm_sms, time)`.
    pub sweep: Vec<(u32, f64)>,
}

/// Sweep `candidates` communicator-SM counts, building the kernel plan for
/// each with `build`, and return the fastest partition.
pub fn tune_comm_sms(
    node: &NodeSpec,
    candidates: &[u32],
    mut build: impl FnMut(u32) -> Plan,
) -> TuneResult {
    assert!(!candidates.is_empty());
    let exec = TimedExec::new(node.clone());
    let mut sweep = Vec::with_capacity(candidates.len());
    for &c in candidates {
        let plan = build(c);
        let t = exec.run(&plan).total_time;
        sweep.push((c, t));
    }
    let (best_comm_sms, best_time) =
        sweep.iter().copied().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
    TuneResult { best_comm_sms, best_time, sweep }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DeviceId;
    use crate::plan::{Op, Role};

    #[test]
    fn tuner_picks_minimum() {
        // Synthetic kernel: time = compute(1/(132-c)) + comm(1/c) —
        // a convex trade-off with an interior optimum.
        let node = NodeSpec::test_node(8);
        let r = tune_comm_sms(&node, &[4, 8, 16, 32, 64], |c| {
            let mut plan = Plan::new();
            let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "w");
            let comp = 1.0 / (132 - c) as f64;
            let comm = 1.0 / c as f64;
            plan.push(w, Op::Compute { dur: comp + comm, label: "synthetic", effect: None });
            plan
        });
        // d/dc [1/(132-c) + 1/c] = 0 at c = 66; among candidates, 64.
        assert_eq!(r.best_comm_sms, 64);
        assert_eq!(r.sweep.len(), 5);
        assert!(r.sweep.iter().all(|(_, t)| *t >= r.best_time));
    }
}
