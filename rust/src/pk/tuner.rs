//! Runtime SM-partition auto-tuner (§3.1.3 "SM partitioning", Figure 5),
//! single-node and cluster-aware.
//!
//! Inter-SM overlap trades compute SMs for communication SMs; the optimum
//! depends on problem size (larger workloads favour more compute SMs). PK
//! "allows users to automatically search for the optimal SM allocation at
//! runtime through a unified program template" — this module is that
//! search: it times candidate partitions with the timed executor and picks
//! the fastest.
//!
//! The sweep is generic over the executor ([`tune_comm_sms_with`]): a plan
//! built for a multi-node cluster must be timed by
//! [`TimedExec::on_cluster`], or its RDMA flows would be rated against the
//! wrong fabric. [`tune_comm_sms`] (single node) and
//! [`tune_comm_sms_cluster`] are the two entry points; when the binding
//! resource moves from NVLink to the NIC, the SM partition alone is no
//! longer the whole story, so [`tune_comm_sms_rdma_chunk`] co-tunes the
//! communicator partition with the coalesced RDMA write size against
//! [`ClusterSpec::nic_bw`] (more, smaller chunks = finer overlap waves but
//! less efficient NIC messages).
//!
//! ## Analytic RDMA-chunk policy
//!
//! The chunk axis of the co-tune has a closed form: the only things a
//! chunk size trades are the RDMA message-size ramp (bigger writes sit
//! higher on [`crate::xfer::curves::rdma_rate`]) and overlap granularity
//! (smaller waves expose less of the flow before downstream work can
//! start). Modelling one rail flow of `B` bytes in `B/c`-sized waves, the
//! exposed time is approximately
//!
//! ```text
//! t(c) ≈ B/R·(1 + h/c)  +  (c + h)/R  +  (B/c)·L
//!        └ ramped flow ┘   └ first-wave ┘  └ per-wave latency ┘
//! ```
//!
//! with `R = nic_bw · nic_peak_frac`, `h = rdma_half_msg`, and `L =
//! nic_latency`. Setting `dt/dc = 0` gives the rate-curve knee
//!
//! ```text
//! c* = sqrt(B · (h + L·R))
//! ```
//!
//! — [`analytic_rdma_chunk`]. Every rail kernel resolves its `rdma_chunk`
//! knob through [`resolve_rdma_chunk`], so the sentinel
//! [`crate::pk::rail::RDMA_CHUNK_AUTO`] (the default in every kernel
//! config) picks `c*` per kernel from [`ClusterSpec::nic_bw`] without a
//! sweep; the swept grid stays available as the ablation/validation path
//! (a property test pins the analytic choice within a fixed tolerance of
//! the swept optimum across the NIC grid).

use crate::exec::TimedExec;
use crate::hw::cluster::ClusterSpec;
use crate::hw::spec::NodeSpec;
use crate::plan::Plan;
use crate::util::par::par_map;

/// Clamp floor of the analytic chunk: far below this, verbs posting
/// overhead dominates any overlap win (the steep left edge of the RDMA
/// curve).
pub const ANALYTIC_CHUNK_MIN: f64 = 64.0 * 1024.0;
/// Clamp ceiling of the analytic chunk: beyond this the message ramp is
/// flat and [`crate::pk::rail::MAX_WAVES`] bounds the wave count anyway.
pub const ANALYTIC_CHUNK_MAX: f64 = 16.0 * 1024.0 * 1024.0;

/// The analytic coalesced-RDMA write size for a rail flow of
/// `max_flow_bytes`: the knee `c* = sqrt(B·(h + L·R))` of the RDMA
/// rate curve (module docs), clamped to
/// [`ANALYTIC_CHUNK_MIN`]..[`ANALYTIC_CHUNK_MAX`]. Monotone in both the
/// flow size and the NIC bandwidth: faster NICs amortize their per-wave
/// latency over bigger writes.
pub fn analytic_rdma_chunk(cluster: &ClusterSpec, max_flow_bytes: f64) -> f64 {
    let rate = cluster.nic_bw * cluster.nic_peak_frac;
    let overhead = cluster.rdma_half_msg + cluster.nic_latency * rate;
    (max_flow_bytes.max(0.0) * overhead).sqrt().clamp(ANALYTIC_CHUNK_MIN, ANALYTIC_CHUNK_MAX)
}

/// Resolve a kernel's `rdma_chunk` knob: the sentinel
/// [`crate::pk::rail::RDMA_CHUNK_AUTO`] becomes the analytic knee for the
/// kernel's largest rail flow; any explicit (tuned or swept) value passes
/// through unchanged. Always returns a positive chunk, so
/// [`crate::pk::rail::RailPlanner::new`] never sees the sentinel.
pub fn resolve_rdma_chunk(chunk: f64, cluster: &ClusterSpec, max_flow_bytes: f64) -> f64 {
    if chunk == crate::pk::rail::RDMA_CHUNK_AUTO {
        analytic_rdma_chunk(cluster, max_flow_bytes)
    } else {
        chunk
    }
}

/// Result of a partition sweep.
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// Best number of communicator SMs.
    pub best_comm_sms: u32,
    /// Kernel time at the best partition.
    pub best_time: f64,
    /// Full sweep: `(num_comm_sms, time)`.
    pub sweep: Vec<(u32, f64)>,
}

/// Result of a joint (communicator SMs × RDMA chunk) sweep.
#[derive(Clone, Debug)]
pub struct ClusterTuneResult {
    pub best_comm_sms: u32,
    pub best_rdma_chunk: f64,
    pub best_time: f64,
    /// Full sweep: `(num_comm_sms, rdma_chunk, time)`.
    pub sweep: Vec<(u32, f64, f64)>,
}

/// Build the `n` sweep plans *in index order* (builders are `FnMut` and
/// may carry order-dependent state) and time each on the scoped-thread
/// pool, a chunk at a time so only O(threads) GEMM-scale plans are ever
/// resident. Times come back in build order, so parallel and serial
/// sweeps are byte-identical (pinned by a determinism test).
fn time_plans_chunked(
    exec: &TimedExec,
    n: usize,
    mut make: impl FnMut(usize) -> Plan,
) -> Vec<f64> {
    let chunk = crate::util::par::default_threads().max(1) * 2;
    let mut times = Vec::with_capacity(n);
    let mut i = 0;
    while i < n {
        let hi = (i + chunk).min(n);
        let batch: Vec<Plan> = (i..hi).map(&mut make).collect();
        times.extend(par_map(&batch, |_, plan| exec.run(plan).total_time));
        i = hi;
    }
    times
}

/// Sweep `candidates` communicator-SM counts on an explicit executor —
/// the generic core both entry points share. Pass
/// [`TimedExec::on_cluster`] for cluster plans; timing them against a
/// single-node executor silently mis-rates every RDMA flow.
///
/// Candidate plans are built serially and timed on a scoped-thread pool
/// ([`par_map`]; `PK_THREADS=1` forces serial). Results keep candidate
/// order, so parallel and serial sweeps are byte-identical.
pub fn tune_comm_sms_with(
    exec: &TimedExec,
    candidates: &[u32],
    mut build: impl FnMut(u32) -> Plan,
) -> TuneResult {
    assert!(!candidates.is_empty());
    let times = time_plans_chunked(exec, candidates.len(), |i| build(candidates[i]));
    let sweep: Vec<(u32, f64)> = candidates.iter().copied().zip(times).collect();
    let (best_comm_sms, best_time) =
        sweep.iter().copied().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
    TuneResult { best_comm_sms, best_time, sweep }
}

/// Sweep `candidates` communicator-SM counts, building the kernel plan for
/// each with `build`, and return the fastest partition. Single-node: the
/// plan is timed on `node` (delegates to [`tune_comm_sms_with`]; the
/// single-node path is unchanged by the cluster generalization).
pub fn tune_comm_sms(
    node: &NodeSpec,
    candidates: &[u32],
    build: impl FnMut(u32) -> Plan,
) -> TuneResult {
    tune_comm_sms_with(&TimedExec::new(node.clone()), candidates, build)
}

/// [`tune_comm_sms`] for cluster plans: candidates are timed with
/// [`TimedExec::on_cluster`], so RDMA flows are rated against the
/// cluster's NIC curve instead of silently against NVLink.
pub fn tune_comm_sms_cluster(
    cluster: &ClusterSpec,
    candidates: &[u32],
    build: impl FnMut(u32) -> Plan,
) -> TuneResult {
    tune_comm_sms_with(&TimedExec::on_cluster(cluster.clone()), candidates, build)
}

/// Cluster co-tune: sweep the (communicator SMs × coalesced RDMA chunk)
/// grid and return the joint optimum. The chunk axis only matters when the
/// NIC is the binding resource — which is exactly when re-tuning the SM
/// partition alone is insufficient (resource-aware overlap).
///
/// The sweep is generic over **any** [`crate::pk::rail`] kernel — the
/// chunk candidate is handed to the build closure, which threads it into
/// the kernel's `rdma_chunk` knob (`MoeCfg::rdma_chunk`,
/// `GemmKernelCfg::rdma_chunk`, the all-to-all's parameter, …); nothing
/// here is MoE-specific.
pub fn tune_comm_sms_rdma_chunk(
    cluster: &ClusterSpec,
    sm_candidates: &[u32],
    chunk_candidates: &[f64],
    mut build: impl FnMut(u32, f64) -> Plan,
) -> ClusterTuneResult {
    assert!(!sm_candidates.is_empty() && !chunk_candidates.is_empty());
    let exec = TimedExec::on_cluster(cluster.clone());
    // enumerate the grid up front (cheap pairs), build plans lazily in
    // grid order and time them chunk-by-chunk on the thread pool; grid
    // order is preserved so the sweep is byte-identical to a serial run.
    let mut points = Vec::with_capacity(sm_candidates.len() * chunk_candidates.len());
    for &c in sm_candidates {
        for &chunk in chunk_candidates {
            assert!(chunk > 0.0, "rdma chunk candidates must be positive");
            points.push((c, chunk));
        }
    }
    let times = time_plans_chunked(&exec, points.len(), |i| build(points[i].0, points[i].1));
    let sweep: Vec<(u32, f64, f64)> =
        points.iter().zip(times).map(|(&(c, chunk), t)| (c, chunk, t)).collect();
    let &(best_comm_sms, best_rdma_chunk, best_time) =
        sweep.iter().min_by(|a, b| a.2.partial_cmp(&b.2).unwrap()).unwrap();
    ClusterTuneResult { best_comm_sms, best_rdma_chunk, best_time, sweep }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DeviceId;
    use crate::kernels::moe::{self, MoeCfg, MoeSchedule, Routing};
    use crate::plan::{Op, Role};

    #[test]
    fn analytic_chunk_monotone_and_clamped() {
        let flow = 32.0 * 1024.0 * 1024.0;
        let mut last = 0.0;
        for nic in [25e9, 50e9, 100e9] {
            let cluster = ClusterSpec::hgx_h100_pod(2).with_nic_bw(nic);
            let c = analytic_rdma_chunk(&cluster, flow);
            assert!(c >= ANALYTIC_CHUNK_MIN && c <= ANALYTIC_CHUNK_MAX);
            assert!(c > last, "knee grows with NIC bandwidth: {c} after {last}");
            last = c;
        }
        // tiny/empty flows clamp to the floor instead of degenerating
        let cluster = ClusterSpec::hgx_h100_pod(2);
        assert_eq!(analytic_rdma_chunk(&cluster, 0.0), ANALYTIC_CHUNK_MIN);
        // flow growth moves the knee up too
        assert!(
            analytic_rdma_chunk(&cluster, 4.0 * flow) > analytic_rdma_chunk(&cluster, flow),
            "bigger flows take bigger writes"
        );
    }

    #[test]
    fn resolve_passes_fixed_values_and_expands_auto() {
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let fixed = 123456.0;
        assert_eq!(resolve_rdma_chunk(fixed, &cluster, 1e8), fixed);
        let auto = resolve_rdma_chunk(crate::pk::rail::RDMA_CHUNK_AUTO, &cluster, 1e8);
        assert!(auto > 0.0, "AUTO must resolve to a positive chunk");
        assert_eq!(auto, analytic_rdma_chunk(&cluster, 1e8));
    }

    #[test]
    fn tuner_picks_minimum() {
        // Synthetic kernel: time = compute(1/(132-c)) + comm(1/c) —
        // a convex trade-off with an interior optimum.
        let node = NodeSpec::test_node(8);
        let r = tune_comm_sms(&node, &[4, 8, 16, 32, 64], |c| {
            let mut plan = Plan::new();
            let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "w");
            let comp = 1.0 / (132 - c) as f64;
            let comm = 1.0 / c as f64;
            plan.push(w, Op::Compute { dur: comp + comm, label: "synthetic", effect: None });
            plan
        });
        // d/dc [1/(132-c) + 1/c] = 0 at c = 66; among candidates, 64.
        assert_eq!(r.best_comm_sms, 64);
        assert_eq!(r.sweep.len(), 5);
        assert!(r.sweep.iter().all(|(_, t)| *t >= r.best_time));
    }

    #[test]
    fn single_node_and_one_node_cluster_sweeps_agree_bitwise() {
        // the executor generalization must leave the single-node entry
        // point exactly where it was: tune over a real kernel both ways.
        let node = NodeSpec::hgx_h100();
        let cluster = ClusterSpec::single(node.clone());
        let cfg = MoeCfg::paper(node.clone(), 4096);
        let routing = Routing::uniform(&cfg, 7);
        let build = |c: u32| {
            let mut cfg = cfg.clone();
            cfg.comm_sms = c;
            moe::build(&cfg, &routing, MoeSchedule::Overlapped, None)
        };
        let a = tune_comm_sms(&node, &[8, 16, 32], build);
        let b = tune_comm_sms_cluster(&cluster, &[8, 16, 32], build);
        assert_eq!(a.best_comm_sms, b.best_comm_sms);
        assert_eq!(a.best_time.to_bits(), b.best_time.to_bits());
        for ((c1, t1), (c2, t2)) in a.sweep.iter().zip(&b.sweep) {
            assert_eq!(c1, c2);
            assert_eq!(t1.to_bits(), t2.to_bits());
        }
    }

    #[test]
    fn cluster_sweep_times_against_the_cluster_executor() {
        // a cluster MoE plan tuned through the cluster path must see NIC
        // rates: the same plan timed by the (wrong) single-node executor
        // at 8 devices would not even run (RDMA routes need NIC ports on a
        // >1-node topology), so this pins that the cluster tuner wires the
        // right executor through — and that the sweep is well-formed.
        let cluster = ClusterSpec::test_cluster(2, 2);
        let cfg = MoeCfg {
            node: NodeSpec::test_node(2),
            tokens: 4 * 64,
            hidden: 256,
            h_expert: 128,
            n_experts: 8,
            top_k: 2,
            comm_sms: 8,
            rdma_chunk: moe::DEFAULT_RDMA_CHUNK,
        };
        let routing = Routing::uniform(&cfg, 5);
        let r = tune_comm_sms_cluster(&cluster, &[4, 8, 16], |c| {
            let mut cfg = cfg.clone();
            cfg.comm_sms = c;
            moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, None)
        });
        assert_eq!(r.sweep.len(), 3);
        assert!(r.sweep.iter().all(|(_, t)| t.is_finite() && *t > 0.0));
        assert!(r.sweep.iter().all(|(_, t)| *t >= r.best_time));
    }

    #[test]
    fn co_tune_explores_the_chunk_axis() {
        // cluster MoE at paper-ish scale: the joint sweep must cover the
        // full grid, pick its minimum, and the chunk axis must actually
        // change the timing (different wave structure / message sizes).
        let cluster = ClusterSpec::hgx_h100_pod(2).with_nic_bw(25e9);
        let cfg = MoeCfg::paper(cluster.node.clone(), 1024 * cluster.total_devices());
        let routing = Routing::uniform(&cfg, 13);
        let chunks = [256.0 * 1024.0, 4.0 * 1024.0 * 1024.0];
        let r = tune_comm_sms_rdma_chunk(&cluster, &[8, 16], &chunks, |c, chunk| {
            let mut cfg = cfg.clone();
            cfg.comm_sms = c;
            cfg.rdma_chunk = chunk;
            moe::build_cluster(&cfg, &cluster, &routing, MoeSchedule::Overlapped, None)
        });
        assert_eq!(r.sweep.len(), 4);
        assert!(r.sweep.iter().all(|(_, _, t)| *t >= r.best_time));
        assert!(chunks.contains(&r.best_rdma_chunk));
        // the chunk axis is live: at a fixed partition the two chunk
        // candidates give different times
        let at8: Vec<f64> = r.sweep.iter().filter(|(c, _, _)| *c == 8).map(|(_, _, t)| *t).collect();
        assert_eq!(at8.len(), 2);
        assert!((at8[0] - at8[1]).abs() > 1e-12, "chunk size must matter: {at8:?}");
    }

    #[test]
    fn co_tune_generalizes_over_rail_kernels() {
        // the same co-tuner drives the hierarchical gemm_rs (a different
        // pk::rail client): the grid is covered and the chunk axis changes
        // the timing through GemmKernelCfg::rdma_chunk.
        use crate::kernels::gemm_rs::{self, Schedule};
        use crate::kernels::GemmKernelCfg;
        let cluster = ClusterSpec::hgx_h100_pod(2).with_nic_bw(25e9);
        let base = GemmKernelCfg::new(cluster.node.clone(), 32768, 8192, 1024);
        let chunks = [64.0 * 1024.0, 4.0 * 1024.0 * 1024.0];
        let r = tune_comm_sms_rdma_chunk(&cluster, &[0, 16], &chunks, |c, chunk| {
            let mut cfg = base.clone();
            cfg.opts.num_comm_sms = c;
            cfg.rdma_chunk = chunk;
            let schedule = if c == 0 { Schedule::IntraSm } else { Schedule::InterSm };
            gemm_rs::build_cluster(&cfg, &cluster, schedule, None)
        });
        assert_eq!(r.sweep.len(), 4);
        assert!(r.sweep.iter().all(|(_, _, t)| t.is_finite() && *t >= r.best_time));
        assert!(chunks.contains(&r.best_rdma_chunk));
        let at0: Vec<f64> = r.sweep.iter().filter(|(c, _, _)| *c == 0).map(|(_, _, t)| *t).collect();
        assert!((at0[0] - at0[1]).abs() > 1e-12, "chunk axis must be live for gemm_rs: {at0:?}");
    }
}
