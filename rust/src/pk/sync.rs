//! Inter-device and inter-SM synchronization primitives (§3.2.2).
//!
//! The paper's `barrier_t` is a PGL of integer counters indexed by an
//! element-wise coordinate; a signal is an atomic add on a specific
//! device's counter (optionally multicast to all devices), and a wait is a
//! spin on the local counter. In plan form each `(coord, device)` counter
//! is one semaphore; signals pay the §3.1.3 latencies (64 ns mbarrier
//! intra-SM, 832 ns HBM inter-SM, ~µs NVLink inter-device).
//!
//! PK deliberately avoids NCCL's two-way rendezvous: a signal is a one-way
//! flag write into a *pre-allocated* destination barrier (§3.1.4), so
//! transfers never wait for a receiver handshake.

use crate::hw::DeviceId;
use crate::plan::{Op, Plan, SemId, SyncScope};

/// A barrier object: one counter per device for one coordinate.
/// Allocate one `Barrier` per tile-coordinate you synchronize on
/// (the paper indexes `barrier_t` by `coord`).
#[derive(Clone, Debug)]
pub struct Barrier {
    pub sems: Vec<SemId>,
}

impl Barrier {
    /// Allocate the per-device counters (initial value 0).
    pub fn alloc(plan: &mut Plan, num_devices: usize) -> Self {
        Barrier { sems: (0..num_devices).map(|_| plan.add_sem(0)).collect() }
    }

    pub fn num_devices(&self) -> usize {
        self.sems.len()
    }
}

/// `signal(bar, coord, dev_idx, val)` — atomically add `val` to device
/// `dst`'s counter. One-way; visible after an inter-device flag write.
pub fn signal(plan: &mut Plan, w: usize, bar: &Barrier, dst: DeviceId, val: u64) {
    plan.push(w, Op::Signal { sem: bar.sems[dst.0], value: val, scope: SyncScope::InterDevice });
}

/// Local-scope signal (same device, different SM): pays the HBM sync
/// latency instead of NVLink (§3.1.3: 832 ns).
pub fn signal_local(plan: &mut Plan, w: usize, bar: &Barrier, dev: DeviceId, val: u64) {
    plan.push(w, Op::Signal { sem: bar.sems[dev.0], value: val, scope: SyncScope::InterSm });
}

/// `signal_all(bar, coord, val)` — multicast atomic add to every device's
/// counter: a single multimem operation in hardware (§3.2.2), modelled as
/// simultaneous signals each paying one inter-device latency.
pub fn signal_all(plan: &mut Plan, w: usize, bar: &Barrier, val: u64) {
    for &s in &bar.sems {
        plan.push(w, Op::Signal { sem: s, value: val, scope: SyncScope::InterDevice });
    }
}

/// `wait(bar, coord, dev_idx, expected)` — spin until device `dev`'s
/// counter reaches `expected`.
pub fn wait(plan: &mut Plan, w: usize, bar: &Barrier, dev: DeviceId, expected: u64) {
    plan.push(w, Op::Wait { sem: bar.sems[dev.0], value: expected });
}

/// `barrier(bar, coord, dev_idx)` — full barrier across all devices:
/// every participant signals everyone (multimem) and waits until its own
/// counter shows all arrivals. `generation` lets the same barrier be
/// reused (expected value = generation × num_devices).
pub fn barrier(plan: &mut Plan, w: usize, bar: &Barrier, me: DeviceId, generation: u64) {
    signal_all(plan, w, bar, 1);
    wait(plan, w, bar, me, generation * bar.num_devices() as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::TimedExec;
    use crate::util::prop::run_functional;
    use crate::hw::spec::NodeSpec;
    use crate::mem::MemPool;
    use crate::plan::Role;

    #[test]
    fn signal_then_wait_releases() {
        let mut plan = Plan::new();
        let bar = Barrier::alloc(&mut plan, 2);
        let w0 = plan.add_worker(DeviceId(0), Role::ComputeSm, "w0");
        let w1 = plan.add_worker(DeviceId(1), Role::ComputeSm, "w1");
        signal(&mut plan, w0, &bar, DeviceId(1), 5);
        wait(&mut plan, w1, &bar, DeviceId(1), 5);
        let mut pool = MemPool::new();
        run_functional(&mut pool, &plan);
        let r = TimedExec::new(NodeSpec::test_node(2)).run(&plan);
        // one inter-device signal latency
        assert!((r.total_time - NodeSpec::test_node(2).gpu.nvlink_signal).abs() < 1e-12);
    }

    #[test]
    fn full_barrier_releases_all_devices() {
        let n = 8;
        let mut plan = Plan::new();
        let bar = Barrier::alloc(&mut plan, n);
        for d in 0..n {
            let w = plan.add_worker(DeviceId(d), Role::ComputeSm, format!("w{d}"));
            barrier(&mut plan, w, &bar, DeviceId(d), 1);
        }
        let mut pool = MemPool::new();
        run_functional(&mut pool, &plan);
        let r = TimedExec::new(NodeSpec::test_node(n)).run(&plan);
        // all signals issued at t=0, visible after one NVLink latency.
        assert!(r.total_time < 2.0 * NodeSpec::test_node(n).gpu.nvlink_signal);
    }

    #[test]
    fn barrier_reuse_with_generations() {
        let n = 3;
        let mut plan = Plan::new();
        let bar = Barrier::alloc(&mut plan, n);
        for d in 0..n {
            let w = plan.add_worker(DeviceId(d), Role::ComputeSm, format!("w{d}"));
            barrier(&mut plan, w, &bar, DeviceId(d), 1);
            barrier(&mut plan, w, &bar, DeviceId(d), 2);
        }
        let mut pool = MemPool::new();
        run_functional(&mut pool, &plan);
    }

    #[test]
    fn intra_vs_inter_sm_latency_microbench() {
        // §3.1.3: mbarrier 64 ns, HBM 832 ns — the µ1 exhibit.
        let node = NodeSpec::test_node(1);
        for (scope, expect) in
            [(SyncScope::IntraSm, node.gpu.mbarrier_sync), (SyncScope::InterSm, node.gpu.hbm_sync)]
        {
            let mut plan = Plan::new();
            let s = plan.add_sem(0);
            let w0 = plan.add_worker(DeviceId(0), Role::ComputeSm, "sig");
            let w1 = plan.add_worker(DeviceId(0), Role::ComputeSm, "wait");
            plan.push(w0, Op::Signal { sem: s, value: 1, scope });
            plan.push(w1, Op::Wait { sem: s, value: 1 });
            let r = TimedExec::new(node.clone()).run(&plan);
            assert!((r.total_time - expect).abs() < 1e-15, "{scope:?}: {}", r.total_time);
        }
    }
}
