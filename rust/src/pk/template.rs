//! The LCSC (loader–consumer–storer–communicator) program template
//! (§3.2.3, Appendix D).
//!
//! The template owns the structural decisions every PK kernel shares:
//!
//! * **SM partitioning** — `num_comm_sms` SMs per device run dedicated
//!   *communicator* workers (inter-SM overlap); the rest are *compute* SMs
//!   whose loader/storer warps issue async transfers around the consumer's
//!   tensor-core work (intra-SM overlap).
//! * **worker granularity** — a fidelity knob: each plan worker models a
//!   group of SMs (`workers_per_device`); durations and rate caps are
//!   scaled by the group size, so paper-scale problems stay tractable
//!   while small functional runs can be SM-exact.
//! * **pipelining** — `pipeline_stages` in-flight async stores per compute
//!   worker (the semaphore ring of the Appendix D listing).
//! * **launch cost** — the cost model's `T_launch`.
//!
//! Kernels built on the template only write per-tile compute and
//! communication logic — the "<50 lines of device code" the paper claims.

use crate::hw::spec::NodeSpec;
use crate::hw::DeviceId;
use crate::plan::{Plan, Role};

/// Template configuration.
#[derive(Clone, Copy, Debug)]
pub struct LcscOpts {
    /// SMs per device dedicated to the communicator (0 = pure intra-SM).
    pub num_comm_sms: u32,
    /// Plan workers modelling the compute SMs of one device.
    pub workers_per_device: u32,
    /// Plan workers modelling the communicator SMs of one device.
    pub comm_workers_per_device: u32,
    /// In-flight async stores per compute worker.
    pub pipeline_stages: u64,
}

impl Default for LcscOpts {
    fn default() -> Self {
        LcscOpts { num_comm_sms: 0, workers_per_device: 8, comm_workers_per_device: 2, pipeline_stages: 4 }
    }
}

impl LcscOpts {
    /// SM-exact worker granularity for small functional runs.
    pub fn exact(node: &NodeSpec, num_comm_sms: u32) -> Self {
        LcscOpts {
            num_comm_sms,
            workers_per_device: node.gpu.num_sms - num_comm_sms,
            comm_workers_per_device: num_comm_sms.max(1),
            pipeline_stages: 4,
        }
    }
}

/// An instantiated template: the plan plus the worker topology.
pub struct Lcsc {
    pub node: NodeSpec,
    pub opts: LcscOpts,
    pub plan: Plan,
    /// `compute[dev][i]` — compute workers of device `dev`.
    pub compute: Vec<Vec<usize>>,
    /// `comm[dev][i]` — communicator workers of device `dev`.
    pub comm: Vec<Vec<usize>>,
}

impl Lcsc {
    /// Create workers for every device per the SM partition.
    pub fn new(node: NodeSpec, opts: LcscOpts) -> Self {
        let n_dev = node.num_devices;
        Self::with_device_count(node, n_dev, opts)
    }

    /// Create workers for every device of a multi-node cluster (global
    /// node-major device ids; the SM partition applies per device).
    pub fn new_cluster(cluster: &crate::hw::ClusterSpec, opts: LcscOpts) -> Self {
        Self::with_device_count(cluster.node.clone(), cluster.total_devices(), opts)
    }

    fn with_device_count(node: NodeSpec, n_dev: usize, opts: LcscOpts) -> Self {
        assert!(opts.num_comm_sms < node.gpu.num_sms, "must leave compute SMs");
        assert!(opts.workers_per_device >= 1);
        let mut plan = Plan::new();
        plan.launch_overhead = node.gpu.kernel_launch;
        let mut compute = vec![];
        let mut comm = vec![];
        for d in 0..n_dev {
            let dev = DeviceId(d);
            let c: Vec<usize> = (0..opts.workers_per_device)
                .map(|i| plan.add_worker(dev, Role::ComputeSm, format!("d{d}/sm{i}")))
                .collect();
            let m: Vec<usize> = if opts.num_comm_sms > 0 {
                (0..opts.comm_workers_per_device)
                    .map(|i| plan.add_worker(dev, Role::CommSm, format!("d{d}/comm{i}")))
                    .collect()
            } else {
                vec![]
            };
            compute.push(c);
            comm.push(m);
        }
        Lcsc { node, opts, plan, compute, comm }
    }

    /// Compute SMs per device under this partition.
    pub fn compute_sms(&self) -> u32 {
        self.node.gpu.num_sms - self.opts.num_comm_sms
    }

    /// Tensor-core throughput of **one compute worker** (its SM group).
    pub fn worker_flops(&self) -> f64 {
        self.node.gpu.tc_flops_for_sms(self.compute_sms()) / self.opts.workers_per_device as f64
    }

    /// Time for one worker to compute a `m×n×k` output-tile GEMM chain.
    pub fn tile_gemm_time(&self, m: usize, n: usize, k: usize) -> f64 {
        2.0 * (m as f64) * (n as f64) * (k as f64) / self.worker_flops()
    }

    /// SMs represented by one communicator worker (drives multimem/TMA
    /// rate caps for communicator-issued transfers).
    pub fn comm_sms_per_worker(&self) -> f64 {
        if self.opts.num_comm_sms == 0 {
            0.0
        } else {
            self.opts.num_comm_sms as f64 / self.opts.comm_workers_per_device as f64
        }
    }

    /// Round-robin assignment of `n_tasks` to this device's compute
    /// workers: returns, for worker `i`, the task indices it owns.
    pub fn split_tasks(&self, dev: usize, n_tasks: usize) -> Vec<(usize, Vec<usize>)> {
        let ws = &self.compute[dev];
        let mut out: Vec<(usize, Vec<usize>)> = ws.iter().map(|&w| (w, vec![])).collect();
        for t in 0..n_tasks {
            out[t % ws.len()].1.push(t);
        }
        out
    }

    pub fn finish(self) -> Plan {
        self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_creates_workers() {
        let node = NodeSpec::test_node(4);
        let l = Lcsc::new(
            node,
            LcscOpts { num_comm_sms: 16, workers_per_device: 4, comm_workers_per_device: 2, pipeline_stages: 4 },
        );
        assert_eq!(l.compute.len(), 4);
        assert_eq!(l.compute[0].len(), 4);
        assert_eq!(l.comm[0].len(), 2);
        assert_eq!(l.plan.workers.len(), 4 * 6);
        assert_eq!(l.compute_sms(), 132 - 16);
        assert!(l.comm_sms_per_worker() == 8.0);
    }

    #[test]
    fn zero_comm_sms_means_no_comm_workers() {
        let l = Lcsc::new(NodeSpec::test_node(2), LcscOpts::default());
        assert!(l.comm[0].is_empty());
        assert_eq!(l.compute_sms(), 132);
    }

    #[test]
    fn worker_flops_scale_with_partition() {
        let node = NodeSpec::test_node(1);
        let full = Lcsc::new(node.clone(), LcscOpts::default());
        let half = Lcsc::new(
            node,
            LcscOpts { num_comm_sms: 66, workers_per_device: 8, comm_workers_per_device: 2, pipeline_stages: 4 },
        );
        assert!((full.worker_flops() / 2.0 - half.worker_flops()).abs() / full.worker_flops() < 1e-9);
    }

    #[test]
    fn split_tasks_covers_all() {
        let l = Lcsc::new(NodeSpec::test_node(1), LcscOpts::default());
        let split = l.split_tasks(0, 19);
        let total: usize = split.iter().map(|(_, t)| t.len()).sum();
        assert_eq!(total, 19);
        // balanced within 1
        let (mn, mx) = split
            .iter()
            .map(|(_, t)| t.len())
            .fold((usize::MAX, 0), |(a, b), l| (a.min(l), b.max(l)));
        assert!(mx - mn <= 1);
    }

    #[test]
    fn cluster_template_creates_workers_for_all_nodes() {
        let cluster = crate::hw::ClusterSpec::test_cluster(2, 4);
        let l = Lcsc::new_cluster(
            &cluster,
            LcscOpts { num_comm_sms: 8, workers_per_device: 2, comm_workers_per_device: 1, pipeline_stages: 2 },
        );
        assert_eq!(l.compute.len(), 8);
        assert_eq!(l.plan.workers.len(), 8 * 3);
        assert_eq!(l.plan.workers[3 * 7].device, DeviceId(7));
    }

    #[test]
    fn tile_gemm_time_scales() {
        let l = Lcsc::new(NodeSpec::test_node(1), LcscOpts::default());
        let t1 = l.tile_gemm_time(128, 128, 1024);
        let t2 = l.tile_gemm_time(128, 128, 2048);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "compute SMs")]
    fn rejects_all_comm_partition() {
        let _ = Lcsc::new(
            NodeSpec::test_node(1),
            LcscOpts { num_comm_sms: 132, workers_per_device: 1, comm_workers_per_device: 1, pipeline_stages: 1 },
        );
    }
}
