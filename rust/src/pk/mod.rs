//! **The ParallelKittens programming layer** — the paper's contribution
//! (§3.2): eight multi-GPU primitives, `barrier_t` synchronization, the
//! LCSC program template, and the runtime SM-partition auto-tuner.
//!
//! The paper's primitives (§3.2.2 / Appendix C) and their homes here:
//!
//! | paper                       | here                                   |
//! |-----------------------------|----------------------------------------|
//! | `store_async`               | [`primitives::store_async`]            |
//! | `store_add_async`           | [`primitives::store_add_async`]        |
//! | `reduce`                    | [`primitives::reduce`]                 |
//! | `all_reduce`                | [`primitives::all_reduce`]             |
//! | `signal`                    | [`sync::signal`]                       |
//! | `signal_all`                | [`sync::signal_all`]                   |
//! | `wait`                      | [`sync::wait`]                         |
//! | `barrier`                   | [`sync::barrier`]                      |
//!
//! Primitives emit [`crate::plan::Op`]s into a worker's program, so one
//! kernel description serves both the functional (numerics) and timed
//! (performance) executors. By design they encode the paper's mechanism
//! choices: point-wise communication uses **TMA** (async, single-thread,
//! tile granularity), in-network acceleration uses **multimem register
//! ops**, and nothing uses the copy engine on the device path (§3.1.2).
//!
//! The cluster layer adds locality-routed variants —
//! [`primitives::store_async_routed`] / [`primitives::store_add_async_routed`]
//! — that keep the same async tile-store API but pick NVLink P2P or
//! GPUDirect RDMA by whether the destination shares the source's node
//! (see [`crate::hw::ClusterSpec`]) — plus the [`rail`] hierarchical
//! transport subsystem: per-rail coalesced RDMA flows, rail-peer
//! forwarders with per-destination credits, and an optional node-local
//! pre-reduce for reducible payloads. `moe`, `gemm_rs`, the two-level
//! all-to-all, and the MoE combine hop are all thin clients of it.

pub mod primitives;
pub mod rail;
pub mod sync;
pub mod template;
pub mod tuner;

pub use primitives::{
    all_reduce, multicast_store_async, reduce, store_add_async, store_add_async_routed,
    store_add_async_scoped, store_async, store_async_routed, TileRef,
};
pub use rail::{rail_waves, wave_share, RailPlanner, RailSems, WaveCredits};
pub use sync::{barrier, signal, signal_all, wait, Barrier};
pub use template::{Lcsc, LcscOpts};
pub use tuner::{
    tune_comm_sms, tune_comm_sms_cluster, tune_comm_sms_rdma_chunk, tune_comm_sms_with,
    ClusterTuneResult, TuneResult,
};
