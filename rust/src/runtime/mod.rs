//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the Rust hot path.
//!
//! Interchange is **HLO text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see `/opt/xla-example/README.md`). Each artifact is
//! lowered with `return_tuple=True`, so execution results are tuples.
//!
//! Python runs once at `make artifacts`; after that this module is the only
//! consumer of the files and no Python is on the request path.

pub mod registry;

pub use registry::{ArtifactMeta, Manifest};

use crate::util::error::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use crate::util::error::anyhow;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Lazily-compiled PJRT executables keyed by artifact name.
///
/// The `xla` bindings are vendored, not on crates.io, so the compiled
/// backend only exists behind the `pjrt` feature; without it the manifest
/// still loads (so availability checks work) and `execute` returns a clear
/// error.
pub struct Runtime {
    dir: PathBuf,
    manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: Option<xla::PjRtClient>,
    #[cfg(feature = "pjrt")]
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Execution counters (name -> calls), used by the coordinator metrics.
    pub call_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Open an artifacts directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(Runtime {
            dir,
            manifest,
            #[cfg(feature = "pjrt")]
            client: None,
            #[cfg(feature = "pjrt")]
            executables: HashMap::new(),
            call_counts: HashMap::new(),
        })
    }

    /// The default artifacts directory: `$PK_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("PK_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    #[cfg(feature = "pjrt")]
    fn client(&mut self) -> Result<&xla::PjRtClient> {
        if self.client.is_none() {
            self.client = Some(xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?);
        }
        Ok(self.client.as_ref().unwrap())
    }

    /// Compile (once) and return the executable for `name`.
    #[cfg(feature = "pjrt")]
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (run `make artifacts`)"))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client()?
                .compile(&comp)
                .map_err(|e| anyhow!("compiling artifact {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute artifact `name` — built without the `pjrt` feature the
    /// vendored xla backend is absent, so this always errors.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute(&mut self, name: &str, _inputs: &[(Vec<f32>, Vec<usize>)]) -> Result<Vec<Vec<f32>>> {
        bail!("artifact '{name}': built without the `pjrt` feature (vendored xla-rs required)")
    }

    /// Execute artifact `name` on row-major f32 inputs with the given dims.
    /// Returns one flat vector per output.
    #[cfg(feature = "pjrt")]
    pub fn execute(&mut self, name: &str, inputs: &[(Vec<f32>, Vec<usize>)]) -> Result<Vec<Vec<f32>>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        if meta.inputs.len() != inputs.len() {
            bail!("artifact {name}: expected {} inputs, got {}", meta.inputs.len(), inputs.len());
        }
        for (i, ((data, dims), want)) in inputs.iter().zip(&meta.inputs).enumerate() {
            let n: usize = dims.iter().product();
            if data.len() != n {
                bail!("artifact {name} input {i}: data len {} != dims {:?}", data.len(), dims);
            }
            let wn: usize = want.iter().product();
            if n != wn {
                bail!("artifact {name} input {i}: got shape {:?}, manifest says {:?}", dims, want);
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| anyhow!("reshape input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        *self.call_counts.entry(name.to_string()).or_insert(0) += 1;
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        if parts.len() != meta.outputs.len() {
            bail!("artifact {name}: manifest says {} outputs, got {}", meta.outputs.len(), parts.len());
        }
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("output to_vec: {e:?}")))
            .collect()
    }

    /// True when every artifact the caller needs is present.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }
}

/// Anything that can execute an AOT artifact. `Runtime` implements it
/// directly; the coordinator's worker threads implement it as a channel
/// proxy to the leader thread (PJRT clients are not `Send`).
pub trait ArtifactRunner {
    fn run_artifact(&mut self, name: &str, inputs: &[(Vec<f32>, Vec<usize>)]) -> Result<Vec<Vec<f32>>>;
}

impl ArtifactRunner for Runtime {
    fn run_artifact(&mut self, name: &str, inputs: &[(Vec<f32>, Vec<usize>)]) -> Result<Vec<Vec<f32>>> {
        self.execute(name, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dir_env_override() {
        // No env set in tests normally; default is ./artifacts
        if std::env::var("PK_ARTIFACTS").is_err() {
            assert_eq!(Runtime::default_dir(), PathBuf::from("artifacts"));
        }
    }

    #[test]
    fn open_missing_dir_fails() {
        let err = match Runtime::open("/nonexistent/dir") {
            Ok(_) => panic!("should fail"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("manifest"));
    }
}
