//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the Rust runtime.

use crate::util::json::Json;
use crate::util::error::{anyhow, Context, Result};
use std::path::Path;

/// One AOT-compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// Lookup key, e.g. `gemm_128x128x128`.
    pub name: String,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
    /// Input shapes (row-major dims), in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes, in tuple order.
    pub outputs: Vec<Vec<usize>>,
    /// Which L1 kernel (if any) the computation routes through —
    /// documentation only (e.g. `pallas:gemm`).
    pub kernel: String,
}

/// The artifact index.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

fn shapes(j: &Json, key: &str) -> Result<Vec<Vec<usize>>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing '{key}' array"))?
        .iter()
        .map(|dims| {
            dims.as_arr()
                .ok_or_else(|| anyhow!("shape must be an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("dim must be a number")))
                .collect()
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let artifacts = arts
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    name: a
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing name"))?
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing file"))?
                        .to_string(),
                    inputs: shapes(a, "inputs")?,
                    outputs: shapes(a, "outputs")?,
                    kernel: a.get("kernel").and_then(Json::as_str).unwrap_or("").to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { artifacts })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "gemm_8x8x8", "file": "gemm_8x8x8.hlo.txt",
         "inputs": [[8, 8], [8, 8]], "outputs": [[8, 8]], "kernel": "pallas:gemm"}
      ]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.get("gemm_8x8x8").unwrap();
        assert_eq!(a.file, "gemm_8x8x8.hlo.txt");
        assert_eq!(a.inputs, vec![vec![8, 8], vec![8, 8]]);
        assert_eq!(a.outputs, vec![vec![8, 8]]);
        assert_eq!(a.kernel, "pallas:gemm");
        assert!(m.get("nope").is_none());
        assert_eq!(m.names(), vec!["gemm_8x8x8"]);
    }

    #[test]
    fn manifest_tolerates_missing_kernel_field() {
        let json = r#"{"artifacts":[{"name":"a","file":"a.hlo.txt","inputs":[[2,2]],"outputs":[[2,2]]}]}"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.get("a").unwrap().kernel, "");
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts":[{"file":"x"}]}"#).is_err());
    }
}
