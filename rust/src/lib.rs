//! # ParallelKittens (PK) — reproduction library
//!
//! A full reproduction of *"ParallelKittens: Systematic and Practical
//! Simplification of Multi-GPU AI Kernels"* (Sul, Arora, Spector, Ré; 2025)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper's substrate — an 8×H100 / 8×B200 NVLink+NVSwitch node — is not
//! available here, so the library is built around a *calibrated simulator*
//! of that node (see `DESIGN.md` §1 for the substitution argument):
//!
//! * [`hw`] — hardware specifications (H100 / B200 numbers from the paper).
//! * [`mem`] — functional device memory: buffers, tiles, and the paper's
//!   **Parallel Global Layout (PGL)**.
//! * [`sim`] — discrete-event simulation core: event queue and a max-min
//!   fair bandwidth-shared flow network (NVLink ports, NVSwitch fabric,
//!   copy engines, HBM).
//! * [`xfer`] — the three transfer mechanisms (copy engine, TMA, register
//!   ops) plus NVSwitch multimem, with the bandwidth curves of
//!   Table 1 / Figures 2–3.
//! * [`plan`] — the tile-granularity Plan IR shared by both executors.
//! * [`exec`] — `FunctionalExec` (moves real data, computes real numerics)
//!   and `TimedExec` (discrete-event timing) over the same plans.
//! * [`pk`] — the paper's contribution: the eight primitives, `barrier_t`
//!   synchronization, the LCSC program template, and the SM-partition
//!   auto-tuner.
//! * [`comm`] — library-design baselines: NCCL-style ring collectives with
//!   two-way rendezvous + channel staging, NVSHMEM-style register transfers.
//! * [`kernels`] — the paper's evaluated kernels: fused AG+GEMM, GEMM+RS,
//!   GEMM+AR, Ring Attention, DeepSpeed-Ulysses all-to-all attention, and
//!   MoE token dispatch + grouped GEMM.
//! * [`baselines`] — behavioural models of the paper's comparators
//!   (non-overlapped cuBLAS+NCCL, Flux, Triton-Distributed, CUTLASS
//!   distributed GEMM, xDiT, YunChang, Comet).
//! * [`runtime`] — PJRT runtime: loads the AOT HLO-text artifacts produced
//!   by `python/compile/aot.py` and executes them on the request path.
//! * [`coordinator`] — tokio leader/worker node driving multi-device runs.
//! * [`report`] — regenerates every table and figure of the paper.

pub mod baselines;
pub mod comm;
pub mod coordinator;
pub mod exec;
pub mod hw;
pub mod kernels;
pub mod mem;
pub mod model;
pub mod pk;
pub mod plan;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod xfer;

pub use hw::spec::{Arch, GpuSpec, NodeSpec};
pub use mem::pgl::Pgl;
