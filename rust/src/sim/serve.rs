//! Trace-driven inference serving layer: continuous batching on top of
//! the timed kernel schedules.
//!
//! This is the layer that turns per-kernel overlap claims into end-to-end
//! serving claims (ROADMAP north star): what does the PK-overlapped
//! GEMM+RS buy at p99 latency under an open-loop request trace, versus
//! the same engine stepping on `baselines::nonoverlap` kernels?
//!
//! * **Step cost** ([`StepCostModel`]) — the per-layer cost of one engine
//!   iteration at a given batched token count is *calibrated* by running
//!   the timed kernel schedules ([`crate::kernels::gemm_rs`] under
//!   [`Schedule::IntraSm`] for [`KernelMode::PkOverlap`];
//!   [`crate::baselines::nonoverlap::gemm_rs`] for
//!   [`KernelMode::Nonoverlap`]) at a few batch-token knots and
//!   interpolating piecewise-linearly between them. The serving engine
//!   itself never re-runs the DES per step — calibration happens once.
//! * **Continuous batching** — each engine step serves one decode token
//!   per active request plus admitted prefill tokens, under a per-step
//!   token budget and a KV-capacity admission gate (the gate is what
//!   creates queueing, and queueing is what makes p99 explode past the
//!   saturation knee).
//! * **Prefill/decode disaggregation** — on `K ≥ 2` nodes, `⌊K/2⌋`
//!   (min 1) nodes run prefill and the rest run decode; finished prefill
//!   KV rides the RDMA fabric ([`crate::xfer::curves::rdma_rate`], chunk
//!   sized by [`crate::pk::tuner::analytic_rdma_chunk`]) and serializes
//!   on the destination node's NIC-ingress FIFO, exactly like every
//!   other cross-node flow in the repo.
//! * **Scheduler policies** ([`SchedPolicy`]) — FCFS (strict
//!   head-of-line), priority (high class may bypass a blocked head), and
//!   chunked prefill (per-step prefill token cap, bounding decode-token
//!   latency jitter).
//!
//! The protocol (no request lost or duplicated, KV occupancy
//! conservation, FCFS ordering) is asserted inline on every run and
//! mirrored by the pure-Python executable model in
//! `python/tests/test_serve_model.py`, which verifies the same scheduler
//! logic in the toolchain-less container.
//!
//! [`Schedule::IntraSm`]: crate::kernels::gemm_rs::Schedule::IntraSm

use crate::baselines::nonoverlap;
use crate::exec::TimedExec;
use crate::hw::cluster::ClusterSpec;
use crate::hw::spec::NodeSpec;
use crate::kernels::gemm_rs::{self, Schedule};
use crate::kernels::GemmKernelCfg;
use crate::pk::tuner::analytic_rdma_chunk;
use crate::sim::fault::{FaultSpec, LinkFault};
use crate::sim::workload::{generate, ArrivalProcess, Request, TraceCfg};
use crate::util::stats::{percentile, summarize, Summary};
use crate::xfer::curves;

/// Which kernel schedules the engine steps on (the ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// PK intra-SM overlapped GEMM+RS per transformer layer.
    PkOverlap,
    /// cuBLAS GEMM + NCCL RS as separate kernels (comm fully exposed).
    Nonoverlap,
}

/// Scheduler policy of the continuous-batching engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Strict FCFS: a head-of-line request blocked on KV capacity blocks
    /// everything behind it (the ordering guarantee the protocol tests
    /// pin).
    Fcfs,
    /// High class (priority 1) may bypass a blocked head of line.
    Priority,
    /// FCFS, but at most `chunk` prefill tokens join any one step —
    /// bounds the latency jitter a long prompt injects into co-running
    /// decodes.
    ChunkedPrefill { chunk: usize },
}

/// The served model, reduced to what the cost/capacity model needs.
#[derive(Clone, Copy, Debug)]
pub struct ModelCfg {
    /// Transformer layers; each engine step pays `layers ×` the per-layer
    /// knot cost.
    pub layers: usize,
    /// KV-cache bytes per token across all layers (GQA-style 8 KV heads ×
    /// 128 head dim × K&V × fp8 in the reference config).
    pub kv_bytes_per_token: f64,
}

impl ModelCfg {
    /// Reference 32-layer, hidden-8192 chat model.
    pub fn reference() -> Self {
        ModelCfg { layers: 32, kv_bytes_per_token: 65536.0 }
    }
}

/// Full serving-engine configuration.
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub cluster: ClusterSpec,
    pub mode: KernelMode,
    pub policy: SchedPolicy,
    pub model: ModelCfg,
    /// Per-step batched token budget (decode tokens + prefill tokens).
    pub max_batch_tokens: usize,
    /// KV capacity per (decode) node, in tokens; admission reserves
    /// `prompt + output` tokens and frees them at completion.
    pub kv_capacity_tokens: usize,
    /// SLO: time-to-first-token budget (seconds).
    pub slo_ttft: f64,
    /// SLO: per-output-token budget (seconds/token).
    pub slo_tpot: f64,
    /// Optional injected fault scenario ([`crate::sim::fault`]). In the
    /// serving layer `nic=` clauses index **nodes** (prefill nodes first,
    /// then decode nodes): an active window throttles (or, at `frac = 0`,
    /// stalls until restore) the KV transfers into that decode node's
    /// NIC-ingress FIFO, and a hard failure with no restore takes the
    /// node out of dispatch rotation entirely — the fleet-level analogue
    /// of the rail reroute. `straggler=` clauses scale a node's step
    /// rate. `jitter=` applies to the kernel-level DES only and is
    /// ignored here.
    pub fault: Option<FaultSpec>,
}

impl ServeCfg {
    /// The reference serving setup used by the `vx1` exhibit.
    pub fn reference(cluster: ClusterSpec, mode: KernelMode) -> Self {
        ServeCfg {
            cluster,
            mode,
            policy: SchedPolicy::Fcfs,
            model: ModelCfg::reference(),
            max_batch_tokens: 4096,
            kv_capacity_tokens: 262_144,
            slo_ttft: 0.2,
            slo_tpot: 2e-3,
            fault: None,
        }
    }
}

/// Per-layer engine-step cost as a function of batched token count,
/// calibrated from the timed kernel schedules.
#[derive(Clone, Debug)]
pub struct StepCostModel {
    /// `(batch_tokens, seconds per layer)`, ascending in tokens; knot 0
    /// is the launch-overhead floor (one fused launch for PK, two kernel
    /// launches for the non-overlapped baseline).
    pub knots: Vec<(f64, f64)>,
    pub layers: usize,
}

/// Batch-token knots the calibration simulates. `m` must divide by
/// `n_dev × tile_m = 1024` on the 8-GPU reference node (the GEMM+RS
/// builder's sharding constraint), so these are the smallest usable grid.
const CALIB_KNOTS: [usize; 3] = [1024, 4096, 16384];

impl StepCostModel {
    /// Calibrate by running the timed schedules at each knot: the
    /// per-layer projection is `[m = batch tokens] × 8192 × 8192` through
    /// the fused (or unfused) GEMM+RS on one node.
    pub fn calibrate(node: &NodeSpec, mode: KernelMode, model: &ModelCfg) -> Self {
        let launch = node.gpu.kernel_launch;
        let floor = match mode {
            KernelMode::PkOverlap => launch,
            KernelMode::Nonoverlap => 2.0 * launch,
        };
        let mut knots = vec![(0.0, floor)];
        for m in CALIB_KNOTS {
            let cfg = GemmKernelCfg::new(node.clone(), m, 8192, 8192);
            let t = match mode {
                KernelMode::PkOverlap => TimedExec::new(node.clone())
                    .run(&gemm_rs::build(&cfg, Schedule::IntraSm, None))
                    .total_time,
                KernelMode::Nonoverlap => nonoverlap::gemm_rs(&cfg),
            };
            knots.push((m as f64, t));
        }
        StepCostModel { knots, layers: model.layers }
    }

    /// Wall-clock cost of one engine step over `tokens` batched tokens:
    /// `layers ×` the piecewise-linear interpolation of the knots (linear
    /// extrapolation past the last knot).
    pub fn step_time(&self, tokens: usize) -> f64 {
        let x = tokens as f64;
        let k = &self.knots;
        let last = k.len() - 1;
        let per_layer = if x >= k[last].0 {
            let (x0, y0) = k[last - 1];
            let (x1, y1) = k[last];
            y1 + (x - x1) * (y1 - y0) / (x1 - x0)
        } else {
            let i = k.windows(2).position(|w| x < w[1].0).expect("ascending knots");
            let (x0, y0) = k[i];
            let (x1, y1) = k[i + 1];
            y0 + (x - x0) * (y1 - y0) / (x1 - x0)
        };
        self.layers as f64 * per_layer
    }
}

/// One completed request (the unit every metric is computed from).
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub id: usize,
    pub arrival: f64,
    pub first_token: f64,
    pub finish: f64,
    pub output_tokens: usize,
    pub priority: u8,
}

/// Aggregated serving metrics of one trace run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub n_requests: usize,
    /// Makespan: time of the last completion.
    pub duration: f64,
    pub output_tokens: usize,
    pub tokens_per_s: f64,
    /// Completed requests per second that met the SLO
    /// (`latency ≤ slo_ttft + output × slo_tpot`).
    pub goodput_rps: f64,
    pub latency_p50: f64,
    pub latency_p99: f64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub mean_step_tokens: f64,
    pub max_step_tokens: usize,
    /// Largest prefill-token share of any single step (chunked prefill
    /// caps this at `chunk`).
    pub max_prefill_step_tokens: usize,
    pub kv_peak_tokens: usize,
    pub slo_violations: usize,
    /// Latency summary over the violators — legitimately `None` at low
    /// load (the empty-sample path `util::stats` now supports).
    pub violator_latency: Option<Summary>,
}

#[derive(Clone, Copy, Debug)]
struct Job {
    req: Request,
    /// When this node may first see the job (arrival, or KV-landing time
    /// on a disaggregated decode node).
    ready: f64,
    prefill_left: usize,
    generated: usize,
    first_token: Option<f64>,
}

#[derive(Clone, Copy, Debug)]
struct Active {
    job: Job,
}

#[derive(Clone, Copy, Debug, Default)]
struct StepStats {
    steps: u64,
    token_steps: u64,
    max_step_tokens: usize,
    max_prefill_step_tokens: usize,
    kv_peak: usize,
}

impl StepStats {
    fn merge(&mut self, o: &StepStats) {
        self.steps += o.steps;
        self.token_steps += o.token_steps;
        self.max_step_tokens = self.max_step_tokens.max(o.max_step_tokens);
        self.max_prefill_step_tokens = self.max_prefill_step_tokens.max(o.max_prefill_step_tokens);
        self.kv_peak = self.kv_peak.max(o.kv_peak);
    }
}

/// The continuous-batching engine of one node (colocated, or the decode
/// half of a disaggregated pair).
struct Engine<'a> {
    cost: &'a StepCostModel,
    policy: SchedPolicy,
    max_batch_tokens: usize,
    kv_capacity_tokens: usize,
}

impl Engine<'_> {
    fn sort_queue(&self, queue: &mut [Job]) {
        match self.policy {
            SchedPolicy::Priority => queue.sort_by(|a, b| {
                b.req
                    .priority
                    .cmp(&a.req.priority)
                    .then(a.req.arrival.total_cmp(&b.req.arrival))
                    .then(a.req.id.cmp(&b.req.id))
            }),
            _ => queue.sort_by(|a, b| {
                a.req.arrival.total_cmp(&b.req.arrival).then(a.req.id.cmp(&b.req.id))
            }),
        }
    }

    /// Run the node to completion over `jobs` (sorted by `ready`
    /// internally). Work-conserving: steps happen only while admitted
    /// work exists; otherwise time jumps to the next ready job.
    fn run_node(&self, mut jobs: Vec<Job>) -> (Vec<Completion>, StepStats) {
        jobs.sort_by(|a, b| a.ready.total_cmp(&b.ready).then(a.req.id.cmp(&b.req.id)));
        let mut queue: Vec<Job> = vec![];
        let mut active: Vec<Active> = vec![];
        let mut comps: Vec<Completion> = Vec::with_capacity(jobs.len());
        let mut stats = StepStats::default();
        let mut kv_used = 0usize;
        let mut ji = 0usize;
        let mut t = 0.0f64;
        loop {
            // pull arrivals
            let mut pulled = false;
            while ji < jobs.len() && jobs[ji].ready <= t {
                queue.push(jobs[ji]);
                ji += 1;
                pulled = true;
            }
            if pulled {
                self.sort_queue(&mut queue);
            }
            // admission: KV reservation + concurrency cap. FCFS blocks on
            // the head; Priority may scan past a blocked job.
            let mut i = 0;
            while i < queue.len() {
                let need = queue[i].req.prompt_tokens + queue[i].req.output_tokens;
                assert!(
                    need <= self.kv_capacity_tokens,
                    "request {} needs {need} KV tokens > capacity {}",
                    queue[i].req.id,
                    self.kv_capacity_tokens
                );
                if active.len() < self.max_batch_tokens && kv_used + need <= self.kv_capacity_tokens
                {
                    kv_used += need;
                    stats.kv_peak = stats.kv_peak.max(kv_used);
                    active.push(Active { job: queue.remove(i) });
                } else if self.policy == SchedPolicy::Priority {
                    i += 1;
                } else {
                    break;
                }
            }
            if active.is_empty() {
                // nothing admitted: the trace is drained, or time must
                // jump to the next ready job (queue is empty here — an
                // empty engine always admits, per the capacity assert)
                debug_assert!(queue.is_empty());
                if ji >= jobs.len() {
                    break;
                }
                t = t.max(jobs[ji].ready);
                continue;
            }
            // form the step: one decode token per decoding request plus
            // admitted prefill tokens under the remaining budget
            let decode_idx: Vec<usize> = active
                .iter()
                .enumerate()
                .filter(|(_, a)| a.job.prefill_left == 0)
                .map(|(i, _)| i)
                .collect();
            let mut budget = self.max_batch_tokens.saturating_sub(decode_idx.len());
            if let SchedPolicy::ChunkedPrefill { chunk } = self.policy {
                assert!(chunk > 0, "chunked prefill needs a positive chunk");
                budget = budget.min(chunk);
            }
            let mut prefill_alloc: Vec<(usize, usize)> = vec![];
            for (ai, a) in active.iter().enumerate() {
                if a.job.prefill_left > 0 && budget > 0 {
                    let take = a.job.prefill_left.min(budget);
                    budget -= take;
                    prefill_alloc.push((ai, take));
                }
            }
            let prefill_tokens: usize = prefill_alloc.iter().map(|p| p.1).sum();
            let step_tokens = decode_idx.len() + prefill_tokens;
            debug_assert!(step_tokens > 0, "active work must produce a step");
            let dt = self.cost.step_time(step_tokens);
            t += dt;
            stats.steps += 1;
            stats.token_steps += step_tokens as u64;
            stats.max_step_tokens = stats.max_step_tokens.max(step_tokens);
            stats.max_prefill_step_tokens = stats.max_prefill_step_tokens.max(prefill_tokens);
            // apply prefill progress; a finished prefill emits the first
            // token in the same step (the engine's prefill step produces
            // logits for token 1)
            for &(ai, take) in &prefill_alloc {
                let j = &mut active[ai].job;
                j.prefill_left -= take;
                if j.prefill_left == 0 {
                    j.generated = 1;
                    j.first_token = Some(t);
                }
            }
            // apply decode progress to the requests that were decoding
            // when the step formed
            for &ai in &decode_idx {
                let j = &mut active[ai].job;
                j.generated += 1;
                if j.first_token.is_none() {
                    j.first_token = Some(t);
                }
            }
            // retire completions, freeing their KV reservation
            let mut ai = 0;
            while ai < active.len() {
                let j = active[ai].job;
                if j.prefill_left == 0 && j.generated >= j.req.output_tokens {
                    kv_used -= j.req.prompt_tokens + j.req.output_tokens;
                    comps.push(Completion {
                        id: j.req.id,
                        arrival: j.req.arrival,
                        first_token: j.first_token.unwrap_or(t),
                        finish: t,
                        output_tokens: j.req.output_tokens,
                        priority: j.req.priority,
                    });
                    active.remove(ai);
                } else {
                    ai += 1;
                }
            }
        }
        assert_eq!(kv_used, 0, "KV occupancy must return to zero when drained");
        (comps, stats)
    }
}

/// Total prefill service time of one prompt on a dedicated prefill node
/// (chunked policies pay per-chunk launches).
fn prefill_service(cost: &StepCostModel, policy: SchedPolicy, prompt: usize) -> f64 {
    match policy {
        SchedPolicy::ChunkedPrefill { chunk } => {
            let mut left = prompt;
            let mut total = 0.0;
            while left > 0 {
                let take = left.min(chunk);
                total += cost.step_time(take);
                left -= take;
            }
            total
        }
        _ => cost.step_time(prompt),
    }
}

/// Compute-rate scale of node `node_id` under the fault scenario
/// (straggler clauses compose multiplicatively).
fn node_rate(fault: &Option<FaultSpec>, node_id: usize) -> f64 {
    fault.as_ref().map_or(1.0, |f| {
        f.stragglers.iter().filter(|(d, _)| *d == node_id).map(|(_, s)| *s).product()
    })
}

/// The cost model slowed to compute-rate `rate` (times scale by 1/rate).
fn scaled_cost(cost: &StepCostModel, rate: f64) -> StepCostModel {
    if rate >= 1.0 {
        return cost.clone();
    }
    StepCostModel {
        knots: cost.knots.iter().map(|&(x, y)| (x, y / rate)).collect(),
        layers: cost.layers,
    }
}

/// True when `node_id`'s NIC is hard-failed and never restored — the
/// dispatcher takes the node out of rotation entirely rather than park
/// requests on a link that will never move them.
fn nic_dead_forever(fault: &Option<FaultSpec>, node_id: usize) -> bool {
    fault.as_ref().map_or(false, |f| {
        f.nic_faults
            .iter()
            .any(|lf| lf.device == node_id && lf.frac <= 1e-9 && lf.restore_at.is_none())
    })
}

/// Finish time of a KV transfer of `bytes` starting at `start` into a
/// NIC whose rate is scaled by the active fault windows: the transfer
/// runs at `rate × ∏ frac` of the windows covering each instant, and an
/// outage (`frac = 0`) stalls it until the window's restore. Each loop
/// step either finishes the transfer or advances `t` to a strictly later
/// window boundary, so it terminates.
fn faulted_xfer_end(start: f64, bytes: f64, rate: f64, latency: f64, faults: &[&LinkFault]) -> f64 {
    let mut t = start + latency;
    let mut left = bytes;
    loop {
        let scale: f64 = faults
            .iter()
            .filter(|f| f.at <= t && f.restore_at.map_or(true, |r| t < r))
            .map(|f| f.frac)
            .product();
        let next = faults
            .iter()
            .flat_map(|f| [Some(f.at), f.restore_at])
            .flatten()
            .filter(|&b| b > t)
            .fold(f64::INFINITY, f64::min);
        let eff = rate * scale;
        if eff <= 1e-30 {
            assert!(
                next.is_finite(),
                "KV transfer stalled on a never-restored NIC (the dispatcher should have \
                 routed around it)"
            );
            t = next;
            continue;
        }
        if left <= eff * (next - t) {
            return t + left / eff;
        }
        left -= eff * (next - t);
        t = next;
    }
}

/// Disaggregated prefill/decode over `K ≥ 2` nodes: `⌊K/2⌋` (min 1)
/// prefill nodes feed the remaining decode nodes; KV crosses the RDMA
/// fabric and serializes on each decode node's NIC-ingress FIFO.
fn run_disaggregated(
    cfg: &ServeCfg,
    cost: &StepCostModel,
    eng: &Engine,
    trace: &[Request],
) -> (Vec<Completion>, StepStats) {
    let k = cfg.cluster.num_nodes;
    debug_assert!(k >= 2);
    let n_prefill = (k / 2).max(1);
    let n_decode = k - n_prefill;
    // per-node cost models under straggler scaling (node ids: prefill
    // nodes first, then decode nodes)
    let pf_cost: Vec<StepCostModel> =
        (0..n_prefill).map(|s| scaled_cost(cost, node_rate(&cfg.fault, s))).collect();
    // --- prefill: a single policy-ordered queue over n_prefill servers
    let mut free = vec![0.0f64; n_prefill];
    let mut ready: Vec<usize> = vec![];
    let mut next = 0usize;
    let mut pf_end = vec![0.0f64; trace.len()];
    let mut stats = StepStats::default();
    let mut dispatched = 0usize;
    while dispatched < trace.len() {
        let (srv, tfree) = free
            .iter()
            .copied()
            .enumerate()
            .fold((0usize, f64::INFINITY), |acc, (i, v)| if v < acc.1 { (i, v) } else { acc });
        let mut t_now = tfree;
        if ready.is_empty() {
            t_now = t_now.max(trace[next].arrival);
        }
        while next < trace.len() && trace[next].arrival <= t_now {
            ready.push(next);
            next += 1;
        }
        debug_assert!(!ready.is_empty());
        let pick = match eng.policy {
            SchedPolicy::Priority => {
                let mut best = 0usize;
                for (pi, &r) in ready.iter().enumerate() {
                    let (bp, br) = (trace[ready[best]], trace[r]);
                    if (br.priority, std::cmp::Reverse(br.id)) > (bp.priority, std::cmp::Reverse(bp.id))
                    {
                        best = pi;
                    }
                }
                best
            }
            _ => 0, // `ready` is pushed in arrival order
        };
        let r = ready.remove(pick);
        let start = t_now.max(trace[r].arrival);
        let service = prefill_service(&pf_cost[srv], eng.policy, trace[r].prompt_tokens);
        pf_end[r] = start + service;
        free[srv] = pf_end[r];
        stats.steps += 1;
        stats.token_steps += trace[r].prompt_tokens as u64;
        let chunked = match eng.policy {
            SchedPolicy::ChunkedPrefill { chunk } => trace[r].prompt_tokens.min(chunk),
            _ => trace[r].prompt_tokens,
        };
        stats.max_prefill_step_tokens = stats.max_prefill_step_tokens.max(chunked);
        stats.max_step_tokens = stats.max_step_tokens.max(chunked);
        dispatched += 1;
    }
    // --- KV transfer + decode-node assignment (least-loaded, then FIFO
    // on the destination NIC ingress)
    let mut order: Vec<usize> = (0..trace.len()).collect();
    order.sort_by(|&a, &b| pf_end[a].total_cmp(&pf_end[b]).then(a.cmp(&b)));
    let mut ingress_free = vec![0.0f64; n_decode];
    let mut assigned_kv = vec![0usize; n_decode];
    let mut jobs_per_node: Vec<Vec<Job>> = vec![vec![]; n_decode];
    let mut comps: Vec<Completion> = vec![];
    for &r in &order {
        let req = trace[r];
        if req.output_tokens <= 1 {
            // the prefill step already produced the only output token
            comps.push(Completion {
                id: req.id,
                arrival: req.arrival,
                first_token: pf_end[r],
                finish: pf_end[r],
                output_tokens: req.output_tokens,
                priority: req.priority,
            });
            continue;
        }
        let kv_bytes = req.prompt_tokens as f64 * cfg.model.kv_bytes_per_token;
        let chunk = analytic_rdma_chunk(&cfg.cluster, kv_bytes);
        let rate = curves::rdma_rate(&cfg.cluster, chunk);
        let dn = (0..n_decode)
            .filter(|&d| !nic_dead_forever(&cfg.fault, n_prefill + d))
            .min_by_key(|&d| (assigned_kv[d], d))
            .expect("every decode node's NIC is permanently failed — no dispatch target left");
        let start = ingress_free[dn].max(pf_end[r]);
        let nf: Vec<&LinkFault> = cfg.fault.as_ref().map_or_else(Vec::new, |f| {
            f.nic_faults.iter().filter(|lf| lf.device == n_prefill + dn).collect()
        });
        ingress_free[dn] = if nf.is_empty() {
            start + cfg.cluster.nic_latency + kv_bytes / rate
        } else {
            faulted_xfer_end(start, kv_bytes, rate, cfg.cluster.nic_latency, &nf)
        };
        assigned_kv[dn] += req.prompt_tokens + req.output_tokens;
        jobs_per_node[dn].push(Job {
            req,
            ready: ingress_free[dn],
            prefill_left: 0,
            generated: 1,
            first_token: Some(pf_end[r]),
        });
    }
    for (dn, jobs) in jobs_per_node.into_iter().enumerate() {
        let ncost = scaled_cost(eng.cost, node_rate(&cfg.fault, n_prefill + dn));
        let neng = Engine {
            cost: &ncost,
            policy: eng.policy,
            max_batch_tokens: eng.max_batch_tokens,
            kv_capacity_tokens: eng.kv_capacity_tokens,
        };
        let (c, s) = neng.run_node(jobs);
        comps.extend(c);
        stats.merge(&s);
    }
    (comps, stats)
}

/// Run the serving engine over a trace with a pre-calibrated cost model
/// (the exhibit calibrates once per mode and reuses it across rows).
pub fn run_with_cost(cfg: &ServeCfg, cost: &StepCostModel, trace: &[Request]) -> ServeReport {
    run_detailed(cfg, cost, trace).0
}

/// Like [`run_with_cost`] but also returns the per-request completions
/// (id-sorted) — the protocol tests assert ordering properties on them.
pub fn run_detailed(
    cfg: &ServeCfg,
    cost: &StepCostModel,
    trace: &[Request],
) -> (ServeReport, Vec<Completion>) {
    assert!(!trace.is_empty(), "serve needs a non-empty trace");
    // colocated: node 0 is the whole system, so a straggler clause on it
    // scales every step (disaggregation scales per node inside
    // `run_disaggregated` instead)
    let cost0 =
        scaled_cost(cost, if cfg.cluster.num_nodes == 1 { node_rate(&cfg.fault, 0) } else { 1.0 });
    let eng = Engine {
        cost: &cost0,
        policy: cfg.policy,
        max_batch_tokens: cfg.max_batch_tokens,
        kv_capacity_tokens: cfg.kv_capacity_tokens,
    };
    let (mut comps, stats) = if cfg.cluster.num_nodes == 1 {
        let jobs: Vec<Job> = trace
            .iter()
            .map(|&req| Job {
                req,
                ready: req.arrival,
                prefill_left: req.prompt_tokens,
                generated: 0,
                first_token: None,
            })
            .collect();
        eng.run_node(jobs)
    } else {
        run_disaggregated(cfg, cost, &eng, trace)
    };
    // protocol invariants: every request completes exactly once
    assert_eq!(comps.len(), trace.len(), "request lost or duplicated");
    comps.sort_by_key(|c| c.id);
    for w in comps.windows(2) {
        assert_ne!(w[0].id, w[1].id, "duplicate completion id {}", w[0].id);
    }
    let latencies: Vec<f64> = comps.iter().map(|c| c.finish - c.arrival).collect();
    let ttfts: Vec<f64> = comps.iter().map(|c| c.first_token - c.arrival).collect();
    let duration = comps.iter().map(|c| c.finish).fold(0.0, f64::max);
    let output_tokens: usize = comps.iter().map(|c| c.output_tokens).sum();
    let slo_ok = |c: &Completion| {
        c.finish - c.arrival <= cfg.slo_ttft + c.output_tokens as f64 * cfg.slo_tpot
    };
    let met = comps.iter().filter(|c| slo_ok(c)).count();
    let violator_lat: Vec<f64> =
        comps.iter().filter(|c| !slo_ok(c)).map(|c| c.finish - c.arrival).collect();
    let report = ServeReport {
        n_requests: comps.len(),
        duration,
        output_tokens,
        tokens_per_s: output_tokens as f64 / duration,
        goodput_rps: met as f64 / duration,
        latency_p50: percentile(&latencies, 50.0).unwrap_or(0.0),
        latency_p99: percentile(&latencies, 99.0).unwrap_or(0.0),
        ttft_p50: percentile(&ttfts, 50.0).unwrap_or(0.0),
        ttft_p99: percentile(&ttfts, 99.0).unwrap_or(0.0),
        mean_step_tokens: stats.token_steps as f64 / stats.steps.max(1) as f64,
        max_step_tokens: stats.max_step_tokens,
        max_prefill_step_tokens: stats.max_prefill_step_tokens,
        kv_peak_tokens: stats.kv_peak,
        slo_violations: comps.len() - met,
        violator_latency: summarize(&violator_lat),
    };
    (report, comps)
}

/// Calibrate and run (one-shot convenience; see [`run_with_cost`]).
pub fn run(cfg: &ServeCfg, trace: &[Request]) -> ServeReport {
    let cost = StepCostModel::calibrate(&cfg.cluster.node, cfg.mode, &cfg.model);
    run_with_cost(cfg, &cost, trace)
}

/// Deterministic capacity probe: back-to-back offered load (all arrivals
/// at t = 0) measures the system's saturation throughput in requests/s;
/// the load grid of the `vx1` exhibit is expressed as fractions of this.
pub fn capacity_probe(cfg: &ServeCfg, cost: &StepCostModel, n: usize, seed: u64) -> f64 {
    let mut trace = generate(&TraceCfg::chat(ArrivalProcess::Poisson, 1.0, n, seed));
    for r in trace.iter_mut() {
        r.arrival = 0.0;
    }
    let rep = run_with_cost(cfg, cost, &trace);
    n as f64 / rep.duration
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Cheap hand-built cost model for protocol tests (no DES run).
    fn toy_cost() -> StepCostModel {
        StepCostModel { knots: vec![(0.0, 1e-5), (1024.0, 1e-4)], layers: 10 }
    }

    fn toy_cfg(nodes: usize) -> ServeCfg {
        ServeCfg::reference(ClusterSpec::hgx_h100_pod(nodes), KernelMode::PkOverlap)
    }

    fn chat_trace(rate: f64, n: usize, seed: u64) -> Vec<Request> {
        generate(&TraceCfg::chat(ArrivalProcess::Poisson, rate, n, seed))
    }

    #[test]
    fn step_time_interpolates_and_extrapolates() {
        let c = toy_cost();
        assert!((c.step_time(0) - 10.0 * 1e-5).abs() < 1e-12);
        assert!((c.step_time(512) - 10.0 * 5.5e-5).abs() < 1e-12);
        assert!((c.step_time(1024) - 10.0 * 1e-4).abs() < 1e-12);
        // linear extrapolation continues the last segment's slope
        assert!((c.step_time(2048) - 10.0 * 1.9e-4).abs() < 1e-10);
    }

    #[test]
    fn calibrated_pk_strictly_beats_nonoverlap_per_step() {
        let node = NodeSpec::hgx_h100();
        let model = ModelCfg::reference();
        let pk = StepCostModel::calibrate(&node, KernelMode::PkOverlap, &model);
        let base = StepCostModel::calibrate(&node, KernelMode::Nonoverlap, &model);
        for t in [1usize, 64, 512, 1024, 4096, 16384] {
            assert!(
                pk.step_time(t) < base.step_time(t),
                "PK must be cheaper at {t} tokens: {} vs {}",
                pk.step_time(t),
                base.step_time(t)
            );
        }
    }

    #[test]
    fn colocated_serves_every_request_exactly_once() {
        let cfg = toy_cfg(1);
        let trace = chat_trace(200.0, 300, 17);
        let rep = run_with_cost(&cfg, &toy_cost(), &trace);
        // the run_with_cost asserts already checked no-loss/no-dup;
        // sanity-check the derived metrics
        assert_eq!(rep.n_requests, 300);
        assert!(rep.duration > 0.0 && rep.duration.is_finite());
        assert!(rep.tokens_per_s > 0.0);
        assert_eq!(rep.output_tokens, trace.iter().map(|r| r.output_tokens).sum::<usize>());
        assert!(rep.kv_peak_tokens <= cfg.kv_capacity_tokens);
        assert!(rep.latency_p99 >= rep.latency_p50);
    }

    #[test]
    fn kv_capacity_gates_admission_but_loses_nothing() {
        let mut cfg = toy_cfg(1);
        cfg.kv_capacity_tokens = 6000; // roughly two chat requests
        let trace = chat_trace(500.0, 120, 5);
        let rep = run_with_cost(&cfg, &toy_cost(), &trace);
        assert_eq!(rep.n_requests, 120);
        assert!(rep.kv_peak_tokens <= 6000, "gate respected: {}", rep.kv_peak_tokens);
    }

    #[test]
    fn fcfs_first_tokens_follow_arrival_order() {
        // strict head-of-line FCFS: first tokens are non-decreasing in
        // arrival order (the ordering guarantee the Python protocol model
        // mirrors)
        let mut cfg = toy_cfg(1);
        cfg.kv_capacity_tokens = 8192; // force queueing so ordering matters
        let trace = chat_trace(300.0, 200, 23);
        let (_, comps) = run_detailed(&cfg, &toy_cost(), &trace);
        let mut by_arrival = comps.clone();
        by_arrival.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        for w in by_arrival.windows(2) {
            assert!(
                w[1].first_token >= w[0].first_token - 1e-12,
                "FCFS order broken: req {} (arr {}) got its first token before req {} (arr {})",
                w[1].id,
                w[1].arrival,
                w[0].id,
                w[0].arrival
            );
        }
    }

    #[test]
    fn priority_cuts_high_class_latency_under_overload() {
        let trace = chat_trace(2000.0, 250, 31); // heavy overload for toy cost
        assert!(trace.iter().any(|r| r.priority == 1), "trace needs a high class");
        let mut cfg_prio = toy_cfg(1);
        cfg_prio.policy = SchedPolicy::Priority;
        cfg_prio.kv_capacity_tokens = 8192; // force queueing so bypass matters
        let mut cfg_fcfs = toy_cfg(1);
        cfg_fcfs.kv_capacity_tokens = 8192;
        let cost = toy_cost();
        let hi_mean = |comps: &[Completion]| {
            let lats: Vec<f64> = comps
                .iter()
                .filter(|c| c.priority == 1)
                .map(|c| c.finish - c.arrival)
                .collect();
            summarize(&lats).expect("high class present").mean
        };
        let (_, comps_p) = run_detailed(&cfg_prio, &cost, &trace);
        let (_, comps_f) = run_detailed(&cfg_fcfs, &cost, &trace);
        assert_eq!(comps_p.len(), comps_f.len(), "priority must not drop requests");
        assert!(
            hi_mean(&comps_p) < hi_mean(&comps_f),
            "priority must cut the high class's latency: {} vs {}",
            hi_mean(&comps_p),
            hi_mean(&comps_f)
        );
    }

    #[test]
    fn chunked_prefill_caps_per_step_prefill_tokens() {
        let mut cfg = toy_cfg(1);
        cfg.policy = SchedPolicy::ChunkedPrefill { chunk: 256 };
        let trace = chat_trace(400.0, 150, 41);
        let rep = run_with_cost(&cfg, &toy_cost(), &trace);
        assert!(
            rep.max_prefill_step_tokens <= 256,
            "chunk cap violated: {}",
            rep.max_prefill_step_tokens
        );
        // plain FCFS admits whole prompts: with 512-token mean prompts the
        // uncapped engine must exceed the chunk at least once
        let mut cfg2 = toy_cfg(1);
        cfg2.policy = SchedPolicy::Fcfs;
        let rep2 = run_with_cost(&cfg2, &toy_cost(), &trace);
        assert!(rep2.max_prefill_step_tokens > 256, "{}", rep2.max_prefill_step_tokens);
    }

    #[test]
    fn disaggregated_two_nodes_completes_with_kv_transfer_in_ttft() {
        let cfg = toy_cfg(2);
        let trace = chat_trace(100.0, 120, 9);
        let rep = run_with_cost(&cfg, &toy_cost(), &trace);
        assert_eq!(rep.n_requests, 120);
        // TTFT must at least cover one prefill service (first token is
        // produced by the prefill node)
        let min_prefill = toy_cost().step_time(1);
        assert!(rep.ttft_p50 >= min_prefill, "{} vs {min_prefill}", rep.ttft_p50);
        assert!(rep.latency_p50 >= rep.ttft_p50);
    }

    #[test]
    fn overload_blows_up_the_tail() {
        let cfg = toy_cfg(1);
        let cost = toy_cost();
        let lo = run_with_cost(&cfg, &cost, &chat_trace(50.0, 200, 3));
        let hi = run_with_cost(&cfg, &cost, &chat_trace(5000.0, 200, 3));
        assert!(
            hi.latency_p99 > lo.latency_p99 * 2.0,
            "saturation must inflate p99: {} vs {}",
            hi.latency_p99,
            lo.latency_p99
        );
    }

    #[test]
    fn capacity_probe_is_positive_and_deterministic() {
        let cfg = toy_cfg(1);
        let cost = toy_cost();
        let a = capacity_probe(&cfg, &cost, 64, 7);
        let b = capacity_probe(&cfg, &cost, 64, 7);
        assert!(a > 0.0 && a.is_finite());
        assert_eq!(a, b);
    }

    #[test]
    fn mid_trace_nic_outage_delays_but_loses_nothing() {
        let cost = toy_cost();
        let trace = chat_trace(100.0, 120, 9);
        let healthy = run_with_cost(&toy_cfg(2), &cost, &trace);
        // outage on the decode node (node 1) from 20% of the healthy
        // makespan until well past it: every KV transfer starting inside
        // the window stalls to the restore, so the makespan must cross it
        let mut cfg = toy_cfg(2);
        cfg.fault = Some(FaultSpec::seeded(1).with_nic_fault(LinkFault {
            device: 1,
            at: 0.2 * healthy.duration,
            frac: 0.0,
            restore_at: Some(2.0 * healthy.duration),
        }));
        let faulted = run_with_cost(&cfg, &cost, &trace);
        // run_with_cost already asserted no request was lost or duplicated
        assert_eq!(faulted.n_requests, 120);
        assert!(
            faulted.duration >= 2.0 * healthy.duration * (1.0 - 1e-9),
            "stalled transfers must push the makespan past the restore: {} vs healthy {}",
            faulted.duration,
            healthy.duration
        );
        assert!(faulted.latency_p99 >= healthy.latency_p99);
    }

    #[test]
    fn brownout_window_throttles_but_preserves_order_and_requests() {
        let cost = toy_cost();
        let trace = chat_trace(100.0, 100, 13);
        let healthy = run_with_cost(&toy_cfg(2), &cost, &trace);
        let mut cfg = toy_cfg(2);
        // 10%-capacity brownout covering the middle of the trace
        cfg.fault = Some(FaultSpec::seeded(1).with_nic_fault(LinkFault {
            device: 1,
            at: 0.1 * healthy.duration,
            frac: 0.1,
            restore_at: Some(0.8 * healthy.duration),
        }));
        let faulted = run_with_cost(&cfg, &cost, &trace);
        assert_eq!(faulted.n_requests, 100);
        assert!(faulted.duration >= healthy.duration * (1.0 - 1e-9));
        assert!(faulted.duration.is_finite());
    }

    #[test]
    fn dead_decode_node_is_routed_around() {
        let cost = toy_cost();
        let trace = chat_trace(100.0, 80, 21);
        // 4 nodes: 2 prefill + 2 decode (nodes 2 and 3); node 3's NIC is
        // permanently down, so every request must land on node 2
        let mut cfg = toy_cfg(4);
        cfg.fault = Some(FaultSpec::seeded(1).with_nic_fault(LinkFault {
            device: 3,
            at: 0.0,
            frac: 0.0,
            restore_at: None,
        }));
        let degraded = run_with_cost(&cfg, &cost, &trace);
        assert_eq!(degraded.n_requests, 80);
        assert!(degraded.duration.is_finite());
        let healthy = run_with_cost(&toy_cfg(4), &cost, &trace);
        assert!(
            degraded.duration >= healthy.duration * (1.0 - 1e-9),
            "half the decode fleet cannot be faster: {} vs {}",
            degraded.duration,
            healthy.duration
        );
    }

    #[test]
    fn straggler_node_scales_every_step() {
        let cost = toy_cost();
        let trace = chat_trace(50.0, 60, 33);
        let healthy = run_with_cost(&toy_cfg(1), &cost, &trace);
        let mut cfg = toy_cfg(1);
        cfg.fault = Some(FaultSpec::seeded(1).with_straggler(0, 0.5));
        let slow = run_with_cost(&cfg, &cost, &trace);
        assert_eq!(slow.n_requests, 60);
        assert!(
            slow.tokens_per_s < healthy.tokens_per_s,
            "a half-rate node must lose throughput: {} vs {}",
            slow.tokens_per_s,
            healthy.tokens_per_s
        );
        assert!(slow.latency_p99 > healthy.latency_p99);
    }
}
