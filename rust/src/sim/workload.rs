//! Request workloads for the serving layer: open-loop arrival traces.
//!
//! The serving exhibits need *offered load* that does not react to the
//! system (open loop — a saturated server keeps receiving requests, which
//! is what makes p99 explode past the knee), generated deterministically
//! from a seed so every sweep point and every CI run sees the same trace.
//!
//! Three arrival processes cover the scenarios the ROADMAP asks for:
//!
//! * [`ArrivalProcess::Poisson`] — the classic memoryless open-loop load.
//! * [`ArrivalProcess::Bursty`] — an on/off modulated Poisson process
//!   (Markov-modulated style): `on_frac` of every `period` runs at
//!   `burst ×` the base rate, the rest at a compensating lower rate, so
//!   the *mean* offered load matches the Poisson trace while the
//!   short-term rate swings.
//! * [`ArrivalProcess::Diurnal`] — a sinusoidally rate-modulated process
//!   (traffic follows the sun; `depth` is the peak-to-mean swing).
//!
//! Non-homogeneous processes are sampled by thinning (Lewis–Shedler):
//! candidates arrive at the peak rate and are accepted with probability
//! `rate(t) / peak`, which keeps the generator exact for any bounded
//! rate function and deterministic under the seeded [`Rng64`].

/// Deterministic splitmix64 RNG — the same generator family as
/// [`crate::util::seeded_vec`], kept dependency-free.
#[derive(Clone, Debug)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        Rng64 { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` (53 mantissa bits).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponential with rate `rate` (mean `1/rate`); inter-arrival times.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // 1 - u is in (0, 1], so ln never sees 0
        -(1.0 - self.next_f64()).ln() / rate
    }
}

/// One inference request of the open-loop trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: usize,
    /// Absolute arrival time (seconds from trace start).
    pub arrival: f64,
    /// Prompt length (prefill tokens).
    pub prompt_tokens: usize,
    /// Tokens to generate (decode steps; includes the first token).
    pub output_tokens: usize,
    /// Scheduling class: higher wins under [`Priority`] scheduling.
    ///
    /// [`Priority`]: crate::sim::serve::SchedPolicy::Priority
    pub priority: u8,
}

/// Shape of the arrival process (all share the mean `rate` of
/// [`TraceCfg`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals.
    Poisson,
    /// On/off modulated Poisson: `on_frac` of each `period` at `burst ×`
    /// the base rate, the rest at a compensating lower (possibly zero)
    /// rate. Requires `burst ≥ 1` and `burst · on_frac ≤ 1` so the off
    /// rate stays non-negative.
    Bursty { burst: f64, on_frac: f64, period: f64 },
    /// Sinusoidal modulation `rate · (1 + depth · sin(2πt/period))`,
    /// `0 ≤ depth < 1`.
    Diurnal { depth: f64, period: f64 },
}

impl ArrivalProcess {
    /// Instantaneous rate multiplier at time `t` (mean 1 over a period).
    fn modulation(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson => 1.0,
            ArrivalProcess::Bursty { burst, on_frac, period } => {
                let phase = (t / period).fract();
                if phase < on_frac {
                    burst
                } else {
                    (1.0 - burst * on_frac) / (1.0 - on_frac)
                }
            }
            ArrivalProcess::Diurnal { depth, period } => {
                1.0 + depth * (2.0 * std::f64::consts::PI * t / period).sin()
            }
        }
    }

    /// Upper bound of the rate multiplier (the thinning envelope).
    fn peak(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson => 1.0,
            ArrivalProcess::Bursty { burst, .. } => burst,
            ArrivalProcess::Diurnal { depth, .. } => 1.0 + depth,
        }
    }
}

/// Trace generator configuration.
#[derive(Clone, Debug)]
pub struct TraceCfg {
    pub process: ArrivalProcess,
    /// Mean offered load, requests per second.
    pub rate: f64,
    /// Number of requests to generate.
    pub n_requests: usize,
    pub seed: u64,
    /// Mean/max prompt length; lengths are exponential-ish, clamped to
    /// `[1, prompt_max]`.
    pub prompt_mean: usize,
    pub prompt_max: usize,
    /// Mean/max output length, clamped to `[1, output_max]`.
    pub output_mean: usize,
    pub output_max: usize,
    /// Fraction of requests tagged priority 1 (the rest are 0).
    pub high_priority_frac: f64,
}

impl TraceCfg {
    /// The reference chat-serving mix: 512-token prompts, 128-token
    /// completions, 10% interactive (high-priority) traffic.
    pub fn chat(process: ArrivalProcess, rate: f64, n_requests: usize, seed: u64) -> Self {
        TraceCfg {
            process,
            rate,
            n_requests,
            seed,
            prompt_mean: 512,
            prompt_max: 2048,
            output_mean: 128,
            output_max: 512,
            high_priority_frac: 0.1,
        }
    }
}

/// Sample a clamped-exponential token count with the given mean.
fn sample_tokens(rng: &mut Rng64, mean: usize, max: usize) -> usize {
    let x = rng.exp(1.0 / mean as f64);
    (x.round() as usize).clamp(1, max)
}

/// Generate the open-loop trace: `n_requests` requests with strictly
/// non-decreasing arrival times. Deterministic in `cfg.seed`.
pub fn generate(cfg: &TraceCfg) -> Vec<Request> {
    assert!(cfg.rate > 0.0, "offered load must be positive");
    assert!(cfg.prompt_mean >= 1 && cfg.output_mean >= 1);
    if let ArrivalProcess::Bursty { burst, on_frac, period } = cfg.process {
        assert!(burst >= 1.0 && period > 0.0, "bursty burst/period");
        assert!(on_frac > 0.0 && on_frac < 1.0, "bursty on_frac in (0,1)");
        assert!(burst * on_frac <= 1.0, "off-phase rate would be negative");
    }
    if let ArrivalProcess::Diurnal { depth, period } = cfg.process {
        assert!((0.0..1.0).contains(&depth) && period > 0.0, "diurnal depth/period");
    }
    let mut rng = Rng64::new(cfg.seed);
    let peak = cfg.rate * cfg.process.peak();
    let mut t = 0.0;
    let mut out = Vec::with_capacity(cfg.n_requests);
    while out.len() < cfg.n_requests {
        // thinning: candidate at the peak rate, accept at rate(t)/peak
        t += rng.exp(peak);
        let accept = cfg.rate * cfg.process.modulation(t) / peak;
        if rng.next_f64() >= accept {
            continue;
        }
        let id = out.len();
        out.push(Request {
            id,
            arrival: t,
            prompt_tokens: sample_tokens(&mut rng, cfg.prompt_mean, cfg.prompt_max),
            output_tokens: sample_tokens(&mut rng, cfg.output_mean, cfg.output_max),
            priority: if rng.next_f64() < cfg.high_priority_frac { 1 } else { 0 },
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cv_of_interarrivals(reqs: &[Request]) -> f64 {
        let gaps: Vec<f64> = reqs.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
        let s = crate::util::stats::summarize(&gaps).unwrap();
        s.std / s.mean
    }

    #[test]
    fn deterministic_and_monotone() {
        let cfg = TraceCfg::chat(ArrivalProcess::Poisson, 100.0, 500, 7);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a, b, "same seed, same trace");
        assert_eq!(a.len(), 500);
        for w in a.windows(2) {
            assert!(w[1].arrival >= w[0].arrival, "arrivals sorted");
            assert_eq!(w[1].id, w[0].id + 1, "ids dense");
        }
        let c = generate(&TraceCfg { seed: 8, ..cfg });
        assert_ne!(a, c, "different seed, different trace");
    }

    #[test]
    fn poisson_hits_the_offered_rate() {
        let cfg = TraceCfg::chat(ArrivalProcess::Poisson, 200.0, 4000, 11);
        let reqs = generate(&cfg);
        let span = reqs.last().unwrap().arrival;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 200.0).abs() / 200.0 < 0.1, "empirical rate {rate}");
        // memoryless arrivals: coefficient of variation ~ 1
        let cv = cv_of_interarrivals(&reqs);
        assert!((cv - 1.0).abs() < 0.15, "poisson CV ~ 1, got {cv}");
    }

    #[test]
    fn bursty_preserves_mean_but_raises_variance() {
        let base = TraceCfg::chat(ArrivalProcess::Poisson, 100.0, 4000, 3);
        let bursty = TraceCfg {
            process: ArrivalProcess::Bursty { burst: 4.0, on_frac: 0.2, period: 2.0 },
            ..base.clone()
        };
        let a = generate(&base);
        let b = generate(&bursty);
        let ra = a.len() as f64 / a.last().unwrap().arrival;
        let rb = b.len() as f64 / b.last().unwrap().arrival;
        assert!((ra - rb).abs() / ra < 0.15, "means match: {ra} vs {rb}");
        assert!(
            cv_of_interarrivals(&b) > cv_of_interarrivals(&a) * 1.2,
            "bursty is burstier: {} vs {}",
            cv_of_interarrivals(&b),
            cv_of_interarrivals(&a)
        );
    }

    #[test]
    fn diurnal_modulates_the_rate() {
        let period = 10.0;
        let cfg = TraceCfg {
            process: ArrivalProcess::Diurnal { depth: 0.8, period },
            ..TraceCfg::chat(ArrivalProcess::Poisson, 100.0, 4000, 5)
        };
        let reqs = generate(&cfg);
        // count arrivals in the rising half vs the falling half of each
        // period: sin > 0 for phase < 0.5, so the first half must carry
        // clearly more than half the traffic at depth 0.8
        let (mut hi, mut lo) = (0usize, 0usize);
        for r in &reqs {
            if (r.arrival / period).fract() < 0.5 {
                hi += 1;
            } else {
                lo += 1;
            }
        }
        assert!(
            hi as f64 > lo as f64 * 1.5,
            "diurnal peak half must dominate: {hi} vs {lo}"
        );
    }

    #[test]
    fn token_lengths_bounded_and_near_mean() {
        let cfg = TraceCfg::chat(ArrivalProcess::Poisson, 50.0, 3000, 13);
        let reqs = generate(&cfg);
        let pm: f64 =
            reqs.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / reqs.len() as f64;
        let om: f64 =
            reqs.iter().map(|r| r.output_tokens as f64).sum::<f64>() / reqs.len() as f64;
        assert!(reqs.iter().all(|r| (1..=2048).contains(&r.prompt_tokens)));
        assert!(reqs.iter().all(|r| (1..=512).contains(&r.output_tokens)));
        // clamping pulls the mean slightly below the nominal value
        assert!((pm - 512.0).abs() / 512.0 < 0.15, "prompt mean {pm}");
        assert!((om - 128.0).abs() / 128.0 < 0.15, "output mean {om}");
        let hp = reqs.iter().filter(|r| r.priority == 1).count() as f64 / reqs.len() as f64;
        assert!((hp - 0.1).abs() < 0.05, "priority mix {hp}");
    }
}
