//! Discrete-event simulation core.
//!
//! The timed executor is a fluid-flow discrete-event simulation: *flows*
//! (data transfers) share *resources* (NVLink ports, copy engines, HBM,
//! the NVSwitch reduce units) under max-min fair bandwidth allocation,
//! while *timers* model compute durations and synchronization latencies.
//!
//! This module provides the reusable pieces:
//! * [`OrdF64`] — totally ordered simulation time,
//! * [`EventQueue`] — timer events,
//! * [`flownet::FlowNet`] — bandwidth-shared flows with max-min fairness
//!   (scan or epoch-keyed-heap event engine),
//! * [`partition::PartitionedFlowNet`] — the same net split into
//!   port-disjoint per-node partitions executed in parallel,
//! * [`trace`] — optional execution traces (the profiling substrate for
//!   the §Perf pass and for debugging schedules),
//! * [`workload`] — deterministic open-loop request traces (Poisson,
//!   bursty, diurnal) for the serving layer,
//! * [`serve`] — the trace-driven inference serving engine (continuous
//!   batching, prefill/decode disaggregation, scheduler policies) whose
//!   per-step cost is calibrated from the timed kernel schedules.

pub mod fault;
pub mod flownet;
pub mod partition;
pub mod serve;
pub mod trace;
pub mod workload;

pub use flownet::{Engine, FlowId, FlowNet};
pub use partition::PartitionedFlowNet;
pub use trace::{Span, Trace};

/// Simulation time in seconds with a total order (panics on NaN, which the
/// simulator never produces).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN simulation time")
    }
}

/// A timer event queue: `(time, seq)`-ordered min-heap. The sequence number
/// makes event ordering deterministic under equal timestamps.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<(OrdF64, u64, EventSlot<E>)>>,
    seq: u64,
}

/// Wrapper so the payload doesn't need Ord; ordering is (time, seq) only.
#[derive(Debug)]
struct EventSlot<E>(E);

impl<E> PartialEq for EventSlot<E> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<E> Eq for EventSlot<E> {}
impl<E> PartialOrd for EventSlot<E> {
    fn partial_cmp(&self, _: &Self) -> Option<std::cmp::Ordering> {
        Some(std::cmp::Ordering::Equal)
    }
}
impl<E> Ord for EventSlot<E> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: std::collections::BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `event` at absolute time `t`.
    pub fn push(&mut self, t: f64, event: E) {
        debug_assert!(t.is_finite() && t >= 0.0, "bad event time {t}");
        self.seq += 1;
        self.heap.push(std::cmp::Reverse((OrdF64(t), self.seq, EventSlot(event))));
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|std::cmp::Reverse((t, _, _))| t.0)
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|std::cmp::Reverse((t, _, EventSlot(e)))| (t.0, e))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordf64_total_order() {
        assert!(OrdF64(1.0) < OrdF64(2.0));
        assert_eq!(OrdF64(3.0), OrdF64(3.0));
        let mut v = vec![OrdF64(3.0), OrdF64(1.0), OrdF64(2.0)];
        v.sort();
        assert_eq!(v, vec![OrdF64(1.0), OrdF64(2.0), OrdF64(3.0)]);
    }

    #[test]
    fn event_queue_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn event_queue_fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }
}
