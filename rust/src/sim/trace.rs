//! Execution traces: per-worker timelines of what the simulator did.
//!
//! The trace is the profiling substrate for the performance pass (the
//! "Perf" section of the repo README): it reports per-category busy time
//! (compute / comm / sync / launch), which is how we attribute `T_comp`,
//! `T_comm`, `T_sync`, and `T_launch` from the paper's cost model
//! (§3.1.1) to a simulated kernel run. `Launch` covers the
//! launch/teardown delays the executor models as [`crate::plan::Op::Delay`]
//! spans; idle time is the remainder (`makespan − worker_busy`), not a
//! recorded span kind.

use std::collections::HashMap;

/// Category of a span, mirroring the cost-model decomposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    Compute,
    Comm,
    Sync,
    Launch,
}

/// One closed interval of activity on a worker.
#[derive(Clone, Debug)]
pub struct Span {
    pub worker: usize,
    pub kind: SpanKind,
    pub label: &'static str,
    pub t0: f64,
    pub t1: f64,
}

/// A collection of spans for one simulated kernel run.
#[derive(Debug, Default)]
pub struct Trace {
    pub enabled: bool,
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn new(enabled: bool) -> Self {
        Trace { enabled, spans: vec![] }
    }

    pub fn record(&mut self, worker: usize, kind: SpanKind, label: &'static str, t0: f64, t1: f64) {
        if self.enabled {
            debug_assert!(t1 >= t0, "span ends before it starts");
            self.spans.push(Span { worker, kind, label, t0, t1 });
        }
    }

    /// Total busy time per kind across all workers. All four [`SpanKind`]s
    /// are accounted — including [`SpanKind::Launch`], which the timed
    /// executor records for `Op::Delay` spans; kinds with no spans are
    /// simply absent from the map.
    pub fn busy_by_kind(&self) -> HashMap<SpanKind, f64> {
        let mut m = HashMap::new();
        for s in &self.spans {
            *m.entry(s.kind).or_insert(0.0) += s.t1 - s.t0;
        }
        m
    }

    /// Busy time of one worker.
    pub fn worker_busy(&self, worker: usize) -> f64 {
        self.spans.iter().filter(|s| s.worker == worker).map(|s| s.t1 - s.t0).sum()
    }

    /// Makespan covered by the trace.
    pub fn makespan(&self) -> f64 {
        self.spans.iter().map(|s| s.t1).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::new(false);
        t.record(0, SpanKind::Compute, "mma", 0.0, 1.0);
        assert!(t.spans.is_empty());
    }

    #[test]
    fn busy_accounting() {
        let mut t = Trace::new(true);
        t.record(0, SpanKind::Compute, "mma", 0.0, 2.0);
        t.record(0, SpanKind::Comm, "store", 2.0, 3.0);
        t.record(1, SpanKind::Comm, "store", 0.0, 4.0);
        let by = t.busy_by_kind();
        assert_eq!(by[&SpanKind::Compute], 2.0);
        assert_eq!(by[&SpanKind::Comm], 5.0);
        assert_eq!(t.worker_busy(0), 3.0);
        assert_eq!(t.makespan(), 4.0);
    }

    #[test]
    fn launch_spans_are_accounted_like_any_other_kind() {
        // the module doc used to omit Launch; pin that busy_by_kind
        // aggregates it exactly like the other kinds and that absent
        // kinds stay absent instead of defaulting to 0.0
        let mut t = Trace::new(true);
        t.record(0, SpanKind::Launch, "kernel_launch", 0.0, 3.5e-6);
        t.record(1, SpanKind::Launch, "drain", 1.0, 1.5);
        t.record(0, SpanKind::Sync, "barrier", 3.5e-6, 1e-3);
        let by = t.busy_by_kind();
        assert!((by[&SpanKind::Launch] - (3.5e-6 + 0.5)).abs() < 1e-12);
        assert!(by.contains_key(&SpanKind::Sync));
        assert!(!by.contains_key(&SpanKind::Compute), "unrecorded kinds absent");
        assert!((t.worker_busy(0) - 1e-3).abs() < 1e-12);
    }
}
