//! Partitioned parallel [`FlowNet`]: per-node-group nets coupled only
//! through the NIC boundary.
//!
//! Every route the executor produces touches ports of exactly one node —
//! `p2p_ports`/`multicast_ports`/`ld_reduce_ports` assert same-node, HBM
//! and copy-engine ports are device-local — **except** RDMA, whose route
//! is `[NicEgress(src), NicIngress(dst)]` and *only* NIC ports
//! (`hw::topology::rdma_ports`). Port sets therefore split cleanly into
//! `num_nodes` in-node partitions plus one NIC *boundary* partition, and
//! max-min fair water-filling decomposes exactly: a class's rate depends
//! only on headroom of ports it crosses, and no class crosses two
//! partitions. Each partition is an ordinary [`FlowNet`] (scan or heap
//! engine), so the whole incremental-solver + memo + heap machinery
//! applies per partition.
//!
//! ## Determinism
//!
//! `advance` fans the partitions out over [`crate::util::par::par_map_mut`]
//! when enough flows are live to amortize the scoped threads, then merges
//! completions by **ascending global slot** — the same order the
//! monolithic net emits — and recycles global slots through the same LIFO
//! free-list discipline. `next_completion` is the min over partitions,
//! which is order-independent for f64 (no NaNs in the model). Parallel
//! output is byte-identical to serial, and partitioned output is
//! bit-identical to the monolithic net (claims-tested on a multi-node
//! kernel in `tests/integration_paper_claims.rs`): the water-fill rounds
//! interleave differently, but with port-disjoint partitions every class
//! level is computed from the same inputs by the same expressions, so the
//! fill fixes the same rates — the only theoretical divergence channel is
//! a *cross-partition* level near-tie inside the solver's 1e-12 relative
//! tie tolerance with non-equal bits, which real port/curve constants sit
//! nowhere near (exact symmetric ties are bit-equal and decompose
//! cleanly).
//!
//! Solver stats are reported summed across partitions; a decomposed run
//! legitimately performs a different number of (smaller) solves than the
//! monolithic net, so equivalence tests compare timings/events/bytes, not
//! stats.

use super::flownet::{Engine, FlowId, FlowNet, SolverStats};
use crate::hw::topology::Port;
use std::collections::HashMap;

/// Below this many live flows, partition fan-out runs serially: a scoped
/// thread spawn per event costs more than the per-partition scans it
/// saves. Crossed only by cluster-scale populations.
const PAR_FANOUT_MIN_FLOWS: usize = 4096;

/// True when `PK_NET_PARTITION=1` asks [`crate::exec::timed::TimedExec`]
/// to run every simulation on the partitioned net. Read once and cached.
pub fn partitioned_from_env() -> bool {
    static ON: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ON.get_or_init(|| matches!(std::env::var("PK_NET_PARTITION").as_deref(), Ok("1")))
}

/// A [`FlowNet`] split into per-node partitions + a NIC boundary
/// partition, with the monolithic net's exact external contract
/// (global `FlowId`s, ascending-slot completion batches, LIFO slot
/// recycling).
#[derive(Debug)]
pub struct PartitionedFlowNet {
    devices_per_node: usize,
    /// `nets[0..num_nodes]` are the in-node partitions; `nets[num_nodes]`
    /// is the NIC boundary partition (cross-node RDMA flows).
    nets: Vec<FlowNet>,
    /// Global slot → (partition, local slot).
    map: Vec<(u32, u32)>,
    /// Per-partition local slot → global slot.
    rev: Vec<Vec<usize>>,
    free: Vec<usize>,
    n_live: usize,
    /// Merged completion scratch (`advance` returns a borrow of it).
    done_buf: Vec<FlowId>,
    par_threshold: usize,
}

impl PartitionedFlowNet {
    /// Partitioned net for `num_nodes` × `devices_per_node` devices, on
    /// the engine selected by `PK_FLOWNET`.
    pub fn new(num_nodes: usize, devices_per_node: usize) -> Self {
        Self::with_engine(num_nodes, devices_per_node, Engine::from_env())
    }

    /// Partitioned net pinned to a specific per-partition event engine.
    pub fn with_engine(num_nodes: usize, devices_per_node: usize, engine: Engine) -> Self {
        assert!(num_nodes >= 1 && devices_per_node >= 1);
        let n_parts = num_nodes + 1; // + NIC boundary
        PartitionedFlowNet {
            devices_per_node,
            nets: (0..n_parts).map(|_| FlowNet::with_engine(engine)).collect(),
            map: vec![],
            rev: vec![vec![]; n_parts],
            free: vec![],
            n_live: 0,
            done_buf: vec![],
            par_threshold: PAR_FANOUT_MIN_FLOWS,
        }
    }

    /// Override the parallel fan-out threshold (bench/test hook; `0`
    /// forces the scoped-thread path on every event).
    pub fn with_par_threshold(mut self, threshold: usize) -> Self {
        self.par_threshold = threshold;
        self
    }

    /// Which partition a port belongs to: NIC ports → boundary, anything
    /// else → its device's node.
    fn partition_of(&self, p: Port) -> usize {
        match p {
            Port::NicEgress(_) | Port::NicIngress(_) => self.nets.len() - 1,
            Port::Egress(d)
            | Port::Ingress(d)
            | Port::Pcie(d)
            | Port::SwitchReduce(d)
            | Port::Hbm(d)
            | Port::CopyEngine(d) => {
                let node = d.0 / self.devices_per_node;
                assert!(node + 1 < self.nets.len(), "device {d:?} outside the cluster");
                node
            }
        }
    }

    pub fn set_capacity(&mut self, port: Port, bytes_per_s: f64) {
        let pi = self.partition_of(port);
        self.nets[pi].set_capacity(port, bytes_per_s);
    }

    /// Start a flow; the route must lie in a single partition (every
    /// executor route does — see module doc).
    pub fn start(&mut self, bytes: f64, ports: Vec<Port>, cap: f64) -> FlowId {
        let pi = self.partition_of(ports[0]);
        debug_assert!(
            ports.iter().all(|&p| self.partition_of(p) == pi),
            "route crosses partitions: {ports:?}"
        );
        let local = self.nets[pi].start(bytes, ports, cap);
        // global slot allocation mirrors the monolithic net: LIFO reuse,
        // append otherwise
        let g = if let Some(g) = self.free.pop() {
            self.map[g] = (pi as u32, local.0 as u32);
            g
        } else {
            self.map.push((pi as u32, local.0 as u32));
            self.map.len() - 1
        };
        if self.rev[pi].len() <= local.0 {
            self.rev[pi].resize(local.0 + 1, usize::MAX);
        }
        self.rev[pi][local.0] = g;
        self.n_live += 1;
        FlowId(g)
    }

    pub fn n_active(&self) -> usize {
        self.n_live
    }

    /// Advance every partition by `dt`; completions merged in ascending
    /// global slot order (byte-identical serial vs parallel — each
    /// partition's batch is deterministic and the merge ignores thread
    /// scheduling).
    pub fn advance(&mut self, dt: f64) -> &[FlowId] {
        self.done_buf.clear();
        if self.n_live == 0 {
            return &self.done_buf;
        }
        let locals: Vec<Vec<FlowId>> = if self.n_live >= self.par_threshold {
            crate::util::par::par_map_mut(
                crate::util::par::default_threads(),
                &mut self.nets,
                |_, net| net.advance(dt).to_vec(),
            )
        } else {
            self.nets.iter_mut().map(|net| net.advance(dt).to_vec()).collect()
        };
        for (pi, local) in locals.iter().enumerate() {
            for &lid in local {
                self.done_buf.push(FlowId(self.rev[pi][lid.0]));
            }
        }
        self.done_buf.sort_unstable_by_key(|id| id.0);
        for i in 0..self.done_buf.len() {
            self.free.push(self.done_buf[i].0);
        }
        self.n_live -= self.done_buf.len();
        &self.done_buf
    }

    /// Earliest completion across partitions (min is order-independent).
    pub fn next_completion(&mut self) -> Option<f64> {
        if self.n_live == 0 {
            return None;
        }
        let locals: Vec<Option<f64>> = if self.n_live >= self.par_threshold {
            crate::util::par::par_map_mut(
                crate::util::par::default_threads(),
                &mut self.nets,
                |_, net| net.next_completion(),
            )
        } else {
            self.nets.iter_mut().map(|net| net.next_completion()).collect()
        };
        let mut best = f64::INFINITY;
        for t in locals.into_iter().flatten() {
            best = best.min(t);
        }
        best.is_finite().then_some(best)
    }

    /// Current rate of a flow (test/inspection hook).
    pub fn rate(&mut self, id: FlowId) -> f64 {
        let (pi, local) = self.map[id.0];
        self.nets[pi as usize].rate(FlowId(local as usize))
    }

    /// Drain cumulative per-port byte accounting (partitions are
    /// port-disjoint, so the union has no collisions).
    pub fn take_port_bytes(&mut self) -> HashMap<Port, f64> {
        let mut out = HashMap::new();
        for net in &mut self.nets {
            out.extend(std::mem::take(&mut net.port_bytes));
        }
        out
    }

    /// Solver instrumentation summed across partitions (see module doc:
    /// not comparable to a monolithic run's stats).
    pub fn solver_stats(&self) -> SolverStats {
        let mut s = SolverStats::default();
        for net in &self.nets {
            let p = net.solver_stats();
            s.solves += p.solves;
            s.memo_hits += p.memo_hits;
            s.classes += p.classes;
            s.ports += p.ports;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DeviceId;

    // 2 nodes × 2 devices: devices 0,1 on node 0; 2,3 on node 1
    fn mono_and_part(engine: Engine) -> (FlowNet, PartitionedFlowNet) {
        let mut mono = FlowNet::with_engine(engine);
        let mut part = PartitionedFlowNet::with_engine(2, 2, engine);
        for d in 0..4 {
            for p in [
                Port::Egress(DeviceId(d)),
                Port::Ingress(DeviceId(d)),
                Port::Hbm(DeviceId(d)),
                Port::NicEgress(DeviceId(d)),
                Port::NicIngress(DeviceId(d)),
            ] {
                let c = match p {
                    Port::NicEgress(_) | Port::NicIngress(_) => 50.0,
                    Port::Hbm(_) => 3350.0,
                    _ => 450.0,
                };
                mono.set_capacity(p, c);
                part.set_capacity(p, c);
            }
        }
        (mono, part)
    }

    /// In-node p2p on both nodes + cross-node RDMA, driven to drain:
    /// every observable (ids, completion batches, timings, rates) must
    /// match the monolithic net bitwise.
    fn drain_matches_mono(engine: Engine, threshold: usize) {
        let (mut mono, mut part) = mono_and_part(engine);
        part = part.with_par_threshold(threshold);
        let routes: [Vec<Port>; 5] = [
            vec![Port::Egress(DeviceId(0)), Port::Ingress(DeviceId(1))],
            vec![Port::Egress(DeviceId(2)), Port::Ingress(DeviceId(3))],
            vec![Port::NicEgress(DeviceId(1)), Port::NicIngress(DeviceId(2))],
            vec![Port::Hbm(DeviceId(0))],
            vec![Port::NicEgress(DeviceId(3)), Port::NicIngress(DeviceId(0))],
        ];
        let mut ids = vec![];
        for (i, route) in routes.iter().enumerate() {
            let bytes = 100.0 + 37.5 * i as f64;
            let a = mono.start(bytes, route.clone(), 1e9);
            let b = part.start(bytes, route.clone(), 1e9);
            assert_eq!(a, b, "global slot allocation must match");
            ids.push(a);
        }
        let mut restarts = 0;
        loop {
            let (tm, tp) = (mono.next_completion(), part.next_completion());
            match (tm, tp) {
                (None, None) => break,
                (Some(x), Some(y)) => assert_eq!(x.to_bits(), y.to_bits()),
                other => panic!("nets diverged: {other:?}"),
            }
            for &id in &ids {
                assert_eq!(mono.rate(id).to_bits(), part.rate(id).to_bits());
            }
            let dt = tm.unwrap() * 0.75; // partial steps exercise replay/merge
            let want = mono.advance(dt).to_vec();
            let got = part.advance(dt).to_vec();
            assert_eq!(got, want);
            // restart a few completed routes to exercise slot recycling
            for d in &want {
                if d.0 < routes.len() && restarts < 8 {
                    restarts += 1;
                    let r = routes[d.0].clone();
                    let a = mono.start(64.0, r.clone(), 1e9);
                    let b = part.start(64.0, r, 1e9);
                    assert_eq!(a, b, "recycled slot must match");
                }
            }
        }
        assert_eq!(mono.n_active(), 0);
        assert_eq!(part.n_active(), 0);
        let pb = part.take_port_bytes();
        for (p, v) in std::mem::take(&mut mono.port_bytes) {
            assert_eq!(pb[&p].to_bits(), v.to_bits(), "{p:?}");
        }
    }

    #[test]
    fn partitioned_bit_identical_to_mono_scan() {
        drain_matches_mono(Engine::Scan, usize::MAX);
    }

    #[test]
    fn partitioned_bit_identical_to_mono_heap() {
        drain_matches_mono(Engine::Heap, usize::MAX);
    }

    #[test]
    fn parallel_fanout_byte_identical_to_serial() {
        // threshold 0 forces the scoped-thread path on every event; the
        // merge discipline must hide the thread scheduling entirely
        drain_matches_mono(Engine::Scan, 0);
        drain_matches_mono(Engine::Heap, 0);
    }

    #[test]
    fn nic_flows_land_in_boundary_partition() {
        let (_, mut part) = mono_and_part(Engine::Scan);
        part.start(10.0, vec![Port::NicEgress(DeviceId(0)), Port::NicIngress(DeviceId(2))], 1e9);
        part.start(10.0, vec![Port::Egress(DeviceId(0)), Port::Ingress(DeviceId(1))], 1e9);
        let s = part.nets[2].solver_stats(); // boundary partition
        assert_eq!(s.ports, 2, "RDMA flow interns only NIC ports: {s:?}");
        assert_eq!(part.nets[0].solver_stats().ports, 2);
        assert_eq!(part.nets[1].solver_stats().ports, 0);
    }
}
