//! Seeded, deterministic fault injection for the timed executor.
//!
//! Production fabrics are not the uniform, healthy clusters the rest of
//! the simulator assumes: links jitter under congestion, individual GPUs
//! straggle, and NICs fail mid-run. [`FaultSpec`] describes a fault
//! scenario; [`FaultSpec::compile`] turns it into a [`FaultPlan`] — a
//! deterministic, seeded schedule of timed capacity changes that
//! [`crate::exec::TimedExec`] applies through `FlowNet::set_capacity`
//! mid-run. Because the plan is compiled once against the executor's
//! declared baseline capacities and driven purely by simulated time, both
//! flow engines (`Engine::Scan` / `Engine::Heap`) and both nets
//! (monolithic / partitioned) observe the *identical* fault schedule, so
//! results stay bit-identical across all four combinations (test-pinned).
//!
//! Three fault classes (composable):
//!
//! * **Bandwidth jitter** — every link-class port (`Egress`/`Ingress`/
//!   `NicEgress`/`NicIngress`) resamples a lognormal rate factor
//!   `min(1, exp(σ·z))` once per `jitter_epoch` seconds from its own
//!   splitmix64 stream ([`crate::sim::workload::Rng64`], seeded from
//!   `(seed, port)`). The factor is clamped at 1: hardware never beats its
//!   nominal rate, and slowdown grows monotonically with σ.
//! * **Stragglers** — a compute-*rate* scale `s ∈ (0, 1]` per device. The
//!   model has no SM port (compute is timer-driven), so the executor
//!   applies the equivalent: `Op::Compute` durations on that device are
//!   multiplied by `1/s`.
//! * **NIC/link failures** — at time `at`, the device's `NicEgress` +
//!   `NicIngress` capacities drop to `frac` of baseline (0.0 = hard
//!   failure: crossing flows stall at rate 0), optionally restored at
//!   `restore_at`. Failure state *composes* with jitter multiplicatively,
//!   so a jitter resample can never resurrect a failed link.

use crate::hw::topology::Port;
use crate::hw::DeviceId;
use crate::sim::workload::Rng64;
use crate::util::error::{bail, Context, Result};

/// One timed NIC/link failure.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFault {
    /// Global device id whose NIC fails.
    pub device: usize,
    /// Simulated time of the failure (seconds).
    pub at: f64,
    /// Remaining capacity fraction after the failure (0.0 = hard fail).
    pub frac: f64,
    /// Optional restore time (capacity returns to baseline).
    pub restore_at: Option<f64>,
}

/// A declarative fault scenario (see module docs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Seed for every sampled stream (jitter). Same seed → same schedule.
    pub seed: u64,
    /// Lognormal jitter σ on link-class ports; 0 disables jitter.
    pub jitter_sigma: f64,
    /// Jitter resample period in simulated seconds.
    pub jitter_epoch: f64,
    /// `(global device, compute-rate scale in (0, 1])` stragglers.
    pub stragglers: Vec<(usize, f64)>,
    /// Timed NIC failures.
    pub nic_faults: Vec<LinkFault>,
}

/// Default jitter resample period: 100 µs — a few resamples per wave on
/// millisecond-scale kernels.
pub const DEFAULT_JITTER_EPOCH: f64 = 1e-4;

impl FaultSpec {
    /// An empty (no-op) scenario with a seed for later knobs.
    pub fn seeded(seed: u64) -> Self {
        FaultSpec { seed, jitter_epoch: DEFAULT_JITTER_EPOCH, ..Default::default() }
    }

    /// Enable lognormal bandwidth jitter with strength `sigma`.
    pub fn with_jitter(mut self, sigma: f64) -> Self {
        assert!(sigma >= 0.0 && sigma.is_finite(), "jitter sigma must be finite and >= 0");
        self.jitter_sigma = sigma;
        if self.jitter_epoch <= 0.0 {
            self.jitter_epoch = DEFAULT_JITTER_EPOCH;
        }
        self
    }

    /// Add a timed NIC failure.
    pub fn with_nic_fault(mut self, fault: LinkFault) -> Self {
        assert!(fault.at >= 0.0 && fault.frac >= 0.0 && fault.frac <= 1.0);
        if let Some(r) = fault.restore_at {
            assert!(r > fault.at, "restore must follow the failure");
        }
        self.nic_faults.push(fault);
        self
    }

    /// Add a straggler device with compute-rate scale `s ∈ (0, 1]`.
    pub fn with_straggler(mut self, device: usize, scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0, "straggler scale must be in (0, 1]");
        self.stragglers.push((device, scale));
        self
    }

    /// True when the scenario injects nothing.
    pub fn is_empty(&self) -> bool {
        self.jitter_sigma == 0.0 && self.stragglers.is_empty() && self.nic_faults.is_empty()
    }

    /// Parse the CLI grammar: comma-separated clauses
    /// `jitter=<sigma>[@<epoch>]`, `nic=<dev>@<t>[:<frac>[:<restore_t>]]`,
    /// `straggler=<dev>:<scale>`. Example:
    /// `jitter=0.3@0.0002,nic=3@0.0005:0.1,straggler=0:0.7`.
    pub fn parse(s: &str, seed: u64) -> Result<FaultSpec> {
        let mut spec = FaultSpec::seeded(seed);
        for clause in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (key, val) = clause
                .split_once('=')
                .with_context(|| format!("bad fault clause '{clause}': expected key=value"))?;
            match key {
                "jitter" => {
                    let (sigma, epoch) = match val.split_once('@') {
                        Some((s, e)) => (
                            s.parse::<f64>().with_context(|| format!("bad jitter sigma '{s}'"))?,
                            e.parse::<f64>().with_context(|| format!("bad jitter epoch '{e}'"))?,
                        ),
                        None => (
                            val.parse::<f64>()
                                .with_context(|| format!("bad jitter sigma '{val}'"))?,
                            DEFAULT_JITTER_EPOCH,
                        ),
                    };
                    if !(sigma >= 0.0) || !sigma.is_finite() {
                        bail!("jitter sigma must be finite and >= 0, got {sigma}");
                    }
                    if !(epoch > 0.0) || !epoch.is_finite() {
                        bail!("jitter epoch must be finite and > 0, got {epoch}");
                    }
                    spec.jitter_sigma = sigma;
                    spec.jitter_epoch = epoch;
                }
                "nic" => {
                    let (dev, rest) = val
                        .split_once('@')
                        .with_context(|| format!("bad nic clause '{val}': expected dev@t"))?;
                    let device =
                        dev.parse::<usize>().with_context(|| format!("bad nic device '{dev}'"))?;
                    let mut parts = rest.split(':');
                    let at_s = parts.next().unwrap_or_default();
                    let at = at_s
                        .parse::<f64>()
                        .with_context(|| format!("bad nic fault time '{at_s}'"))?;
                    let frac = match parts.next() {
                        Some(f) => {
                            f.parse::<f64>().with_context(|| format!("bad nic frac '{f}'"))?
                        }
                        None => 0.0,
                    };
                    let restore_at = match parts.next() {
                        Some(r) => Some(
                            r.parse::<f64>()
                                .with_context(|| format!("bad nic restore time '{r}'"))?,
                        ),
                        None => None,
                    };
                    if parts.next().is_some() {
                        bail!("bad nic clause '{val}': too many ':' fields");
                    }
                    if !(at >= 0.0) || !(0.0..=1.0).contains(&frac) {
                        bail!("nic fault needs t >= 0 and frac in [0, 1], got t={at} frac={frac}");
                    }
                    if let Some(r) = restore_at {
                        if r <= at {
                            bail!("nic restore time {r} must follow the failure at {at}");
                        }
                    }
                    spec.nic_faults.push(LinkFault { device, at, frac, restore_at });
                }
                "straggler" => {
                    let (dev, sc) = val.split_once(':').with_context(|| {
                        format!("bad straggler clause '{val}': expected dev:scale")
                    })?;
                    let device = dev
                        .parse::<usize>()
                        .with_context(|| format!("bad straggler device '{dev}'"))?;
                    let scale =
                        sc.parse::<f64>().with_context(|| format!("bad straggler scale '{sc}'"))?;
                    if !(scale > 0.0 && scale <= 1.0) {
                        bail!("straggler scale must be in (0, 1], got {scale}");
                    }
                    spec.stragglers.push((device, scale));
                }
                other => bail!("unknown fault clause key '{other}' (jitter|nic|straggler)"),
            }
        }
        Ok(spec)
    }

    /// Compile against the executor's declared baseline `(port, capacity)`
    /// list into the timed event schedule. `num_devices` sizes the
    /// straggler slowdown table. Ports a NIC fault names that were never
    /// declared (e.g. on a single-node run) are skipped.
    pub fn compile(&self, ports: &[(Port, f64)], num_devices: usize) -> FaultPlan {
        let mut jitter = vec![];
        if self.jitter_sigma > 0.0 {
            assert!(self.jitter_epoch > 0.0, "jitter needs a positive epoch");
            for &(port, base) in ports {
                if !is_link_port(port) {
                    continue;
                }
                jitter.push(JitterStream {
                    port,
                    base,
                    factor: 1.0,
                    rng: Rng64::new(self.seed ^ port_stream_key(port)),
                    next_t: 0.0,
                });
            }
        }
        let base_of = |p: Port| ports.iter().find(|&&(q, _)| q == p).map(|&(_, c)| c);
        let mut link_events: Vec<(f64, Port, f64)> = vec![];
        for f in &self.nic_faults {
            for port in
                [Port::NicEgress(DeviceId(f.device)), Port::NicIngress(DeviceId(f.device))]
            {
                if base_of(port).is_none() {
                    continue;
                }
                link_events.push((f.at, port, f.frac));
                if let Some(r) = f.restore_at {
                    link_events.push((r, port, 1.0));
                }
            }
        }
        // stable order: by (time, port) so simultaneous events apply in a
        // deterministic sequence whatever order the spec listed them in
        link_events.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then_with(|| port_stream_key(a.1).cmp(&port_stream_key(b.1)))
        });
        let mut slowdown = vec![1.0; num_devices];
        for &(d, s) in &self.stragglers {
            if d < num_devices {
                // compute-rate scale s → durations stretch by 1/s
                slowdown[d] = slowdown[d].max(1.0 / s);
            }
        }
        let link_scale = ports.iter().map(|&(p, c)| (p, (1.0, c))).collect();
        FaultPlan {
            sigma: self.jitter_sigma,
            epoch: self.jitter_epoch,
            jitter,
            link_events,
            li: 0,
            link_scale,
            slowdown,
        }
    }
}

/// Ports that bandwidth jitter applies to: the link-class resources.
fn is_link_port(p: Port) -> bool {
    matches!(
        p,
        Port::Egress(_) | Port::Ingress(_) | Port::NicEgress(_) | Port::NicIngress(_)
    )
}

/// A stable 64-bit key per port, independent of declaration order — the
/// per-port jitter stream seed and the simultaneous-event tiebreak.
fn port_stream_key(p: Port) -> u64 {
    let (tag, dev) = match p {
        Port::Egress(d) => (1u64, d.0),
        Port::Ingress(d) => (2, d.0),
        Port::Pcie(d) => (3, d.0),
        Port::SwitchReduce(d) => (4, d.0),
        Port::Hbm(d) => (5, d.0),
        Port::CopyEngine(d) => (6, d.0),
        Port::NicEgress(d) => (7, d.0),
        Port::NicIngress(d) => (8, d.0),
    };
    // splitmix-style scramble of (tag, dev) so per-port streams decorrelate
    let mut z = tag.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(dev as u64);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^ (z >> 27)
}

/// A standard normal via Box–Muller on the splitmix64 stream.
fn gauss(rng: &mut Rng64) -> f64 {
    let u1 = 1.0 - rng.next_f64(); // (0, 1]: ln stays finite
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

struct JitterStream {
    port: Port,
    base: f64,
    factor: f64,
    rng: Rng64,
    next_t: f64,
}

/// The compiled, stateful fault schedule [`crate::exec::TimedExec`]
/// drives: `next_time` feeds the event loop's dt computation, `apply_due`
/// fires every event with `t <= now` through the provided `set_capacity`
/// sink. Effective capacity is `base × jitter_factor × link_scale`, so
/// failures and jitter compose without resurrecting each other.
pub struct FaultPlan {
    sigma: f64,
    epoch: f64,
    jitter: Vec<JitterStream>,
    /// `(t, port, link scale)` sorted ascending; `li` = next unapplied.
    link_events: Vec<(f64, Port, f64)>,
    li: usize,
    /// port → (current link scale, baseline capacity).
    link_scale: std::collections::HashMap<Port, (f64, f64)>,
    /// Per-device `Op::Compute` duration multiplier (≥ 1.0).
    slowdown: Vec<f64>,
}

impl FaultPlan {
    /// Earliest pending fault event of any kind.
    pub fn next_time(&self) -> Option<f64> {
        let j = self.jitter.iter().map(|s| s.next_t).fold(f64::INFINITY, f64::min);
        let l = self.link_events.get(self.li).map_or(f64::INFINITY, |e| e.0);
        let t = j.min(l);
        t.is_finite().then_some(t)
    }

    /// Earliest pending *link-state* event — the only kind that can
    /// unstall a net whose live flows are all at rate 0.
    pub fn next_link_time(&self) -> Option<f64> {
        self.link_events.get(self.li).map(|e| e.0)
    }

    /// Compute-duration multiplier for global device `dev`.
    pub fn slowdown(&self, dev: usize) -> f64 {
        self.slowdown.get(dev).copied().unwrap_or(1.0)
    }

    /// Fire every event with `t <= now`, pushing the resulting effective
    /// capacities through `apply`. Jitter streams resample once per epoch
    /// boundary passed (one draw per epoch — the stream's consumption
    /// depends only on simulated time, never on the caller's cadence).
    pub fn apply_due(&mut self, now: f64, apply: &mut dyn FnMut(Port, f64)) {
        for s in &mut self.jitter {
            if s.next_t > now {
                continue;
            }
            while s.next_t <= now {
                let z = gauss(&mut s.rng);
                s.factor = (self.sigma * z).exp().min(1.0);
                s.next_t += self.epoch;
            }
            let link = self.link_scale.get(&s.port).map_or(1.0, |&(l, _)| l);
            apply(s.port, s.base * s.factor * link);
        }
        while self.li < self.link_events.len() && self.link_events[self.li].0 <= now {
            let (_, port, scale) = self.link_events[self.li];
            self.li += 1;
            let entry = match self.link_scale.get_mut(&port) {
                Some(e) => e,
                None => continue,
            };
            entry.0 = scale;
            let base = entry.1;
            let jf = self
                .jitter
                .iter()
                .find(|s| s.port == port)
                .map_or(1.0, |s| s.factor);
            apply(port, base * jf * scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ports() -> Vec<(Port, f64)> {
        vec![
            (Port::Egress(DeviceId(0)), 400e9),
            (Port::Ingress(DeviceId(0)), 400e9),
            (Port::Hbm(DeviceId(0)), 3000e9),
            (Port::NicEgress(DeviceId(0)), 50e9),
            (Port::NicIngress(DeviceId(0)), 50e9),
        ]
    }

    #[test]
    fn same_seed_same_schedule() {
        let spec = FaultSpec::seeded(7).with_jitter(0.4);
        let drive = || {
            let mut plan = spec.compile(&ports(), 1);
            let mut log: Vec<(Port, u64)> = vec![];
            for k in 1..=20 {
                plan.apply_due(k as f64 * 3e-5, &mut |p, c| log.push((p, c.to_bits())));
            }
            log
        };
        assert_eq!(drive(), drive());
        // a different seed produces a different schedule
        let mut other = FaultSpec::seeded(8).with_jitter(0.4).compile(&ports(), 1);
        let mut log2 = vec![];
        for k in 1..=20 {
            other.apply_due(k as f64 * 3e-5, &mut |p, c| log2.push((p, c.to_bits())));
        }
        assert_ne!(drive(), log2);
    }

    #[test]
    fn jitter_consumption_is_cadence_independent() {
        // applying in many small steps or one big step must land on the
        // same factors: one draw per epoch boundary, keyed to sim time.
        let spec = FaultSpec::seeded(3).with_jitter(0.5);
        let mut fine = spec.compile(&ports(), 1);
        let mut coarse = spec.compile(&ports(), 1);
        let mut last_fine: std::collections::HashMap<Port, u64> = Default::default();
        for k in 1..=100 {
            fine.apply_due(k as f64 * 1e-5, &mut |p, c| {
                last_fine.insert(p, c.to_bits());
            });
        }
        let mut last_coarse: std::collections::HashMap<Port, u64> = Default::default();
        coarse.apply_due(100.0 * 1e-5, &mut |p, c| {
            last_coarse.insert(p, c.to_bits());
        });
        assert_eq!(last_fine, last_coarse);
    }

    #[test]
    fn jitter_never_exceeds_baseline_and_skips_non_link_ports() {
        let spec = FaultSpec::seeded(11).with_jitter(1.0);
        let mut plan = spec.compile(&ports(), 1);
        let mut seen = vec![];
        plan.apply_due(1.0, &mut |p, c| seen.push((p, c)));
        assert!(!seen.is_empty());
        for (p, c) in seen {
            assert!(c.is_finite() && c >= 0.0);
            match p {
                Port::Egress(_) | Port::Ingress(_) => assert!(c <= 400e9),
                Port::NicEgress(_) | Port::NicIngress(_) => assert!(c <= 50e9),
                other => panic!("jitter must not touch {other:?}"),
            }
        }
    }

    #[test]
    fn nic_fault_fires_and_restores_and_composes_with_jitter() {
        let spec = FaultSpec::seeded(5).with_jitter(0.3).with_nic_fault(LinkFault {
            device: 0,
            at: 2e-4,
            frac: 0.0,
            restore_at: Some(6e-4),
        });
        let mut plan = spec.compile(&ports(), 1);
        let mut caps: std::collections::HashMap<Port, f64> = Default::default();
        plan.apply_due(3e-4, &mut |p, c| {
            caps.insert(p, c);
        });
        assert_eq!(caps[&Port::NicEgress(DeviceId(0))], 0.0, "hard-failed NIC");
        assert_eq!(caps[&Port::NicIngress(DeviceId(0))], 0.0);
        // jitter resamples while failed must not resurrect the link
        plan.apply_due(5e-4, &mut |p, c| {
            caps.insert(p, c);
        });
        assert_eq!(caps[&Port::NicEgress(DeviceId(0))], 0.0, "jitter resurrection");
        // restore returns to base × current jitter factor (≤ base, > 0)
        plan.apply_due(7e-4, &mut |p, c| {
            caps.insert(p, c);
        });
        let c = caps[&Port::NicEgress(DeviceId(0))];
        assert!(c > 0.0 && c <= 50e9, "restored: {c}");
    }

    #[test]
    fn next_time_orders_link_and_jitter_events() {
        let spec = FaultSpec::seeded(1).with_nic_fault(LinkFault {
            device: 0,
            at: 5e-4,
            frac: 0.5,
            restore_at: None,
        });
        let plan = spec.compile(&ports(), 1);
        assert_eq!(plan.next_time(), Some(5e-4));
        assert_eq!(plan.next_link_time(), Some(5e-4));
        let jitter = FaultSpec::seeded(1).with_jitter(0.2).compile(&ports(), 1);
        assert_eq!(jitter.next_time(), Some(0.0), "jitter starts at t=0");
        assert_eq!(jitter.next_link_time(), None);
        // an empty spec has no events at all
        let empty = FaultSpec::seeded(1).compile(&ports(), 1);
        assert_eq!(empty.next_time(), None);
    }

    #[test]
    fn straggler_slowdown_table() {
        let spec = FaultSpec::seeded(0).with_straggler(2, 0.5);
        let plan = spec.compile(&ports(), 4);
        assert_eq!(plan.slowdown(2), 2.0);
        assert_eq!(plan.slowdown(0), 1.0);
        assert_eq!(plan.slowdown(99), 1.0, "out of range defaults to 1");
    }

    #[test]
    fn parse_full_grammar() {
        let s = FaultSpec::parse("jitter=0.3@0.0002,nic=3@0.0005:0.1:0.001,straggler=0:0.7", 42)
            .unwrap();
        assert_eq!(s.seed, 42);
        assert_eq!(s.jitter_sigma, 0.3);
        assert_eq!(s.jitter_epoch, 2e-4);
        assert_eq!(
            s.nic_faults,
            vec![LinkFault { device: 3, at: 5e-4, frac: 0.1, restore_at: Some(1e-3) }]
        );
        assert_eq!(s.stragglers, vec![(0, 0.7)]);
        // defaults: bare jitter keeps the default epoch, bare nic is hard
        let s = FaultSpec::parse("jitter=0.5,nic=1@0.002", 0).unwrap();
        assert_eq!(s.jitter_epoch, DEFAULT_JITTER_EPOCH);
        assert_eq!(s.nic_faults[0].frac, 0.0);
        assert_eq!(s.nic_faults[0].restore_at, None);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "jitter",            // no value
            "jitter=abc",        // not a float
            "jitter=-0.5",       // negative sigma
            "jitter=0.3@0",      // zero epoch
            "nic=0",             // no time
            "nic=x@0.1",         // bad device
            "nic=0@0.1:2.0",     // frac > 1
            "nic=0@0.5:0.1:0.2", // restore before failure
            "nic=0@1:0:2:3",     // too many fields
            "straggler=0",       // no scale
            "straggler=0:0",     // scale out of range
            "warp=1",            // unknown key
        ] {
            assert!(FaultSpec::parse(bad, 0).is_err(), "should reject '{bad}'");
        }
    }
}
