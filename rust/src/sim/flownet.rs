//! Bandwidth-shared flow network with max-min fair rate allocation.
//!
//! Each active flow moves `remaining` bytes across a set of [`Port`]
//! resources (its route) and has an intrinsic rate cap — the
//! mechanism-derived limit from [`crate::xfer::curves`] (message-size
//! efficiency × issuing-SM throughput). Concurrent flows sharing a port
//! split its capacity max-min fairly, which is how concurrent peer writes
//! "serialize at the destination" in the paper's intra-SM all-reduce
//! analysis (§3.1.3): N incoming flows each get 1/N of the ingress port.

use crate::hw::topology::Port;
use std::collections::HashMap;

/// Handle to an active flow.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FlowId(pub usize);

#[derive(Clone, Debug)]
struct Flow {
    remaining: f64,
    /// Original size; completion uses a *relative* epsilon because
    /// `now + dt` rounds in f64 — a flow can otherwise be left with a
    /// sub-resolution residue whose completion time rounds to `now`,
    /// livelocking the event loop.
    total: f64,
    ports: Vec<Port>,
    cap: f64,
    rate: f64,
    alive: bool,
}

impl Flow {
    #[inline]
    fn eps(&self) -> f64 {
        // 1e-6 relative residue: ~microsecond-relative timing slack on a
        // full-size flow, far below the model's fidelity, comfortably
        // above f64 rounding from (now + dt) round-trips.
        self.total * 1e-6 + 1e-12
    }
}

/// The set of active flows plus port capacities.
#[derive(Debug, Default)]
pub struct FlowNet {
    capacity: HashMap<Port, f64>,
    flows: Vec<Flow>,
    free: Vec<usize>,
    n_active: usize,
    rates_dirty: bool,
    /// Cumulative bytes completed per port (conservation accounting,
    /// verified by property tests and used by the report layer).
    pub port_bytes: HashMap<Port, f64>,
}

impl FlowNet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a port's capacity in bytes/s. Ports default to infinite
    /// capacity if never declared (useful for tests).
    pub fn set_capacity(&mut self, port: Port, bytes_per_s: f64) {
        assert!(bytes_per_s > 0.0);
        self.capacity.insert(port, bytes_per_s);
    }

    /// Start a flow of `bytes` over `ports` with intrinsic rate cap `cap`.
    pub fn start(&mut self, bytes: f64, ports: Vec<Port>, cap: f64) -> FlowId {
        assert!(bytes > 0.0, "zero-byte flow");
        assert!(cap > 0.0, "flow needs positive cap");
        for &p in &ports {
            *self.port_bytes.entry(p).or_insert(0.0) += bytes;
        }
        let flow = Flow { remaining: bytes, total: bytes, ports, cap, rate: 0.0, alive: true };
        self.n_active += 1;
        self.rates_dirty = true;
        if let Some(idx) = self.free.pop() {
            self.flows[idx] = flow;
            FlowId(idx)
        } else {
            self.flows.push(flow);
            FlowId(self.flows.len() - 1)
        }
    }

    pub fn n_active(&self) -> usize {
        self.n_active
    }

    /// Advance all flows by `dt` seconds at current rates; returns flows
    /// that completed (remaining hit zero). Rates must be current
    /// (`recompute_rates` is called lazily by `next_completion`).
    pub fn advance(&mut self, dt: f64) -> Vec<FlowId> {
        if self.n_active == 0 {
            return vec![];
        }
        self.ensure_rates();
        let mut done = vec![];
        for (i, f) in self.flows.iter_mut().enumerate() {
            if !f.alive {
                continue;
            }
            let finishes_now = f.rate > 0.0 && f.remaining <= f.rate * dt * (1.0 + 1e-12);
            if dt > 0.0 {
                f.remaining -= f.rate * dt;
            }
            // complete when the finish time fell inside the window or the
            // residue is within the relative epsilon (fp-rounding guards)
            if finishes_now || (f.remaining <= f.eps() && f.rate > 0.0) {
                f.alive = false;
                f.remaining = 0.0;
                done.push(FlowId(i));
            }
        }
        if !done.is_empty() {
            self.n_active -= done.len();
            for &id in &done {
                self.free.push(id.0);
            }
            self.rates_dirty = true;
        }
        done
    }

    /// Earliest time-from-now at which some active flow completes.
    pub fn next_completion(&mut self) -> Option<f64> {
        if self.n_active == 0 {
            return None;
        }
        self.ensure_rates();
        let mut best = f64::INFINITY;
        for f in &self.flows {
            if f.alive && f.rate > 0.0 {
                // aim half an epsilon *past* the completion threshold so
                // the subsequent advance() robustly crosses it
                best = best.min(((f.remaining - 0.5 * f.eps()).max(0.0)) / f.rate);
            }
        }
        (best.is_finite()).then_some(best)
    }

    fn ensure_rates(&mut self) {
        if self.rates_dirty {
            let rates = compute_rates(
                &self
                    .flows
                    .iter()
                    .map(|f| FlowSpec {
                        active: f.alive,
                        ports: f.ports.clone(),
                        cap: f.cap,
                    })
                    .collect::<Vec<_>>(),
                &self.capacity,
            );
            for (f, r) in self.flows.iter_mut().zip(rates) {
                f.rate = r;
            }
            self.rates_dirty = false;
        }
    }

    /// Current rate of a flow (test/inspection hook).
    pub fn rate(&mut self, id: FlowId) -> f64 {
        self.ensure_rates();
        self.flows[id.0].rate
    }
}

/// Input to the fair-share solver (kept standalone for property testing).
#[derive(Clone, Debug)]
pub struct FlowSpec {
    pub active: bool,
    pub ports: Vec<Port>,
    pub cap: f64,
}

/// Max-min fair ("water-filling") rate allocation with per-flow caps.
///
/// Flows with identical `(ports, cap)` signatures are collapsed into a
/// single *class* before solving: symmetric kernels create thousands of
/// identical concurrent flows (e.g. every tile store of a GEMM+RS), and
/// max-min fairness gives equal rates to identical flows, so the solve is
/// exact on classes while dropping the cost from O(F^2 P) to O(C^2 P) with
/// C = distinct routes (this took the Table-3 sweep from hours to
/// seconds; see EXPERIMENTS.md Perf).
///
/// Invariants (checked by property tests):
/// * feasibility: per-port sum of rates <= capacity (within fp tolerance);
/// * cap respected: rate <= cap for every flow;
/// * Pareto/bottleneck: every flow is limited either by its cap or by a
///   saturated port it crosses.
pub fn compute_rates(flows: &[FlowSpec], capacity: &HashMap<Port, f64>) -> Vec<f64> {
    let n = flows.len();
    let mut rate = vec![0.0f64; n];
    // ---- group active flows into classes by (sorted ports, cap bits)
    #[derive(PartialEq, Eq, Hash)]
    struct ClassKey(Vec<Port>, u64);
    struct Class {
        ports: Vec<Port>,
        cap: f64,
        members: Vec<usize>,
    }
    let mut class_of: HashMap<ClassKey, usize> = HashMap::new();
    let mut classes: Vec<Class> = vec![];
    for (i, f) in flows.iter().enumerate() {
        if !f.active {
            continue;
        }
        let mut ports = f.ports.clone();
        ports.sort_unstable_by(port_order);
        let key = ClassKey(ports.clone(), f.cap.to_bits());
        let ci = *class_of.entry(key).or_insert_with(|| {
            classes.push(Class { ports, cap: f.cap, members: vec![] });
            classes.len() - 1
        });
        classes[ci].members.push(i);
    }
    if classes.is_empty() {
        return rate;
    }
    // ---- dense port indexing over the ports actually in use
    let mut port_idx: HashMap<Port, usize> = HashMap::new();
    let mut port_cap: Vec<f64> = vec![];
    for c in &classes {
        for &p in &c.ports {
            port_idx.entry(p).or_insert_with(|| {
                port_cap.push(capacity.get(&p).copied().unwrap_or(f64::INFINITY));
                port_cap.len() - 1
            });
        }
    }
    let class_ports: Vec<Vec<usize>> =
        classes.iter().map(|c| c.ports.iter().map(|p| port_idx[p]).collect()).collect();
    // ---- water-fill over classes
    let nc = classes.len();
    let mut fixed = vec![false; nc];
    let mut class_rate = vec![0.0f64; nc]; // per-member rate
    loop {
        // headroom and unfixed member count per port
        let mut headroom = port_cap.clone();
        let mut unfixed_on = vec![0usize; port_cap.len()];
        for (ci, c) in classes.iter().enumerate() {
            for &pi in &class_ports[ci] {
                if fixed[ci] {
                    headroom[pi] -= class_rate[ci] * c.members.len() as f64;
                } else {
                    unfixed_on[pi] += c.members.len();
                }
            }
        }
        // per-class achievable level
        let mut any_unfixed = false;
        let mut min_level = f64::INFINITY;
        let mut level = vec![0.0f64; nc];
        for (ci, c) in classes.iter().enumerate() {
            if fixed[ci] {
                continue;
            }
            any_unfixed = true;
            let mut l = c.cap;
            for &pi in &class_ports[ci] {
                l = l.min(headroom[pi].max(0.0) / unfixed_on[pi] as f64);
            }
            level[ci] = l;
            min_level = min_level.min(l);
        }
        if !any_unfixed {
            break;
        }
        let mut progressed = false;
        for ci in 0..nc {
            if !fixed[ci] && level[ci] <= min_level * (1.0 + 1e-12) {
                class_rate[ci] = min_level.max(0.0);
                fixed[ci] = true;
                progressed = true;
            }
        }
        if !progressed {
            for ci in 0..nc {
                if !fixed[ci] {
                    class_rate[ci] = min_level.max(0.0);
                    fixed[ci] = true;
                }
            }
            break;
        }
    }
    for (ci, c) in classes.iter().enumerate() {
        for &i in &c.members {
            rate[i] = class_rate[ci];
        }
    }
    rate
}

/// A cheap total order on ports (for class canonicalisation).
fn port_order(a: &Port, b: &Port) -> std::cmp::Ordering {
    fn key(p: &Port) -> (u8, usize) {
        match p {
            Port::Egress(d) => (0, d.0),
            Port::Ingress(d) => (1, d.0),
            Port::Pcie(d) => (2, d.0),
            Port::SwitchReduce(d) => (3, d.0),
            Port::Hbm(d) => (4, d.0),
            Port::CopyEngine(d) => (5, d.0),
            Port::NicEgress(d) => (6, d.0),
            Port::NicIngress(d) => (7, d.0),
        }
    }
    key(a).cmp(&key(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DeviceId;

    fn egress(d: usize) -> Port {
        Port::Egress(DeviceId(d))
    }
    fn ingress(d: usize) -> Port {
        Port::Ingress(DeviceId(d))
    }

    #[test]
    fn single_flow_takes_min_of_cap_and_port() {
        let mut caps = HashMap::new();
        caps.insert(egress(0), 100.0);
        let flows = vec![FlowSpec { active: true, ports: vec![egress(0)], cap: 40.0 }];
        assert_eq!(compute_rates(&flows, &caps), vec![40.0]);
        let flows = vec![FlowSpec { active: true, ports: vec![egress(0)], cap: 400.0 }];
        assert_eq!(compute_rates(&flows, &caps), vec![100.0]);
    }

    #[test]
    fn two_flows_share_port_equally() {
        let mut caps = HashMap::new();
        caps.insert(ingress(1), 100.0);
        let flows = vec![
            FlowSpec { active: true, ports: vec![ingress(1)], cap: 1e9 },
            FlowSpec { active: true, ports: vec![ingress(1)], cap: 1e9 },
        ];
        assert_eq!(compute_rates(&flows, &caps), vec![50.0, 50.0]);
    }

    #[test]
    fn capped_flow_releases_share_to_other() {
        let mut caps = HashMap::new();
        caps.insert(ingress(1), 100.0);
        let flows = vec![
            FlowSpec { active: true, ports: vec![ingress(1)], cap: 20.0 },
            FlowSpec { active: true, ports: vec![ingress(1)], cap: 1e9 },
        ];
        let r = compute_rates(&flows, &caps);
        assert_eq!(r[0], 20.0);
        assert!((r[1] - 80.0).abs() < 1e-9);
    }

    #[test]
    fn n_to_one_ingress_serialises() {
        // The §3.1.3 intra-SM AR effect: N writers into one ingress port
        // each get 1/N of it.
        let mut caps = HashMap::new();
        caps.insert(ingress(0), 450.0);
        for d in 1..8 {
            caps.insert(egress(d), 450.0);
        }
        let flows: Vec<_> = (1..8)
            .map(|d| FlowSpec { active: true, ports: vec![egress(d), ingress(0)], cap: 1e9 })
            .collect();
        let r = compute_rates(&flows, &caps);
        for v in &r {
            assert!((v - 450.0 / 7.0).abs() < 1e-9, "{r:?}");
        }
    }

    #[test]
    fn multi_bottleneck() {
        // f0 crosses A(100) only; f1 crosses A and B(30).
        let mut caps = HashMap::new();
        caps.insert(egress(0), 100.0);
        caps.insert(ingress(1), 30.0);
        let flows = vec![
            FlowSpec { active: true, ports: vec![egress(0)], cap: 1e9 },
            FlowSpec { active: true, ports: vec![egress(0), ingress(1)], cap: 1e9 },
        ];
        let r = compute_rates(&flows, &caps);
        assert!((r[1] - 30.0).abs() < 1e-9);
        assert!((r[0] - 70.0).abs() < 1e-9);
    }

    #[test]
    fn flownet_advance_and_complete() {
        let mut net = FlowNet::new();
        net.set_capacity(egress(0), 100.0);
        let a = net.start(50.0, vec![egress(0)], 1e9);
        let b = net.start(100.0, vec![egress(0)], 1e9);
        // both run at 50 B/s
        assert!((net.rate(a) - 50.0).abs() < 1e-9);
        let dt = net.next_completion().unwrap();
        assert!((dt - 1.0).abs() < 1e-4, "a finishes at t=1 (within eps slack): {dt}");
        let done = net.advance(dt);
        assert_eq!(done, vec![a]);
        // b now gets the whole port: 50 bytes left at 100 B/s
        let dt2 = net.next_completion().unwrap();
        assert!((dt2 - 0.5).abs() < 1e-4, "{dt2}");
        assert_eq!(net.advance(dt2), vec![b]);
        assert_eq!(net.n_active(), 0);
        assert_eq!(net.next_completion(), None);
    }

    #[test]
    fn flownet_reuses_slots() {
        let mut net = FlowNet::new();
        net.set_capacity(egress(0), 10.0);
        let a = net.start(10.0, vec![egress(0)], 1e9);
        let dt = net.next_completion().unwrap();
        net.advance(dt);
        let b = net.start(10.0, vec![egress(0)], 1e9);
        assert_eq!(a.0, b.0, "slot reused");
    }

    #[test]
    fn port_bytes_accounting() {
        let mut net = FlowNet::new();
        net.set_capacity(egress(0), 10.0);
        net.start(10.0, vec![egress(0), ingress(1)], 1e9);
        net.start(5.0, vec![egress(0)], 1e9);
        assert_eq!(net.port_bytes[&egress(0)], 15.0);
        assert_eq!(net.port_bytes[&ingress(1)], 10.0);
    }
}
