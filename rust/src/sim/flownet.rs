//! Bandwidth-shared flow network with max-min fair rate allocation.
//!
//! Each active flow moves `remaining` bytes across a set of [`Port`]
//! resources (its route) and has an intrinsic rate cap — the
//! mechanism-derived limit from [`crate::xfer::curves`] (message-size
//! efficiency × issuing-SM throughput). Concurrent flows sharing a port
//! split its capacity max-min fairly, which is how concurrent peer writes
//! "serialize at the destination" in the paper's intra-SM all-reduce
//! analysis (§3.1.3): N incoming flows each get 1/N of the ingress port.
//!
//! ## Incremental solving
//!
//! The rate solve is the engine's hot path: symmetric kernels keep
//! thousands of identical flows in flight, and every start/completion
//! invalidates the allocation. [`FlowNet`] therefore:
//!
//! * **interns** each route signature once at [`FlowNet::start`] into a
//!   class registry (sorted port list + cap bits → class id) instead of
//!   re-sorting and re-hashing every flow's port list on every solve;
//! * keeps a **dense port table** (port → small integer, capacity in a
//!   flat `Vec`) so the solve never touches a `HashMap`;
//! * stores flows as a **struct-of-arrays arena** (`remaining`/`rate`/
//!   `class`/`alive` in parallel dense `Vec`s with a LIFO free list), so
//!   the solve, `advance`, and `next_completion` touch cache-linear
//!   memory and `start` is O(1) — no sorted active-list insert;
//! * **memoizes** the water-fill keyed on the ordered active
//!   `(class, members)` multiset — repeated phases of a symmetric kernel
//!   (every wave of a GEMM+RS epilogue looks identical to the solver)
//!   skip the solve entirely.
//!
//! ## Event engines: scan vs epoch-keyed heap
//!
//! Two event paths answer "who completes next":
//!
//! * [`Engine::Scan`] (default) — the reference: `advance` and
//!   `next_completion` walk every live slot, O(A) per event.
//! * [`Engine::Heap`] — completion candidates live in a min-heap keyed
//!   by `(conservative completion time, slot, seq)`. Entries are
//!   invalidated **lazily**: a rate change bumps the flow's `seq` and
//!   pushes a fresh entry; stale entries are discarded when popped.
//!   Between rate changes, `advance` defers the per-flow
//!   `remaining -= rate * dt` update into a per-epoch dt log replayed
//!   per flow on demand, so steady (timer-dominated) phases pay
//!   O(log A) per event instead of O(A). Keys are *conservative* (the
//!   eps subtraction plus the [`HEAP_SAFETY`] shrink put them strictly
//!   before the true completion), so a candidate is always popped before
//!   it can complete — and every popped candidate is then evaluated with
//!   the exact eager-scan float expressions on its replayed `remaining`.
//!   That replay performs the *same subtractions in the same order* as
//!   the scan, which is what keeps the heap path **bit-identical** to it
//!   (pinned under random churn by `tests/prop_invariants.rs` and the
//!   pure-Python mirror in `python/tests/test_des_engine_model.py`).
//!   Fully symmetric populations (thousands of flows tied at the same
//!   completion time) degrade gracefully to ~scan cost × log A — the
//!   heap wins on staggered/heterogeneous traffic, which is what serving
//!   traces and multi-kernel models produce at 100k-flow scale.
//!
//! The default stays `Scan` until measured numbers from a
//! toolchain-equipped run land in `BENCH_hotpath.json`; set
//! `PK_FLOWNET=heap` (or construct via [`FlowNet::with_engine`]) to run
//! everything on the heap path.
//!
//! The naive solver is retained as [`compute_rates`]; a property test
//! pins the incremental path **bit-identical** to it under random flow
//! churn (`tests/prop_invariants.rs`), which is what licenses the
//! optimisation: class enumeration follows first-appearance order over
//! ascending live slots and port enumeration follows first-appearance
//! order over those classes, so the water-fill performs the same
//! floating-point operations in the same order as the reference.

use super::OrdF64;
use crate::hw::topology::Port;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Handle to an active flow.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FlowId(pub usize);

/// Which event path answers `advance`/`next_completion` (see module doc).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Engine {
    /// Reference full-scan path, O(A) per event.
    #[default]
    Scan,
    /// Epoch-keyed completion heap with lazy invalidation, O(log A) per
    /// event in steady phases; bit-identical to `Scan`.
    Heap,
}

impl Engine {
    /// Engine selected by the `PK_FLOWNET` env var (`heap` opts in to the
    /// heap path); `Scan` otherwise. Read once and cached.
    pub fn from_env() -> Self {
        static MODE: std::sync::OnceLock<Engine> = std::sync::OnceLock::new();
        *MODE.get_or_init(|| match std::env::var("PK_FLOWNET").as_deref() {
            Ok("heap") => Engine::Heap,
            _ => Engine::Scan,
        })
    }
}

/// Completion uses a *relative* epsilon because `now + dt` rounds in f64 —
/// a flow can otherwise be left with a sub-resolution residue whose
/// completion time rounds to `now`, livelocking the event loop. 1e-6
/// relative residue: ~microsecond-relative timing slack on a full-size
/// flow, far below the model's fidelity, comfortably above f64 rounding
/// from `(now + dt)` round-trips.
#[inline]
fn flow_eps(total: f64) -> f64 {
    total * 1e-6 + 1e-12
}

/// Heap keys are shrunk by this factor so they land strictly *before* the
/// true completion: replay drift is ulp-scale, the 1e-9 slack is ~10^7
/// ulps, and an early pop only costs a re-examination (the exact
/// completion test runs on the replayed remaining either way).
const HEAP_SAFETY: f64 = 1.0 - 1e-9;
/// Pop-threshold slack, same scale as [`HEAP_SAFETY`].
const HEAP_MARGIN_REL: f64 = 1e-9;

/// One interned route signature: the sorted dense-port route plus the cap,
/// with a live-member count maintained by `start`/`advance`.
#[derive(Debug)]
struct FlowClass {
    ports: Vec<u32>,
    cap: f64,
    active_members: usize,
}

/// Solver instrumentation: how often the water-fill ran vs was served
/// from the memo (reported by the hotpath bench and the perf tests).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SolverStats {
    /// Rate recomputations requested (dirty solves).
    pub solves: u64,
    /// Of those, how many were answered from the memo without water-filling.
    pub memo_hits: u64,
    /// Distinct route classes interned over the run.
    pub classes: u64,
    /// Distinct ports interned over the run.
    pub ports: u64,
}

/// The set of active flows plus port capacities.
#[derive(Debug, Default)]
pub struct FlowNet {
    engine: Engine,
    capacity: HashMap<Port, f64>,
    // ---- SoA flow arena: parallel dense arrays indexed by slot, slots
    // recycled LIFO through `free`. Live slots are enumerated by a dense
    // scan (ascending slot order — the class first-appearance order the
    // solver's bit-identity to the naive reference depends on).
    f_remaining: Vec<f64>,
    f_total: Vec<f64>,
    f_rate: Vec<f64>,
    f_class: Vec<u32>,
    f_alive: Vec<bool>,
    free: Vec<usize>,
    n_live: usize,
    rates_dirty: bool,
    /// Cumulative bytes completed per port (conservation accounting,
    /// verified by property tests and used by the report layer).
    pub port_bytes: HashMap<Port, f64>,

    // ---- interning tables (live for the whole run)
    port_id: HashMap<Port, u32>,
    port_cap: Vec<f64>,
    class_id: HashMap<(Vec<u32>, u64), u32>,
    classes: Vec<FlowClass>,

    // ---- solve scratch (epoch-stamped; no per-solve clearing)
    epoch: u64,
    class_seen: Vec<u64>,
    class_local: Vec<u32>,
    port_seen: Vec<u64>,
    port_local: Vec<u32>,
    /// Distinct active classes this solve, first-appearance order.
    order: Vec<u32>,
    /// Dense per-solve port capacities, first-appearance order.
    local_port_cap: Vec<f64>,
    /// Flattened per-class local port indices + offsets (CSR layout).
    cp_local: Vec<u32>,
    cp_off: Vec<usize>,
    class_rate: Vec<f64>,
    key_buf: Vec<(u32, u32)>,

    // ---- water-fill memo keyed on the ordered active class multiset
    solve_cache: HashMap<Vec<(u32, u32)>, Vec<f64>>,
    stats: SolverStats,

    // ---- Engine::Heap state (untouched in Scan mode)
    /// Min-heap of `(conservative completion key, slot, seq)`.
    heap: BinaryHeap<Reverse<(OrdF64, u32, u64)>>,
    /// Per-slot entry generation; a popped entry with a mismatched seq is
    /// stale (lazy invalidation).
    f_seq: Vec<u64>,
    /// Per-slot count of `dt_log` entries already applied to remaining.
    f_synced: Vec<usize>,
    /// dts applied since rates were last assigned (cleared on solve).
    dt_log: Vec<f64>,
    /// Accumulated elapsed time; keys/pruning only, never in outputs.
    vtime: f64,
    /// Reused completion scratch (`advance` returns a borrow of it).
    done_buf: Vec<FlowId>,
    /// Reused candidate scratch for heap pops.
    cand_buf: Vec<u32>,
}

/// Memo entries are bounded; a sweep that somehow produces more distinct
/// active multisets than this simply starts over (correctness is
/// unaffected — the cache only ever replays its own water-fill output).
const SOLVE_CACHE_MAX: usize = 8192;

impl FlowNet {
    /// A net on the engine selected by `PK_FLOWNET` (default: scan).
    pub fn new() -> Self {
        Self::with_engine(Engine::from_env())
    }

    /// A net pinned to a specific event engine (test/bench hook).
    pub fn with_engine(engine: Engine) -> Self {
        FlowNet { engine, ..Default::default() }
    }

    /// The event engine this net runs on.
    pub fn engine(&self) -> Engine {
        self.engine
    }

    /// Declare a port's capacity in bytes/s. Ports default to infinite
    /// capacity if never declared (useful for tests). Zero is a legal
    /// capacity — a failed link: flows crossing it stall at rate 0 (the
    /// water-fill assigns them level 0 and terminates normally) and
    /// [`FlowNet::next_completion`] reports `None` while every live flow
    /// is stalled. Restoring a positive capacity resumes them.
    pub fn set_capacity(&mut self, port: Port, bytes_per_s: f64) {
        assert!(bytes_per_s >= 0.0 && !bytes_per_s.is_nan(), "capacity must be >= 0, got {bytes_per_s}");
        self.capacity.insert(port, bytes_per_s);
        if let Some(&id) = self.port_id.get(&port) {
            // capacity changed after the port was interned: refresh the
            // dense table, drop memoized solves computed against the old
            // value, and force a re-solve even if no flow churn follows
            // (fault injection changes capacities mid-flight with no
            // accompanying start/completion).
            self.port_cap[id as usize] = bytes_per_s;
            self.solve_cache.clear();
            self.rates_dirty = true;
        }
    }

    fn intern_port(&mut self, p: Port) -> u32 {
        if let Some(&id) = self.port_id.get(&p) {
            return id;
        }
        let id = self.port_cap.len() as u32;
        self.port_cap.push(self.capacity.get(&p).copied().unwrap_or(f64::INFINITY));
        self.port_seen.push(0);
        self.port_local.push(0);
        self.port_id.insert(p, id);
        self.stats.ports += 1;
        id
    }

    /// Start a flow of `bytes` over `ports` with intrinsic rate cap `cap`.
    pub fn start(&mut self, bytes: f64, ports: Vec<Port>, cap: f64) -> FlowId {
        assert!(bytes > 0.0, "zero-byte flow");
        assert!(cap > 0.0, "flow needs positive cap");
        for &p in &ports {
            *self.port_bytes.entry(p).or_insert(0.0) += bytes;
        }
        // ---- intern the route signature once (the naive solver re-sorts
        // and re-hashes every flow on every rate change; see module doc)
        let mut sorted = ports;
        sorted.sort_unstable_by(port_order);
        let mut pids = Vec::with_capacity(sorted.len());
        for &p in &sorted {
            pids.push(self.intern_port(p));
        }
        let key = (pids, cap.to_bits());
        let class = if let Some(&c) = self.class_id.get(&key) {
            c
        } else {
            let c = self.classes.len() as u32;
            self.classes.push(FlowClass { ports: key.0.clone(), cap, active_members: 0 });
            self.class_seen.push(0);
            self.class_local.push(0);
            self.class_id.insert(key, c);
            self.stats.classes += 1;
            c
        };
        self.classes[class as usize].active_members += 1;
        self.rates_dirty = true;
        // rate starts at 0.0 even on a recycled slot: the heap engine
        // re-keys on rate-bit *change*, so a stale rate here could
        // swallow the re-key that gives the flow its completion entry.
        let slot = if let Some(idx) = self.free.pop() {
            self.f_remaining[idx] = bytes;
            self.f_total[idx] = bytes;
            self.f_rate[idx] = 0.0;
            self.f_class[idx] = class;
            self.f_alive[idx] = true;
            idx
        } else {
            self.f_remaining.push(bytes);
            self.f_total.push(bytes);
            self.f_rate.push(0.0);
            self.f_class.push(class);
            self.f_alive.push(true);
            self.f_seq.push(0);
            self.f_synced.push(0);
            self.f_remaining.len() - 1
        };
        self.f_synced[slot] = self.dt_log.len();
        self.n_live += 1;
        FlowId(slot)
    }

    pub fn n_active(&self) -> usize {
        self.n_live
    }

    /// Solver instrumentation for the run so far.
    pub fn solver_stats(&self) -> SolverStats {
        self.stats
    }

    /// Advance all flows by `dt` seconds at current rates; returns flows
    /// that completed (remaining hit zero), in ascending slot order. Rates
    /// must be current (`ensure_rates` is called lazily). The returned
    /// slice borrows a reused scratch buffer — no per-event allocation.
    pub fn advance(&mut self, dt: f64) -> &[FlowId] {
        self.done_buf.clear();
        if self.n_live == 0 {
            return &self.done_buf;
        }
        self.ensure_rates();
        match self.engine {
            Engine::Scan => self.advance_scan(dt),
            Engine::Heap => self.advance_heap(dt),
        }
        if !self.done_buf.is_empty() {
            for i in 0..self.done_buf.len() {
                let s = self.done_buf[i].0;
                self.free.push(s);
                self.classes[self.f_class[s] as usize].active_members -= 1;
            }
            self.n_live -= self.done_buf.len();
            self.rates_dirty = true;
        }
        &self.done_buf
    }

    fn advance_scan(&mut self, dt: f64) {
        for s in 0..self.f_alive.len() {
            if !self.f_alive[s] {
                continue;
            }
            let rate = self.f_rate[s];
            let finishes_now = rate > 0.0 && self.f_remaining[s] <= rate * dt * (1.0 + 1e-12);
            if dt > 0.0 {
                self.f_remaining[s] -= rate * dt;
            }
            // complete when the finish time fell inside the window or the
            // residue is within the relative epsilon (fp-rounding guards)
            if finishes_now || (self.f_remaining[s] <= flow_eps(self.f_total[s]) && rate > 0.0) {
                self.f_alive[s] = false;
                self.f_remaining[s] = 0.0;
                self.done_buf.push(FlowId(s));
            }
        }
    }

    fn advance_heap(&mut self, dt: f64) {
        if dt > 0.0 {
            self.dt_log.push(dt);
        }
        self.vtime += dt;
        let margin = (self.vtime.abs() + dt) * HEAP_MARGIN_REL + 1e-18;
        self.cand_buf.clear();
        while let Some(&Reverse((OrdF64(k), slot, seq))) = self.heap.peek() {
            let s = slot as usize;
            if self.f_seq[s] != seq || !self.f_alive[s] {
                self.heap.pop();
                continue;
            }
            if k > self.vtime + margin {
                break;
            }
            self.heap.pop();
            // replay prior steps, then mirror the scan's per-advance body:
            // finishes_now on the pre-subtraction remaining, subtract, eps
            let rate = self.f_rate[s];
            self.replay(s, self.dt_log.len() - usize::from(dt > 0.0));
            let finishes_now = rate > 0.0 && self.f_remaining[s] <= rate * dt * (1.0 + 1e-12);
            if dt > 0.0 {
                self.f_remaining[s] -= rate * dt;
            }
            self.f_synced[s] = self.dt_log.len();
            if finishes_now || (self.f_remaining[s] <= flow_eps(self.f_total[s]) && rate > 0.0) {
                self.f_alive[s] = false;
                self.f_remaining[s] = 0.0;
                self.f_seq[s] += 1;
                self.done_buf.push(FlowId(s));
            } else {
                self.cand_buf.push(slot);
            }
        }
        // early pops re-key *after* the loop — re-pushing inside it could
        // re-examine the same entry forever when its key sits inside the
        // pop margin
        for i in 0..self.cand_buf.len() {
            self.push_entry(self.cand_buf[i] as usize);
        }
        // heap pops come out in key order; the contract (and the scan
        // path, and the free-list LIFO discipline) is ascending slot order
        self.done_buf.sort_unstable_by_key(|id| id.0);
    }

    /// Earliest time-from-now at which some active flow completes.
    pub fn next_completion(&mut self) -> Option<f64> {
        if self.n_live == 0 {
            return None;
        }
        self.ensure_rates();
        match self.engine {
            Engine::Scan => self.next_completion_scan(),
            Engine::Heap => self.next_completion_heap(),
        }
    }

    fn next_completion_scan(&mut self) -> Option<f64> {
        let mut best = f64::INFINITY;
        for s in 0..self.f_alive.len() {
            if !self.f_alive[s] {
                continue;
            }
            let rate = self.f_rate[s];
            if rate > 0.0 {
                // aim half an epsilon *past* the completion threshold so
                // the subsequent advance() robustly crosses it
                best = best
                    .min((self.f_remaining[s] - 0.5 * flow_eps(self.f_total[s])).max(0.0) / rate);
            }
        }
        best.is_finite().then_some(best)
    }

    fn next_completion_heap(&mut self) -> Option<f64> {
        let mut best = f64::INFINITY;
        self.cand_buf.clear();
        while let Some(&Reverse((OrdF64(k), slot, seq))) = self.heap.peek() {
            let s = slot as usize;
            if self.f_seq[s] != seq || !self.f_alive[s] {
                self.heap.pop();
                continue;
            }
            // a remaining entry's true value sits at or above its
            // conservative key, so nothing past this bound can beat best
            if best.is_finite()
                && k > self.vtime + best + ((self.vtime.abs() + best) * HEAP_MARGIN_REL + 1e-18)
            {
                break;
            }
            self.heap.pop();
            self.replay(s, self.dt_log.len());
            best = best
                .min((self.f_remaining[s] - 0.5 * flow_eps(self.f_total[s])).max(0.0)
                    / self.f_rate[s]);
            self.cand_buf.push(slot);
        }
        for i in 0..self.cand_buf.len() {
            self.push_entry(self.cand_buf[i] as usize);
        }
        best.is_finite().then_some(best)
    }

    /// Push a fresh heap entry for live slot `s` (rate must be > 0),
    /// invalidating any previous entry via the seq bump.
    fn push_entry(&mut self, s: usize) {
        let rel =
            (self.f_remaining[s] - flow_eps(self.f_total[s])).max(0.0) / self.f_rate[s]
                * HEAP_SAFETY;
        self.f_seq[s] += 1;
        self.heap.push(Reverse((OrdF64(self.vtime + rel), s as u32, self.f_seq[s])));
    }

    /// Apply `dt_log[f_synced[s]..upto]` to the flow's remaining — the
    /// same subtraction sequence the eager scan performed, deferred.
    fn replay(&mut self, s: usize, upto: usize) {
        let rate = self.f_rate[s];
        for i in self.f_synced[s]..upto {
            self.f_remaining[s] -= rate * self.dt_log[i];
        }
        self.f_synced[s] = upto;
    }

    /// Catch every live flow up under the *current* rates and clear the
    /// epoch's dt log (heap engine; called before rates change).
    fn materialize_all(&mut self) {
        for s in 0..self.f_alive.len() {
            if self.f_alive[s] {
                self.replay(s, self.dt_log.len());
                self.f_synced[s] = 0;
            }
        }
        self.dt_log.clear();
    }

    fn ensure_rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        if self.engine == Engine::Heap {
            self.materialize_all();
        }
        self.rates_dirty = false;
        if self.n_live == 0 {
            return;
        }
        self.stats.solves += 1;
        self.epoch += 1;
        // ---- distinct active classes, first-appearance order over
        // ascending live slots (matches the naive reference's flow scan)
        self.order.clear();
        for s in 0..self.f_alive.len() {
            if !self.f_alive[s] {
                continue;
            }
            let c = self.f_class[s];
            if self.class_seen[c as usize] != self.epoch {
                self.class_seen[c as usize] = self.epoch;
                self.class_local[c as usize] = self.order.len() as u32;
                self.order.push(c);
            }
        }
        // ---- memo lookup on the ordered (class, members) multiset
        self.key_buf.clear();
        for &c in &self.order {
            self.key_buf.push((c, self.classes[c as usize].active_members as u32));
        }
        if let Some(cached) = self.solve_cache.get(&self.key_buf) {
            self.stats.memo_hits += 1;
            self.class_rate.clear();
            self.class_rate.extend_from_slice(cached);
        } else {
            self.water_fill();
            if self.solve_cache.len() >= SOLVE_CACHE_MAX {
                self.solve_cache.clear();
            }
            self.solve_cache.insert(self.key_buf.clone(), self.class_rate.clone());
        }
        for s in 0..self.f_alive.len() {
            if !self.f_alive[s] {
                continue;
            }
            let li = self.class_local[self.f_class[s] as usize] as usize;
            let r = self.class_rate[li];
            match self.engine {
                Engine::Scan => self.f_rate[s] = r,
                Engine::Heap => {
                    // rate changed: the old entry's key is no longer
                    // conservative — bump seq (lazy invalidation), re-key.
                    // Unchanged rates keep their entry: the old key stays
                    // conservative, which is what makes memo-hit phases
                    // cheap.
                    if r.to_bits() != self.f_rate[s].to_bits() {
                        self.f_rate[s] = r;
                        if r > 0.0 {
                            self.push_entry(s);
                        } else {
                            self.f_seq[s] += 1;
                        }
                    }
                }
            }
        }
    }

    /// Max-min water-fill over the active classes in `self.order`,
    /// writing per-member rates into `self.class_rate`. The loop body is
    /// a dense-index transliteration of [`compute_rates`]'s — same
    /// operations in the same order, so results are bit-identical.
    fn water_fill(&mut self) {
        // dense local port table in first-appearance order over classes
        self.local_port_cap.clear();
        self.cp_local.clear();
        self.cp_off.clear();
        for &c in &self.order {
            self.cp_off.push(self.cp_local.len());
            for &pid in &self.classes[c as usize].ports {
                let p = pid as usize;
                if self.port_seen[p] != self.epoch {
                    self.port_seen[p] = self.epoch;
                    self.port_local[p] = self.local_port_cap.len() as u32;
                    self.local_port_cap.push(self.port_cap[p]);
                }
                self.cp_local.push(self.port_local[p]);
            }
        }
        self.cp_off.push(self.cp_local.len());
        let nc = self.order.len();
        let np = self.local_port_cap.len();
        let mut fixed = vec![false; nc];
        self.class_rate.clear();
        self.class_rate.resize(nc, 0.0);
        loop {
            // headroom and unfixed member count per port
            let mut headroom = self.local_port_cap.clone();
            let mut unfixed_on = vec![0usize; np];
            for oi in 0..nc {
                let members = self.classes[self.order[oi] as usize].active_members;
                for &pi in &self.cp_local[self.cp_off[oi]..self.cp_off[oi + 1]] {
                    if fixed[oi] {
                        headroom[pi as usize] -= self.class_rate[oi] * members as f64;
                    } else {
                        unfixed_on[pi as usize] += members;
                    }
                }
            }
            // per-class achievable level
            let mut any_unfixed = false;
            let mut min_level = f64::INFINITY;
            let mut level = vec![0.0f64; nc];
            for oi in 0..nc {
                if fixed[oi] {
                    continue;
                }
                any_unfixed = true;
                let mut l = self.classes[self.order[oi] as usize].cap;
                for &pi in &self.cp_local[self.cp_off[oi]..self.cp_off[oi + 1]] {
                    l = l.min(headroom[pi as usize].max(0.0) / unfixed_on[pi as usize] as f64);
                }
                level[oi] = l;
                min_level = min_level.min(l);
            }
            if !any_unfixed {
                break;
            }
            let mut progressed = false;
            for oi in 0..nc {
                if !fixed[oi] && level[oi] <= min_level * (1.0 + 1e-12) {
                    self.class_rate[oi] = min_level.max(0.0);
                    fixed[oi] = true;
                    progressed = true;
                }
            }
            if !progressed {
                for oi in 0..nc {
                    if !fixed[oi] {
                        self.class_rate[oi] = min_level.max(0.0);
                        fixed[oi] = true;
                    }
                }
                break;
            }
        }
    }

    /// Current rate of a flow (test/inspection hook). Only meaningful for
    /// live flows; a completed flow's slot keeps its last assigned rate.
    pub fn rate(&mut self, id: FlowId) -> f64 {
        self.ensure_rates();
        self.f_rate[id.0]
    }

    /// Drop all memoized solves (test hook: forces the next `ensure_rates`
    /// to water-fill from scratch, for memo-vs-recompute equivalence
    /// pins).
    pub fn clear_solve_cache(&mut self) {
        self.solve_cache.clear();
    }
}

/// Input to the fair-share solver (kept standalone for property testing).
#[derive(Clone, Debug)]
pub struct FlowSpec {
    pub active: bool,
    pub ports: Vec<Port>,
    pub cap: f64,
}

/// Max-min fair ("water-filling") rate allocation with per-flow caps —
/// the retained **naive reference** for the incremental solver inside
/// [`FlowNet`] (property tests pin the two bit-identical under churn).
///
/// Flows with identical `(ports, cap)` signatures are collapsed into a
/// single *class* before solving: symmetric kernels create thousands of
/// identical concurrent flows (e.g. every tile store of a GEMM+RS), and
/// max-min fairness gives equal rates to identical flows, so the solve is
/// exact on classes while dropping the cost from O(F^2 P) to O(C^2 P) with
/// C = distinct routes (this took the Table-3 sweep from hours to
/// seconds; see EXPERIMENTS.md Perf).
///
/// Invariants (checked by property tests):
/// * feasibility: per-port sum of rates <= capacity (within fp tolerance);
/// * cap respected: rate <= cap for every flow;
/// * Pareto/bottleneck: every flow is limited either by its cap or by a
///   saturated port it crosses.
pub fn compute_rates(flows: &[FlowSpec], capacity: &HashMap<Port, f64>) -> Vec<f64> {
    let n = flows.len();
    let mut rate = vec![0.0f64; n];
    // ---- group active flows into classes by (sorted ports, cap bits)
    #[derive(PartialEq, Eq, Hash)]
    struct ClassKey(Vec<Port>, u64);
    struct Class {
        ports: Vec<Port>,
        cap: f64,
        members: Vec<usize>,
    }
    let mut class_of: HashMap<ClassKey, usize> = HashMap::new();
    let mut classes: Vec<Class> = vec![];
    for (i, f) in flows.iter().enumerate() {
        if !f.active {
            continue;
        }
        let mut ports = f.ports.clone();
        ports.sort_unstable_by(port_order);
        let key = ClassKey(ports.clone(), f.cap.to_bits());
        let ci = *class_of.entry(key).or_insert_with(|| {
            classes.push(Class { ports, cap: f.cap, members: vec![] });
            classes.len() - 1
        });
        classes[ci].members.push(i);
    }
    if classes.is_empty() {
        return rate;
    }
    // ---- dense port indexing over the ports actually in use
    let mut port_idx: HashMap<Port, usize> = HashMap::new();
    let mut port_cap: Vec<f64> = vec![];
    for c in &classes {
        for &p in &c.ports {
            port_idx.entry(p).or_insert_with(|| {
                port_cap.push(capacity.get(&p).copied().unwrap_or(f64::INFINITY));
                port_cap.len() - 1
            });
        }
    }
    let class_ports: Vec<Vec<usize>> =
        classes.iter().map(|c| c.ports.iter().map(|p| port_idx[p]).collect()).collect();
    // ---- water-fill over classes
    let nc = classes.len();
    let mut fixed = vec![false; nc];
    let mut class_rate = vec![0.0f64; nc]; // per-member rate
    loop {
        // headroom and unfixed member count per port
        let mut headroom = port_cap.clone();
        let mut unfixed_on = vec![0usize; port_cap.len()];
        for (ci, c) in classes.iter().enumerate() {
            for &pi in &class_ports[ci] {
                if fixed[ci] {
                    headroom[pi] -= class_rate[ci] * c.members.len() as f64;
                } else {
                    unfixed_on[pi] += c.members.len();
                }
            }
        }
        // per-class achievable level
        let mut any_unfixed = false;
        let mut min_level = f64::INFINITY;
        let mut level = vec![0.0f64; nc];
        for (ci, c) in classes.iter().enumerate() {
            if fixed[ci] {
                continue;
            }
            any_unfixed = true;
            let mut l = c.cap;
            for &pi in &class_ports[ci] {
                l = l.min(headroom[pi].max(0.0) / unfixed_on[pi] as f64);
            }
            level[ci] = l;
            min_level = min_level.min(l);
        }
        if !any_unfixed {
            break;
        }
        let mut progressed = false;
        for ci in 0..nc {
            if !fixed[ci] && level[ci] <= min_level * (1.0 + 1e-12) {
                class_rate[ci] = min_level.max(0.0);
                fixed[ci] = true;
                progressed = true;
            }
        }
        if !progressed {
            for ci in 0..nc {
                if !fixed[ci] {
                    class_rate[ci] = min_level.max(0.0);
                    fixed[ci] = true;
                }
            }
            break;
        }
    }
    for (ci, c) in classes.iter().enumerate() {
        for &i in &c.members {
            rate[i] = class_rate[ci];
        }
    }
    rate
}

/// A cheap total order on ports (for class canonicalisation).
fn port_order(a: &Port, b: &Port) -> std::cmp::Ordering {
    fn key(p: &Port) -> (u8, usize) {
        match p {
            Port::Egress(d) => (0, d.0),
            Port::Ingress(d) => (1, d.0),
            Port::Pcie(d) => (2, d.0),
            Port::SwitchReduce(d) => (3, d.0),
            Port::Hbm(d) => (4, d.0),
            Port::CopyEngine(d) => (5, d.0),
            Port::NicEgress(d) => (6, d.0),
            Port::NicIngress(d) => (7, d.0),
        }
    }
    key(a).cmp(&key(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DeviceId;

    fn egress(d: usize) -> Port {
        Port::Egress(DeviceId(d))
    }
    fn ingress(d: usize) -> Port {
        Port::Ingress(DeviceId(d))
    }

    #[test]
    fn single_flow_takes_min_of_cap_and_port() {
        let mut caps = HashMap::new();
        caps.insert(egress(0), 100.0);
        let flows = vec![FlowSpec { active: true, ports: vec![egress(0)], cap: 40.0 }];
        assert_eq!(compute_rates(&flows, &caps), vec![40.0]);
        let flows = vec![FlowSpec { active: true, ports: vec![egress(0)], cap: 400.0 }];
        assert_eq!(compute_rates(&flows, &caps), vec![100.0]);
    }

    #[test]
    fn two_flows_share_port_equally() {
        let mut caps = HashMap::new();
        caps.insert(ingress(1), 100.0);
        let flows = vec![
            FlowSpec { active: true, ports: vec![ingress(1)], cap: 1e9 },
            FlowSpec { active: true, ports: vec![ingress(1)], cap: 1e9 },
        ];
        assert_eq!(compute_rates(&flows, &caps), vec![50.0, 50.0]);
    }

    #[test]
    fn capped_flow_releases_share_to_other() {
        let mut caps = HashMap::new();
        caps.insert(ingress(1), 100.0);
        let flows = vec![
            FlowSpec { active: true, ports: vec![ingress(1)], cap: 20.0 },
            FlowSpec { active: true, ports: vec![ingress(1)], cap: 1e9 },
        ];
        let r = compute_rates(&flows, &caps);
        assert_eq!(r[0], 20.0);
        assert!((r[1] - 80.0).abs() < 1e-9);
    }

    #[test]
    fn n_to_one_ingress_serialises() {
        // The §3.1.3 intra-SM AR effect: N writers into one ingress port
        // each get 1/N of it.
        let mut caps = HashMap::new();
        caps.insert(ingress(0), 450.0);
        for d in 1..8 {
            caps.insert(egress(d), 450.0);
        }
        let flows: Vec<_> = (1..8)
            .map(|d| FlowSpec { active: true, ports: vec![egress(d), ingress(0)], cap: 1e9 })
            .collect();
        let r = compute_rates(&flows, &caps);
        for v in &r {
            assert!((v - 450.0 / 7.0).abs() < 1e-9, "{r:?}");
        }
    }

    #[test]
    fn multi_bottleneck() {
        // f0 crosses A(100) only; f1 crosses A and B(30).
        let mut caps = HashMap::new();
        caps.insert(egress(0), 100.0);
        caps.insert(ingress(1), 30.0);
        let flows = vec![
            FlowSpec { active: true, ports: vec![egress(0)], cap: 1e9 },
            FlowSpec { active: true, ports: vec![egress(0), ingress(1)], cap: 1e9 },
        ];
        let r = compute_rates(&flows, &caps);
        assert!((r[1] - 30.0).abs() < 1e-9);
        assert!((r[0] - 70.0).abs() < 1e-9);
    }

    fn advance_and_complete_on(engine: Engine) {
        let mut net = FlowNet::with_engine(engine);
        net.set_capacity(egress(0), 100.0);
        let a = net.start(50.0, vec![egress(0)], 1e9);
        let b = net.start(100.0, vec![egress(0)], 1e9);
        // both run at 50 B/s
        assert!((net.rate(a) - 50.0).abs() < 1e-9);
        let dt = net.next_completion().unwrap();
        assert!((dt - 1.0).abs() < 1e-4, "a finishes at t=1 (within eps slack): {dt}");
        let done = net.advance(dt);
        assert_eq!(done, vec![a]);
        // b now gets the whole port: 50 bytes left at 100 B/s
        let dt2 = net.next_completion().unwrap();
        assert!((dt2 - 0.5).abs() < 1e-4, "{dt2}");
        assert_eq!(net.advance(dt2), vec![b]);
        assert_eq!(net.n_active(), 0);
        assert_eq!(net.next_completion(), None);
    }

    #[test]
    fn flownet_advance_and_complete() {
        advance_and_complete_on(Engine::Scan);
    }

    #[test]
    fn flownet_advance_and_complete_heap() {
        advance_and_complete_on(Engine::Heap);
    }

    #[test]
    fn flownet_reuses_slots() {
        for engine in [Engine::Scan, Engine::Heap] {
            let mut net = FlowNet::with_engine(engine);
            net.set_capacity(egress(0), 10.0);
            let a = net.start(10.0, vec![egress(0)], 1e9);
            let dt = net.next_completion().unwrap();
            net.advance(dt);
            let b = net.start(10.0, vec![egress(0)], 1e9);
            assert_eq!(a.0, b.0, "slot reused ({engine:?})");
        }
    }

    #[test]
    fn port_bytes_accounting() {
        let mut net = FlowNet::new();
        net.set_capacity(egress(0), 10.0);
        net.start(10.0, vec![egress(0), ingress(1)], 1e9);
        net.start(5.0, vec![egress(0)], 1e9);
        assert_eq!(net.port_bytes[&egress(0)], 15.0);
        assert_eq!(net.port_bytes[&ingress(1)], 10.0);
    }

    #[test]
    fn identical_routes_intern_to_one_class() {
        let mut net = FlowNet::new();
        net.set_capacity(egress(0), 100.0);
        for _ in 0..16 {
            // route given in both orders: canonicalised to one signature
            net.start(10.0, vec![egress(0), ingress(1)], 50.0);
            net.start(10.0, vec![ingress(1), egress(0)], 50.0);
        }
        let s = net.solver_stats();
        assert_eq!(s.classes, 1, "{s:?}");
        assert_eq!(s.ports, 2);
    }

    #[test]
    fn memo_hits_on_repeated_phases() {
        // symmetric churn: every generation of flows presents the same
        // (class, members) multiset, so only the first solve water-fills.
        let mut net = FlowNet::new();
        net.set_capacity(egress(0), 100.0);
        for _ in 0..8 {
            let a = net.start(10.0, vec![egress(0)], 1e9);
            let b = net.start(10.0, vec![egress(0)], 1e9);
            let dt = net.next_completion().unwrap();
            // slot recycling is LIFO, so generation ids swap after the
            // first round; completions always come out slot-ascending
            let mut want = vec![a, b];
            want.sort_by_key(|id| id.0);
            let done = net.advance(dt);
            assert_eq!(done, want);
        }
        let s = net.solver_stats();
        assert!(s.memo_hits >= s.solves - 2, "memo should serve repeats: {s:?}");
    }

    #[test]
    fn memo_and_fresh_solves_agree_bitwise() {
        // identical churn on two nets; one has its memo cleared before
        // every query so it always water-fills. Rates must match bitwise.
        let run = |clear: bool| -> Vec<u64> {
            let mut net = FlowNet::new();
            net.set_capacity(egress(0), 173.5);
            net.set_capacity(ingress(1), 91.25);
            let mut bits = vec![];
            for round in 0..6 {
                let mut ids = vec![];
                for i in 0..4 {
                    let ports = if i % 2 == 0 {
                        vec![egress(0), ingress(1)]
                    } else {
                        vec![egress(0)]
                    };
                    ids.push(net.start(10.0 + round as f64, ports, 37.0 + (i % 2) as f64));
                }
                if clear {
                    net.clear_solve_cache();
                }
                for &id in &ids {
                    bits.push(net.rate(id).to_bits());
                }
                while net.n_active() > 0 {
                    if clear {
                        net.clear_solve_cache();
                    }
                    let dt = net.next_completion().unwrap();
                    net.advance(dt);
                }
            }
            bits
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn late_capacity_change_invalidates_memo() {
        let mut net = FlowNet::new();
        net.set_capacity(egress(0), 100.0);
        let a = net.start(1000.0, vec![egress(0)], 1e9);
        assert_eq!(net.rate(a), 100.0);
        // halve the port mid-run: next solve must see it, not the memo
        net.set_capacity(egress(0), 50.0);
        let b = net.start(1000.0, vec![egress(0)], 1e9);
        let _ = b;
        assert_eq!(net.rate(a), 25.0);
    }

    #[test]
    fn heap_engine_bit_identical_on_partial_advances() {
        // timer-style partial advances inside one epoch: the heap net
        // defers the subtractions into its dt log, the scan net applies
        // them eagerly — every observable must still agree bitwise.
        let mut scan = FlowNet::with_engine(Engine::Scan);
        let mut heap = FlowNet::with_engine(Engine::Heap);
        for net in [&mut scan, &mut heap] {
            net.set_capacity(egress(0), 173.5);
            net.set_capacity(ingress(1), 91.25);
        }
        let mut ids = vec![];
        for i in 0..6 {
            let b = 100.0 + 37.0 * i as f64;
            ids.push(scan.start(b, vec![egress(0), ingress(1)], 333.25));
            heap.start(b, vec![egress(0), ingress(1)], 333.25);
        }
        for k in 0..5 {
            let dt = scan.next_completion().unwrap();
            assert_eq!(heap.next_completion().unwrap().to_bits(), dt.to_bits());
            let frac = 0.125 * (k + 1) as f64;
            let want = scan.advance(dt * frac).to_vec();
            let got = heap.advance(dt * frac).to_vec();
            assert_eq!(got, want);
            for &id in &ids {
                assert_eq!(heap.rate(id).to_bits(), scan.rate(id).to_bits());
            }
        }
        // drain both: completion batches must mirror to the end
        loop {
            let (a, b) = (scan.next_completion(), heap.next_completion());
            match (a, b) {
                (None, None) => break,
                (Some(x), Some(y)) => assert_eq!(x.to_bits(), y.to_bits()),
                other => panic!("engines diverged: {other:?}"),
            }
            let dt = a.unwrap();
            let want = scan.advance(dt).to_vec();
            let got = heap.advance(dt).to_vec();
            assert_eq!(got, want);
        }
        assert_eq!(scan.n_active(), 0);
        assert_eq!(heap.n_active(), 0);
    }

    fn zero_capacity_stalls_cleanly_on(engine: Engine) {
        // a failed link: capacity -> 0 must not produce NaN/Inf rates or a
        // non-terminating water-fill; stalled flows report no completion
        // and resume when the capacity is restored.
        let mut net = FlowNet::with_engine(engine);
        net.set_capacity(egress(0), 100.0);
        let a = net.start(100.0, vec![egress(0)], 1e9);
        assert_eq!(net.rate(a), 100.0);
        net.set_capacity(egress(0), 0.0);
        let r = net.rate(a);
        assert_eq!(r, 0.0, "stalled flow rate must be exactly 0 ({engine:?}): {r}");
        assert!(net.next_completion().is_none(), "all-stalled net has no next completion");
        // advancing time while stalled moves no bytes and completes nothing
        assert!(net.advance(5.0).is_empty());
        assert_eq!(net.n_active(), 1);
        // a second flow on a healthy port still progresses around the stall
        net.set_capacity(ingress(1), 50.0);
        let b = net.start(50.0, vec![ingress(1)], 1e9);
        assert_eq!(net.rate(b), 50.0);
        assert_eq!(net.rate(a), 0.0);
        let dt = net.next_completion().expect("healthy flow must progress");
        assert!((dt - 1.0).abs() < 1e-4, "{dt}");
        assert_eq!(net.advance(dt), vec![b]);
        // restore: the stalled flow picks the full port back up and drains
        net.set_capacity(egress(0), 100.0);
        assert_eq!(net.rate(a), 100.0);
        let dt = net.next_completion().expect("restored flow must progress");
        assert!((dt - 1.0).abs() < 1e-4, "full 100 bytes remain: {dt}");
        assert_eq!(net.advance(dt), vec![a]);
        assert_eq!(net.n_active(), 0);
    }

    #[test]
    fn zero_capacity_stalls_cleanly_scan() {
        zero_capacity_stalls_cleanly_on(Engine::Scan);
    }

    #[test]
    fn zero_capacity_stalls_cleanly_heap() {
        zero_capacity_stalls_cleanly_on(Engine::Heap);
    }

    #[test]
    fn zero_capacity_shared_port_starves_only_the_crossing_class() {
        // two classes share egress(0); one also crosses a failed ingress.
        // The water-fill must give the failed class exactly 0 and hand the
        // full shared-port capacity to the healthy class — no NaN, no
        // livelock, identical on both solvers.
        let mut caps = HashMap::new();
        caps.insert(egress(0), 100.0);
        caps.insert(ingress(1), 0.0);
        let flows = vec![
            FlowSpec { active: true, ports: vec![egress(0)], cap: 1e9 },
            FlowSpec { active: true, ports: vec![egress(0), ingress(1)], cap: 1e9 },
        ];
        let r = compute_rates(&flows, &caps);
        assert_eq!(r[1], 0.0);
        assert!((r[0] - 100.0).abs() < 1e-9, "{r:?}");
        for engine in [Engine::Scan, Engine::Heap] {
            let mut net = FlowNet::with_engine(engine);
            net.set_capacity(egress(0), 100.0);
            net.set_capacity(ingress(1), 0.0);
            let h = net.start(100.0, vec![egress(0)], 1e9);
            let s = net.start(100.0, vec![egress(0), ingress(1)], 1e9);
            assert_eq!(net.rate(s), 0.0, "{engine:?}");
            assert_eq!(net.rate(h), 100.0, "{engine:?}");
            assert!(net.rate(h).is_finite() && !net.rate(s).is_nan());
        }
    }

    #[test]
    fn heap_engine_survives_capacity_rekey() {
        // mid-run capacity change: old heap entries are stale (lazy
        // invalidation), the re-key must still produce correct timings
        let mut net = FlowNet::with_engine(Engine::Heap);
        net.set_capacity(egress(0), 100.0);
        let a = net.start(1000.0, vec![egress(0)], 1e9);
        assert_eq!(net.rate(a), 100.0);
        let _ = net.next_completion().unwrap(); // seed a heap entry
        net.set_capacity(egress(0), 50.0);
        let b = net.start(1000.0, vec![egress(0)], 1e9);
        assert_eq!(net.rate(a), 25.0);
        let dt = net.next_completion().unwrap();
        assert!((dt - 40.0).abs() < 1e-2, "{dt}");
        let done = net.advance(dt);
        assert_eq!(done, vec![a, b]);
    }
}
