//! Static verification of [`Plan`]s: happens-before construction, liveness
//! checking, a data-race detector over effect regions, and a registry of
//! lint rules (view bounds, effect shapes, signal scopes, RDMA routing).
//!
//! The eight-primitive template (§3.2.2) makes every kernel a set of
//! straight-line worker programs synchronized only by monotone counting
//! semaphores, which is exactly the shape a static analysis can certify:
//!
//! 1. **Happens-before graph.** Program order within each worker, plus one
//!    synchronization edge per *necessary* increment: an increment `e` of
//!    sem `s` must precede `Wait { s, v }` in every satisfying execution
//!    iff the other usable increments of `s` cannot reach `v` without it
//!    (an increment is *usable* when the wait does not itself precede it).
//!    Edges are added to a fixpoint — each edge shrinks downstream usable
//!    sets, which can make further increments necessary.
//! 2. **Liveness.** A wait whose usable increments (plus the initial
//!    value) cannot reach its target can never be passed; a cycle in the
//!    combined program-order/synchronization graph is a cross-worker
//!    deadlock. Both report exact worker/op indices.
//! 3. **Races.** Every pair of effect accesses (read / write / reduce,
//!    classified per [`Effect`] operand) on overlapping regions of the
//!    same buffer must be ordered by the happens-before relation — except
//!    two reads, and two reduces with the same (commuting) operator.
//!    Attention states are tracked as their own resources.
//! 4. **Lints.** Views outside their buffer's [`crate::mem::Shape4`]
//!    (release builds skip the executor's `debug_assert`s), shape-
//!    mismatched effects, scope downgrades (a wait satisfied only by
//!    signals whose [`SyncScope`] cannot reach the waiter), semaphores
//!    signalled but never waited on (warning), RDMA routes that stay
//!    inside a node or NVLink routes that cross one, and RDMA transfers
//!    whose claimed NIC bytes undercount their semantic payload.
//!
//! **Soundness caveats** (the analysis is conservative, not complete): it
//! assumes every reduce pair with *different* operators conflicts even
//! where the values happen to commute, it does not model value-dependent
//! waits (a `Wait` target is a constant in this IR, so none exist today),
//! and timed-only plans carry no effects, so only liveness/scope/route
//! rules apply to them. A clean report therefore certifies deadlock- and
//! race-freedom for functional plans under the executor semantics of
//! [`crate::exec::functional`]; it does not certify timing.

use std::collections::BTreeMap;
use std::fmt;

use crate::mem::pgl::ReduceOp;
use crate::mem::{MemPool, ELEM_BYTES};

use super::{Effect, MatView, Op, Plan, Route, SyncScope, TransferSpec};

/// How bad a finding is: errors gate CI and panic `run_functional`;
/// warnings are advisory (e.g. a broadcast arrival nobody waits on).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

/// Which rule produced a finding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// Unsatisfiable wait or cross-worker wait cycle.
    Deadlock,
    /// Unordered conflicting accesses to overlapping regions.
    Race,
    /// View or row index outside its buffer (or undeclared sem/buffer).
    Bounds,
    /// Effect operand shapes inconsistent with the executor's contract.
    Shape,
    /// Wait satisfied only by signals of insufficient scope.
    Scope,
    /// RDMA route inside a node / NVLink route across nodes / wrong src.
    RdmaRoute,
    /// RDMA transfer bytes undercount the semantic payload.
    RdmaBytes,
    /// Semaphore signalled but never waited on.
    DeadSem,
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Rule::Deadlock => "deadlock",
            Rule::Race => "race",
            Rule::Bounds => "bounds",
            Rule::Shape => "shape",
            Rule::Scope => "scope",
            Rule::RdmaRoute => "rdma-route",
            Rule::RdmaBytes => "rdma-bytes",
            Rule::DeadSem => "dead-sem",
        };
        f.write_str(s)
    }
}

/// One verifier finding, anchored at a specific worker/op.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    pub severity: Severity,
    pub worker: usize,
    /// The anchoring worker's label (for readable reports).
    pub label: String,
    pub op: usize,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(
            f,
            "{sev}[{}] worker {} '{}' op {}: {}",
            self.rule, self.worker, self.label, self.op, self.msg
        )
    }
}

/// What the verifier examined (reported by `pk lint`).
#[derive(Clone, Debug, Default)]
pub struct VerifyStats {
    pub workers: usize,
    pub ops: usize,
    pub sems: usize,
    /// Synchronization (necessity) edges in the happens-before graph.
    pub sync_edges: usize,
    /// Effect accesses extracted for the race detector.
    pub accesses: usize,
    /// Conflicting overlapping pairs whose ordering was checked.
    pub pairs_checked: usize,
    /// Total bytes routed over RDMA (NIC egress == ingress by construction
    /// once every transfer's bytes cover its payload — the conservation
    /// rule is enforced per transfer).
    pub rdma_bytes: f64,
}

/// The verifier's output: findings plus coverage stats.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub findings: Vec<Finding>,
    pub stats: VerifyStats,
}

impl VerifyReport {
    pub fn num_errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    pub fn num_warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// No error-severity findings (warnings are allowed).
    pub fn is_clean(&self) -> bool {
        self.num_errors() == 0
    }

    /// Render every finding, one per line (errors first).
    pub fn render(&self) -> String {
        let mut lines: Vec<String> = self
            .findings
            .iter()
            .filter(|f| f.severity == Severity::Error)
            .map(|f| f.to_string())
            .collect();
        lines.extend(
            self.findings.iter().filter(|f| f.severity == Severity::Warning).map(|f| f.to_string()),
        );
        lines.join("\n")
    }

    /// Panic with a readable report if any error-severity finding exists.
    pub fn assert_clean(&self, what: &str) {
        if !self.is_clean() {
            panic!("plan verification failed for {what}:\n{}", self.render());
        }
    }
}

/// Verification context: a [`MemPool`] enables bounds and multimem-
/// locality checks (functional plans), and `devices_per_node` enables the
/// topology-dependent rules (full scope ranking, RDMA routing).
#[derive(Default)]
pub struct VerifyCtx<'a> {
    pub pool: Option<&'a MemPool>,
    pub devices_per_node: Option<usize>,
}

impl<'a> VerifyCtx<'a> {
    /// The context `run_functional` uses: buffers known, topology not.
    pub fn functional(pool: &'a MemPool) -> Self {
        VerifyCtx { pool: Some(pool), devices_per_node: None }
    }

    /// Enable topology-dependent rules.
    pub fn with_nodes(mut self, devices_per_node: usize) -> Self {
        self.devices_per_node = Some(devices_per_node);
        self
    }
}

/// Verify `plan` under `ctx`, returning every finding plus coverage stats.
pub fn verify(plan: &Plan, ctx: &VerifyCtx) -> VerifyReport {
    let mut a = Analysis::new(plan, ctx);
    a.collect_sync();
    a.static_lints();
    if let Some(reach) = a.hb_fixpoint() {
        a.wait_accounting(&reach);
        a.races(&reach);
    }
    a.stats.sync_edges = a.sync.iter().map(|s| s.len()).sum();
    VerifyReport { findings: a.findings, stats: a.stats }
}

/// Don't flood the report when a single missing wait unorders many pairs.
const MAX_RACE_FINDINGS: usize = 100;

fn scope_rank(s: SyncScope) -> usize {
    match s {
        SyncScope::IntraSm => 0,
        SyncScope::InterSm => 1,
        SyncScope::InterDevice => 2,
        SyncScope::InterNode => 3,
    }
}

fn scope_name(rank: usize) -> &'static str {
    ["IntraSm", "InterSm", "InterDevice", "InterNode"][rank.min(3)]
}

fn op_label(op: &Op) -> &'static str {
    match op {
        Op::Compute { label, .. } | Op::Transfer { label, .. } | Op::Delay { label, .. } => *label,
        Op::Wait { .. } => "wait",
        Op::Signal { .. } => "signal",
    }
}

/// One semaphore increment (a `Signal` or a transfer's `done_sem` bump).
#[derive(Clone, Copy)]
struct Inc {
    node: usize,
    worker: usize,
    value: u64,
    scope: SyncScope,
}

#[derive(Clone, Copy)]
struct Wt {
    node: usize,
    worker: usize,
    sem: usize,
    value: u64,
}

/// Row coordinates of an access region (absolute buffer rows).
#[derive(Clone, Debug)]
enum RowSet {
    Range(usize, usize),
    List(Vec<usize>),
}

#[derive(Clone, Debug)]
struct Region {
    buf: usize,
    b: usize,
    d: usize,
    rows: RowSet,
    c0: usize,
    c1: usize,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum AccessKind {
    Read,
    Write,
    Reduce(ReduceOp),
}

impl fmt::Display for AccessKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AccessKind::Read => f.write_str("read"),
            AccessKind::Write => f.write_str("write"),
            AccessKind::Reduce(op) => write!(f, "reduce({op:?})"),
        }
    }
}

struct Access {
    node: usize,
    kind: AccessKind,
    region: Region,
}

struct StateAccess {
    node: usize,
    write: bool,
    state: usize,
}

fn region_of(v: &MatView) -> Region {
    Region {
        buf: v.buf.0,
        b: v.b,
        d: v.d,
        rows: RowSet::Range(v.row0, v.row0 + v.rows),
        c0: v.col0,
        c1: v.col0 + v.cols,
    }
}

fn rows_overlap(a: &RowSet, b: &RowSet) -> bool {
    match (a, b) {
        (RowSet::Range(a0, a1), RowSet::Range(b0, b1)) => a0.max(b0) < a1.min(b1),
        (RowSet::Range(a0, a1), RowSet::List(l)) | (RowSet::List(l), RowSet::Range(a0, a1)) => {
            l.iter().any(|r| a0 <= r && r < a1)
        }
        (RowSet::List(x), RowSet::List(y)) => x.iter().any(|r| y.contains(r)),
    }
}

fn regions_overlap(a: &Region, b: &Region) -> bool {
    a.buf == b.buf
        && a.b == b.b
        && a.d == b.d
        && a.c0.max(b.c0) < a.c1.min(b.c1)
        && rows_overlap(&a.rows, &b.rows)
}

fn kinds_conflict(a: AccessKind, b: AccessKind) -> bool {
    match (a, b) {
        (AccessKind::Read, AccessKind::Read) => false,
        (AccessKind::Reduce(x), AccessKind::Reduce(y)) => x != y,
        _ => true,
    }
}

/// The write-side element count an RDMA transfer's bytes must cover.
fn payload_elems(e: &Effect) -> Option<u128> {
    match e {
        Effect::CopyMat { dst, .. } => Some(dst.rows as u128 * dst.cols as u128),
        Effect::GatherRows { rows, dst, .. } => Some(rows.len() as u128 * dst.cols as u128),
        Effect::ScatterRows { rows, src, .. } => Some(rows.len() as u128 * src.cols as u128),
        _ => None,
    }
}

/// Every view an effect touches (for bounds and locality lints).
fn effect_views(e: &Effect) -> Vec<MatView> {
    match e {
        Effect::CopyMat { src, dst, .. } => vec![*src, *dst],
        Effect::MulticastMat { src, dsts, .. } => {
            let mut v = vec![*src];
            v.extend(dsts.iter().copied());
            v
        }
        Effect::LdReduceMat { srcs, dst, .. } => {
            let mut v: Vec<MatView> = srcs.to_vec();
            v.push(*dst);
            v
        }
        Effect::Gemm { a, b, c, .. } => vec![*a, *b, *c],
        Effect::Gelu { x } => vec![*x],
        Effect::AttnBlock { q, k, v, .. } => vec![*q, *k, *v],
        Effect::AttnFinalize { out, .. } => vec![*out],
        Effect::GatherRows { src, dst, .. } | Effect::ScatterRows { src, dst, .. } => {
            vec![*src, *dst]
        }
        Effect::RunArtifact { inputs, outputs, .. } => {
            let mut v: Vec<MatView> = inputs.to_vec();
            v.extend(outputs.iter().copied());
            v
        }
    }
}

/// Dense reachability over the happens-before graph, self-inclusive.
struct Reach {
    words: usize,
    bits: Vec<u64>,
}

impl Reach {
    fn reaches(&self, a: usize, b: usize) -> bool {
        (self.bits[a * self.words + b / 64] >> (b % 64)) & 1 != 0
    }
}

struct Analysis<'a> {
    plan: &'a Plan,
    ctx: &'a VerifyCtx<'a>,
    worker_of: Vec<usize>,
    op_of: Vec<usize>,
    n: usize,
    /// Program-order successor (next op of the same worker).
    prog_next: Vec<Option<usize>>,
    /// Necessity (synchronization) edges: `sync[from]` lists `to` nodes.
    sync: Vec<Vec<usize>>,
    /// Increments per semaphore, in worker-major program order.
    incs: Vec<Vec<Inc>>,
    waits: Vec<Wt>,
    findings: Vec<Finding>,
    stats: VerifyStats,
}

impl<'a> Analysis<'a> {
    fn new(plan: &'a Plan, ctx: &'a VerifyCtx<'a>) -> Self {
        let mut worker_of = Vec::new();
        let mut op_of = Vec::new();
        let mut n = 0;
        for (wi, w) in plan.workers.iter().enumerate() {
            for oi in 0..w.ops.len() {
                worker_of.push(wi);
                op_of.push(oi);
            }
            n += w.ops.len();
        }
        let prog_next = (0..n)
            .map(|i| if i + 1 < n && worker_of[i + 1] == worker_of[i] { Some(i + 1) } else { None })
            .collect();
        let stats = VerifyStats {
            workers: plan.workers.len(),
            ops: n,
            sems: plan.sems.len(),
            ..Default::default()
        };
        Analysis {
            plan,
            ctx,
            worker_of,
            op_of,
            n,
            prog_next,
            sync: vec![Vec::new(); n],
            incs: vec![Vec::new(); plan.sems.len()],
            waits: Vec::new(),
            findings: Vec::new(),
            stats,
        }
    }

    fn finding(&mut self, rule: Rule, severity: Severity, node: usize, msg: String) {
        let worker = self.worker_of[node];
        self.findings.push(Finding {
            rule,
            severity,
            worker,
            label: self.plan.workers[worker].label.clone(),
            op: self.op_of[node],
            msg,
        });
    }

    fn coord(&self, node: usize) -> String {
        let (w, o) = (self.worker_of[node], self.op_of[node]);
        let op = &self.plan.workers[w].ops[o];
        format!("worker {} '{}' op {} ({})", w, self.plan.workers[w].label, o, op_label(op))
    }

    /// Collect semaphore increments and waits; flag undeclared sems.
    fn collect_sync(&mut self) {
        enum Evt {
            Inc { kind: &'static str, sem: usize, value: u64, scope: SyncScope },
            Wait { sem: usize, value: u64 },
        }
        let n_sems = self.plan.sems.len();
        for node in 0..self.n {
            let (wi, oi) = (self.worker_of[node], self.op_of[node]);
            let evt = match &self.plan.workers[wi].ops[oi] {
                Op::Signal { sem, value, scope } => {
                    Some(Evt::Inc { kind: "signal", sem: sem.0, value: *value, scope: *scope })
                }
                Op::Transfer { done_sem: Some(s), done_scope, .. } => {
                    Some(Evt::Inc { kind: "done_sem", sem: s.0, value: 1, scope: *done_scope })
                }
                Op::Wait { sem, value } => Some(Evt::Wait { sem: sem.0, value: *value }),
                _ => None,
            };
            match evt {
                Some(Evt::Inc { kind, sem, value, scope }) => {
                    if sem >= n_sems {
                        let msg = format!("{kind} references undeclared sem {sem}");
                        self.finding(Rule::Bounds, Severity::Error, node, msg);
                    } else {
                        self.incs[sem].push(Inc { node, worker: wi, value, scope });
                    }
                }
                Some(Evt::Wait { sem, value }) => {
                    if sem >= n_sems {
                        let msg = format!("wait references undeclared sem {sem}");
                        self.finding(Rule::Bounds, Severity::Error, node, msg);
                    } else {
                        self.waits.push(Wt { node, worker: wi, sem, value });
                    }
                }
                None => {}
            }
        }
    }

    /// Kahn topo sort + reverse-order bitset union. `Err` carries a sample
    /// of the nodes stuck on a cycle.
    fn compute_reach(&self) -> Result<Reach, Vec<usize>> {
        let n = self.n;
        let words = n.div_ceil(64).max(1);
        let mut indeg = vec![0usize; n];
        for i in 0..n {
            if let Some(j) = self.prog_next[i] {
                indeg[j] += 1;
            }
            for &j in &self.sync[i] {
                indeg[j] += 1;
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut topo = Vec::with_capacity(n);
        while let Some(i) = queue.pop() {
            topo.push(i);
            if let Some(j) = self.prog_next[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    queue.push(j);
                }
            }
            for &j in &self.sync[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    queue.push(j);
                }
            }
        }
        if topo.len() < n {
            return Err((0..n).filter(|&i| indeg[i] > 0).take(6).collect());
        }
        let mut bits = vec![0u64; n * words];
        for &i in topo.iter().rev() {
            bits[i * words + i / 64] |= 1u64 << (i % 64);
            if let Some(j) = self.prog_next[i] {
                for k in 0..words {
                    let v = bits[j * words + k];
                    bits[i * words + k] |= v;
                }
            }
            for &j in &self.sync[i] {
                for k in 0..words {
                    let v = bits[j * words + k];
                    bits[i * words + k] |= v;
                }
            }
        }
        Ok(Reach { words, bits })
    }

    /// Increments of `w.sem` that can still fire before the wait passes
    /// (the wait does not happen-before them). Grouped per worker in
    /// program order by construction.
    fn usable_incs(&self, reach: &Reach, w: &Wt) -> Vec<usize> {
        (0..self.incs[w.sem].len())
            .filter(|&i| !reach.reaches(w.node, self.incs[w.sem][i].node))
            .collect()
    }

    /// Add necessity edges to a fixpoint. Returns the final reachability,
    /// or `None` after recording a wait-cycle deadlock finding.
    fn hb_fixpoint(&mut self) -> Option<Reach> {
        loop {
            let reach = match self.compute_reach() {
                Ok(r) => r,
                Err(cyc) => {
                    let desc: Vec<String> = cyc.iter().map(|&c| self.coord(c)).collect();
                    let anchor = cyc[0];
                    let msg = format!("cross-worker wait cycle among: {}", desc.join("; "));
                    self.finding(Rule::Deadlock, Severity::Error, anchor, msg);
                    return None;
                }
            };
            let mut added = false;
            for wi in 0..self.waits.len() {
                let w = self.waits[wi];
                let need = w.value.saturating_sub(self.plan.sems[w.sem]) as u128;
                if need == 0 {
                    continue;
                }
                let usable = self.usable_incs(&reach, &w);
                let total: u128 = usable.iter().map(|&i| self.incs[w.sem][i].value as u128).sum();
                if total < need {
                    continue; // unsatisfiable — reported by wait_accounting
                }
                // Per worker stream, the *latest* increment the wait cannot
                // do without (dropping it and its program-order successors
                // leaves < need) must precede the wait in every execution;
                // earlier stream elements are then ordered transitively.
                let mut i = 0;
                while i < usable.len() {
                    let wk = self.incs[w.sem][usable[i]].worker;
                    let mut j = i;
                    while j < usable.len() && self.incs[w.sem][usable[j]].worker == wk {
                        j += 1;
                    }
                    let mut suffix: u128 = 0;
                    for t in (i..j).rev() {
                        let inc = self.incs[w.sem][usable[t]];
                        suffix += inc.value as u128;
                        if total - suffix < need {
                            if !reach.reaches(inc.node, w.node) {
                                self.sync[inc.node].push(w.node);
                                added = true;
                            }
                            break;
                        }
                    }
                    i = j;
                }
            }
            if !added {
                return Some(reach);
            }
        }
    }

    /// The minimum signal scope for an increment to reach a waiter.
    fn required_rank(&self, inc_worker: usize, wait_worker: usize) -> usize {
        if inc_worker == wait_worker {
            return 0;
        }
        let a = self.plan.workers[inc_worker].device.0;
        let b = self.plan.workers[wait_worker].device.0;
        if a == b {
            return 1;
        }
        match self.ctx.devices_per_node {
            Some(p) if p > 0 && a / p != b / p => 3,
            _ => 2,
        }
    }

    /// Liveness (unsatisfiable waits) + scope-downgrade lint.
    fn wait_accounting(&mut self, reach: &Reach) {
        for wi in 0..self.waits.len() {
            let w = self.waits[wi];
            let init = self.plan.sems[w.sem];
            let need = w.value.saturating_sub(init) as u128;
            if need == 0 {
                continue;
            }
            let usable = self.usable_incs(reach, &w);
            let total: u128 = usable.iter().map(|&i| self.incs[w.sem][i].value as u128).sum();
            if total < need {
                let msg = format!(
                    "wait(sem {}, >= {}) can never pass: initial {} plus at most {} \
                     from increments not ordered after it",
                    w.sem, w.value, init, total
                );
                self.finding(Rule::Deadlock, Severity::Error, w.node, msg);
                continue;
            }
            let mut scoped: u128 = 0;
            let mut example: Option<Inc> = None;
            for &ii in &usable {
                let inc = self.incs[w.sem][ii];
                let req = self.required_rank(inc.worker, w.worker);
                if scope_rank(inc.scope) >= req {
                    scoped += inc.value as u128;
                } else if example.is_none() {
                    example = Some(inc);
                }
            }
            if scoped < need {
                let inc = example.expect("insufficient scope implies an offending increment");
                let req = self.required_rank(inc.worker, w.worker);
                let msg = format!(
                    "wait(sem {}, >= {}) is only satisfied by downgraded signals: {} \
                     signals {:?} but {} is required to reach this waiter",
                    w.sem,
                    w.value,
                    self.coord(inc.node),
                    inc.scope,
                    scope_name(req)
                );
                self.finding(Rule::Scope, Severity::Error, w.node, msg);
            }
        }
    }

    /// Pairwise race check over effect accesses, bucketed per resource.
    fn races(&mut self, reach: &Reach) {
        let mut mem: Vec<Access> = Vec::new();
        let mut states: Vec<StateAccess> = Vec::new();
        for node in 0..self.n {
            let (wi, oi) = (self.worker_of[node], self.op_of[node]);
            let effect = match &self.plan.workers[wi].ops[oi] {
                Op::Compute { effect, .. } | Op::Transfer { effect, .. } => effect.as_ref(),
                _ => None,
            };
            if let Some(e) = effect {
                collect_accesses(e, node, &mut mem, &mut states);
            }
        }
        self.stats.accesses = mem.len() + states.len();
        let mut by_buf: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, a) in mem.iter().enumerate() {
            by_buf.entry(a.region.buf).or_default().push(i);
        }
        let mut races = 0usize;
        for idxs in by_buf.values() {
            for (pos, &i) in idxs.iter().enumerate() {
                for &j in &idxs[pos + 1..] {
                    let (a, b) = (&mem[i], &mem[j]);
                    if !kinds_conflict(a.kind, b.kind) || !regions_overlap(&a.region, &b.region) {
                        continue;
                    }
                    self.stats.pairs_checked += 1;
                    if reach.reaches(a.node, b.node) || reach.reaches(b.node, a.node) {
                        continue;
                    }
                    if races < MAX_RACE_FINDINGS {
                        let msg = format!(
                            "unordered conflicting accesses to buf {} (b={}, d={}): \
                             {} here vs {} at {}",
                            a.region.buf,
                            a.region.b,
                            a.region.d,
                            a.kind,
                            b.kind,
                            self.coord(b.node)
                        );
                        self.finding(Rule::Race, Severity::Error, a.node, msg);
                    }
                    races += 1;
                }
            }
        }
        let mut by_state: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, a) in states.iter().enumerate() {
            by_state.entry(a.state).or_default().push(i);
        }
        for idxs in by_state.values() {
            for (pos, &i) in idxs.iter().enumerate() {
                for &j in &idxs[pos + 1..] {
                    let (a, b) = (&states[i], &states[j]);
                    if !a.write && !b.write {
                        continue;
                    }
                    self.stats.pairs_checked += 1;
                    if reach.reaches(a.node, b.node) || reach.reaches(b.node, a.node) {
                        continue;
                    }
                    if races < MAX_RACE_FINDINGS {
                        let msg = format!(
                            "unordered conflicting accesses to attention state {}: here vs {}",
                            a.state,
                            self.coord(b.node)
                        );
                        self.finding(Rule::Race, Severity::Error, a.node, msg);
                    }
                    races += 1;
                }
            }
        }
    }

    /// Per-op rules that need no happens-before: bounds, shapes, routes,
    /// RDMA byte conservation, dead semaphores.
    fn static_lints(&mut self) {
        for node in 0..self.n {
            let (wi, oi) = (self.worker_of[node], self.op_of[node]);
            enum Kind {
                Effect(Effect),
                Xfer(TransferSpec, Option<Effect>),
            }
            let kind = match &self.plan.workers[wi].ops[oi] {
                Op::Compute { effect: Some(e), .. } => Some(Kind::Effect(e.clone())),
                Op::Transfer { spec, effect, .. } => Some(Kind::Xfer(spec.clone(), effect.clone())),
                _ => None,
            };
            match kind {
                Some(Kind::Effect(e)) => self.effect_lints(node, &e),
                Some(Kind::Xfer(spec, effect)) => {
                    if let Some(e) = &effect {
                        self.effect_lints(node, e);
                    }
                    self.route_lints(node, wi, &spec, effect.as_ref());
                }
                None => {}
            }
        }
        self.dead_sems();
    }

    fn view_lints(&mut self, node: usize, v: &MatView) {
        let Some(pool) = self.ctx.pool else { return };
        if v.buf.0 >= pool.len() {
            let msg =
                format!("view references buffer {} but the pool holds {}", v.buf.0, pool.len());
            self.finding(Rule::Bounds, Severity::Error, node, msg);
            return;
        }
        let shape = pool.get(v.buf).shape;
        if v.b >= shape.b || v.d >= shape.d {
            let msg = format!(
                "view plane (b={}, d={}) outside buffer {} shape ({}, {}, {}, {})",
                v.b, v.d, v.buf.0, shape.b, shape.d, shape.r, shape.c
            );
            self.finding(Rule::Bounds, Severity::Error, node, msg);
        }
        let parent =
            MatView { buf: v.buf, b: v.b, d: v.d, row0: 0, col0: 0, rows: shape.r, cols: shape.c };
        if parent.try_sub(v.row0, v.col0, v.rows, v.cols).is_none() {
            let msg = format!(
                "view rows {}..{} cols {}..{} outside buffer {} plane {}x{}",
                v.row0,
                v.row0 + v.rows,
                v.col0,
                v.col0 + v.cols,
                v.buf.0,
                shape.r,
                shape.c
            );
            self.finding(Rule::Bounds, Severity::Error, node, msg);
        }
    }

    fn shape_finding(&mut self, node: usize, msg: String) {
        self.finding(Rule::Shape, Severity::Error, node, msg);
    }

    fn effect_lints(&mut self, node: usize, e: &Effect) {
        for v in effect_views(e) {
            self.view_lints(node, &v);
        }
        match e {
            Effect::CopyMat { src, dst, .. } => {
                if src.rows != dst.rows || src.cols != dst.cols {
                    self.shape_finding(
                        node,
                        format!(
                            "CopyMat shape mismatch: src {}x{} vs dst {}x{}",
                            src.rows, src.cols, dst.rows, dst.cols
                        ),
                    );
                }
            }
            Effect::MulticastMat { src, dsts, .. } => {
                for dv in dsts {
                    if src.rows != dv.rows || src.cols != dv.cols {
                        self.shape_finding(
                            node,
                            format!(
                                "MulticastMat shape mismatch: src {}x{} vs dst {}x{}",
                                src.rows, src.cols, dv.rows, dv.cols
                            ),
                        );
                        break;
                    }
                }
            }
            Effect::LdReduceMat { srcs, dst, .. } => {
                for sv in srcs {
                    if sv.rows != dst.rows || sv.cols != dst.cols {
                        self.shape_finding(
                            node,
                            format!(
                                "LdReduceMat shape mismatch: src {}x{} vs dst {}x{}",
                                sv.rows, sv.cols, dst.rows, dst.cols
                            ),
                        );
                        break;
                    }
                }
            }
            Effect::Gemm { a, b, c, .. } => {
                if a.cols != b.rows || c.rows != a.rows || c.cols != b.cols {
                    self.shape_finding(
                        node,
                        format!(
                            "Gemm shape mismatch: a {}x{}, b {}x{}, c {}x{}",
                            a.rows, a.cols, b.rows, b.cols, c.rows, c.cols
                        ),
                    );
                }
            }
            Effect::AttnBlock { q, k, v, .. } => {
                if q.cols != k.cols || k.rows != v.rows {
                    self.shape_finding(
                        node,
                        format!(
                            "AttnBlock shape mismatch: q {}x{}, k {}x{}, v {}x{}",
                            q.rows, q.cols, k.rows, k.cols, v.rows, v.cols
                        ),
                    );
                }
            }
            Effect::GatherRows { src, rows, dst } => {
                if rows.len() != dst.rows || src.cols != dst.cols {
                    self.shape_finding(
                        node,
                        format!(
                            "GatherRows shape mismatch: {} rows into dst {}x{} (src cols {})",
                            rows.len(),
                            dst.rows,
                            dst.cols,
                            src.cols
                        ),
                    );
                }
                if let Some(r) = rows.iter().find(|&&r| r >= src.rows) {
                    let msg =
                        format!("GatherRows index {} outside src view of {} rows", r, src.rows);
                    self.finding(Rule::Bounds, Severity::Error, node, msg);
                }
            }
            Effect::ScatterRows { src, dst, rows, .. } => {
                if rows.len() != src.rows || src.cols != dst.cols {
                    self.shape_finding(
                        node,
                        format!(
                            "ScatterRows shape mismatch: {} rows from src {}x{} (dst cols {})",
                            rows.len(),
                            src.rows,
                            src.cols,
                            dst.cols
                        ),
                    );
                }
                if let Some(r) = rows.iter().find(|&&r| r >= dst.rows) {
                    let msg =
                        format!("ScatterRows index {} outside dst view of {} rows", r, dst.rows);
                    self.finding(Rule::Bounds, Severity::Error, node, msg);
                }
            }
            Effect::Gelu { .. } | Effect::AttnFinalize { .. } | Effect::RunArtifact { .. } => {}
        }
    }

    fn route_lints(
        &mut self,
        node: usize,
        wi: usize,
        spec: &TransferSpec,
        effect: Option<&Effect>,
    ) {
        if let Route::Rdma { .. } = spec.route {
            self.stats.rdma_bytes += spec.bytes;
        }
        let Some(p) = self.ctx.devices_per_node else { return };
        if p == 0 {
            return;
        }
        match spec.route {
            Route::Rdma { src, dst } => {
                if src.0 / p == dst.0 / p {
                    let msg = format!(
                        "RDMA route d{}->d{} stays inside node {} (should be NVLink)",
                        src.0,
                        dst.0,
                        src.0 / p
                    );
                    self.finding(Rule::RdmaRoute, Severity::Error, node, msg);
                }
                let wd = self.plan.workers[wi].device;
                if wd != src {
                    let msg = format!(
                        "RDMA issued from worker on d{} but the route src is d{}",
                        wd.0, src.0
                    );
                    self.finding(Rule::RdmaRoute, Severity::Error, node, msg);
                }
                if let Some(elems) = effect.and_then(payload_elems) {
                    let payload = elems as f64 * ELEM_BYTES as f64;
                    if spec.bytes + 0.5 < payload {
                        let msg = format!(
                            "RDMA transfer claims {:.0} bytes but its payload is {:.0} \
                             (NIC accounting would undercount)",
                            spec.bytes, payload
                        );
                        self.finding(Rule::RdmaBytes, Severity::Error, node, msg);
                    }
                }
            }
            Route::P2p { src, dst } | Route::CopyEngineP2p { src, dst } => {
                if src.0 / p != dst.0 / p {
                    let msg = format!(
                        "NVLink route d{}->d{} crosses nodes {}->{} (should be RDMA)",
                        src.0,
                        dst.0,
                        src.0 / p,
                        dst.0 / p
                    );
                    self.finding(Rule::RdmaRoute, Severity::Error, node, msg);
                }
            }
            Route::Multicast { src } | Route::LdReduce { reader: src } => {
                if let (Some(pool), Some(e)) = (self.ctx.pool, effect) {
                    let home = src.0 / p;
                    for v in effect_views(e) {
                        if v.buf.0 < pool.len() && pool.get(v.buf).dev.0 / p != home {
                            let msg = format!(
                                "multimem effect touches buffer {} on d{} outside node {}",
                                v.buf.0,
                                pool.get(v.buf).dev.0,
                                home
                            );
                            self.finding(Rule::RdmaRoute, Severity::Error, node, msg);
                            break;
                        }
                    }
                }
            }
            Route::LocalHbm { .. } => {}
        }
    }

    fn dead_sems(&mut self) {
        let mut waited = vec![false; self.plan.sems.len()];
        for w in &self.waits {
            waited[w.sem] = true;
        }
        for s in 0..self.plan.sems.len() {
            if !waited[s] && !self.incs[s].is_empty() {
                let anchor = self.incs[s][0].node;
                let msg = format!("sem {s} is signalled but never waited on");
                self.finding(Rule::DeadSem, Severity::Warning, anchor, msg);
            }
        }
    }
}

fn collect_accesses(e: &Effect, node: usize, mem: &mut Vec<Access>, states: &mut Vec<StateAccess>) {
    let push = |mem: &mut Vec<Access>, kind: AccessKind, region: Region| {
        mem.push(Access { node, kind, region });
    };
    let wr_kind = |reduce: &Option<ReduceOp>| match reduce {
        Some(op) => AccessKind::Reduce(*op),
        None => AccessKind::Write,
    };
    match e {
        Effect::CopyMat { src, dst, reduce } => {
            push(mem, AccessKind::Read, region_of(src));
            push(mem, wr_kind(reduce), region_of(dst));
        }
        Effect::MulticastMat { src, dsts, reduce } => {
            push(mem, AccessKind::Read, region_of(src));
            for dv in dsts {
                push(mem, wr_kind(reduce), region_of(dv));
            }
        }
        Effect::LdReduceMat { srcs, dst, .. } => {
            for sv in srcs {
                push(mem, AccessKind::Read, region_of(sv));
            }
            push(mem, AccessKind::Write, region_of(dst));
        }
        Effect::Gemm { a, b, c, accumulate } => {
            push(mem, AccessKind::Read, region_of(a));
            push(mem, AccessKind::Read, region_of(b));
            let kind =
                if *accumulate { AccessKind::Reduce(ReduceOp::Add) } else { AccessKind::Write };
            push(mem, kind, region_of(c));
        }
        Effect::Gelu { x } => push(mem, AccessKind::Write, region_of(x)),
        Effect::AttnBlock { q, k, v, state } => {
            push(mem, AccessKind::Read, region_of(q));
            push(mem, AccessKind::Read, region_of(k));
            push(mem, AccessKind::Read, region_of(v));
            states.push(StateAccess { node, write: true, state: state.0 });
        }
        Effect::AttnFinalize { state, out } => {
            states.push(StateAccess { node, write: false, state: state.0 });
            push(mem, AccessKind::Write, region_of(out));
        }
        Effect::GatherRows { src, rows, dst } => {
            let read = Region {
                buf: src.buf.0,
                b: src.b,
                d: src.d,
                rows: RowSet::List(rows.iter().map(|r| src.row0 + r).collect()),
                c0: src.col0,
                c1: src.col0 + src.cols,
            };
            push(mem, AccessKind::Read, read);
            push(mem, AccessKind::Write, region_of(dst));
        }
        Effect::ScatterRows { src, dst, rows, reduce } => {
            push(mem, AccessKind::Read, region_of(src));
            let write = Region {
                buf: dst.buf.0,
                b: dst.b,
                d: dst.d,
                rows: RowSet::List(rows.iter().map(|r| dst.row0 + r).collect()),
                c0: dst.col0,
                c1: dst.col0 + dst.cols,
            };
            push(mem, wr_kind(reduce), write);
        }
        Effect::RunArtifact { inputs, outputs, .. } => {
            for v in inputs {
                push(mem, AccessKind::Read, region_of(v));
            }
            for v in outputs {
                push(mem, AccessKind::Write, region_of(v));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DeviceId;
    use crate::mem::buffer::BufId;
    use crate::mem::tile::Shape4;
    use crate::plan::{Role, SemId};
    use crate::xfer::Mechanism;

    fn compute_copy(src: MatView, dst: MatView, reduce: Option<ReduceOp>) -> Op {
        Op::Compute { dur: 0.0, label: "copy", effect: Some(Effect::CopyMat { src, dst, reduce }) }
    }

    fn rdma_transfer(src: usize, dst: usize, bytes: f64, effect: Option<Effect>) -> Op {
        Op::Transfer {
            spec: TransferSpec {
                mech: Mechanism::Tma,
                route: Route::Rdma { src: DeviceId(src), dst: DeviceId(dst) },
                bytes,
                msg_bytes: bytes,
                n_sms: 1.0,
            },
            blocking: false,
            done_sem: None,
            done_scope: SyncScope::InterNode,
            label: "rdma",
            effect,
        }
    }

    fn rules(r: &VerifyReport) -> Vec<Rule> {
        r.findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn clean_handshake_gets_a_sync_edge() {
        let mut p = Plan::new();
        let s = p.add_sem(0);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "sig");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "wait");
        p.push(w0, Op::Signal { sem: s, value: 1, scope: SyncScope::InterDevice });
        p.push(w1, Op::Wait { sem: s, value: 1 });
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.stats.sync_edges, 1);
        assert_eq!(r.num_warnings(), 0);
    }

    #[test]
    fn unsatisfiable_wait_is_flagged() {
        let mut p = Plan::new();
        let s = p.add_sem(0);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "sig");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "wait");
        p.push(w0, Op::Signal { sem: s, value: 1, scope: SyncScope::InterDevice });
        p.push(w1, Op::Wait { sem: s, value: 2 });
        let r = verify(&p, &VerifyCtx::default());
        assert_eq!(r.num_errors(), 1);
        assert_eq!(rules(&r), vec![Rule::Deadlock]);
        assert!(r.findings[0].msg.contains("never pass"), "{}", r.findings[0]);
        assert_eq!((r.findings[0].worker, r.findings[0].op), (1, 0));
    }

    #[test]
    fn cross_worker_wait_cycle_is_flagged() {
        let mut p = Plan::new();
        let s0 = p.add_sem(0);
        let s1 = p.add_sem(0);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "b");
        p.push(w0, Op::Wait { sem: s1, value: 1 });
        p.push(w0, Op::Signal { sem: s0, value: 1, scope: SyncScope::InterDevice });
        p.push(w1, Op::Wait { sem: s0, value: 1 });
        p.push(w1, Op::Signal { sem: s1, value: 1, scope: SyncScope::InterDevice });
        let r = verify(&p, &VerifyCtx::default());
        assert!(rules(&r).contains(&Rule::Deadlock), "{}", r.render());
        assert!(r.findings.iter().any(|f| f.msg.contains("cycle")), "{}", r.render());
    }

    #[test]
    fn value_zero_wait_is_trivially_satisfied() {
        // The MoE Sequential schedule waits `>= 0` on experts with no
        // routed tokens; that must neither deadlock nor warn.
        let mut p = Plan::new();
        let s = p.add_sem(0);
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "gemm");
        p.push(w, Op::Wait { sem: s, value: 0 });
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean() && r.num_warnings() == 0, "{}", r.render());
    }

    #[test]
    fn initial_value_counts_toward_waits() {
        let mut p = Plan::new();
        let s = p.add_sem(2);
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "pipe");
        p.push(w, Op::Wait { sem: s, value: 2 });
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn unordered_conflicting_writes_race() {
        let mut p = Plan::new();
        let src = MatView::full2d(BufId(0), 16, 16);
        let dst = MatView::full2d(BufId(1), 16, 16);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "b");
        p.push(w0, compute_copy(src, dst, None));
        p.push(w1, compute_copy(src, dst, None));
        let r = verify(&p, &VerifyCtx::default());
        assert_eq!(rules(&r), vec![Rule::Race], "{}", r.render());
        assert_eq!(r.stats.pairs_checked, 1);
    }

    #[test]
    fn sync_orders_the_same_writes_clean() {
        let mut p = Plan::new();
        let s = p.add_sem(0);
        let src = MatView::full2d(BufId(0), 16, 16);
        let dst = MatView::full2d(BufId(1), 16, 16);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "b");
        p.push(w0, compute_copy(src, dst, None));
        p.push(w0, Op::Signal { sem: s, value: 1, scope: SyncScope::InterDevice });
        p.push(w1, Op::Wait { sem: s, value: 1 });
        p.push(w1, compute_copy(src, dst, None));
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn hb_is_transitive_through_chains() {
        // w0 writes X, signals s0; w1 waits s0, signals s1 (never touching
        // X); w2 waits s1, writes X. Ordering is only transitive.
        let mut p = Plan::new();
        let s0 = p.add_sem(0);
        let s1 = p.add_sem(0);
        let src = MatView::full2d(BufId(0), 8, 8);
        let x = MatView::full2d(BufId(1), 8, 8);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "w0");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "w1");
        let w2 = p.add_worker(DeviceId(2), Role::ComputeSm, "w2");
        p.push(w0, compute_copy(src, x, None));
        p.push(w0, Op::Signal { sem: s0, value: 1, scope: SyncScope::InterDevice });
        p.push(w1, Op::Wait { sem: s0, value: 1 });
        p.push(w1, Op::Signal { sem: s1, value: 1, scope: SyncScope::InterDevice });
        p.push(w2, Op::Wait { sem: s1, value: 1 });
        p.push(w2, compute_copy(src, x, None));
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn disjoint_regions_do_not_race() {
        let mut p = Plan::new();
        let src = MatView::full2d(BufId(0), 16, 16);
        let dst = MatView::full2d(BufId(1), 16, 16);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "b");
        p.push(w0, compute_copy(src.sub(0, 0, 8, 16), dst.sub(0, 0, 8, 16), None));
        p.push(w1, compute_copy(src.sub(8, 0, 8, 16), dst.sub(8, 0, 8, 16), None));
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn commuting_reduces_are_clean_mixed_ops_race() {
        for (op1, op2, clean) in [
            (ReduceOp::Add, ReduceOp::Add, true),
            (ReduceOp::Add, ReduceOp::Max, false),
        ] {
            let mut p = Plan::new();
            let src = MatView::full2d(BufId(0), 16, 16);
            let dst = MatView::full2d(BufId(1), 16, 16);
            let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
            let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "b");
            p.push(w0, compute_copy(src, dst, Some(op1)));
            p.push(w1, compute_copy(src, dst, Some(op2)));
            let r = verify(&p, &VerifyCtx::default());
            assert_eq!(r.is_clean(), clean, "{op1:?}/{op2:?}: {}", r.render());
        }
    }

    #[test]
    fn blocking_transfer_done_sem_counts_as_increment() {
        // The functional executor bumps done_sem for blocking transfers
        // too; liveness must credit them.
        let mut p = Plan::new();
        let s = p.add_sem(0);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "xfer");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "wait");
        p.push(
            w0,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::Tma,
                    route: Route::P2p { src: DeviceId(0), dst: DeviceId(1) },
                    bytes: 64.0,
                    msg_bytes: 64.0,
                    n_sms: 1.0,
                },
                blocking: true,
                done_sem: Some(s),
                done_scope: SyncScope::InterDevice,
                label: "x",
                effect: None,
            },
        );
        p.push(w1, Op::Wait { sem: s, value: 1 });
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn attention_state_accesses_need_ordering() {
        let mut p = Plan::new();
        let st = p.add_state();
        let q = MatView::full2d(BufId(0), 8, 4);
        let k = MatView::full2d(BufId(1), 8, 4);
        let v = MatView::full2d(BufId(2), 8, 4);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "b");
        for w in [w0, w1] {
            p.push(
                w,
                Op::Compute {
                    dur: 0.0,
                    label: "attn",
                    effect: Some(Effect::AttnBlock { q, k, v, state: st }),
                },
            );
        }
        let r = verify(&p, &VerifyCtx::default());
        assert_eq!(rules(&r), vec![Rule::Race], "{}", r.render());
    }

    #[test]
    fn out_of_bounds_view_is_flagged_via_pool() {
        let mut pool = MemPool::new();
        let b = pool.alloc(DeviceId(0), Shape4::mat(16, 16));
        let mut p = Plan::new();
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        p.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "gelu",
                effect: Some(Effect::Gelu { x: MatView::full2d(b, 16, 32) }),
            },
        );
        let r = verify(&p, &VerifyCtx::functional(&pool));
        assert_eq!(rules(&r), vec![Rule::Bounds], "{}", r.render());
    }

    #[test]
    fn bad_plane_index_is_flagged() {
        let mut pool = MemPool::new();
        let b = pool.alloc(DeviceId(0), Shape4 { b: 2, d: 1, r: 8, c: 8 });
        let mut p = Plan::new();
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        let bad = MatView { buf: b, b: 2, d: 0, row0: 0, col0: 0, rows: 8, cols: 8 };
        p.push(w, Op::Compute { dur: 0.0, label: "gelu", effect: Some(Effect::Gelu { x: bad }) });
        let r = verify(&p, &VerifyCtx::functional(&pool));
        assert_eq!(rules(&r), vec![Rule::Bounds], "{}", r.render());
    }

    #[test]
    fn gemm_shape_mismatch_is_flagged() {
        let mut p = Plan::new();
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        p.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "gemm",
                effect: Some(Effect::Gemm {
                    a: MatView::full2d(BufId(0), 16, 8),
                    b: MatView::full2d(BufId(1), 16, 16), // a.cols != b.rows
                    c: MatView::full2d(BufId(2), 16, 16),
                    accumulate: false,
                }),
            },
        );
        let r = verify(&p, &VerifyCtx::default());
        assert_eq!(rules(&r), vec![Rule::Shape], "{}", r.render());
    }

    #[test]
    fn gather_row_index_out_of_view_is_flagged() {
        let mut p = Plan::new();
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        p.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "gather",
                effect: Some(Effect::GatherRows {
                    src: MatView::full2d(BufId(0), 16, 4),
                    rows: vec![3, 20],
                    dst: MatView::full2d(BufId(1), 2, 4),
                }),
            },
        );
        let r = verify(&p, &VerifyCtx::default());
        assert_eq!(rules(&r), vec![Rule::Bounds], "{}", r.render());
    }

    #[test]
    fn scope_downgrade_is_flagged() {
        let mut p = Plan::new();
        let s = p.add_sem(0);
        let w0 = p.add_worker(DeviceId(0), Role::ComputeSm, "sig");
        let w1 = p.add_worker(DeviceId(1), Role::ComputeSm, "wait");
        p.push(w0, Op::Signal { sem: s, value: 1, scope: SyncScope::IntraSm });
        p.push(w1, Op::Wait { sem: s, value: 1 });
        let r = verify(&p, &VerifyCtx::default());
        assert_eq!(rules(&r), vec![Rule::Scope], "{}", r.render());
        // cross-node with topology known: InterDevice is still too weak
        let mut p2 = Plan::new();
        let s2 = p2.add_sem(0);
        let a = p2.add_worker(DeviceId(0), Role::ComputeSm, "sig");
        let b = p2.add_worker(DeviceId(1), Role::ComputeSm, "wait");
        p2.push(a, Op::Signal { sem: s2, value: 1, scope: SyncScope::InterDevice });
        p2.push(b, Op::Wait { sem: s2, value: 1 });
        let r2 = verify(&p2, &VerifyCtx::default().with_nodes(1));
        assert_eq!(rules(&r2), vec![Rule::Scope], "{}", r2.render());
        assert!(r2.findings[0].msg.contains("InterNode"), "{}", r2.findings[0]);
    }

    #[test]
    fn same_worker_intrasm_signal_is_fine() {
        let mut p = Plan::new();
        let s = p.add_sem(0);
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "pipe");
        p.push(w, Op::Signal { sem: s, value: 1, scope: SyncScope::IntraSm });
        p.push(w, Op::Wait { sem: s, value: 1 });
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn dead_sem_is_a_warning_not_an_error() {
        let mut p = Plan::new();
        let s = p.add_sem(0);
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "sig");
        p.push(w, Op::Signal { sem: s, value: 1, scope: SyncScope::InterDevice });
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean());
        assert_eq!(r.num_warnings(), 1);
        assert_eq!(rules(&r), vec![Rule::DeadSem]);
    }

    #[test]
    fn rdma_routing_rules_fire() {
        // p = 2: d0/d1 share node 0, d2/d3 are node 1.
        let ctx = VerifyCtx::default().with_nodes(2);
        let mut p = Plan::new();
        let w = p.add_worker(DeviceId(0), Role::CommSm, "send");
        p.push(w, rdma_transfer(0, 1, 64.0, None)); // same node
        let r = verify(&p, &ctx);
        assert_eq!(rules(&r), vec![Rule::RdmaRoute], "{}", r.render());

        let mut p2 = Plan::new();
        let w2 = p2.add_worker(DeviceId(0), Role::CommSm, "send");
        p2.push(
            w2,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::Tma,
                    route: Route::P2p { src: DeviceId(0), dst: DeviceId(2) },
                    bytes: 64.0,
                    msg_bytes: 64.0,
                    n_sms: 1.0,
                },
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::IntraSm,
                label: "p2p",
                effect: None,
            },
        );
        let r2 = verify(&p2, &ctx);
        assert_eq!(rules(&r2), vec![Rule::RdmaRoute], "{}", r2.render());

        // issued from the wrong device
        let mut p3 = Plan::new();
        let w3 = p3.add_worker(DeviceId(1), Role::CommSm, "send");
        p3.push(w3, rdma_transfer(0, 2, 64.0, None));
        let r3 = verify(&p3, &ctx);
        assert_eq!(rules(&r3), vec![Rule::RdmaRoute], "{}", r3.render());
    }

    #[test]
    fn rdma_byte_undercount_is_flagged() {
        let ctx = VerifyCtx::default().with_nodes(1);
        let mut p = Plan::new();
        let w = p.add_worker(DeviceId(0), Role::CommSm, "send");
        let eff = Effect::CopyMat {
            src: MatView::full2d(BufId(0), 16, 16),
            dst: MatView::full2d(BufId(1), 16, 16),
            reduce: None,
        };
        // payload is 16*16*ELEM_BYTES = 512 bytes; claim only 10
        p.push(w, rdma_transfer(0, 1, 10.0, Some(eff)));
        let r = verify(&p, &ctx);
        assert_eq!(rules(&r), vec![Rule::RdmaBytes], "{}", r.render());
        assert!(r.stats.rdma_bytes > 0.0);
    }

    #[test]
    fn undeclared_sem_is_flagged_not_panicking() {
        let mut p = Plan::new();
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "a");
        p.push(w, Op::Wait { sem: SemId(7), value: 1 });
        let r = verify(&p, &VerifyCtx::default());
        assert_eq!(rules(&r), vec![Rule::Bounds], "{}", r.render());
    }

    #[test]
    fn barrier_generations_stay_clean() {
        // Reused barrier: every worker signals everyone twice, waiting at
        // n then 2n — the generation pattern of pk::sync::barrier.
        let n = 3;
        let mut p = Plan::new();
        let sems: Vec<_> = (0..n).map(|_| p.add_sem(0)).collect();
        for d in 0..n {
            let w = p.add_worker(DeviceId(d), Role::ComputeSm, format!("w{d}"));
            for generation in 1..=2u64 {
                for s in &sems {
                    p.push(w, Op::Signal { sem: *s, value: 1, scope: SyncScope::InterDevice });
                }
                p.push(w, Op::Wait { sem: sems[d], value: generation * n as u64 });
            }
        }
        let r = verify(&p, &VerifyCtx::default());
        assert!(r.is_clean(), "{}", r.render());
    }
}
