//! The Plan IR: a tile-granularity description of a multi-GPU kernel.
//!
//! A [`Plan`] is a set of *workers* (SMs or SM-groups, plus host threads
//! and copy engines), each executing a straight-line list of [`Op`]s that
//! synchronize through monotonically increasing *semaphores* — exactly the
//! signal/wait/barrier model of the paper's primitives (§3.2.2) and its
//! LCSC template (§3.2.3, Appendix D).
//!
//! The same plan is consumed by two executors:
//! * [`crate::exec::functional`] applies each op's [`Effect`] to real
//!   buffers in a [`crate::mem::MemPool`] — numerics are verified against
//!   references;
//! * [`crate::exec::timed`] runs the discrete-event timing model — compute
//!   durations, flow bandwidth sharing, and synchronization latencies.
//!
//! Builders may *coarsen* timed-only plans (group `G` tiles into one op,
//! keeping per-message granularity for the bandwidth curves) to keep event
//! counts tractable at paper-scale problem sizes; functional plans are
//! always tile-exact.
//!
//! A third consumer is the static analyzer in [`verify`]: it constructs
//! the happens-before graph of a plan (program order + synchronization
//! edges from semaphore accounting) and certifies deadlock-freedom,
//! race-freedom over effect regions, and a battery of lints (view bounds,
//! effect shapes, signal scopes, RDMA routing/byte conservation). Every
//! functional test verifies its plan via
//! [`crate::util::prop::run_functional`] before executing it, and the
//! `pk lint` subcommand sweeps the whole kernel zoo. The analysis is
//! *conservative*: it treats mixed-operator reduces as conflicting even
//! where values happen to commute, and it cannot model value-dependent
//! waits — a clean report is a proof under those approximations, a
//! finding is always worth reading but warnings may be intentional.

pub mod verify;

use crate::hw::DeviceId;
use crate::mem::buffer::BufId;
use crate::mem::pgl::ReduceOp;
use crate::xfer::Mechanism;

/// Semaphore handle within a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SemId(pub usize);

/// Online-softmax (attention) state handle within a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StateId(pub usize);

/// Which latency a signal pays before becoming visible (§3.1.3: 64 ns for
/// an intra-SM mbarrier, 832 ns through HBM, ~µs over NVLink, a few µs
/// across the inter-node RDMA fabric).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncScope {
    IntraSm,
    InterSm,
    InterDevice,
    /// Cross-node flag write over the NIC (GPUDirect RDMA one-way).
    InterNode,
}

/// The route a transfer takes, determining which ports it occupies.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Route {
    /// Point-to-point over NVLink (or within a device if src == dst).
    P2p { src: DeviceId, dst: DeviceId },
    /// In-fabric broadcast from `src` to every device.
    Multicast { src: DeviceId },
    /// In-fabric reduction read by `reader` (multimem.ld_reduce).
    LdReduce { reader: DeviceId },
    /// Local HBM pass on `dev` (staging copies, reshapes — §3.1.4 costs).
    LocalHbm { dev: DeviceId },
    /// Host-initiated copy-engine transfer (occupies the CE serially).
    CopyEngineP2p { src: DeviceId, dst: DeviceId },
    /// Cross-node GPUDirect RDMA write: occupies the endpoint NICs and is
    /// rated by the NIC curve of [`crate::hw::ClusterSpec`], not by the
    /// NVLink mechanism curves.
    Rdma { src: DeviceId, dst: DeviceId },
}

/// A data transfer: `bytes` total moved in `msg_bytes` messages by `n_sms`
/// issuing SMs via `mech`.
#[derive(Clone, Debug)]
pub struct TransferSpec {
    pub mech: Mechanism,
    pub route: Route,
    pub bytes: f64,
    pub msg_bytes: f64,
    pub n_sms: f64,
}

/// A 2-D view into a buffer's `(r, c)` plane at batch/depth `(b, d)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatView {
    pub buf: BufId,
    pub b: usize,
    pub d: usize,
    pub row0: usize,
    pub col0: usize,
    pub rows: usize,
    pub cols: usize,
}

impl MatView {
    /// Whole `(r, c)` plane of a 2-D buffer.
    pub fn full2d(buf: BufId, rows: usize, cols: usize) -> Self {
        MatView { buf, b: 0, d: 0, row0: 0, col0: 0, rows, cols }
    }

    /// Sub-view offset by rows/cols.
    pub fn sub(&self, row0: usize, col0: usize, rows: usize, cols: usize) -> Self {
        debug_assert!(row0 + rows <= self.rows && col0 + cols <= self.cols);
        MatView { row0: self.row0 + row0, col0: self.col0 + col0, rows, cols, ..*self }
    }

    /// Checked [`MatView::sub`]: `None` if the sub-rectangle escapes this
    /// view. Builders keep the unchecked fast path; the verifier (and any
    /// code handling untrusted plans) uses this so release builds cannot
    /// silently alias out-of-range views.
    pub fn try_sub(&self, row0: usize, col0: usize, rows: usize, cols: usize) -> Option<Self> {
        let row_ok = row0.checked_add(rows).is_some_and(|end| end <= self.rows);
        let col_ok = col0.checked_add(cols).is_some_and(|end| end <= self.cols);
        if row_ok && col_ok {
            Some(MatView { row0: self.row0 + row0, col0: self.col0 + col0, rows, cols, ..*self })
        } else {
            None
        }
    }
}

/// Functional semantics of an op (ignored by the timed executor).
#[derive(Clone, Debug)]
pub enum Effect {
    /// `dst = src` (or `dst op= src` with a reduction) between two views of
    /// identical shape, possibly on different devices.
    CopyMat { src: MatView, dst: MatView, reduce: Option<ReduceOp> },
    /// Broadcast `src` into the same region of every buffer in `dsts`
    /// (functional multicast; with `reduce`, multimem.red semantics).
    MulticastMat { src: MatView, dsts: Vec<MatView>, reduce: Option<ReduceOp> },
    /// `dst = reduce(srcs)` elementwise (functional multimem.ld_reduce).
    LdReduceMat { srcs: Vec<MatView>, dst: MatView, op: ReduceOp },
    /// `c (+)= a @ b`.
    Gemm { a: MatView, b: MatView, c: MatView, accumulate: bool },
    /// In-place tanh-GeLU.
    Gelu { x: MatView },
    /// Fold one KV block into a blockwise-attention state:
    /// `state.update(q, k, v)`.
    AttnBlock { q: MatView, k: MatView, v: MatView, state: StateId },
    /// Normalise an attention state into `out`.
    AttnFinalize { state: StateId, out: MatView },
    /// Copy selected rows of `src` to consecutive rows of `dst` starting at
    /// `dst.row0` (MoE token gather/scatter). `rows` are src row indices.
    GatherRows { src: MatView, rows: Vec<usize>, dst: MatView },
    /// Scatter consecutive rows of `src` to the listed row indices of `dst`.
    ScatterRows { src: MatView, dst: MatView, rows: Vec<usize>, reduce: Option<ReduceOp> },
    /// Execute an AOT-compiled artifact via the PJRT runtime:
    /// `outputs = artifact(inputs)` (views flattened row-major).
    RunArtifact { name: String, inputs: Vec<MatView>, outputs: Vec<MatView> },
}

/// One instruction of a worker program.
#[derive(Clone, Debug)]
pub enum Op {
    /// Local compute taking `dur` seconds (timed) with optional numerics.
    Compute { dur: f64, label: &'static str, effect: Option<Effect> },
    /// A data transfer. If `blocking`, the worker waits for completion
    /// (register-op semantics); otherwise it proceeds immediately
    /// (TMA/CE async issue) and `done_sem` (if any) is signalled at
    /// completion + `done_scope` latency.
    Transfer {
        spec: TransferSpec,
        blocking: bool,
        done_sem: Option<SemId>,
        done_scope: SyncScope,
        label: &'static str,
        effect: Option<Effect>,
    },
    /// Block until `sem >= value`.
    Wait { sem: SemId, value: u64 },
    /// `sem += value`, visible after the scope's latency.
    Signal { sem: SemId, value: u64, scope: SyncScope },
    /// Fixed delay (library overheads, launch gaps).
    Delay { dur: f64, label: &'static str },
}

/// The execution role of a worker (reporting/trace categories follow the
/// LCSC template's specializations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// A compute SM (consumer + its loader/storer warps).
    ComputeSm,
    /// A dedicated communication SM (the template's communicator).
    CommSm,
    /// Host thread (launches, copy-engine programming).
    Host,
}

/// One worker's straight-line program.
#[derive(Clone, Debug)]
pub struct WorkerPlan {
    pub device: DeviceId,
    pub role: Role,
    pub label: String,
    pub ops: Vec<Op>,
}

/// A complete kernel plan.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    /// Initial values of each semaphore.
    pub sems: Vec<u64>,
    /// Number of attention states used by `AttnBlock`/`AttnFinalize`.
    pub num_states: usize,
    pub workers: Vec<WorkerPlan>,
    /// One-time kernel launch overhead added before t=0 work (T_launch).
    pub launch_overhead: f64,
}

impl Plan {
    pub fn new() -> Self {
        Plan::default()
    }

    pub fn add_sem(&mut self, initial: u64) -> SemId {
        self.sems.push(initial);
        SemId(self.sems.len() - 1)
    }

    pub fn add_state(&mut self) -> StateId {
        self.num_states += 1;
        StateId(self.num_states - 1)
    }

    pub fn add_worker(&mut self, device: DeviceId, role: Role, label: impl Into<String>) -> usize {
        self.workers.push(WorkerPlan { device, role, label: label.into(), ops: vec![] });
        self.workers.len() - 1
    }

    pub fn push(&mut self, worker: usize, op: Op) {
        self.workers[worker].ops.push(op);
    }

    pub fn total_ops(&self) -> usize {
        self.workers.iter().map(|w| w.ops.len()).sum()
    }
}

/// Convenience builder that carries the plan plus common context.
pub struct PlanBuilder {
    pub plan: Plan,
}

impl Default for PlanBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl PlanBuilder {
    pub fn new() -> Self {
        PlanBuilder { plan: Plan::new() }
    }

    pub fn finish(self) -> Plan {
        self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_accumulates_workers_and_sems() {
        let mut p = Plan::new();
        let s = p.add_sem(0);
        assert_eq!(s, SemId(0));
        let w = p.add_worker(DeviceId(0), Role::ComputeSm, "sm0");
        p.push(w, Op::Wait { sem: s, value: 1 });
        p.push(w, Op::Signal { sem: s, value: 1, scope: SyncScope::IntraSm });
        assert_eq!(p.total_ops(), 2);
        assert_eq!(p.workers[w].role, Role::ComputeSm);
    }

    #[test]
    fn matview_sub() {
        let v = MatView::full2d(BufId(0), 64, 64);
        let s = v.sub(16, 32, 16, 16);
        assert_eq!((s.row0, s.col0, s.rows, s.cols), (16, 32, 16, 16));
        let s2 = s.sub(1, 1, 4, 4);
        assert_eq!((s2.row0, s2.col0), (17, 33));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn matview_sub_bounds_checked() {
        let v = MatView::full2d(BufId(0), 16, 16);
        let _ = v.sub(8, 8, 16, 16);
    }

    #[test]
    fn matview_try_sub() {
        let v = MatView::full2d(BufId(0), 16, 16);
        let s = v.try_sub(8, 4, 8, 12).expect("in bounds");
        assert_eq!(s, v.sub(8, 4, 8, 12));
        assert!(v.try_sub(8, 8, 16, 16).is_none());
        assert!(v.try_sub(0, 9, 16, 8).is_none());
        assert!(v.try_sub(usize::MAX, 0, 2, 2).is_none(), "offset overflow is caught");
    }

    #[test]
    fn state_alloc() {
        let mut p = Plan::new();
        assert_eq!(p.add_state(), StateId(0));
        assert_eq!(p.add_state(), StateId(1));
        assert_eq!(p.num_states, 2);
    }
}
