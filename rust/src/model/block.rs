//! Transformer-layer blocks: dense (attention + MLP around AG+GEMM /
//! GEMM+RS) and MoE (dispatch → grouped GEMM → combine), built per
//! pipeline stage through the unified [`KernelBuild`] entry and chained
//! into per-stage layer stacks.
//!
//! Two chaining disciplines:
//! - **Fences** ([`Composer::fence`] / [`Composer::gate`]): a stage-wide
//!   barrier between consecutive sub-kernels — the conservative default,
//!   and the baseline the credit overlap is measured against.
//! - **Wave-level credits** (MoE stacks with `overlap = true`): layer
//!   *l*'s combine deliveries credit per-device gates that layer *l+1*'s
//!   dispatch waves consume ([`moe::build_cluster_layer_gated`]), so the
//!   combine hop overlaps the next dispatch instead of meeting the
//!   per-device `gemm_done`-style barrier.

use super::compose::{Appended, Composer};
use super::{ModelCfg, StageCtx};
use crate::hw::DeviceId;
use crate::kernels::ag_gemm::AgGemm;
use crate::kernels::gemm::Gemm;
use crate::kernels::gemm_rs::{ClusterPath, GemmRs, Schedule};
use crate::kernels::moe::{self, MoeCfg, MoeSchedule, Routing};
use crate::kernels::{GemmKernelCfg, KernelBuild};
use crate::plan::{Op, Plan, Role, SemId};

/// Stage-local GEMM cfg (the node shape is the stage's).
fn gcfg(stage: &StageCtx, m: usize, n: usize, k: usize) -> GemmKernelCfg {
    GemmKernelCfg::new(stage.cluster.node.clone(), m, n, k)
}

fn ag(stage: &StageCtx, m: usize, n: usize, k: usize) -> Plan {
    AgGemm { cfg: gcfg(stage, m, n, k), path: ClusterPath::RailReduce }
        .build(&stage.build_ctx(), None)
}

fn rs(stage: &StageCtx, m: usize, n: usize, k: usize) -> Plan {
    GemmRs { cfg: gcfg(stage, m, n, k), schedule: Schedule::InterSm, path: ClusterPath::RailReduce }
        .build(&stage.build_ctx(), None)
}

fn local_gemm(stage: &StageCtx, m: usize, n: usize, k: usize) -> Plan {
    Gemm { cfg: gcfg(stage, m, n, k) }.build(&stage.build_ctx(), None)
}

/// The flash-attention core (timed compute only; the projections around it
/// are the AG+GEMM / GEMM+RS kernels). Heads shard over the stage, so each
/// device runs `4·s²·(hidden/w)` FLOPs (backward ≈ 2.5×).
fn attn_core(stage: &StageCtx, m: &ModelCfg, bwd: bool) -> Plan {
    let w = stage.cluster.total_devices();
    let g = &stage.cluster.node.gpu;
    let flops = 4.0 * (m.seq as f64).powi(2) * m.hidden as f64 / w as f64;
    let flops = if bwd { flops * 2.5 } else { flops };
    let dur = flops / (g.tc_flops_for_sms(g.num_sms) * m.flash_util);
    let mut plan = Plan::new();
    plan.launch_overhead = g.kernel_launch;
    for d in 0..w {
        let wk = plan.add_worker(DeviceId(d), Role::ComputeSm, format!("attn/d{d}"));
        let label = if bwd { "attn_core_bwd" } else { "attn_core" };
        plan.push(wk, Op::Compute { dur, label, effect: None });
    }
    plan
}

/// The sub-kernel plans of one dense layer, forward: optional attention
/// sublayer (qkv AG+GEMM → core → out-proj GEMM+RS), then the MLP
/// (up AG+GEMM → down GEMM+RS). Sequence-sharded activations in,
/// sequence-sharded out — exactly the Megatron TP wiring.
pub fn dense_fwd_parts(stage: &StageCtx, m: &ModelCfg) -> Vec<Plan> {
    let w = stage.cluster.total_devices();
    let mut parts = vec![];
    if m.n_heads > 0 {
        parts.push(ag(stage, m.seq, 3 * m.hidden / w, m.hidden));
        parts.push(attn_core(stage, m, false));
        parts.push(rs(stage, m.seq, m.hidden, m.hidden / w));
    }
    parts.push(ag(stage, m.seq, m.ffn / w, m.hidden));
    parts.push(rs(stage, m.seq, m.hidden, m.ffn / w));
    parts
}

/// One dense layer, backward: each forward kernel's **comm-dual** (AG+GEMM
/// ↔ GEMM+RS swap for the dgrads — the transpose of a gather is a scatter
/// of the reduction) plus the purely local wgrad GEMMs.
pub fn dense_bwd_parts(stage: &StageCtx, m: &ModelCfg) -> Vec<Plan> {
    let w = stage.cluster.total_devices();
    let mut parts = vec![
        // down-proj dgrad (dual of GEMM+RS) + wgrad
        ag(stage, m.seq, m.ffn / w, m.hidden),
        local_gemm(stage, m.ffn / w, m.hidden, m.seq),
        // up-proj dgrad (dual of AG+GEMM) + wgrad
        rs(stage, m.seq, m.hidden, m.ffn / w),
        local_gemm(stage, m.hidden, m.ffn / w, m.seq),
    ];
    if m.n_heads > 0 {
        parts.push(ag(stage, m.seq, m.hidden / w, m.hidden));
        parts.push(attn_core(stage, m, true));
        parts.push(rs(stage, m.seq, m.hidden, 3 * m.hidden / w));
        parts.push(local_gemm(stage, m.hidden, 3 * m.hidden / w, m.seq));
    }
    parts
}

/// Chain sub-plans with stage-wide fences: part *i+1*'s every worker waits
/// for part *i*'s every worker. Returns the fused plan.
pub fn chain(parts: Vec<Plan>, stage: &StageCtx) -> Plan {
    let mut c = Composer::new();
    chain_into(&mut c, parts, stage);
    c.plan
}

/// [`chain`] into an existing composer; returns the last part's fence.
pub fn chain_into(c: &mut Composer, parts: Vec<Plan>, stage: &StageCtx) -> Option<(SemId, u64)> {
    let scope = stage.scope();
    let mut prev: Option<(SemId, u64)> = None;
    for part in parts {
        let r = c.append(part, 0);
        if let Some((s, v)) = prev {
            c.gate(&r, s, v);
        }
        prev = Some(c.fence(&r, scope));
    }
    prev
}

/// Stage-local MoE cfg from the model shape.
pub fn moe_cfg(stage: &StageCtx, m: &ModelCfg) -> MoeCfg {
    let p = m.moe.expect("moe_cfg needs ModelCfg::moe");
    MoeCfg {
        node: stage.cluster.node.clone(),
        tokens: m.seq,
        hidden: m.hidden,
        h_expert: p.h_expert,
        n_experts: p.n_experts,
        top_k: p.top_k,
        comm_sms: 16,
        rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO,
    }
}

/// A stack of `layers` MoE layers on one stage. With `overlap = false`
/// consecutive layers meet at a stage-wide fence (the barrier baseline);
/// with `overlap = true` layer *l*'s combine deliveries credit layer
/// *l+1*'s dispatch gates at wave granularity — monotone proportional
/// thresholds that can never exceed the grant total, so the credit
/// protocol is deadlock-free by construction (and pinned by the verify
/// mutation tests).
pub fn moe_stack(stage: &StageCtx, m: &ModelCfg, layers: usize, overlap: bool, seed: u64) -> Plan {
    let w = stage.cluster.total_devices();
    let cfg = moe_cfg(stage, m);
    let routing = Routing::uniform(&cfg, seed);
    let labels = [moe::LABEL_COMBINE_SEND, moe::LABEL_COMBINE_FWD];
    let mut c = Composer::new();
    let scope = stage.scope();
    let mut prev: Option<Appended> = None;
    let mut prev_fence: Option<(SemId, u64)> = None;
    for _ in 0..layers {
        if overlap && prev.is_some() {
            let prange = prev.as_ref().unwrap();
            // how many combine deliveries the previous layer lands on each
            // stage device — the gate grant totals
            let mut exp = vec![0u64; w];
            for (d, cnt) in c.count_deliveries(prange, &labels) {
                exp[d] = cnt;
            }
            let (plan, gates) = moe::build_cluster_layer_gated(
                &cfg,
                &stage.cluster,
                &routing,
                MoeSchedule::Overlapped,
                &stage.health,
                &exp,
                None,
            );
            let r = c.append(plan, 0);
            let fused: Vec<SemId> = gates.iter().map(|g| r.sem(*g)).collect();
            let prange = prev.as_ref().unwrap();
            let attached = c.attach_done(prange, &labels, |d| {
                if exp[d] > 0 {
                    Some(fused[d])
                } else {
                    None
                }
            });
            // the grant totals the gates wait for must be exactly the
            // credits the previous layer now emits
            for (d, cnt) in attached {
                assert_eq!(exp[d], cnt, "credit accounting drift on device {d}");
            }
            prev = Some(r);
            prev_fence = Some(c.fence(prev.as_ref().unwrap(), scope));
        } else {
            let plan = moe::MoeLayer {
                cfg: cfg.clone(),
                routing: &routing,
                schedule: MoeSchedule::Overlapped,
            }
            .build(&stage.build_ctx(), None);
            let r = c.append(plan, 0);
            if let Some((s, v)) = prev_fence {
                c.gate(&r, s, v);
            }
            prev = Some(r);
            prev_fence = Some(c.fence(prev.as_ref().unwrap(), scope));
        }
    }
    c.plan
}

/// One pipeline cell, forward: the stage's `layers` transformer layers for
/// one microbatch. Dense models chain AG+GEMM / GEMM+RS sublayers with
/// fences; MoE models stack expert layers (credit-overlapped when
/// `overlap`).
pub fn fwd_cell(stage: &StageCtx, m: &ModelCfg, layers: usize, overlap: bool) -> Plan {
    match m.moe {
        Some(_) => moe_stack(stage, m, layers, overlap, 11),
        None => {
            let mut parts = vec![];
            for _ in 0..layers {
                parts.extend(dense_fwd_parts(stage, m));
            }
            chain(parts, stage)
        }
    }
}

/// One pipeline cell, backward. The MoE backward re-runs the layer's
/// dispatch/GEMM/combine shape (the grad exchange is byte- and
/// FLOP-symmetric to the forward); dense backward chains the comm-duals.
pub fn bwd_cell(stage: &StageCtx, m: &ModelCfg, layers: usize, overlap: bool) -> Plan {
    match m.moe {
        Some(_) => moe_stack(stage, m, layers, overlap, 23),
        None => {
            let mut parts = vec![];
            for _ in 0..layers {
                parts.extend(dense_bwd_parts(stage, m));
            }
            chain(parts, stage)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::ClusterSpec;
    use crate::kernels::{ag_gemm, gemm_rs};
    use crate::model::ParallelSpec;
    use crate::pk::rail::RailHealth;
    use crate::plan::verify::{verify, VerifyCtx};

    #[test]
    fn two_layer_dense_tp_block_bit_identical_to_hand_chaining() {
        // The composition guarantee: a 2-layer MLP-only TP block built by
        // the model layer is *exactly* the two kernel plans (built through
        // the deprecated wrappers, pinning those too) appended through the
        // same composer with the same fence discipline — bit for bit.
        let cluster = ClusterSpec::test_cluster(1, 2);
        let health = RailHealth::all_healthy(&cluster);
        let layout = ParallelSpec::dense(2, 1).resolve(&cluster, &health);
        let stage = &layout.stages[0];
        let m = ModelCfg {
            hidden: 128,
            ffn: 512,
            seq: 256,
            n_heads: 0,
            n_layers: 2,
            microbatches: 1,
            moe: None,
            flash_util: 0.75,
        };
        let via_model = fwd_cell(stage, &m, 2, false);

        let w = 2usize;
        let mut c = Composer::new();
        let mut prev: Option<(SemId, u64)> = None;
        for _ in 0..2 {
            for plan in [
                ag_gemm::build_cluster_health(
                    &GemmKernelCfg::new(stage.cluster.node.clone(), m.seq, m.ffn / w, m.hidden),
                    &stage.cluster,
                    ClusterPath::RailReduce,
                    &stage.health,
                    None,
                ),
                gemm_rs::build_cluster_health(
                    &GemmKernelCfg::new(stage.cluster.node.clone(), m.seq, m.hidden, m.ffn / w),
                    &stage.cluster,
                    Schedule::InterSm,
                    ClusterPath::RailReduce,
                    &stage.health,
                    None,
                ),
            ] {
                let r = c.append(plan, 0);
                if let Some((s, v)) = prev {
                    c.gate(&r, s, v);
                }
                prev = Some(c.fence(&r, stage.scope()));
            }
        }
        assert_eq!(
            format!("{via_model:?}"),
            format!("{:?}", c.plan),
            "model-layer block drifted from hand-chained kernel plans"
        );

        let ctx = VerifyCtx { pool: None, devices_per_node: Some(2) };
        let report = verify(&via_model, &ctx);
        assert!(report.is_clean(), "2-layer dense block must verify clean:\n{}", report.render());
    }
}
