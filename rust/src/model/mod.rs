//! Model layer: transformer blocks and pipeline-parallel model plans
//! composed from the kernel library through the unified
//! [`crate::kernels::KernelBuild`] / [`crate::kernels::BuildCtx`] entry.
//!
//! The kernel zoo below this module emits one fused plan per *operator*
//! (AG+GEMM, GEMM+RS, MoE dispatch/combine, …). This layer assembles those
//! plans into whole transformer layers and multi-layer models under a
//! declarative [`ParallelSpec`] resolved against a [`ClusterSpec`]:
//!
//! - [`block`] chains kernels into dense (attention + MLP around
//!   AG+GEMM / GEMM+RS) and MoE (dispatch → grouped GEMM → combine)
//!   layers, including wave-level credit overlap between consecutive MoE
//!   layers (the combine hop of layer *l* overlaps the dispatch of layer
//!   *l+1* instead of meeting a per-device barrier).
//! - [`pipeline`] chains pipeline stages with 1F1B / interleaved
//!   schedules (plus the non-overlapped sequential baseline) into a
//!   single fused [`crate::plan::Plan`] with cross-layer overlap.
//! - [`compose`] is the underlying plan surgery: id remapping, fences,
//!   and credit attachment.
//!
//! Every plan this module emits is `plan::verify`-clean (asserted by the
//! `px1` exhibit runner and the lint zoo).

pub mod block;
pub mod compose;
pub mod pipeline;

use crate::hw::cluster::ClusterSpec;
use crate::hw::spec::NodeSpec;
use crate::kernels::BuildCtx;
use crate::pk::rail::RailHealth;

/// Declarative parallelism layout, resolved against a [`ClusterSpec`] by
/// [`ParallelSpec::resolve`]. Exactly one of `tp` / `ep` carries each
/// pipeline stage's width: dense models shard tensor-parallel (`tp`), MoE
/// models shard expert-parallel (`ep`). `sp` additionally splits the
/// pipeline-boundary activation transfers into that many sequence shards
/// (chunked, so boundary bytes pipeline instead of moving as one flow).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelSpec {
    pub tp: usize,
    pub ep: usize,
    pub pp: usize,
    pub sp: usize,
}

impl Default for ParallelSpec {
    fn default() -> Self {
        ParallelSpec { tp: 1, ep: 1, pp: 1, sp: 1 }
    }
}

impl ParallelSpec {
    /// Dense layout: `tp`-way tensor parallel × `pp` pipeline stages.
    pub fn dense(tp: usize, pp: usize) -> Self {
        ParallelSpec { tp, ep: 1, pp, sp: 1 }
    }

    /// MoE layout: `ep`-way expert parallel × `pp` pipeline stages.
    pub fn moe(ep: usize, pp: usize) -> Self {
        ParallelSpec { tp: 1, ep, pp, sp: 1 }
    }

    /// Builder-style sequence-parallel degree for boundary transfers.
    pub fn with_sp(mut self, sp: usize) -> Self {
        assert!(sp >= 1);
        self.sp = sp;
        self
    }

    /// Per-stage device count this spec asks for.
    pub fn stage_width(&self) -> usize {
        self.tp.max(self.ep)
    }

    /// Resolve the spec against a cluster + health mask into per-stage
    /// build contexts. Stages occupy consecutive device windows; a stage
    /// is either a whole number of nodes or a sub-slice of one node
    /// (windows never straddle a node boundary mid-stage).
    pub fn resolve(&self, cluster: &ClusterSpec, health: &RailHealth) -> Layout {
        let n = cluster.total_devices();
        let p = cluster.devices_per_node();
        let width = self.stage_width();
        assert!(self.tp == 1 || self.ep == 1, "a stage is tp- or ep-sharded, not both");
        assert!(self.pp >= 1 && width >= 1);
        assert_eq!(
            width * self.pp,
            n,
            "ParallelSpec ({}x{} over {} stages) must cover the cluster's {} devices",
            self.tp,
            self.ep,
            self.pp,
            n
        );
        let stages = (0..self.pp)
            .map(|s| {
                let dev0 = s * width;
                let cluster = if width % p == 0 {
                    // whole nodes: keep the node shape, shrink the node count
                    ClusterSpec { num_nodes: width / p, ..cluster.clone() }
                } else {
                    assert_eq!(
                        p % width,
                        0,
                        "stage width {width} must divide or be a multiple of the node size {p}"
                    );
                    let node = NodeSpec { num_devices: width, ..cluster.node.clone() };
                    ClusterSpec { node, num_nodes: 1, ..cluster.clone() }
                };
                StageCtx { cluster, dev0, health: health.restrict(dev0, width) }
            })
            .collect();
        Layout { stages, width, sp: self.sp }
    }
}

/// Resolved pipeline layout: one [`StageCtx`] per stage.
#[derive(Clone, Debug)]
pub struct Layout {
    pub stages: Vec<StageCtx>,
    pub width: usize,
    pub sp: usize,
}

/// One pipeline stage's slice of the cluster: a stage-local cluster spec
/// (devices renumbered `0..width`), the stage's first global device, and
/// the restricted NIC health mask.
#[derive(Clone, Debug)]
pub struct StageCtx {
    pub cluster: ClusterSpec,
    pub dev0: usize,
    pub health: RailHealth,
}

impl StageCtx {
    /// The unified kernel-builder context for this stage.
    pub fn build_ctx(&self) -> BuildCtx<'_> {
        BuildCtx::new(&self.cluster, &self.health)
    }

    /// Widest sync boundary inside the stage.
    pub fn scope(&self) -> crate::plan::SyncScope {
        if self.cluster.num_nodes > 1 {
            crate::plan::SyncScope::InterNode
        } else {
            crate::plan::SyncScope::InterDevice
        }
    }
}

/// Expert-parallel layer parameters (the MoE analogue of `ffn`).
#[derive(Clone, Copy, Debug)]
pub struct MoeParams {
    pub n_experts: usize,
    pub top_k: usize,
    pub h_expert: usize,
}

/// Whole-model shape: `n_layers` identical transformer layers, each
/// microbatch carrying `seq` tokens. `moe: Some(..)` swaps the dense MLP
/// for an expert layer (dispatch → grouped GEMM → combine); `n_heads: 0`
/// drops the attention sublayer (MLP-only blocks, used by the identity
/// tests).
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub hidden: usize,
    pub ffn: usize,
    pub seq: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub microbatches: usize,
    pub moe: Option<MoeParams>,
    /// Attention sustains a lower fraction of peak than GEMM.
    pub flash_util: f64,
}

impl ModelCfg {
    /// A dense reference model sized so every kernel divisibility
    /// constraint holds at stage widths up to 16 (`seq % (128·W) == 0`).
    pub fn dense_example() -> Self {
        ModelCfg {
            hidden: 2048,
            ffn: 4096,
            seq: 2048,
            n_heads: 16,
            n_layers: 4,
            microbatches: 4,
            moe: None,
            flash_util: 0.75,
        }
    }

    /// An MoE reference model (32 experts, top-2) on the same trunk.
    pub fn moe_example() -> Self {
        ModelCfg {
            moe: Some(MoeParams { n_experts: 32, top_k: 2, h_expert: 1024 }),
            ..Self::dense_example()
        }
    }

    /// Bytes of one microbatch's boundary activation (`seq × hidden`).
    pub fn act_bytes(&self) -> f64 {
        (self.seq * self.hidden) as f64 * crate::mem::ELEM_BYTES as f64
    }
}
