//! Plan composition: append independently-built kernel plans into one
//! fused [`Plan`], remapping semaphore / attention-state ids into the
//! fused id space and shifting device ids into a pipeline stage's global
//! window. Buffer ids never remap — functional compositions allocate from
//! one shared [`crate::mem::MemPool`], so `BufId`s are global already.
//!
//! The composer is deliberately dumb about scheduling: it appends worker
//! programs verbatim and exposes two explicit coupling primitives —
//! [`Composer::fence`] (a barrier sem the appended range signals and a
//! later range waits on) and [`Composer::attach_done`] (retarget a
//! delivered transfer's `done_sem` to credit a downstream gate). The
//! pipeline and block layers build every schedule out of those two.

use crate::plan::{Op, Plan, Route, SemId, StateId, SyncScope};

/// Id bases of one appended sub-plan, for wiring cross-plan edges.
#[derive(Clone, Copy, Debug)]
pub struct Appended {
    /// First fused sem id of the sub-plan (`sub sem s` → `sem_base + s`).
    pub sem_base: usize,
    /// First fused attention-state id of the sub-plan.
    pub state_base: usize,
    /// First fused worker index of the sub-plan.
    pub worker_base: usize,
    /// One past the last fused worker index.
    pub worker_end: usize,
}

impl Appended {
    /// Translate a sub-plan-local sem id into the fused id space.
    pub fn sem(&self, s: SemId) -> SemId {
        SemId(s.0 + self.sem_base)
    }

    /// Fused worker indices of the appended sub-plan.
    pub fn workers(&self) -> std::ops::Range<usize> {
        self.worker_base..self.worker_end
    }
}

/// Accumulates kernel plans into one fused model plan.
#[derive(Debug, Default)]
pub struct Composer {
    pub plan: Plan,
}

impl Composer {
    pub fn new() -> Self {
        Composer { plan: Plan::new() }
    }

    /// Append `sub` with its device ids shifted by `dev_offset` (the
    /// stage's first global device). Sems keep their initial values;
    /// worker programs are appended verbatim apart from id remaps. The
    /// fused launch overhead is the max over sub-plans (one fused launch).
    pub fn append(&mut self, sub: Plan, dev_offset: usize) -> Appended {
        let sem_base = self.plan.sems.len();
        let state_base = self.plan.num_states;
        let worker_base = self.plan.workers.len();
        self.plan.sems.extend(sub.sems.iter().copied());
        self.plan.num_states += sub.num_states;
        self.plan.launch_overhead = self.plan.launch_overhead.max(sub.launch_overhead);
        for mut w in sub.workers {
            w.device.0 += dev_offset;
            for op in &mut w.ops {
                remap_op(op, sem_base, state_base, dev_offset);
            }
            self.plan.workers.push(w);
        }
        Appended { sem_base, state_base, worker_base, worker_end: self.plan.workers.len() }
    }

    /// Barrier after an appended range: every worker in `range` signals a
    /// fresh sem once at its end; returns `(sem, target)` for later ranges
    /// to wait on (`Wait { sem, value: target }`). `scope` should span the
    /// widest boundary any signaller crosses to a waiter.
    pub fn fence(&mut self, range: &Appended, scope: SyncScope) -> (SemId, u64) {
        let sem = self.plan.add_sem(0);
        for wi in range.workers() {
            self.plan.push(wi, Op::Signal { sem, value: 1, scope });
        }
        (sem, (range.worker_end - range.worker_base) as u64)
    }

    /// Prepend `Wait { sem, value }` to every worker of `range` — the
    /// receiving half of [`Composer::fence`].
    pub fn gate(&mut self, range: &Appended, sem: SemId, value: u64) {
        for wi in range.workers() {
            let mut ops = vec![Op::Wait { sem, value }];
            ops.append(&mut self.plan.workers[wi].ops);
            self.plan.workers[wi].ops = ops;
        }
    }

    /// Non-mutating twin of [`Composer::attach_done`]: count how many
    /// delivered transfers in `range` (label in `labels`, `done_sem` still
    /// `None`, point-to-point route) land on each destination device.
    /// Used to size gate grant totals *before* the gated consumer plan —
    /// and therefore its gate sems — exists.
    pub fn count_deliveries(&self, range: &Appended, labels: &[&str]) -> Vec<(usize, u64)> {
        let mut counts: std::collections::BTreeMap<usize, u64> = Default::default();
        for wi in range.workers() {
            for op in &self.plan.workers[wi].ops {
                if let Op::Transfer { spec, done_sem, label, .. } = op {
                    if done_sem.is_some() || !labels.contains(label) {
                        continue;
                    }
                    let dst = match spec.route {
                        Route::P2p { dst, .. }
                        | Route::CopyEngineP2p { dst, .. }
                        | Route::Rdma { dst, .. } => dst.0,
                        _ => continue,
                    };
                    *counts.entry(dst).or_insert(0) += 1;
                }
            }
        }
        counts.into_iter().collect()
    }

    /// Retarget the `done_sem` of delivered transfers in `range`: every
    /// `Transfer` whose label is in `labels` and whose `done_sem` is
    /// `None` gets `done_sem = pick(dst_device)` (global id), crediting a
    /// downstream gate at completion. Returns how many transfers now
    /// credit each device the picker matched (the caller's
    /// `gate_expected`). Transfers that already carry a `done_sem` are
    /// left alone — they are internal protocol counters.
    pub fn attach_done(
        &mut self,
        range: &Appended,
        labels: &[&str],
        mut pick: impl FnMut(usize) -> Option<SemId>,
    ) -> Vec<(usize, u64)> {
        let mut counts: std::collections::BTreeMap<usize, u64> = Default::default();
        for wi in range.workers() {
            for op in &mut self.plan.workers[wi].ops {
                if let Op::Transfer { spec, done_sem, done_scope, label, .. } = op {
                    if done_sem.is_some() || !labels.contains(label) {
                        continue;
                    }
                    let dst = match spec.route {
                        Route::P2p { dst, .. }
                        | Route::CopyEngineP2p { dst, .. }
                        | Route::Rdma { dst, .. } => dst.0,
                        _ => continue,
                    };
                    if let Some(sem) = pick(dst) {
                        *done_sem = Some(sem);
                        *done_scope = SyncScope::InterDevice;
                        *counts.entry(dst).or_insert(0) += 1;
                    }
                }
            }
        }
        counts.into_iter().collect()
    }
}

/// Remap one op's sem / state / device ids into the fused id space.
fn remap_op(op: &mut Op, sem_base: usize, state_base: usize, dev_offset: usize) {
    match op {
        Op::Wait { sem, .. } | Op::Signal { sem, .. } => sem.0 += sem_base,
        Op::Transfer { spec, done_sem, effect, .. } => {
            if let Some(s) = done_sem {
                s.0 += sem_base;
            }
            remap_route(&mut spec.route, dev_offset);
            if let Some(e) = effect {
                remap_effect_state(e, state_base);
            }
        }
        Op::Compute { effect, .. } => {
            if let Some(e) = effect {
                remap_effect_state(e, state_base);
            }
        }
        Op::Delay { .. } => {}
    }
}

fn remap_route(route: &mut Route, dev_offset: usize) {
    if dev_offset == 0 {
        return;
    }
    match route {
        Route::P2p { src, dst } | Route::CopyEngineP2p { src, dst } | Route::Rdma { src, dst } => {
            src.0 += dev_offset;
            dst.0 += dev_offset;
        }
        Route::Multicast { src } => src.0 += dev_offset,
        Route::LdReduce { reader } => reader.0 += dev_offset,
        Route::LocalHbm { dev } => dev.0 += dev_offset,
    }
}

/// Attention states are the only effect payload carrying plan-scoped ids
/// (buffers are pool-global; views are coordinates).
fn remap_effect_state(effect: &mut crate::plan::Effect, state_base: usize) {
    use crate::plan::Effect;
    match effect {
        Effect::AttnBlock { state, .. } | Effect::AttnFinalize { state, .. } => {
            *state = StateId(state.0 + state_base)
        }
        _ => {}
    }
}
