//! Pipeline-parallel model assembly: chain per-stage transformer cells
//! (one [`block::fwd_cell`] / [`block::bwd_cell`] per microbatch per
//! virtual stage) into a single fused [`Plan`].
//!
//! Three schedules:
//! - [`PipeSchedule::Sequential`] — the non-overlapped baseline: a global
//!   total order with a full barrier between consecutive cells (and MoE
//!   layer barriers inside each cell). No two stages ever overlap.
//! - [`PipeSchedule::OneFOneB`] — classic 1F1B: stage `s` runs
//!   `min(S-1-s, M)` warmup forwards, then alternates one-forward /
//!   one-backward, then drains. Stages only couple through activation /
//!   gradient edges, so different microbatches overlap across stages.
//! - [`PipeSchedule::Interleaved`] — each physical stage owns `c > 1`
//!   non-contiguous virtual stages (layer chunks), shrinking the
//!   pipeline bubble by `c`; cell order is chosen greedily
//!   (backward-first once steady).
//!
//! Cross-stage edges are explicit `pipe_act` / `pipe_grad` transfer
//! workers: after the producer cell's fence, each stage device sends its
//! activation shard (further split `sp` ways) to its peer in the consumer
//! stage — RDMA when the stages sit on different nodes — and the edge
//! semaphore gates the consumer cell. Dropping one of those credits is a
//! deadlock, which `plan::verify` catches (see the mutation tests).

use std::collections::HashMap;

use super::block;
use super::compose::Composer;
use super::{ModelCfg, ParallelSpec};
use crate::hw::cluster::ClusterSpec;
use crate::hw::DeviceId;
use crate::pk::rail::RailHealth;
use crate::plan::{Op, Plan, Role, Route, SemId, SyncScope, TransferSpec};
use crate::xfer::Mechanism;

/// How pipeline cells are ordered on each stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipeSchedule {
    /// Global total order with full barriers — the no-overlap baseline.
    Sequential,
    /// One-forward-one-backward with warmup/drain.
    OneFOneB,
    /// 1F1B over interleaved virtual stages (2 layer chunks per stage
    /// when `n_layers` allows it, else identical to [`Self::OneFOneB`]).
    Interleaved,
}

/// One pipeline cell: virtual stage `vs`'s layers for microbatch `mb`,
/// forward or backward.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Cell {
    vs: usize,
    mb: usize,
    fwd: bool,
}

impl Cell {
    fn f(vs: usize, mb: usize) -> Cell {
        Cell { vs, mb, fwd: true }
    }

    fn b(vs: usize, mb: usize) -> Cell {
        Cell { vs, mb, fwd: false }
    }

    /// Data dependencies: F(vs) ← F(vs-1); B(vs) ← F(vs) + B(vs+1).
    fn deps(&self, v: usize) -> Vec<Cell> {
        if self.fwd {
            if self.vs > 0 { vec![Cell::f(self.vs - 1, self.mb)] } else { vec![] }
        } else {
            let mut d = vec![Cell::f(self.vs, self.mb)];
            if self.vs + 1 < v {
                d.push(Cell::b(self.vs + 1, self.mb));
            }
            d
        }
    }

    /// The cross-stage consumer of this cell's output, if any.
    fn consumer(&self, v: usize) -> Option<Cell> {
        if self.fwd {
            (self.vs + 1 < v).then(|| Cell::f(self.vs + 1, self.mb))
        } else {
            (self.vs > 0).then(|| Cell::b(self.vs - 1, self.mb))
        }
    }
}

/// Build the whole-model training-step plan: `M` microbatches through
/// `pp` pipeline stages of `tp`/`ep`-sharded transformer layers, as one
/// fused verify-clean [`Plan`].
pub fn build_model(
    m: &ModelCfg,
    spec: &ParallelSpec,
    cluster: &ClusterSpec,
    health: &RailHealth,
    sched: PipeSchedule,
) -> Plan {
    let layout = spec.resolve(cluster, health);
    let s_cnt = spec.pp;
    let mb_cnt = m.microbatches.max(1);
    // Interleaving needs 2 chunks per stage and a forward+backward's worth
    // of layers per chunk; fall back to plain 1F1B granularity otherwise.
    let chunks = if sched == PipeSchedule::Interleaved && s_cnt > 1 && m.n_layers % (2 * s_cnt) == 0
    {
        2
    } else {
        1
    };
    let v_cnt = s_cnt * chunks;
    assert_eq!(
        m.n_layers % v_cnt,
        0,
        "n_layers ({}) must split evenly over {} virtual stages",
        m.n_layers,
        v_cnt
    );
    let layers_per_v = m.n_layers / v_cnt;
    // The sequential baseline is fully non-overlapped: MoE layers meet at
    // barriers inside each cell too. The pipelined schedules use the
    // wave-level credit overlap.
    let overlap = sched != PipeSchedule::Sequential;
    let scope = if cluster.num_nodes > 1 { SyncScope::InterNode } else { SyncScope::InterDevice };
    let p = cluster.devices_per_node();
    let width = layout.width;

    // One cell template per physical stage and direction; cells clone it.
    let fwd_tpl: Vec<Plan> =
        layout.stages.iter().map(|st| block::fwd_cell(st, m, layers_per_v, overlap)).collect();
    let bwd_tpl: Vec<Plan> =
        layout.stages.iter().map(|st| block::bwd_cell(st, m, layers_per_v, overlap)).collect();

    let order = global_order(sched, s_cnt, v_cnt, mb_cnt);

    let mut c = Composer::new();
    // incoming cross-stage edge per consumer cell: (sem, credits)
    let mut edges: HashMap<Cell, (SemId, u64)> = HashMap::new();
    let mut stage_fence: Vec<Option<(SemId, u64)>> = vec![None; s_cnt];
    let mut global_fence: Option<(SemId, u64)> = None;

    for cell in order {
        let phys = cell.vs % s_cnt;
        let tpl = if cell.fwd { &fwd_tpl[phys] } else { &bwd_tpl[phys] };
        let r = c.append(tpl.clone(), layout.stages[phys].dev0);
        // chain: the baseline chains globally (no overlap anywhere), the
        // pipelined schedules only chain each stage's own hardware
        let chain = if sched == PipeSchedule::Sequential {
            global_fence
        } else {
            stage_fence[phys]
        };
        if let Some((sem, v)) = chain {
            c.gate(&r, sem, v);
        }
        if let Some((sem, v)) = edges.remove(&cell) {
            c.gate(&r, sem, v);
        }
        let fence = c.fence(&r, scope);
        stage_fence[phys] = Some(fence);
        global_fence = Some(fence);

        // boundary transfer to the consumer stage, if it is a different
        // physical stage (same-stage consumers ride the stage chain)
        if let Some(cons) = cell.consumer(v_cnt) {
            let phys2 = cons.vs % s_cnt;
            if phys2 != phys {
                let edge = c.plan.add_sem(0);
                let bytes = m.act_bytes() / (width * layout.sp) as f64;
                for d in 0..width {
                    // backward edges flow tail→head; match shard d to
                    // shard d so each device's NIC carries 1/width
                    let sd = DeviceId(layout.stages[phys].dev0 + d);
                    let dd = DeviceId(layout.stages[phys2].dev0 + d);
                    let cross = sd.0 / p != dd.0 / p;
                    let dir = if cell.fwd { "f" } else { "b" };
                    let wk = c.plan.add_worker(
                        sd,
                        Role::CommSm,
                        format!("pipe/{dir}{}m{}/d{d}", cell.vs, cell.mb),
                    );
                    c.plan.push(wk, Op::Wait { sem: fence.0, value: fence.1 });
                    for _ in 0..layout.sp {
                        c.plan.push(
                            wk,
                            Op::Transfer {
                                spec: TransferSpec {
                                    mech: Mechanism::Tma,
                                    route: if cross {
                                        Route::Rdma { src: sd, dst: dd }
                                    } else {
                                        Route::P2p { src: sd, dst: dd }
                                    },
                                    bytes,
                                    msg_bytes: bytes,
                                    n_sms: 8.0,
                                },
                                blocking: false,
                                done_sem: Some(edge),
                                done_scope: if cross {
                                    SyncScope::InterNode
                                } else {
                                    SyncScope::InterDevice
                                },
                                label: if cell.fwd { "pipe_act" } else { "pipe_grad" },
                                effect: None,
                            },
                        );
                    }
                }
                edges.insert(cons, (edge, (width * layout.sp) as u64));
            }
        }
    }
    assert!(edges.is_empty(), "dangling pipeline edges: {edges:?}");
    c.plan
}

/// A global emission order that is simultaneously (a) topological over the
/// data dependencies and (b) consistent with each stage's execution order
/// — so the per-stage chains plus the cross-stage edges can never form a
/// cycle.
fn global_order(sched: PipeSchedule, s_cnt: usize, v_cnt: usize, mb_cnt: usize) -> Vec<Cell> {
    match sched {
        PipeSchedule::Sequential => {
            // all forwards of a microbatch head-to-tail, then all backwards
            let mut order = vec![];
            for mb in 0..mb_cnt {
                order.extend((0..v_cnt).map(|vs| Cell::f(vs, mb)));
                order.extend((0..v_cnt).rev().map(|vs| Cell::b(vs, mb)));
            }
            order
        }
        PipeSchedule::OneFOneB => {
            assert_eq!(v_cnt, s_cnt);
            let per_stage: Vec<Vec<Cell>> =
                (0..s_cnt).map(|s| one_f_one_b(s, s_cnt, mb_cnt)).collect();
            merge_stage_orders(per_stage, v_cnt)
        }
        PipeSchedule::Interleaved => greedy_interleaved(s_cnt, v_cnt, mb_cnt),
    }
}

/// Stage `s`'s classic 1F1B order: `w = min(S-1-s, M)` warmup forwards,
/// steady 1F1B, backward drain.
fn one_f_one_b(s: usize, s_cnt: usize, mb_cnt: usize) -> Vec<Cell> {
    let w = (s_cnt - 1 - s).min(mb_cnt);
    let mut order: Vec<Cell> = (0..w).map(|mb| Cell::f(s, mb)).collect();
    for mb in w..mb_cnt {
        order.push(Cell::f(s, mb));
        order.push(Cell::b(s, mb - w));
    }
    order.extend((mb_cnt - w..mb_cnt).map(|mb| Cell::b(s, mb)));
    order
}

/// Round-robin merge of fixed per-stage orders into one global
/// topological order. Panics if the per-stage orders deadlock against the
/// data dependencies (a malformed schedule).
fn merge_stage_orders(per_stage: Vec<Vec<Cell>>, v_cnt: usize) -> Vec<Cell> {
    let total: usize = per_stage.iter().map(Vec::len).sum();
    let mut next = vec![0usize; per_stage.len()];
    let mut emitted: std::collections::HashSet<Cell> = Default::default();
    let mut order = Vec::with_capacity(total);
    while order.len() < total {
        let mut progress = false;
        for (s, stage_order) in per_stage.iter().enumerate() {
            if next[s] < stage_order.len() {
                let cell = stage_order[next[s]];
                if cell.deps(v_cnt).iter().all(|d| emitted.contains(d)) {
                    emitted.insert(cell);
                    order.push(cell);
                    next[s] += 1;
                    progress = true;
                }
            }
        }
        assert!(progress, "pipeline schedule deadlocked while merging stage orders");
    }
    order
}

/// Greedy interleaved schedule: each pass every stage emits its best
/// ready cell — backward-first once one is ready (drains activations),
/// earliest microbatch first, forward chunks in ascending virtual-stage
/// order and backward chunks descending.
fn greedy_interleaved(s_cnt: usize, v_cnt: usize, mb_cnt: usize) -> Vec<Cell> {
    let total = 2 * v_cnt * mb_cnt;
    let mut emitted: std::collections::HashSet<Cell> = Default::default();
    let mut order = Vec::with_capacity(total);
    while order.len() < total {
        let mut progress = false;
        for s in 0..s_cnt {
            let best = (0..mb_cnt)
                .flat_map(|mb| {
                    (0..v_cnt).filter(|vs| vs % s_cnt == s).flat_map(move |vs| {
                        [Cell::f(vs, mb), Cell::b(vs, mb)]
                    })
                })
                .filter(|cell| {
                    !emitted.contains(cell) && cell.deps(v_cnt).iter().all(|d| emitted.contains(d))
                })
                .min_by_key(|cell| {
                    let chunk = if cell.fwd { cell.vs } else { v_cnt - cell.vs };
                    (cell.fwd as usize, cell.mb, chunk)
                });
            if let Some(cell) = best {
                emitted.insert(cell);
                order.push(cell);
                progress = true;
            }
        }
        assert!(progress, "interleaved schedule deadlocked");
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_f_one_b_shape() {
        // S=4, M=4, stage 0: 3 warmup forwards, 1F1B, 3-drain
        let o = one_f_one_b(0, 4, 4);
        assert_eq!(o.len(), 8);
        assert!(o[0..3].iter().all(|c| c.fwd));
        assert_eq!(o[3], Cell::f(0, 3));
        assert_eq!(o[4], Cell::b(0, 0));
        assert!(o[5..].iter().all(|c| !c.fwd));
        // last stage alternates from the start
        let o = one_f_one_b(3, 4, 4);
        assert_eq!(o[0], Cell::f(3, 0));
        assert_eq!(o[1], Cell::b(3, 0));
    }

    #[test]
    fn orders_are_topological_and_complete() {
        for (sched, chunks) in [
            (PipeSchedule::Sequential, 1),
            (PipeSchedule::OneFOneB, 1),
            (PipeSchedule::Interleaved, 2),
        ] {
            let (s_cnt, mb_cnt) = (4, 4);
            let v_cnt = s_cnt * chunks;
            let order = global_order(sched, s_cnt, v_cnt, mb_cnt);
            assert_eq!(order.len(), 2 * v_cnt * mb_cnt, "{sched:?}");
            let mut seen = std::collections::HashSet::new();
            for cell in &order {
                for d in cell.deps(v_cnt) {
                    assert!(seen.contains(&d), "{sched:?}: {cell:?} before its dep {d:?}");
                }
                assert!(seen.insert(*cell), "{sched:?}: duplicate {cell:?}");
            }
        }
    }
}
