//! The threaded node executor: one OS thread per plan worker, shared
//! memory pool, condvar-backed semaphores.
//!
//! This is the "leader + workers" runtime the examples and the end-to-end
//! driver run on: the leader (caller) owns allocation, plan construction,
//! and the PJRT runtime; worker threads execute their op streams
//! concurrently and synchronize exactly through the plan's semaphores —
//! the same protocol the simulator times and the functional executor
//! verifies, now actually racing. PJRT clients are not `Send`, so
//! `RunArtifact` effects are proxied over a channel to a service loop on
//! the leader thread (the paper's host process owning the CUDA context,
//! Appendix E).

use crate::exec::functional::apply_effect;
use crate::mem::MemPool;
use crate::plan::{Op, Plan};
use crate::runtime::{ArtifactRunner, Runtime};
use crate::util::linalg::OnlineSoftmaxState;
use crate::util::error::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Execution statistics of one node run.
#[derive(Clone, Debug, Default)]
pub struct NodeMetrics {
    /// Wall-clock of the threaded run.
    pub wall: Duration,
    /// Ops executed per worker.
    pub ops_per_worker: Vec<usize>,
    /// PJRT artifact invocations (name -> calls).
    pub artifact_calls: HashMap<String, u64>,
}

struct Shared {
    pool: Mutex<MemPool>,
    sems: Mutex<Vec<u64>>,
    cv: Condvar,
    failed: Mutex<Option<String>>,
}

/// A request to the leader-side PJRT service loop.
struct RtReq {
    name: String,
    inputs: Vec<(Vec<f32>, Vec<usize>)>,
    reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
}

/// Channel-backed [`ArtifactRunner`] used inside worker threads.
struct RtProxy {
    tx: mpsc::Sender<RtReq>,
}

impl ArtifactRunner for RtProxy {
    fn run_artifact(&mut self, name: &str, inputs: &[(Vec<f32>, Vec<usize>)]) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(RtReq { name: name.to_string(), inputs: inputs.to_vec(), reply: reply_tx })
            .map_err(|_| anyhow!("runtime service loop gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }
}

/// A multi-device node executing plans with real thread-per-worker
/// parallelism.
pub struct Node {
    pub spec: crate::hw::spec::NodeSpec,
    shared: Arc<Shared>,
    runtime: Option<Runtime>,
}

/// Maximum time a worker may block on one semaphore before the run is
/// declared wedged (protects tests against malformed plans).
const WAIT_TIMEOUT: Duration = Duration::from_secs(30);

impl Node {
    pub fn new(spec: crate::hw::spec::NodeSpec, pool: MemPool) -> Self {
        Node {
            spec,
            shared: Arc::new(Shared {
                pool: Mutex::new(pool),
                sems: Mutex::new(vec![]),
                cv: Condvar::new(),
                failed: Mutex::new(None),
            }),
            runtime: None,
        }
    }

    /// Attach a PJRT runtime (enables `Effect::RunArtifact`).
    pub fn with_runtime(spec: crate::hw::spec::NodeSpec, pool: MemPool, runtime: Runtime) -> Self {
        let mut n = Node::new(spec, pool);
        n.runtime = Some(runtime);
        n
    }

    /// Access the pool (leader-side setup/inspection).
    pub fn pool(&self) -> std::sync::MutexGuard<'_, MemPool> {
        self.shared.pool.lock().unwrap()
    }

    /// Execute a plan with one thread per worker. The leader thread serves
    /// PJRT requests while workers run.
    pub fn run_plan(&mut self, plan: &Plan) -> Result<NodeMetrics> {
        {
            let mut sems = self.shared.sems.lock().unwrap();
            *sems = plan.sems.clone();
            *self.shared.failed.lock().unwrap() = None;
        }
        let start = Instant::now();
        let n_workers = plan.workers.len();
        let mut ops_per_worker = vec![0usize; n_workers];
        let (rt_tx, rt_rx) = mpsc::channel::<RtReq>();
        let has_rt = self.runtime.is_some();
        let runtime = &mut self.runtime;
        let shared = &self.shared;
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = vec![];
            for wp in plan.workers.iter() {
                let shared = Arc::clone(shared);
                let rt_tx = rt_tx.clone();
                handles.push(scope.spawn(move || -> Result<usize> {
                    let mut proxy = has_rt.then(|| RtProxy { tx: rt_tx });
                    run_worker(&shared, wp, &mut proxy)
                }));
            }
            drop(rt_tx); // service loop ends when all workers finish
            if let Some(rt) = runtime.as_mut() {
                for req in rt_rx.iter() {
                    let res = rt.execute(&req.name, &req.inputs);
                    let _ = req.reply.send(res);
                }
            }
            for (wi, h) in handles.into_iter().enumerate() {
                match h.join() {
                    Ok(Ok(n)) => ops_per_worker[wi] = n,
                    Ok(Err(e)) => return Err(e),
                    Err(_) => bail!("worker {wi} panicked"),
                }
            }
            Ok(())
        })?;
        if let Some(msg) = self.shared.failed.lock().unwrap().clone() {
            bail!("node run failed: {msg}");
        }
        let artifact_calls =
            self.runtime.as_ref().map(|rt| rt.call_counts.clone()).unwrap_or_default();
        Ok(NodeMetrics { wall: start.elapsed(), ops_per_worker, artifact_calls })
    }
}

fn run_worker(shared: &Shared, wp: &crate::plan::WorkerPlan, proxy: &mut Option<RtProxy>) -> Result<usize> {
    let mut executed = 0usize;
    let mut local_states: Vec<OnlineSoftmaxState> = vec![];
    for (oi, op) in wp.ops.iter().enumerate() {
        if shared.failed.lock().unwrap().is_some() {
            return Ok(executed);
        }
        match op {
            Op::Compute { effect, .. } | Op::Transfer { effect, .. } => {
                if let Some(e) = effect {
                    let mut pool = shared.pool.lock().unwrap();
                    let res = apply_effect(
                        &mut pool,
                        proxy.as_mut().map(|p| p as &mut dyn ArtifactRunner),
                        &mut local_states,
                        e,
                    );
                    drop(pool);
                    if let Err(err) = res {
                        let msg = format!("{}@op{}: {err:#}", wp.label, oi);
                        *shared.failed.lock().unwrap() = Some(msg.clone());
                        shared.cv.notify_all();
                        return Err(anyhow!(msg));
                    }
                }
                if let Op::Transfer { done_sem: Some(s), .. } = op {
                    let mut sems = shared.sems.lock().unwrap();
                    sems[s.0] += 1;
                    shared.cv.notify_all();
                }
                executed += 1;
            }
            Op::Wait { sem, value } => {
                let mut sems = shared.sems.lock().unwrap();
                let deadline = Instant::now() + WAIT_TIMEOUT;
                while sems[sem.0] < *value {
                    if shared.failed.lock().unwrap().is_some() {
                        return Ok(executed);
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        let msg = format!("{}: wedged waiting sem{} >= {value}", wp.label, sem.0);
                        *shared.failed.lock().unwrap() = Some(msg.clone());
                        shared.cv.notify_all();
                        return Err(anyhow!(msg));
                    }
                    let (guard, _) = shared.cv.wait_timeout(sems, deadline - now).unwrap();
                    sems = guard;
                }
                executed += 1;
            }
            Op::Signal { sem, value, .. } => {
                let mut sems = shared.sems.lock().unwrap();
                sems[sem.0] += value;
                shared.cv.notify_all();
                executed += 1;
            }
            Op::Delay { .. } => {
                executed += 1;
            }
        }
    }
    Ok(executed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::spec::NodeSpec;
    use crate::hw::DeviceId;
    use crate::mem::tile::Shape4;
    use crate::plan::{Effect, MatView, Role, SyncScope};
    use crate::util::seeded_vec;

    #[test]
    fn threaded_run_matches_functional() {
        // NCCL ring all-reduce under real thread interleaving must still
        // produce the elementwise sum.
        let n = 4;
        let (rows, cols) = (n * 2, 5);
        let mut pool = MemPool::new();
        let mut bufs = vec![];
        let mut inits = vec![];
        for d in 0..n {
            let data = seeded_vec(d as u64 + 3, rows * cols);
            inits.push(data.clone());
            bufs.push(pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), data));
        }
        let node_spec = NodeSpec::test_node(n);
        let ctx = crate::comm::nccl::RingCtx {
            node: &node_spec,
            model: crate::comm::nccl::NcclModel::default(),
            replicas: bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect(),
        };
        let mut plan = Plan::new();
        crate::comm::nccl::ring_all_reduce(&mut plan, &ctx);
        let mut node = Node::new(node_spec, pool);
        let metrics = node.run_plan(&plan).unwrap();
        assert_eq!(metrics.ops_per_worker.len(), plan.workers.len());
        let mut want = vec![0.0f32; rows * cols];
        for v in &inits {
            for (w, x) in want.iter_mut().zip(v) {
                *w += x;
            }
        }
        let pool = node.pool();
        for &b in &bufs {
            crate::util::assert_allclose(&pool.get(b).data, &want, 1e-5, 1e-6);
        }
    }

    #[test]
    fn threaded_signal_wait_ordering() {
        let mut pool = MemPool::new();
        let a = pool.alloc(DeviceId(0), Shape4::mat(1, 1));
        let b = pool.alloc(DeviceId(1), Shape4::mat(1, 1));
        pool.get_mut(a).data[0] = 7.0;
        let mut plan = Plan::new();
        let s = plan.add_sem(0);
        let w0 = plan.add_worker(DeviceId(0), Role::ComputeSm, "producer");
        let w1 = plan.add_worker(DeviceId(1), Role::ComputeSm, "consumer");
        plan.push(w0, Op::Signal { sem: s, value: 1, scope: SyncScope::InterDevice });
        plan.push(w1, Op::Wait { sem: s, value: 1 });
        plan.push(
            w1,
            Op::Compute {
                dur: 0.0,
                label: "copy",
                effect: Some(Effect::CopyMat {
                    src: MatView::full2d(a, 1, 1),
                    dst: MatView::full2d(b, 1, 1),
                    reduce: None,
                }),
            },
        );
        let mut node = Node::new(NodeSpec::test_node(2), pool);
        node.run_plan(&plan).unwrap();
        assert_eq!(node.pool().get(b).data[0], 7.0);
    }

    #[test]
    fn artifact_without_runtime_errors() {
        let mut pool = MemPool::new();
        let a = pool.alloc(DeviceId(0), Shape4::mat(2, 2));
        let mut plan = Plan::new();
        let w = plan.add_worker(DeviceId(0), Role::ComputeSm, "bad");
        plan.push(
            w,
            Op::Compute {
                dur: 0.0,
                label: "bad_artifact",
                effect: Some(Effect::RunArtifact {
                    name: "missing".into(),
                    inputs: vec![MatView::full2d(a, 2, 2)],
                    outputs: vec![MatView::full2d(a, 2, 2)],
                }),
            },
        );
        let mut node = Node::new(NodeSpec::test_node(1), pool);
        let err = match node.run_plan(&plan) {
            Ok(_) => panic!("should fail"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("runtime") || err.to_string().contains("artifact"), "{err}");
    }
}
