//! Leader/worker coordinator: drives multi-device functional runs with one
//! OS thread per simulated device (the torchrun-style multi-process model
//! of Appendix E, collapsed into threads sharing a memory pool the way
//! CUDA IPC shares device memory).

pub mod node;

pub use node::{Node, NodeMetrics};
