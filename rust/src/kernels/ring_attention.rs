//! Fused Ring Attention (Figure 10; §3.1.3 "remote cache reuse").
//!
//! Q, K, V are sequence-sharded. Over `N` steps each device computes
//! blockwise (online-softmax) attention of its local Q against the
//! currently-resident KV shard while its communicator SMs bulk-transfer
//! that shard to the ring neighbour's HBM. Staging KV through *local* HBM
//! (instead of letting every thread block read peer memory) is the
//! paper's remote-cache-reuse argument: peer reads are never cached on
//! the requester, so per-block remote loads would re-cross NVLink for
//! every Q block.
//!
//! PK fuses the whole ring into one kernel: one launch, one-way signals
//! between steps. The xDiT baseline (separate NCCL P2P + FlashAttention
//! launches per step) is in [`crate::baselines::xdit`].

use crate::hw::cluster::ClusterSpec;
use crate::hw::spec::NodeSpec;
use crate::hw::DeviceId;
use crate::mem::tile::Shape4;
use crate::mem::{BufId, MemPool, ELEM_BYTES};
use crate::kernels::{BuildCtx, KernelBuild};
use crate::pk::rail::RailHealth;
use crate::pk::template::{Lcsc, LcscOpts};
use crate::plan::{Effect, MatView, Op, Plan, Route, SyncScope, TransferSpec};
use crate::xfer::Mechanism;

/// Ring-attention configuration. `s` is the **total** sequence length,
/// partitioned evenly across devices (the paper's Figure 10 x-axis).
#[derive(Clone, Debug)]
pub struct RingAttnCfg {
    pub node: NodeSpec,
    pub b: usize,
    pub h: usize,
    pub s: usize,
    pub d: usize,
    pub opts: LcscOpts,
    /// Attention kernels sustain a lower fraction of peak than GEMM
    /// (softmax + rescaling on CUDA cores).
    pub flash_util: f64,
}

impl RingAttnCfg {
    /// Paper configuration: B=16, H=16, D=128.
    pub fn paper(node: NodeSpec, s: usize) -> Self {
        RingAttnCfg { node, b: 16, h: 16, s, d: 128, opts: LcscOpts::default(), flash_util: 0.75 }
    }

    pub fn s_local(&self) -> usize {
        assert_eq!(self.s % self.node.num_devices, 0);
        self.s / self.node.num_devices
    }

    /// Per-device FLOPs of one ring step (QK^T + PV over one KV shard).
    pub fn step_flops(&self) -> f64 {
        4.0 * (self.b * self.h) as f64 * (self.s_local() as f64).powi(2) * self.d as f64
    }

    /// KV shard bytes (K and V).
    pub fn kv_shard_bytes(&self) -> f64 {
        2.0 * (self.b * self.h * self.s_local() * self.d) as f64 * ELEM_BYTES as f64
    }

    /// Total attention FLOPs per device (what Figure 10's TFLOP/s divides).
    pub fn total_flops(&self) -> f64 {
        self.step_flops() * self.node.num_devices as f64
    }
}

/// Functional buffers. K/V are full-sequence buffers per device whose
/// shard slots fill as the ring rotates (the local-HBM staging).
#[derive(Clone, Debug)]
pub struct RingAttnBufs {
    /// `q[d]`: (B, H, S_local, D) local queries.
    pub q: Vec<BufId>,
    /// `k[d]`, `v[d]`: (B, H, S, D); shard `d` resident initially.
    pub k: Vec<BufId>,
    pub v: Vec<BufId>,
    /// `o[d]`: (B, H, S_local, D) outputs.
    pub o: Vec<BufId>,
}

impl RingAttnBufs {
    pub fn alloc(pool: &mut MemPool, cfg: &RingAttnCfg) -> Self {
        Self::alloc_n(pool, cfg.node.num_devices, cfg.b, cfg.h, cfg.s, cfg.d)
    }

    /// Buffers for a multi-node ring (one KV ring across the cluster).
    pub fn alloc_cluster(pool: &mut MemPool, cfg: &ClusterRingAttnCfg) -> Self {
        Self::alloc_n(pool, cfg.cluster.total_devices(), cfg.b, cfg.h, cfg.s, cfg.d)
    }

    fn alloc_n(pool: &mut MemPool, n: usize, b: usize, h: usize, s: usize, d: usize) -> Self {
        assert_eq!(s % n, 0, "sequence {s} must divide across {n} devices");
        let sl = s / n;
        let q_shape = Shape4 { b, d: h, r: sl, c: d };
        let kv_shape = Shape4 { b, d: h, r: s, c: d };
        RingAttnBufs {
            q: (0..n).map(|dev| pool.alloc(DeviceId(dev), q_shape)).collect(),
            k: (0..n).map(|dev| pool.alloc(DeviceId(dev), kv_shape)).collect(),
            v: (0..n).map(|dev| pool.alloc(DeviceId(dev), kv_shape)).collect(),
            o: (0..n).map(|dev| pool.alloc(DeviceId(dev), q_shape)).collect(),
        }
    }
}

/// Multi-node ring-attention configuration: one KV ring over **all** GPUs
/// of the cluster. The hops inside a node ride NVLink; the hop from the
/// last GPU of node `k` to the first GPU of node `k+1` crosses the NIC —
/// with the ring laid out node-major only `K` of the `N` hops pay the NIC,
/// and they overlap with the other devices' compute exactly like the
/// NVLink hops do.
#[derive(Clone, Debug)]
pub struct ClusterRingAttnCfg {
    pub cluster: ClusterSpec,
    pub b: usize,
    pub h: usize,
    pub s: usize,
    pub d: usize,
    pub opts: LcscOpts,
    pub flash_util: f64,
    /// Target coalesced RDMA write size for the node-boundary KV hops
    /// (normalized cfg knob; [`crate::pk::rail::RDMA_CHUNK_AUTO`] resolves
    /// through [`BuildCtx::resolve_chunk`] against the KV shard size).
    pub rdma_chunk: f64,
}

impl ClusterRingAttnCfg {
    /// Paper configuration (B=16, H=16, D=128) over a cluster.
    pub fn paper(cluster: ClusterSpec, s: usize) -> Self {
        ClusterRingAttnCfg {
            cluster,
            b: 16,
            h: 16,
            s,
            d: 128,
            opts: LcscOpts::default(),
            flash_util: 0.75,
            rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO,
        }
    }

    /// Builder-style chunk override (the shared normalized-cfg method; see
    /// [`crate::kernels::GemmKernelCfg::with_rdma_chunk`]).
    pub fn with_rdma_chunk(mut self, rdma_chunk: f64) -> Self {
        self.rdma_chunk = rdma_chunk;
        self
    }

    pub fn s_local(&self) -> usize {
        assert_eq!(self.s % self.cluster.total_devices(), 0);
        self.s / self.cluster.total_devices()
    }

    pub fn step_flops(&self) -> f64 {
        4.0 * (self.b * self.h) as f64 * (self.s_local() as f64).powi(2) * self.d as f64
    }

    pub fn kv_shard_bytes(&self) -> f64 {
        2.0 * (self.b * self.h * self.s_local() * self.d) as f64 * ELEM_BYTES as f64
    }

    pub fn total_flops(&self) -> f64 {
        self.step_flops() * self.cluster.total_devices() as f64
    }
}

/// Build the fused PK ring-attention kernel (single node). Delegates to
/// [`build_cluster`] over a one-node cluster — the same code path, so the
/// cluster refactor cannot drift from the single-node numbers.
pub fn build(cfg: &RingAttnCfg, bufs: Option<&RingAttnBufs>) -> Plan {
    let ccfg = ClusterRingAttnCfg {
        cluster: ClusterSpec::single(cfg.node.clone()),
        b: cfg.b,
        h: cfg.h,
        s: cfg.s,
        d: cfg.d,
        opts: cfg.opts,
        flash_util: cfg.flash_util,
        rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO,
    };
    build_cluster(&ccfg, bufs)
}

/// Build the fused ring-attention kernel over a cluster: one node-major KV
/// ring across all GPUs; node-boundary hops ride the NIC.
pub fn build_cluster(cfg: &ClusterRingAttnCfg, bufs: Option<&RingAttnBufs>) -> Plan {
    let health = RailHealth::all_healthy(&cfg.cluster);
    RingAttn { cfg: cfg.clone() }.build(&BuildCtx::new(&cfg.cluster, &health), bufs)
}

/// [`KernelBuild`] spec for the cluster ring-attention kernel. The legacy
/// [`build_cluster`] free function is a one-line wrapper over this entry.
/// The ring carries its own cluster in the cfg (the node-major ring order
/// *is* the schedule); the ctx cluster must agree in shape, and the KV
/// ring has no degraded-rail reroute, so the ctx health mask must be
/// all-healthy.
#[derive(Clone, Debug)]
pub struct RingAttn {
    pub cfg: ClusterRingAttnCfg,
}

impl KernelBuild for RingAttn {
    type Bufs<'b> = &'b RingAttnBufs;

    fn build(&self, ctx: &BuildCtx, bufs: Option<&RingAttnBufs>) -> Plan {
        assert!(
            !ctx.health.any_failed(),
            "the KV ring has no degraded-rail reroute; pass a healthy mask"
        );
        assert_eq!(
            self.cfg.cluster.node.num_devices, ctx.cluster.node.num_devices,
            "cfg.cluster must match ctx.cluster"
        );
        assert_eq!(
            self.cfg.cluster.num_nodes, ctx.cluster.num_nodes,
            "cfg.cluster must match ctx.cluster"
        );
        let mut cfg = self.cfg.clone();
        cfg.rdma_chunk = ctx.resolve_chunk(cfg.rdma_chunk, cfg.kv_shard_bytes());
        cluster_impl(&cfg, bufs)
    }
}

fn cluster_impl(cfg: &ClusterRingAttnCfg, bufs: Option<&RingAttnBufs>) -> Plan {
    let n = cfg.cluster.total_devices();
    let sl = cfg.s_local();
    let mut opts = cfg.opts;
    if opts.num_comm_sms == 0 {
        // auto-partition (the template's tuning): just enough communicator
        // SMs that the KV forward keeps up with the attention step, capped
        // at the TMA saturation point — at long sequences compute
        // dominates and 2 SMs suffice, at short sequences comm is the
        // bottleneck and we saturate the link.
        let g = &cfg.cluster.node.gpu;
        let comp_est = cfg.step_flops() / (g.tc_flops_for_sms(g.num_sms - 8) * cfg.flash_util);
        let required_rate = cfg.kv_shard_bytes() / (0.9 * comp_est);
        let tma_full = g.nvlink_bw * g.tma_peak_frac;
        let sms = (g.tma_sat_sms * required_rate / tma_full).ceil() as u32;
        opts.num_comm_sms = sms.clamp(2, 16);
    }
    let mut l = Lcsc::new_cluster(&cfg.cluster, opts);
    // a single communicator worker drives the whole partition's SMs (the
    // KV forward is one bulk transfer, not split across workers)
    let comm_sms = opts.num_comm_sms as f64;
    // attention step time on the compute partition
    let comp_flops = cfg.cluster.node.gpu.tc_flops_for_sms(l.compute_sms()) * cfg.flash_util;
    // tasks: (b, h) pairs, split across compute workers; duration scales
    // by the worker's share.
    let bh = cfg.b * cfg.h;

    // arrived[dev][step]: shard for step `step+1` landed on `dev`.
    let arrived: Vec<Vec<_>> = (0..n).map(|_| (0..n).map(|_| l.plan.add_sem(0)).collect()).collect();
    // consumed[dev][step]: device finished computing with the shard it
    // forwards at `step` (send can't outpace compute reads — in practice
    // double-buffering decouples these; sending the *resident* shard is
    // safe immediately, so the communicator only waits for arrival).
    for dev in 0..n {
        // --- communicator: forward the rotating shard each step.
        let cw = l.comm[dev][0];
        for step in 0..n - 1 {
            let shard = (dev + n - step) % n; // shard resident at this step
            if step > 0 {
                l.plan.push(cw, Op::Wait { sem: arrived[dev][step - 1], value: 1 });
            }
            let next = (dev + 1) % n;
            // functional: copy every (b, h) plane of K and V
            if let Some(b) = bufs {
                for bi in 0..cfg.b {
                    for hi in 0..cfg.h {
                        for (src_buf, dst_buf) in [(b.k[dev], b.k[next]), (b.v[dev], b.v[next])] {
                            l.plan.push(
                                cw,
                                Op::Compute {
                                    dur: 0.0,
                                    label: "kv_fwd_copy",
                                    effect: Some(Effect::CopyMat {
                                        src: MatView { buf: src_buf, b: bi, d: hi, row0: shard * sl, col0: 0, rows: sl, cols: cfg.d },
                                        dst: MatView { buf: dst_buf, b: bi, d: hi, row0: shard * sl, col0: 0, rows: sl, cols: cfg.d },
                                        reduce: None,
                                    }),
                                },
                            );
                        }
                    }
                }
            }
            // the timed bulk transfer (one flow for the whole shard); the
            // node-boundary hop crosses the NIC instead of NVLink
            let cross = !cfg.cluster.same_node(DeviceId(dev), DeviceId(next));
            l.plan.push(
                cw,
                Op::Transfer {
                    spec: TransferSpec {
                        mech: Mechanism::Tma,
                        route: if cross {
                            Route::Rdma { src: DeviceId(dev), dst: DeviceId(next) }
                        } else {
                            Route::P2p { src: DeviceId(dev), dst: DeviceId(next) }
                        },
                        bytes: cfg.kv_shard_bytes(),
                        // NIC hops coalesce rows up to the chunk target;
                        // NVLink hops move at TMA row granularity
                        msg_bytes: if cross {
                            cfg.rdma_chunk.min(cfg.kv_shard_bytes())
                        } else {
                            (sl * cfg.d) as f64 * ELEM_BYTES as f64
                        },
                        n_sms: comm_sms,
                    },
                    blocking: true,
                    done_sem: Some(arrived[next][step]),
                    done_scope: if cross { SyncScope::InterNode } else { SyncScope::InterDevice },
                    label: "kv_ring_fwd",
                    effect: None,
                },
            );
        }
        // --- compute: blockwise attention over the resident shard.
        let tasks = l.split_tasks(dev, bh);
        for (w, items) in &tasks {
            // per-worker state per (b,h) it owns
            let states: Vec<_> = items.iter().map(|_| l.plan.add_state()).collect();
            // this worker's share of the step's FLOPs, at this worker's
            // share of the compute partition's throughput
            let per_worker = items.len().max(1) as f64 / bh as f64;
            let worker_flops = comp_flops / l.opts.workers_per_device as f64;
            let dur = cfg.step_flops() * per_worker / worker_flops;
            for step in 0..n {
                let shard = (dev + n - step) % n;
                if step > 0 {
                    l.plan.push(*w, Op::Wait { sem: arrived[dev][step - 1], value: 1 });
                }
                for (ti, &bh_idx) in items.iter().enumerate() {
                    let (bi, hi) = (bh_idx / cfg.h, bh_idx % cfg.h);
                    let effect = bufs.map(|b| Effect::AttnBlock {
                        q: MatView { buf: b.q[dev], b: bi, d: hi, row0: 0, col0: 0, rows: sl, cols: cfg.d },
                        k: MatView { buf: b.k[dev], b: bi, d: hi, row0: shard * sl, col0: 0, rows: sl, cols: cfg.d },
                        v: MatView { buf: b.v[dev], b: bi, d: hi, row0: shard * sl, col0: 0, rows: sl, cols: cfg.d },
                        state: states[ti],
                    });
                    let d_each = dur / items.len().max(1) as f64;
                    l.plan.push(*w, Op::Compute { dur: d_each, label: "attn_block", effect });
                }
            }
            for (ti, &bh_idx) in items.iter().enumerate() {
                let (bi, hi) = (bh_idx / cfg.h, bh_idx % cfg.h);
                let effect = bufs.map(|b| Effect::AttnFinalize {
                    state: states[ti],
                    out: MatView { buf: b.o[dev], b: bi, d: hi, row0: 0, col0: 0, rows: sl, cols: cfg.d },
                });
                l.plan.push(*w, Op::Compute { dur: 0.0, label: "attn_finalize", effect });
            }
        }
    }
    l.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::TimedExec;
    use crate::util::prop::run_functional;
    use crate::util::{assert_allclose, linalg, seeded_vec};

    #[test]
    fn functional_ring_attention_matches_full_attention() {
        let n = 4;
        let node = NodeSpec::test_node(n);
        let cfg = RingAttnCfg {
            node,
            b: 2,
            h: 2,
            s: 32,
            d: 8,
            opts: LcscOpts { num_comm_sms: 4, workers_per_device: 2, comm_workers_per_device: 1, pipeline_stages: 2 },
            flash_util: 0.75,
        };
        let sl = cfg.s_local();
        let mut pool = MemPool::new();
        let bufs = RingAttnBufs::alloc(&mut pool, &cfg);
        // fill Q everywhere; K/V shards on their home devices only
        let mut k_global = vec![vec![vec![0.0f32; 0]; cfg.h]; cfg.b];
        let mut v_global = vec![vec![vec![0.0f32; 0]; cfg.h]; cfg.b];
        for bi in 0..cfg.b {
            for hi in 0..cfg.h {
                k_global[bi][hi] = seeded_vec((bi * 7 + hi) as u64 + 1, cfg.s * cfg.d);
                v_global[bi][hi] = seeded_vec((bi * 7 + hi) as u64 + 100, cfg.s * cfg.d);
            }
        }
        for dev in 0..n {
            for bi in 0..cfg.b {
                for hi in 0..cfg.h {
                    let q = seeded_vec((dev * 31 + bi * 7 + hi) as u64 + 500, sl * cfg.d);
                    let qb = pool.get_mut(bufs.q[dev]);
                    let off = qb.shape.offset(bi, hi, 0, 0);
                    qb.data[off..off + sl * cfg.d].copy_from_slice(&q);
                    // home shard of K/V
                    let kb = pool.get_mut(bufs.k[dev]);
                    let koff = kb.shape.offset(bi, hi, dev * sl, 0);
                    kb.data[koff..koff + sl * cfg.d]
                        .copy_from_slice(&k_global[bi][hi][dev * sl * cfg.d..(dev + 1) * sl * cfg.d]);
                    let vb = pool.get_mut(bufs.v[dev]);
                    let voff = vb.shape.offset(bi, hi, dev * sl, 0);
                    vb.data[voff..voff + sl * cfg.d]
                        .copy_from_slice(&v_global[bi][hi][dev * sl * cfg.d..(dev + 1) * sl * cfg.d]);
                }
            }
        }
        let plan = build(&cfg, Some(&bufs));
        run_functional(&mut pool, &plan);
        // each device's output == attention(Q_local, K_full, V_full)
        for dev in 0..n {
            for bi in 0..cfg.b {
                for hi in 0..cfg.h {
                    let qb = pool.get(bufs.q[dev]);
                    let off = qb.shape.offset(bi, hi, 0, 0);
                    let q = &qb.data[off..off + sl * cfg.d];
                    let want = linalg::attention_ref(q, &k_global[bi][hi], &v_global[bi][hi], sl, cfg.s, cfg.d);
                    let ob = pool.get(bufs.o[dev]);
                    let ooff = ob.shape.offset(bi, hi, 0, 0);
                    assert_allclose(&ob.data[ooff..ooff + sl * cfg.d], &want, 1e-4, 1e-5);
                }
            }
        }
    }

    #[test]
    fn functional_cluster_ring_matches_full_attention() {
        // 2 nodes x 2 GPUs: the KV ring crosses the NIC twice per rotation
        // and the numerics must still equal full attention.
        let cluster = ClusterSpec::test_cluster(2, 2);
        let n = cluster.total_devices();
        let cfg = ClusterRingAttnCfg {
            cluster,
            b: 2,
            h: 2,
            s: 32,
            d: 8,
            opts: LcscOpts { num_comm_sms: 4, workers_per_device: 2, comm_workers_per_device: 1, pipeline_stages: 2 },
            flash_util: 0.75,
            rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO,
        };
        let sl = cfg.s_local();
        let mut pool = MemPool::new();
        let bufs = RingAttnBufs::alloc_cluster(&mut pool, &cfg);
        let mut k_global = vec![vec![vec![0.0f32; 0]; cfg.h]; cfg.b];
        let mut v_global = vec![vec![vec![0.0f32; 0]; cfg.h]; cfg.b];
        for bi in 0..cfg.b {
            for hi in 0..cfg.h {
                k_global[bi][hi] = seeded_vec((bi * 7 + hi) as u64 + 1, cfg.s * cfg.d);
                v_global[bi][hi] = seeded_vec((bi * 7 + hi) as u64 + 100, cfg.s * cfg.d);
            }
        }
        for dev in 0..n {
            for bi in 0..cfg.b {
                for hi in 0..cfg.h {
                    let q = seeded_vec((dev * 31 + bi * 7 + hi) as u64 + 500, sl * cfg.d);
                    let qb = pool.get_mut(bufs.q[dev]);
                    let off = qb.shape.offset(bi, hi, 0, 0);
                    qb.data[off..off + sl * cfg.d].copy_from_slice(&q);
                    let kb = pool.get_mut(bufs.k[dev]);
                    let koff = kb.shape.offset(bi, hi, dev * sl, 0);
                    kb.data[koff..koff + sl * cfg.d]
                        .copy_from_slice(&k_global[bi][hi][dev * sl * cfg.d..(dev + 1) * sl * cfg.d]);
                    let vb = pool.get_mut(bufs.v[dev]);
                    let voff = vb.shape.offset(bi, hi, dev * sl, 0);
                    vb.data[voff..voff + sl * cfg.d]
                        .copy_from_slice(&v_global[bi][hi][dev * sl * cfg.d..(dev + 1) * sl * cfg.d]);
                }
            }
        }
        let plan = build_cluster(&cfg, Some(&bufs));
        run_functional(&mut pool, &plan);
        for dev in 0..n {
            for bi in 0..cfg.b {
                for hi in 0..cfg.h {
                    let qb = pool.get(bufs.q[dev]);
                    let off = qb.shape.offset(bi, hi, 0, 0);
                    let q = &qb.data[off..off + sl * cfg.d];
                    let want = linalg::attention_ref(q, &k_global[bi][hi], &v_global[bi][hi], sl, cfg.s, cfg.d);
                    let ob = pool.get(bufs.o[dev]);
                    let ooff = ob.shape.offset(bi, hi, 0, 0);
                    assert_allclose(&ob.data[ooff..ooff + sl * cfg.d], &want, 1e-4, 1e-5);
                }
            }
        }
    }

    #[test]
    fn timed_cluster_ring_pays_the_nic() {
        // the same total sequence over 2 nodes is slower per step than one
        // node would be, because K of the hops are NIC-bound; but the ring
        // must still complete and charge the NICs.
        use crate::hw::topology::Port;
        let cluster = ClusterSpec::hgx_h100_pod(2);
        let cfg = ClusterRingAttnCfg::paper(cluster.clone(), 98304);
        let plan = build_cluster(&cfg, None);
        let r = crate::exec::TimedExec::on_cluster(cluster.clone()).run(&plan);
        assert!(r.total_time.is_finite() && r.total_time > 0.0);
        // boundary devices forwarded every rotation step over their NIC
        let n = cluster.total_devices();
        let boundary = DeviceId(cluster.devices_per_node() - 1); // last GPU of node 0
        let nic = r.port_bytes[&Port::NicEgress(boundary)];
        let want = cfg.kv_shard_bytes() * (n - 1) as f64;
        assert!((nic - want).abs() / want < 1e-6, "{nic} vs {want}");
        // non-boundary devices never touch their NIC
        assert!(r.port_bytes.get(&Port::NicEgress(DeviceId(0))).is_none());
    }

    #[test]
    fn timed_large_s_is_compute_bound() {
        let node = NodeSpec::hgx_h100();
        let cfg = RingAttnCfg::paper(node.clone(), 98304); // 12288 * 8
        let plan = build(&cfg, None);
        let r = TimedExec::new(node.clone()).run(&plan);
        let pure_comp = cfg.total_flops() / (node.gpu.tc_flops_for_sms(132 - 12) * cfg.flash_util);
        let ratio = (r.total_time - pure_comp) / r.total_time;
        assert!(ratio < 0.15, "long-S non-overlapped fraction ≤ ~9% (paper): {ratio}");
    }

    #[test]
    fn timed_small_s_is_comm_dominated() {
        let node = NodeSpec::hgx_h100();
        let small = RingAttnCfg::paper(node.clone(), 6144);
        let r = TimedExec::new(node.clone()).run(&build(&small, None));
        let pure_comp = small.total_flops() / (node.gpu.tc_flops_for_sms(120) * small.flash_util);
        assert!(r.total_time > 1.5 * pure_comp, "short-S should be comm/sync bound");
    }
}
