//! DeepSpeed-Ulysses attention layer (Figure 11; all-to-all of Figure 17).
//!
//! Sequence-sharded activations are exchanged head-sharded around
//! self-attention: an all-to-all before (gather sequence, scatter heads)
//! and after (the inverse). The bottleneck is the *fine-grained*
//! all-to-all along inner dimensions: NCCL needs contiguous partitions, so
//! the baseline reshapes before and after each exchange (Appendix B);
//! PK's tile-granular all-to-all runs directly on the `(B, S, H, D)`
//! layout. The YunChang baseline is in [`crate::baselines::yunchang`].
//!
//! [`build`] is the single-node layer. [`build_cluster`] extends it across
//! a multi-node [`ClusterSpec`] for sequence-parallel serving at cluster
//! scale: sequence and heads shard over **all** `K·P` GPUs, and every
//! exchange runs through the **two-level**
//! [`crate::kernels::collectives::pk_all_to_all_4d_cluster`] — intra-node
//! NVLink tiles plus one [`crate::pk::rail`]-coalesced RDMA flow per
//! (device, remote node) pair with rail-peer forwarders. (Until the
//! two-level all-to-all landed, that entry point *failed fast* on several
//! nodes, because a flat all-to-all would silently rate cross-node tiles
//! at NVLink speed and corrupt any scale-out sweep; the fail-fast is gone
//! and the `rx1` exhibit sweeps Ulysses over 1→4 nodes.) A one-node
//! cluster delegates to [`build`] bit-identically.

use super::collectives::{pk_all_to_all_4d, pk_all_to_all_4d_cluster, A2aCfg};
use super::{BuildCtx, KernelBuild};
use crate::hw::cluster::ClusterSpec;
use crate::hw::spec::NodeSpec;
use crate::hw::DeviceId;
use crate::mem::tile::Shape4;
use crate::mem::{BufId, MemPool, ELEM_BYTES};
use crate::plan::{Effect, MatView, Op, Plan, Role, SyncScope};

/// Ulysses configuration; `s` is the total sequence length (Figure 11
/// x-axis), `h` the total head count (head-sharded inside attention).
#[derive(Clone, Debug)]
pub struct UlyssesCfg {
    pub node: NodeSpec,
    pub b: usize,
    pub h: usize,
    pub s: usize,
    pub d: usize,
    pub flash_util: f64,
    /// Target coalesced-RDMA write size for the cluster exchanges
    /// (shared cfg idiom: shape fields first, transport knob last).
    /// [`crate::pk::rail::RDMA_CHUNK_AUTO`] resolves in
    /// [`BuildCtx::resolve_chunk`] / downstream of the all-to-all.
    pub rdma_chunk: f64,
}

impl UlyssesCfg {
    /// Paper configuration: B=16, H=128, D=128.
    pub fn paper(node: NodeSpec, s: usize) -> Self {
        UlyssesCfg {
            node,
            b: 16,
            h: 128,
            s,
            d: 128,
            flash_util: 0.75,
            rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO,
        }
    }

    /// Builder-style override of the RDMA chunk knob.
    pub fn with_rdma_chunk(mut self, rdma_chunk: f64) -> Self {
        self.rdma_chunk = rdma_chunk;
        self
    }

    pub fn s_local(&self) -> usize {
        self.s_local_of(self.node.num_devices)
    }

    pub fn h_local(&self) -> usize {
        self.h_local_of(self.node.num_devices)
    }

    /// Sequence shard when the layer spreads over `n_dev` devices (the
    /// cluster path shards over all `K·P` GPUs).
    pub fn s_local_of(&self, n_dev: usize) -> usize {
        assert_eq!(self.s % n_dev, 0);
        self.s / n_dev
    }

    /// Head shard over `n_dev` devices.
    pub fn h_local_of(&self, n_dev: usize) -> usize {
        assert_eq!(self.h % n_dev, 0);
        self.h / n_dev
    }

    /// Attention FLOPs per device: local heads, full sequence.
    pub fn attn_flops(&self) -> f64 {
        self.attn_flops_of(self.node.num_devices)
    }

    /// Attention FLOPs per device when heads spread over `n_dev`.
    pub fn attn_flops_of(&self, n_dev: usize) -> f64 {
        4.0 * (self.b * self.h_local_of(n_dev)) as f64 * (self.s as f64).powi(2) * self.d as f64
    }

    /// Bytes each device exchanges in one all-to-all direction.
    pub fn a2a_bytes(&self) -> f64 {
        (self.b * self.s_local() * self.h * self.d) as f64 * ELEM_BYTES as f64
    }
}

/// Functional buffers for the full layer.
pub struct UlyssesBufs {
    /// Sequence-sharded inputs `(B, S_local, H, D)` per device.
    pub q_in: Vec<BufId>,
    pub k_in: Vec<BufId>,
    pub v_in: Vec<BufId>,
    /// Head-sharded exchange targets `(B, S, H_local, D)`.
    pub q_h: Vec<BufId>,
    pub k_h: Vec<BufId>,
    pub v_h: Vec<BufId>,
    /// Transposed attention scratch `(B, H_local, S, D)`.
    pub q_t: Vec<BufId>,
    pub k_t: Vec<BufId>,
    pub v_t: Vec<BufId>,
    pub o_t: Vec<BufId>,
    /// Head-sharded output, then scattered back sequence-sharded.
    pub o_h: Vec<BufId>,
    pub o_out: Vec<BufId>,
}

impl UlyssesBufs {
    pub fn alloc(pool: &mut MemPool, cfg: &UlyssesCfg) -> Self {
        let n = cfg.node.num_devices;
        let seq_sharded = Shape4 { b: cfg.b, d: cfg.s_local(), r: cfg.h, c: cfg.d };
        let head_sharded = Shape4 { b: cfg.b, d: cfg.s, r: cfg.h_local(), c: cfg.d };
        let transposed = Shape4 { b: cfg.b, d: cfg.h_local(), r: cfg.s, c: cfg.d };
        let mk = |pool: &mut MemPool, shape| (0..n).map(|d| pool.alloc(DeviceId(d), shape)).collect::<Vec<_>>();
        UlyssesBufs {
            q_in: mk(pool, seq_sharded),
            k_in: mk(pool, seq_sharded),
            v_in: mk(pool, seq_sharded),
            q_h: mk(pool, head_sharded),
            k_h: mk(pool, head_sharded),
            v_h: mk(pool, head_sharded),
            q_t: mk(pool, transposed),
            k_t: mk(pool, transposed),
            v_t: mk(pool, transposed),
            o_t: mk(pool, transposed),
            o_h: mk(pool, head_sharded),
            o_out: mk(pool, seq_sharded),
        }
    }
}

/// Build the PK Ulysses attention layer: a2a(q,k,v) → head-sharded
/// attention → a2a(o).
pub fn build(cfg: &UlyssesCfg, bufs: Option<&UlyssesBufs>) -> Plan {
    let n = cfg.node.num_devices;
    let mut plan = Plan::new();
    plan.launch_overhead = cfg.node.gpu.kernel_launch;
    let a2a = A2aCfg { b_dim: cfg.b, s_local: cfg.s_local(), h: cfg.h, d_head: cfg.d };
    // ---- forward all-to-all for q, k, v
    for tensor in 0..3 {
        let (srcs, dsts) = match bufs {
            Some(b) => (
                Some(match tensor {
                    0 => &b.q_in[..],
                    1 => &b.k_in[..],
                    _ => &b.v_in[..],
                }),
                Some(match tensor {
                    0 => &b.q_h[..],
                    1 => &b.k_h[..],
                    _ => &b.v_h[..],
                }),
            ),
            None => (None, None),
        };
        pk_all_to_all_4d(&mut plan, &cfg.node, &a2a, srcs, dsts, 16.0);
    }
    // readiness barrier: attention waits for all three exchanges.
    let ready: Vec<_> = (0..n).map(|_| plan.add_sem(0)).collect();
    for wi in 0..plan.workers.len() {
        if plan.workers[wi].label.starts_with("pk_a2a") {
            for r in ready.iter().take(n) {
                plan.push(wi, Op::Signal { sem: *r, value: 1, scope: SyncScope::InterDevice });
            }
        }
    }
    let comp_flops = cfg.node.gpu.tc_flops_for_sms(cfg.node.gpu.num_sms) * cfg.flash_util;
    let out_ready: Vec<_> = (0..n).map(|_| plan.add_sem(0)).collect();
    for dev in 0..n {
        let w = plan.add_worker(DeviceId(dev), Role::ComputeSm, format!("ulysses_attn/d{dev}"));
        plan.push(w, Op::Wait { sem: ready[dev], value: 3 * n as u64 });
        match bufs {
            Some(b) => {
                // transpose (B, S, H_local, D) -> (B, H_local, S, D) one
                // sequence-row at a time (the SMEM load of a real kernel)
                for bi in 0..cfg.b {
                    for hi in 0..cfg.h_local() {
                        for si in 0..cfg.s {
                            for (src, dst) in [(&b.q_h, &b.q_t), (&b.k_h, &b.k_t), (&b.v_h, &b.v_t)] {
                                plan.push(
                                    w,
                                    Op::Compute {
                                        dur: 0.0,
                                        label: "attn_transpose",
                                        effect: Some(Effect::CopyMat {
                                            src: MatView { buf: src[dev], b: bi, d: si, row0: hi, col0: 0, rows: 1, cols: cfg.d },
                                            dst: MatView { buf: dst[dev], b: bi, d: hi, row0: si, col0: 0, rows: 1, cols: cfg.d },
                                            reduce: None,
                                        }),
                                    },
                                );
                            }
                        }
                        // full-sequence attention for this (b, head)
                        let st = plan.add_state();
                        plan.push(
                            w,
                            Op::Compute {
                                dur: 0.0,
                                label: "attn_full",
                                effect: Some(Effect::AttnBlock {
                                    q: MatView { buf: b.q_t[dev], b: bi, d: hi, row0: 0, col0: 0, rows: cfg.s, cols: cfg.d },
                                    k: MatView { buf: b.k_t[dev], b: bi, d: hi, row0: 0, col0: 0, rows: cfg.s, cols: cfg.d },
                                    v: MatView { buf: b.v_t[dev], b: bi, d: hi, row0: 0, col0: 0, rows: cfg.s, cols: cfg.d },
                                    state: st,
                                }),
                            },
                        );
                        plan.push(
                            w,
                            Op::Compute {
                                dur: 0.0,
                                label: "attn_finalize",
                                effect: Some(Effect::AttnFinalize {
                                    state: st,
                                    out: MatView { buf: b.o_t[dev], b: bi, d: hi, row0: 0, col0: 0, rows: cfg.s, cols: cfg.d },
                                }),
                            },
                        );
                        // transpose back into the head-sharded layout
                        for si in 0..cfg.s {
                            plan.push(
                                w,
                                Op::Compute {
                                    dur: 0.0,
                                    label: "attn_transpose_back",
                                    effect: Some(Effect::CopyMat {
                                        src: MatView { buf: b.o_t[dev], b: bi, d: hi, row0: si, col0: 0, rows: 1, cols: cfg.d },
                                        dst: MatView { buf: b.o_h[dev], b: bi, d: si, row0: hi, col0: 0, rows: 1, cols: cfg.d },
                                        reduce: None,
                                    }),
                                },
                            );
                        }
                    }
                }
                plan.push(w, Op::Compute { dur: cfg.attn_flops() / comp_flops, label: "ulysses_attn", effect: None });
            }
            None => {
                plan.push(w, Op::Compute { dur: cfg.attn_flops() / comp_flops, label: "ulysses_attn", effect: None });
            }
        }
        plan.push(w, Op::Signal { sem: out_ready[dev], value: 1, scope: SyncScope::InterSm });
    }
    // ---- backward all-to-all for o: (B, S, H_local, D) -> (B, S_local, H, D).
    // The exchange volume and granularity are symmetric to the forward
    // direction; functionally it is the inverse permutation.
    let nw0 = plan.workers.len();
    match bufs {
        Some(b) => {
            build_reverse_a2a(&mut plan, cfg, &b.o_h, &b.o_out);
        }
        None => {
            pk_all_to_all_4d(&mut plan, &cfg.node, &a2a, None, None, 16.0);
        }
    }
    // reverse-exchange workers wait for local attention output
    for wi in nw0..plan.workers.len() {
        let dev = plan.workers[wi].device;
        let mut ops = vec![Op::Wait { sem: out_ready[dev.0], value: 1 }];
        ops.append(&mut plan.workers[wi].ops);
        plan.workers[wi].ops = ops;
    }
    plan
}

/// Build the Ulysses layer across a multi-node cluster (timing model):
/// sequence and heads shard over all `K·P` GPUs and all four exchanges run
/// through the two-level [`pk_all_to_all_4d_cluster`] — intra-node NVLink
/// tiles plus per-rail coalesced RDMA flows with forwarders. A one-node
/// cluster delegates to [`build`] (bit-identical; pinned by tests).
pub fn build_cluster(cfg: &UlyssesCfg, cluster: &ClusterSpec) -> Plan {
    build_cluster_opts(cfg, cluster, cfg.rdma_chunk)
}

/// [`build_cluster`] with an explicit coalesced-RDMA chunk target (the
/// `rx1` exhibit's "naive uncoalesced" ablation passes one tile's bytes
/// here, putting every cross-node message on the slow end of the RDMA
/// curve).
pub fn build_cluster_opts(cfg: &UlyssesCfg, cluster: &ClusterSpec, rdma_chunk: f64) -> Plan {
    let cfg = cfg.clone().with_rdma_chunk(rdma_chunk);
    let health = crate::pk::rail::RailHealth::all_healthy(cluster);
    Ulysses { cfg }.build(&BuildCtx::new(cluster, &health), None)
}

/// [`KernelBuild`] spec for the Ulysses layer. The legacy `build_cluster*`
/// free functions are one-line wrappers over this entry. The two-level
/// all-to-all has no degraded-rail reroute, so the ctx health mask must be
/// all-healthy.
#[derive(Clone, Debug)]
pub struct Ulysses {
    pub cfg: UlyssesCfg,
}

impl KernelBuild for Ulysses {
    type Bufs<'b> = &'b UlyssesBufs;

    fn build(&self, ctx: &BuildCtx, bufs: Option<&UlyssesBufs>) -> Plan {
        assert!(
            !ctx.health.any_failed(),
            "the Ulysses all-to-all has no degraded-rail reroute; pass a healthy mask"
        );
        if ctx.cluster.num_nodes == 1 {
            return build(&self.cfg, bufs);
        }
        assert!(bufs.is_none(), "the cluster Ulysses path is timing-only");
        cluster_impl(&self.cfg, ctx)
    }
}

fn cluster_impl(cfg: &UlyssesCfg, ctx: &BuildCtx) -> Plan {
    let cluster = ctx.cluster;
    let rdma_chunk = ctx.effective_chunk(cfg.rdma_chunk);
    assert_eq!(cfg.node.num_devices, cluster.node.num_devices, "cfg.node must match cluster.node");
    assert_eq!(cfg.node.gpu.arch, cluster.node.gpu.arch, "cfg.node must match cluster.node");
    if cluster.num_nodes == 1 {
        return build(cfg, None);
    }
    let n = cluster.total_devices();
    let a2a = A2aCfg { b_dim: cfg.b, s_local: cfg.s_local_of(n), h: cfg.h, d_head: cfg.d };
    let mut plan = Plan::new();
    plan.launch_overhead = cfg.node.gpu.kernel_launch;
    // ---- forward exchanges for q, k, v
    for _ in 0..3 {
        pk_all_to_all_4d_cluster(&mut plan, cluster, &a2a, None, None, None, rdma_chunk, 16.0);
    }
    // readiness barrier: attention waits for all three exchanges — both
    // the exchange workers and the rail forwarders signal completion.
    let n_a2a = plan.workers.len();
    let ready: Vec<_> = (0..n).map(|_| plan.add_sem(0)).collect();
    for wi in 0..n_a2a {
        for r in ready.iter().take(n) {
            plan.push(wi, Op::Signal { sem: *r, value: 1, scope: SyncScope::InterDevice });
        }
    }
    let comp_flops = cfg.node.gpu.tc_flops_for_sms(cfg.node.gpu.num_sms) * cfg.flash_util;
    let out_ready: Vec<_> = (0..n).map(|_| plan.add_sem(0)).collect();
    for dev in 0..n {
        let w = plan.add_worker(DeviceId(dev), Role::ComputeSm, format!("ulysses_attn/d{dev}"));
        plan.push(w, Op::Wait { sem: ready[dev], value: n_a2a as u64 });
        plan.push(w, Op::Compute {
            dur: cfg.attn_flops_of(n) / comp_flops,
            label: "ulysses_attn",
            effect: None,
        });
        plan.push(w, Op::Signal { sem: out_ready[dev], value: 1, scope: SyncScope::InterSm });
    }
    // ---- output exchange, gated on the local attention output
    let nw0 = plan.workers.len();
    pk_all_to_all_4d_cluster(&mut plan, cluster, &a2a, None, None, None, rdma_chunk, 16.0);
    for wi in nw0..plan.workers.len() {
        let dev = plan.workers[wi].device;
        let mut ops = vec![Op::Wait { sem: out_ready[dev.0], value: 1 }];
        ops.append(&mut plan.workers[wi].ops);
        plan.workers[wi].ops = ops;
    }
    plan
}

/// Inverse exchange: device `j` holds `(B, S, H_local, D)`; send each
/// `(b, s ∈ shard_d, head-block j)` tile back to device `d`'s
/// `(B, S_local, H, D)` layout.
fn build_reverse_a2a(plan: &mut Plan, cfg: &UlyssesCfg, srcs: &[BufId], dsts: &[BufId]) {
    let n = cfg.node.num_devices;
    let h_blk = cfg.h_local();
    let tile_bytes = (h_blk * cfg.d) as f64 * ELEM_BYTES as f64;
    for j in 0..n {
        let w = plan.add_worker(DeviceId(j), Role::CommSm, format!("pk_a2a_rev/d{j}"));
        for d in 0..n {
            for bi in 0..cfg.b {
                for si in 0..cfg.s_local() {
                    let src = MatView { buf: srcs[j], b: bi, d: d * cfg.s_local() + si, row0: 0, col0: 0, rows: h_blk, cols: cfg.d };
                    let dst = MatView { buf: dsts[d], b: bi, d: si, row0: j * h_blk, col0: 0, rows: h_blk, cols: cfg.d };
                    if j == d {
                        plan.push(w, Op::Compute { dur: 0.0, label: "a2a_rev_local", effect: Some(Effect::CopyMat { src, dst, reduce: None }) });
                    } else {
                        plan.push(
                            w,
                            Op::Transfer {
                                spec: crate::plan::TransferSpec {
                                    mech: crate::xfer::Mechanism::Tma,
                                    route: crate::plan::Route::P2p { src: DeviceId(j), dst: DeviceId(d) },
                                    bytes: tile_bytes,
                                    msg_bytes: tile_bytes,
                                    n_sms: 16.0 / (n - 1) as f64,
                                },
                                blocking: false,
                                done_sem: None,
                                done_scope: SyncScope::IntraSm,
                                label: "pk_a2a_rev_tile",
                                effect: Some(Effect::CopyMat { src, dst, reduce: None }),
                            },
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::TimedExec;
    use crate::util::prop::run_functional;
    use crate::util::{assert_allclose, linalg, seeded_vec};

    #[test]
    fn functional_ulysses_matches_single_device_attention() {
        let n = 2;
        let node = NodeSpec::test_node(n);
        let cfg = UlyssesCfg { node, b: 2, h: 4, s: 8, d: 4, flash_util: 0.75, rdma_chunk: crate::pk::rail::RDMA_CHUNK_AUTO };
        let mut pool = MemPool::new();
        let bufs = UlyssesBufs::alloc(&mut pool, &cfg);
        // global tensors (B, S, H, D) — fill the sequence-sharded inputs
        let numel_g = cfg.b * cfg.s * cfg.h * cfg.d;
        let qg = seeded_vec(1, numel_g);
        let kg = seeded_vec(2, numel_g);
        let vg = seeded_vec(3, numel_g);
        let idx = |bi: usize, si: usize, hi: usize, di: usize| ((bi * cfg.s + si) * cfg.h + hi) * cfg.d + di;
        for dev in 0..n {
            for bi in 0..cfg.b {
                for sl in 0..cfg.s_local() {
                    let si = dev * cfg.s_local() + sl;
                    for hi in 0..cfg.h {
                        for di in 0..cfg.d {
                            for (buf, g) in [(&bufs.q_in, &qg), (&bufs.k_in, &kg), (&bufs.v_in, &vg)] {
                                let bb = pool.get_mut(buf[dev]);
                                let off = bb.shape.offset(bi, sl, hi, di);
                                bb.data[off] = g[idx(bi, si, hi, di)];
                            }
                        }
                    }
                }
            }
        }
        let plan = build(&cfg, Some(&bufs));
        run_functional(&mut pool, &plan);
        // reference: per (b, h) full attention over the global sequence
        for bi in 0..cfg.b {
            for hi in 0..cfg.h {
                let mut q = vec![0.0; cfg.s * cfg.d];
                let mut k = vec![0.0; cfg.s * cfg.d];
                let mut v = vec![0.0; cfg.s * cfg.d];
                for si in 0..cfg.s {
                    for di in 0..cfg.d {
                        q[si * cfg.d + di] = qg[idx(bi, si, hi, di)];
                        k[si * cfg.d + di] = kg[idx(bi, si, hi, di)];
                        v[si * cfg.d + di] = vg[idx(bi, si, hi, di)];
                    }
                }
                let want = linalg::attention_ref(&q, &k, &v, cfg.s, cfg.s, cfg.d);
                // outputs are sequence-sharded on o_out
                for si in 0..cfg.s {
                    let dev = si / cfg.s_local();
                    let sl = si % cfg.s_local();
                    let ob = pool.get(bufs.o_out[dev]);
                    let off = ob.shape.offset(bi, sl, hi, 0);
                    assert_allclose(&ob.data[off..off + cfg.d], &want[si * cfg.d..(si + 1) * cfg.d], 1e-4, 1e-5);
                }
            }
        }
    }

    #[test]
    fn timed_ulysses_scales_with_sequence() {
        let node = NodeSpec::hgx_h100();
        let t1 = TimedExec::new(node.clone()).run(&build(&UlyssesCfg::paper(node.clone(), 8192), None)).total_time;
        let t2 = TimedExec::new(node.clone()).run(&build(&UlyssesCfg::paper(node.clone(), 16384), None)).total_time;
        assert!(t2 / t1 > 2.0, "quadratic scaling: {t1} -> {t2}");
    }

    #[test]
    fn a2a_bytes_accounting() {
        let node = NodeSpec::hgx_h100();
        let cfg = UlyssesCfg::paper(node, 8192);
        assert_eq!(cfg.a2a_bytes(), 16.0 * 1024.0 * 128.0 * 128.0 * 2.0);
    }

    #[test]
    fn cluster_single_node_delegates_bit_identically() {
        use crate::hw::cluster::ClusterSpec;
        let node = NodeSpec::hgx_h100();
        let cfg = UlyssesCfg::paper(node.clone(), 8192);
        let a = build(&cfg, None);
        let b = build_cluster(&cfg, &ClusterSpec::single(node.clone()));
        assert_eq!(a.total_ops(), b.total_ops());
        assert_eq!(a.workers.len(), b.workers.len());
        let ta = TimedExec::new(node.clone()).run(&a).total_time;
        let tb = TimedExec::on_cluster(ClusterSpec::single(node)).run(&b).total_time;
        assert_eq!(ta.to_bits(), tb.to_bits(), "1-node cluster Ulysses must not drift");
    }

    #[test]
    fn cluster_ulysses_runs_and_rail_coalescing_helps() {
        // multi-node Ulysses no longer panics — and the coalesced rail
        // flows must beat the per-tile-message (uncoalesced) ablation when
        // the NIC is the binding resource.
        use crate::hw::cluster::ClusterSpec;
        let cluster = ClusterSpec::hgx_h100_pod(2).with_nic_bw(25e9);
        let n = cluster.total_devices();
        let cfg = UlyssesCfg::paper(cluster.node.clone(), 16384);
        let exec = TimedExec::on_cluster(cluster.clone());
        let t_rail = exec.run(&build_cluster(&cfg, &cluster)).total_time;
        assert!(t_rail.is_finite() && t_rail > 0.0);
        let tile_bytes = (cfg.h_local_of(n) * cfg.d) as f64 * ELEM_BYTES as f64;
        let t_naive = exec.run(&build_cluster_opts(&cfg, &cluster, tile_bytes)).total_time;
        assert!(
            t_rail < t_naive,
            "coalesced rail flows must beat per-tile RDMA messages: {t_rail} vs {t_naive}"
        );
    }
}
