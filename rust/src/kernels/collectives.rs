//! PK pure-communication collectives (Figure 6, Figures 15–17).
//!
//! Built directly on the primitives: **no rendezvous** (one-way signals
//! into pre-allocated destination buffers), **no staging** (transfers go
//! HBM→HBM), and **tile-granular addressing**, so collectives along the
//! tensor (last) dimension run directly on the original layout — the
//! Appendix B comparisons where NCCL pays reshape passes.
//!
//! Layout convention: a collective operates on per-device *replica* views.
//! Sharding can be along rows (contiguous, NCCL's happy path) or columns
//! (the tensor dimension, NCCL's unhappy path — for PK they cost the
//! same, which is the point).

use crate::hw::spec::NodeSpec;
use crate::hw::DeviceId;
use crate::mem::pgl::ReduceOp;
use crate::mem::ELEM_BYTES;
use crate::plan::{Effect, MatView, Op, Plan, Role, Route, SyncScope, TransferSpec};
use crate::xfer::Mechanism;

/// Sharding axis of a collective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    /// Leading (batch) dimension — contiguous chunks.
    Row,
    /// Tensor (last) dimension — strided chunks (Appendix B).
    Col,
}

/// Context for the PK collectives.
pub struct PkCollCtx<'a> {
    pub node: &'a NodeSpec,
    /// `replicas[d]`: device d's full-size buffer view.
    pub replicas: Vec<MatView>,
    /// SMs each device dedicates to the collective.
    pub n_sms: f64,
    /// Message granularity (one shared-tile store).
    pub msg_bytes: f64,
}

impl<'a> PkCollCtx<'a> {
    pub fn new(node: &'a NodeSpec, replicas: Vec<MatView>) -> Self {
        PkCollCtx { node, replicas, n_sms: 16.0, msg_bytes: 128.0 * 256.0 * ELEM_BYTES as f64 }
    }

    fn n(&self) -> usize {
        self.replicas.len()
    }

    /// Device `dev`'s shard view within `view` along `axis`.
    fn shard(&self, view: &MatView, dev: usize, axis: Axis) -> MatView {
        let n = self.n();
        match axis {
            Axis::Row => {
                assert_eq!(view.rows % n, 0);
                let cr = view.rows / n;
                view.sub(dev * cr, 0, cr, view.cols)
            }
            Axis::Col => {
                assert_eq!(view.cols % n, 0);
                let cc = view.cols / n;
                view.sub(0, dev * cc, view.rows, cc)
            }
        }
    }

    fn shard_bytes(&self) -> f64 {
        let v = &self.replicas[0];
        (v.rows * v.cols) as f64 * ELEM_BYTES as f64 / self.n() as f64
    }
}

/// PK all-reduce (Figure 6): shard ownership round-robin; each device
/// in-network-reduces its shard and multicasts the result back. Per-port
/// traffic ≈ S instead of the ring's 2S(N−1)/N plus staging.
pub fn pk_all_reduce(plan: &mut Plan, ctx: &PkCollCtx) {
    let n = ctx.n();
    plan.launch_overhead = ctx.node.gpu.kernel_launch;
    // arrival barrier: all devices ready (one-way signals, no rendezvous)
    let ready: Vec<_> = (0..n).map(|_| plan.add_sem(0)).collect();
    for d in 0..n {
        let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("pk_ar/d{d}"));
        for r in &ready {
            plan.push(w, Op::Signal { sem: *r, value: 1, scope: SyncScope::InterDevice });
        }
        plan.push(w, Op::Wait { sem: ready[d], value: n as u64 });
        let mine = ctx.shard(&ctx.replicas[d], d, Axis::Row);
        let srcs: Vec<MatView> = (0..n).map(|o| ctx.shard(&ctx.replicas[o], d, Axis::Row)).collect();
        // in-fabric reduce of my shard
        plan.push(
            w,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::Multimem,
                    route: Route::LdReduce { reader: DeviceId(d) },
                    bytes: ctx.shard_bytes(),
                    msg_bytes: 1024.0,
                    n_sms: ctx.n_sms,
                },
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::IntraSm,
                label: "pk_ar_ldreduce",
                effect: Some(Effect::LdReduceMat { srcs: srcs.clone(), dst: mine, op: ReduceOp::Add }),
            },
        );
        // multicast the reduced shard back to all replicas
        let others: Vec<MatView> =
            (0..n).filter(|&o| o != d).map(|o| ctx.shard(&ctx.replicas[o], d, Axis::Row)).collect();
        plan.push(
            w,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::Multimem,
                    route: Route::Multicast { src: DeviceId(d) },
                    bytes: ctx.shard_bytes(),
                    msg_bytes: 1024.0,
                    n_sms: ctx.n_sms,
                },
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::IntraSm,
                label: "pk_ar_mc",
                effect: Some(Effect::MulticastMat { src: mine, dsts: others, reduce: None }),
            },
        );
    }
}

/// PK all-gather (Figure 15 when `axis == Col`): each device multicasts its
/// shard tiles straight from the source layout — identical cost on either
/// axis.
pub fn pk_all_gather(plan: &mut Plan, ctx: &PkCollCtx, axis: Axis) {
    let n = ctx.n();
    plan.launch_overhead = ctx.node.gpu.kernel_launch;
    for d in 0..n {
        let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("pk_ag/d{d}"));
        let src = ctx.shard(&ctx.replicas[d], d, axis);
        let dsts: Vec<MatView> =
            (0..n).filter(|&o| o != d).map(|o| ctx.shard(&ctx.replicas[o], d, axis)).collect();
        plan.push(
            w,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::Tma,
                    route: Route::Multicast { src: DeviceId(d) },
                    bytes: ctx.shard_bytes(),
                    msg_bytes: ctx.msg_bytes,
                    n_sms: ctx.n_sms,
                },
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::IntraSm,
                label: "pk_ag_mc",
                effect: Some(Effect::MulticastMat { src, dsts, reduce: None }),
            },
        );
    }
}

/// PK reduce-scatter (Figure 16 when `axis == Col`): each device
/// in-network-reduces its own shard from all replicas.
pub fn pk_reduce_scatter(plan: &mut Plan, ctx: &PkCollCtx, axis: Axis) {
    let n = ctx.n();
    plan.launch_overhead = ctx.node.gpu.kernel_launch;
    for d in 0..n {
        let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("pk_rs/d{d}"));
        let mine = ctx.shard(&ctx.replicas[d], d, axis);
        let srcs: Vec<MatView> = (0..n).map(|o| ctx.shard(&ctx.replicas[o], d, axis)).collect();
        plan.push(
            w,
            Op::Transfer {
                spec: TransferSpec {
                    mech: Mechanism::Multimem,
                    route: Route::LdReduce { reader: DeviceId(d) },
                    bytes: ctx.shard_bytes(),
                    msg_bytes: 1024.0,
                    n_sms: ctx.n_sms,
                },
                blocking: true,
                done_sem: None,
                done_scope: SyncScope::IntraSm,
                label: "pk_rs_ldreduce",
                effect: Some(Effect::LdReduceMat { srcs, dst: mine, op: ReduceOp::Add }),
            },
        );
    }
}

/// PK fine-grained all-to-all on a 4-D `(B, S, H, D)` layout (Figures 11 &
/// 17): the sequence dimension is gathered while heads scatter. Device `d`
/// holds `(B, S/n, H, D)`; afterwards device `j` holds `(B, S, H/n, D)`
/// (its head block, all sequence positions). Transfers address the
/// original layout tile-by-tile — no reshape.
///
/// `srcs[d]` / `dsts[d]` are the per-device 4-D buffers; `b_dim`, `s_local`,
/// `h`, `dd` give the logical dims of the source side.
pub struct A2aCfg {
    pub b_dim: usize,
    pub s_local: usize,
    pub h: usize,
    pub d_head: usize,
}

pub fn pk_all_to_all_4d(
    plan: &mut Plan,
    node: &NodeSpec,
    cfg: &A2aCfg,
    srcs: Option<&[crate::mem::BufId]>,
    dsts: Option<&[crate::mem::BufId]>,
    n_sms: f64,
) {
    let n = node.num_devices;
    assert_eq!(cfg.h % n, 0, "heads must divide across devices");
    let h_blk = cfg.h / n;
    let tile_bytes = (h_blk * cfg.d_head) as f64 * ELEM_BYTES as f64;
    plan.launch_overhead = node.gpu.kernel_launch;
    for d in 0..n {
        let w = plan.add_worker(DeviceId(d), Role::CommSm, format!("pk_a2a/d{d}"));
        let drain = plan.add_sem(0);
        let mut in_flight: u64 = 0;
        for j in 0..n {
            match (srcs, dsts) {
                (Some(sb), Some(db)) => {
                    // per-(b, s) tile effects — functional mode (small shapes)
                    for bi in 0..cfg.b_dim {
                        for si in 0..cfg.s_local {
                            let src = MatView {
                                buf: sb[d],
                                b: bi,
                                d: si,
                                row0: j * h_blk,
                                col0: 0,
                                rows: h_blk,
                                cols: cfg.d_head,
                            };
                            let dst = MatView {
                                buf: db[j],
                                b: bi,
                                d: d * cfg.s_local + si,
                                row0: 0,
                                col0: 0,
                                rows: h_blk,
                                cols: cfg.d_head,
                            };
                            if j == d {
                                plan.push(
                                    w,
                                    Op::Compute {
                                        dur: 0.0,
                                        label: "a2a_local",
                                        effect: Some(Effect::CopyMat { src, dst, reduce: None }),
                                    },
                                );
                            } else {
                                in_flight += 1;
                                plan.push(
                                    w,
                                    Op::Transfer {
                                        spec: TransferSpec {
                                            mech: Mechanism::Tma,
                                            route: Route::P2p { src: DeviceId(d), dst: DeviceId(j) },
                                            bytes: tile_bytes,
                                            msg_bytes: tile_bytes,
                                            n_sms: n_sms / (n - 1) as f64,
                                        },
                                        blocking: false,
                                        done_sem: Some(drain),
                                        done_scope: SyncScope::IntraSm,
                                        label: "pk_a2a_tile",
                                        effect: Some(Effect::CopyMat { src, dst, reduce: None }),
                                    },
                                );
                            }
                        }
                    }
                }
                _ if j != d => {
                    // timing mode: one aggregated flow per destination,
                    // message granularity = one (h_blk x d_head) tile
                    let bytes = (cfg.b_dim * cfg.s_local) as f64 * tile_bytes;
                    in_flight += 1;
                    plan.push(
                        w,
                        Op::Transfer {
                            spec: TransferSpec {
                                mech: Mechanism::Tma,
                                route: Route::P2p { src: DeviceId(d), dst: DeviceId(j) },
                                bytes,
                                msg_bytes: tile_bytes,
                                n_sms: n_sms / (n - 1) as f64,
                            },
                            blocking: false,
                            done_sem: Some(drain),
                            done_scope: SyncScope::IntraSm,
                            label: "pk_a2a_bulk",
                            effect: None,
                        },
                    );
                }
                _ => {}
            }
        }
        // drain: the exchange is complete only when every send landed
        plan.push(w, Op::Wait { sem: drain, value: in_flight });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{FunctionalExec, TimedExec};
    use crate::mem::tile::Shape4;
    use crate::mem::MemPool;
    use crate::util::{assert_allclose, seeded_vec};

    fn replicas(pool: &mut MemPool, n: usize, rows: usize, cols: usize, seed: u64) -> (Vec<crate::mem::BufId>, Vec<Vec<f32>>) {
        let mut bufs = vec![];
        let mut inits = vec![];
        for d in 0..n {
            let data = seeded_vec(seed + d as u64, rows * cols);
            inits.push(data.clone());
            bufs.push(pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), data));
        }
        (bufs, inits)
    }

    #[test]
    fn pk_all_reduce_is_sum_everywhere() {
        let n = 8;
        let (rows, cols) = (n * 2, 4);
        let node = NodeSpec::test_node(n);
        let mut pool = MemPool::new();
        let (bufs, inits) = replicas(&mut pool, n, rows, cols, 70);
        let ctx = PkCollCtx::new(&node, bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect());
        let mut plan = Plan::new();
        pk_all_reduce(&mut plan, &ctx);
        FunctionalExec::new(&mut pool).run(&plan).unwrap();
        let mut want = vec![0.0f32; rows * cols];
        for v in &inits {
            for (w, x) in want.iter_mut().zip(v) {
                *w += x;
            }
        }
        for &b in &bufs {
            assert_allclose(&pool.get(b).data, &want, 1e-5, 1e-6);
        }
    }

    #[test]
    fn pk_all_gather_col_axis() {
        // tensor-dimension all-gather: device d owns column block d
        let n = 4;
        let (rows, cols) = (4, n * 3);
        let node = NodeSpec::test_node(n);
        let mut pool = MemPool::new();
        // start: each device has only its column shard of the global matrix
        let global = seeded_vec(500, rows * cols);
        let mut bufs = vec![];
        for d in 0..n {
            let mut data = vec![0.0; rows * cols];
            for r in 0..rows {
                for c in d * 3..(d + 1) * 3 {
                    data[r * cols + c] = global[r * cols + c];
                }
            }
            bufs.push(pool.alloc_init(DeviceId(d), Shape4::mat(rows, cols), data));
        }
        let ctx = PkCollCtx::new(&node, bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect());
        let mut plan = Plan::new();
        pk_all_gather(&mut plan, &ctx, Axis::Col);
        FunctionalExec::new(&mut pool).run(&plan).unwrap();
        for &b in &bufs {
            assert_allclose(&pool.get(b).data, &global, 1e-6, 1e-7);
        }
    }

    #[test]
    fn pk_reduce_scatter_col_axis() {
        let n = 4;
        let (rows, cols) = (4, n * 2);
        let node = NodeSpec::test_node(n);
        let mut pool = MemPool::new();
        let (bufs, inits) = replicas(&mut pool, n, rows, cols, 900);
        let ctx = PkCollCtx::new(&node, bufs.iter().map(|&b| MatView::full2d(b, rows, cols)).collect());
        let mut plan = Plan::new();
        pk_reduce_scatter(&mut plan, &ctx, Axis::Col);
        FunctionalExec::new(&mut pool).run(&plan).unwrap();
        let mut want = vec![0.0f32; rows * cols];
        for v in &inits {
            for (w, x) in want.iter_mut().zip(v) {
                *w += x;
            }
        }
        for (d, &b) in bufs.iter().enumerate() {
            // device d's column block d is the reduced shard
            for r in 0..rows {
                for c in d * 2..(d + 1) * 2 {
                    let got = pool.get(b).data[r * cols + c];
                    assert!((got - want[r * cols + c]).abs() < 1e-4, "r{r} c{c}");
                }
            }
        }
    }

    #[test]
    fn pk_a2a_4d_permutes_heads_and_sequence() {
        let n = 4;
        let cfg = A2aCfg { b_dim: 2, s_local: 3, h: 8, d_head: 4 };
        let node = NodeSpec::test_node(n);
        let mut pool = MemPool::new();
        // src[d]: (B, S/n, H, D); dst[d]: (B, S, H/n, D)
        let mut srcs = vec![];
        let mut dsts = vec![];
        for d in 0..n {
            srcs.push(pool.alloc_init(
                DeviceId(d),
                Shape4 { b: cfg.b_dim, d: cfg.s_local, r: cfg.h, c: cfg.d_head },
                seeded_vec(1000 + d as u64, cfg.b_dim * cfg.s_local * cfg.h * cfg.d_head),
            ));
            dsts.push(pool.alloc(
                DeviceId(d),
                Shape4 { b: cfg.b_dim, d: cfg.s_local * n, r: cfg.h / n, c: cfg.d_head },
            ));
        }
        let mut plan = Plan::new();
        pk_all_to_all_4d(&mut plan, &node, &cfg, Some(&srcs), Some(&dsts), 8.0);
        FunctionalExec::new(&mut pool).run(&plan).unwrap();
        // check: dst[j] at (b, s_global=d*s_local+si, h_in_blk, :) ==
        //        src[d] at (b, si, j*h_blk + h_in_blk, :)
        let h_blk = cfg.h / n;
        for d in 0..n {
            for j in 0..n {
                for bi in 0..cfg.b_dim {
                    for si in 0..cfg.s_local {
                        for hh in 0..h_blk {
                            let src_buf = pool.get(srcs[d]);
                            let dst_buf = pool.get(dsts[j]);
                            for x in 0..cfg.d_head {
                                let sv = src_buf.data
                                    [src_buf.shape.offset(bi, si, j * h_blk + hh, x)];
                                let dv = dst_buf.data
                                    [dst_buf.shape.offset(bi, d * cfg.s_local + si, hh, x)];
                                assert_eq!(sv, dv, "d{d} j{j} b{bi} s{si} h{hh} x{x}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn figure6_pk_ar_beats_nccl() {
        // Figure 6: PK all-reduce up to ~1.79× over NCCL (BF16).
        let n = 8;
        let node = NodeSpec::hgx_h100();
        let rows = 16384;
        let cols = 4096; // 128 Mi elements = 256 MB bf16
        let mut pool = MemPool::new();
        let bufs: Vec<_> = (0..n).map(|d| pool.alloc(DeviceId(d), Shape4::mat(1, 1))).collect();
        let views: Vec<MatView> = bufs
            .iter()
            .map(|&b| MatView { buf: b, b: 0, d: 0, row0: 0, col0: 0, rows, cols })
            .collect();
        // PK
        let ctx = PkCollCtx { node: &node, replicas: views.clone(), n_sms: 76.0, msg_bytes: 64.0 * 1024.0 };
        let mut pk_plan = Plan::new();
        pk_all_reduce(&mut pk_plan, &ctx);
        strip_effects(&mut pk_plan);
        let t_pk = TimedExec::new(node.clone()).run(&pk_plan).total_time;
        // NCCL (library tuner picks ring vs NVLS)
        let _ = views;
        let t_nccl = crate::comm::nccl::allreduce_time(&node, rows, cols);
        let speedup = t_nccl / t_pk;
        assert!(speedup > 1.1 && speedup < 2.2, "PK AR up to ~1.79x NCCL, got {speedup}");
    }

    fn strip_effects(plan: &mut Plan) {
        for w in &mut plan.workers {
            for op in &mut w.ops {
                if let Op::Transfer { effect, .. } = op {
                    *effect = None;
                }
                if let Op::Compute { effect, .. } = op {
                    *effect = None;
                }
            }
        }
    }
}
